"""L2: DilatedVGG in JAX — the DNN workload of the paper's evaluation.

The paper processes a "slightly modified" DilatedVGG [Yu & Koltun 2015] on
its DNN system (Fig 5), naming layers Conv1_1, Conv4_0–Conv4_5, Dense1 and
Upscaling. This module reconstructs that network (DESIGN.md §7): a VGG
front-end, a six-layer dilated conv4 stage, FC-as-conv dense layers and a
bilinear upscaling head, in NCHW.

Two roles:
  * the *functional* model — AOT-lowered (aot.py) and executed from the rust
    runtime via PJRT, with every convolution running through the L1 Pallas
    NCE kernel;
  * the *graph source* — `graph_dict()` exports the layer topology as JSON,
    which `rust/src/graph/` imports and the deep-learning compiler lowers to
    the hardware-adapted task graph (the paper's Fig 1 left-hand input).

`scale` divides all channel counts: scale=1 is the paper-sized network used
for timing simulation (non-functional, weights never materialised); scale=8
("tiny") is the functional variant whose weights are baked into the AOT
artifact so the rust binary needs only an input image.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import conv_mxu, ref

NUM_CLASSES = 16


def dilated_vgg_spec(
    *, num_classes: int = NUM_CLASSES, scale: int = 1, input_hw: int = 256
) -> dict[str, Any]:
    """Layer-list specification of DilatedVGG.

    Returns a dict with `input` shape and an ordered `layers` list; this is
    the single source of truth shared by the JAX forward pass, the JSON
    graph export and (via import) the rust compiler.
    """
    if scale < 1:
        raise ValueError("scale must be >= 1")
    c = lambda ch: max(ch // scale, 1)
    nc = max(num_classes // (scale if scale > 1 else 1), 2)

    def conv(name, cin, cout, k=3, dilation=1):
        return dict(
            name=name, op="conv2d", cin=cin, cout=cout, kh=k, kw=k,
            stride=1, dilation=dilation, padding="same", activation="relu",
        )

    layers = [
        conv("conv1_0", 3, c(64)),
        conv("conv1_1", c(64), c(64)),
        dict(name="pool1", op="maxpool", window=2, stride=2),
        conv("conv2_0", c(64), c(128)),
        conv("conv2_1", c(128), c(128)),
        dict(name="pool2", op="maxpool", window=2, stride=2),
        conv("conv3_0", c(128), c(256)),
        conv("conv3_1", c(256), c(256)),
        conv("conv3_2", c(256), c(256)),
        dict(name="pool3", op="maxpool", window=2, stride=2),
        # The six dilated context layers — the compute-bound dots of Fig 7.
        conv("conv4_0", c(256), c(512), dilation=2),
        conv("conv4_1", c(512), c(512), dilation=2),
        conv("conv4_2", c(512), c(512), dilation=2),
        conv("conv4_3", c(512), c(512), dilation=2),
        conv("conv4_4", c(512), c(512), dilation=2),
        conv("conv4_5", c(512), c(512), dilation=2),
        # FC-as-conv head (Dense1 of Fig 5/6).
        conv("dense1", c(512), c(1024), k=7, dilation=4),
        dict(
            name="dense2", op="conv2d", cin=c(1024), cout=nc, kh=1, kw=1,
            stride=1, dilation=1, padding="same", activation="none",
        ),
        # The communication-bound outlier of Fig 6.
        dict(name="upscaling", op="upsample_bilinear", factor=8),
    ]
    return dict(
        name="dilated_vgg" if scale == 1 else f"dilated_vgg_s{scale}",
        input=dict(n=1, c=3, h=input_hw, w=input_hw),
        dtype_bytes=2,  # the FPGA NCE streams 16-bit fixed-point operands
        layers=layers,
    )


def dilated_vgg_tiny_spec(*, input_hw: int = 64) -> dict[str, Any]:
    """The functional (weights-materialised) variant: channels /8."""
    return dilated_vgg_spec(scale=8, input_hw=input_hw)


def init_params(spec: dict[str, Any], key: jax.Array) -> dict[str, Any]:
    """He-init weights for every conv layer of a spec."""
    params: dict[str, Any] = {}
    for layer in spec["layers"]:
        if layer["op"] != "conv2d":
            continue
        key, wk = jax.random.split(key)
        fan_in = layer["cin"] * layer["kh"] * layer["kw"]
        w = jax.random.normal(
            wk, (layer["cout"], layer["cin"], layer["kh"], layer["kw"]),
            dtype=jnp.float32,
        ) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((layer["cout"],), jnp.float32)
        params[layer["name"]] = dict(w=w, b=b)
    return params


def _apply_layer(layer, x, params, conv_fn):
    op = layer["op"]
    if op == "conv2d":
        p = params[layer["name"]]
        y = conv_fn(
            x, p["w"], p["b"],
            stride=layer["stride"], padding=layer["padding"].upper(),
            dilation=layer["dilation"],
        )
        if layer["activation"] == "relu":
            y = ref.relu_ref(y)
        return y
    if op == "maxpool":
        return ref.maxpool2d_ref(x, window=layer["window"], stride=layer["stride"])
    if op == "upsample_bilinear":
        return ref.upsample_bilinear_ref(x, layer["factor"])
    raise ValueError(f"unknown op {op!r}")


def forward(
    params: dict[str, Any],
    x: jax.Array,
    spec: dict[str, Any],
    *,
    use_pallas: bool = True,
    conv_block=(128, 128, 128),
) -> jax.Array:
    """Run the network. With use_pallas=True every conv is the L1 kernel."""
    if use_pallas:
        bm, bk, bn = conv_block
        conv_fn = functools.partial(conv_mxu.conv2d_pallas, bm=bm, bk=bk, bn=bn)
    else:
        conv_fn = ref.conv2d_ref
    for layer in spec["layers"]:
        x = _apply_layer(layer, x, params, conv_fn)
    return x


def layer_shapes(spec: dict[str, Any]) -> list[dict[str, Any]]:
    """Static shape inference over the spec — no tracing.

    Mirrors rust/src/graph shape inference; the python test suite asserts
    both agree with actual traced shapes.
    """
    inp = spec["input"]
    n, c, h, w = inp["n"], inp["c"], inp["h"], inp["w"]
    out = []
    for layer in spec["layers"]:
        if layer["op"] == "conv2d":
            c = layer["cout"]
            h = -(-h // layer["stride"])
            w = -(-w // layer["stride"])
        elif layer["op"] == "maxpool":
            h //= layer["stride"]
            w //= layer["stride"]
        elif layer["op"] == "upsample_bilinear":
            h *= layer["factor"]
            w *= layer["factor"]
        out.append(dict(name=layer["name"], n=n, c=c, h=h, w=w))
    return out


def graph_dict(spec: dict[str, Any]) -> dict[str, Any]:
    """The DNN-graph JSON consumed by rust/src/graph/ (schema v1)."""
    shapes = layer_shapes(spec)
    layers = []
    for layer, shp in zip(spec["layers"], shapes):
        entry = dict(layer)
        entry["out_shape"] = dict(n=shp["n"], c=shp["c"], h=shp["h"], w=shp["w"])
        layers.append(entry)
    return dict(
        schema="avsm-dnn-graph-v1",
        name=spec["name"],
        input=spec["input"],
        dtype_bytes=spec["dtype_bytes"],
        layers=layers,
    )
