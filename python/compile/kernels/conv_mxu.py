"""L1 Pallas kernel: tiled im2col-GEMM convolution — the NCE hot-spot.

The paper's Neural Complex Engine (NCE) is a 32x64 multiplier array: input
channels stream across the 32 rows, output channels across the 64 columns,
and the house-keeping processor feeds it one task-graph tile at a time from
on-chip buffers. On TPU the analogous engine is the MXU systolic array and
the on-chip buffer is VMEM; the BlockSpec grid below plays exactly the role
of the paper's hardware-adapted task-graph tiles (DESIGN.md
§Hardware-Adaptation):

  * grid axis 0/1  — (M, N) output tile walk  == the HKP's OFM tile loop
  * grid axis 2    — K reduction tile walk    == IFM/weight-tile streaming
  * BlockSpec      — the HBM->VMEM staging schedule the paper expresses
                     with DMA nodes in the task graph
  * f32 VMEM accumulator scratch              == the NCE accumulator bank

Block shapes default to MXU-friendly (128, 128) x (128, 128); the wrapper
pads arbitrary GEMM shapes up to block multiples so the kernel itself only
ever sees full tiles (same trick the deep-learning compiler in rust/ uses:
partial tiles are padded to array geometry, costed at full-tile occupancy).

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same kernel runs in
pytest, in the AOT artifacts and from the rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# MXU-aligned default tile geometry. 128 is the MXU systolic dimension; the
# f32 accumulator tile (BM x BN) plus one A tile (BM x BK) and one B tile
# (BK x BN) occupy 3 * 128*128*4 B = 192 KiB of VMEM, far under the ~16 MiB
# per-core budget, leaving room for double buffering (see DESIGN.md §Perf).
DEFAULT_BM = 128
DEFAULT_BK = 128
DEFAULT_BN = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    """One (BM, BN) output tile; grid axis 2 walks the K reduction.

    acc_ref is VMEM scratch that lives across the K walk — the Pallas
    revolving-accumulator idiom, mirroring the NCE accumulator bank that
    holds partial sums while IFM/weight tiles stream in.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU-shaped MAC: f32 accumulate regardless of input dtype.
    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _vmem_scratch(shape, dtype):
    """Accumulator scratch allocation — the VMEM accumulator bank analogue.

    Uses the generic `pl.MemoryRef` memory-space form so the same kernel
    body serves interpret mode (CPU PJRT) and a real TPU lowering (where the
    space would be pltpu.VMEM).
    """
    import jax.core as jcore

    return pl.MemoryRef(jcore.ShapedArray(shape, dtype), pl.MemorySpace.ANY)


def _pad_to(x: jax.Array, m0: int, m1: int) -> jax.Array:
    """Zero-pad a 2-D array so both dims are multiples of (m0, m1)."""
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 == 0 and p1 == 0:
        return x
    return jnp.pad(x, ((0, p0), (0, p1)))


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "out_dtype", "interpret")
)
def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """Tiled GEMM (M,K) @ (K,N) -> (M,N) on the Pallas NCE/MXU kernel.

    Arbitrary shapes are supported by zero-padding up to tile multiples and
    slicing the result back — zero padding is exact for matmul.
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad GEMM shapes {a.shape} @ {b.shape}")
    m, k = a.shape
    _, n = b.shape
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    mp, kp = ap.shape
    np_ = bp.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]


def conv2d_pallas(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding="SAME",
    dilation: int = 1,
    bm: int = DEFAULT_BM,
    bk: int = DEFAULT_BK,
    bn: int = DEFAULT_BN,
    interpret: bool = True,
) -> jax.Array:
    """Convolution lowered exactly the way the NCE executes it:
    im2col patch extraction (the DMA/reshape task-graph nodes) followed by
    the Pallas tiled GEMM (the NCE MAC array). NCHW x OIHW -> NCHW."""
    cout = w.shape[0]
    cols, (n, oh, ow) = ref.im2col(
        x, w.shape[2], w.shape[3], stride=stride, padding=padding, dilation=dilation
    )
    flat = matmul_pallas(
        cols,
        w.reshape(cout, -1).T.astype(jnp.float32),
        bm=bm,
        bk=bk,
        bn=bn,
        interpret=interpret,
    )
    out = flat.reshape(n, oh, ow, cout).transpose(0, 3, 1, 2)
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out


def vmem_footprint_bytes(bm: int = DEFAULT_BM, bk: int = DEFAULT_BK,
                         bn: int = DEFAULT_BN, in_bytes: int = 4) -> int:
    """Static VMEM budget of one kernel instance (A tile + B tile + f32 acc).

    Used by python/tests and DESIGN.md §Perf to assert the tile geometry fits
    the 16 MiB VMEM with 2x headroom for double buffering.
    """
    return bm * bk * in_bytes + bk * bn * in_bytes + bm * bn * 4
