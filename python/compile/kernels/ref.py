"""Pure-jnp reference oracle for the Pallas kernels and the DilatedVGG ops.

Everything here is straight-line jax.numpy / lax — no Pallas — and serves as
the numerical ground truth for pytest/hypothesis checks of the L1 kernels and
the L2 model. Layout convention is NCHW for feature maps and OIHW for conv
weights (matching the paper's FPGA NCE which streams channel-major tiles).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain f32-accumulated GEMM: (M,K) @ (K,N) -> (M,N)."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))


def conv2d_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding="SAME",
    dilation: int = 1,
) -> jax.Array:
    """Reference 2-D convolution, NCHW x OIHW -> NCHW, with RHS dilation.

    `padding` is either an explicit symmetric pixel count or the literal
    "SAME" (output spatial size == input size / stride, as used by every conv
    layer of DilatedVGG).
    """
    pad = padding if isinstance(padding, str) else [(padding, padding), (padding, padding)]
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=pad,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out


def relu_ref(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def maxpool2d_ref(x: jax.Array, *, window: int = 2, stride: int = 2) -> jax.Array:
    """2x2/2 max pooling over NCHW."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 1, window, window),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def upsample_bilinear_ref(x: jax.Array, factor: int) -> jax.Array:
    """Bilinear upsampling of an NCHW tensor by an integer factor."""
    n, c, h, w = x.shape
    return jax.image.resize(x, (n, c, h * factor, w * factor), method="bilinear")


def im2col(
    x: jax.Array,
    kh: int,
    kw: int,
    *,
    stride: int = 1,
    padding="SAME",
    dilation: int = 1,
):
    """Extract convolution patches: NCHW -> ((N*OH*OW, C*kh*kw), (n, oh, ow)).

    Column order matches `w.reshape(cout, -1).T` for OIHW weights, i.e. the
    GEMM `im2col(x) @ w.reshape(cout,-1).T` equals `conv2d_ref(x, w)`.
    """
    pad = padding if isinstance(padding, str) else [(padding, padding), (padding, padding)]
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=pad,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )  # (N, C*kh*kw, OH, OW)
    n, ckk, oh, ow = patches.shape
    return patches.transpose(0, 2, 3, 1).reshape(n * oh * ow, ckk), (n, oh, ow)


def conv2d_via_gemm_ref(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array | None = None,
    *,
    stride: int = 1,
    padding="SAME",
    dilation: int = 1,
) -> jax.Array:
    """conv2d expressed as im2col + GEMM — the decomposition the NCE (and the
    Pallas kernel) actually execute. Must equal conv2d_ref up to float
    association order."""
    cout = w.shape[0]
    cols, (n, oh, ow) = im2col(
        x, w.shape[2], w.shape[3], stride=stride, padding=padding, dilation=dilation
    )
    out = matmul_ref(cols, w.reshape(cout, -1).T)  # (N*OH*OW, Cout)
    out = out.reshape(n, oh, ow, cout).transpose(0, 3, 1, 2)
    if b is not None:
        out = out + b.reshape(1, -1, 1, 1)
    return out
