"""AOT bridge: lower the L2/L1 JAX computations to HLO *text* artifacts and
export the DNN graph JSONs for the rust deep-learning compiler.

Run once at build time (`make artifacts`); the rust binary is self-contained
afterwards. HLO text — NOT a serialized HloModuleProto — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Artifacts written to --outdir:
  dilated_vgg_tiny.hlo.txt   functional DilatedVGG (scale /8), weights baked
                             in as constants; signature f32[1,3,64,64] ->
                             (f32[1,nc,64,64],)
  conv_block.hlo.txt         one Pallas NCE conv layer (64ch 3x3 on 32x32),
                             weights baked; the runtime microbench target
  gemm_tile.hlo.txt          one bare Pallas GEMM tile (256x256x256) — the
                             L1 kernel in isolation for perf probing
  dilated_vgg.graph.json     paper-sized DNN graph (timing simulation input)
  dilated_vgg_tiny.graph.json  functional-variant graph
  manifest.json              index: artifact -> entry signature
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import conv_mxu


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    `as_hlo_text(True)` = print_large_constants: without it the HLO text
    printer elides big weight tensors as `constant({...})`, which the rust
    side's parser would silently read back as zeros.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def lower_tiny_model(input_hw: int = 64, seed: int = 0):
    """Functional DilatedVGG with parameters closed over (baked as HLO
    constants) so the rust side only supplies the input image."""
    spec = model.dilated_vgg_tiny_spec(input_hw=input_hw)
    params = model.init_params(spec, jax.random.PRNGKey(seed))

    def infer(x):
        return (model.forward(params, x, spec, use_pallas=True,
                              conv_block=(128, 128, 128)),)

    x_spec = jax.ShapeDtypeStruct((1, 3, input_hw, input_hw), jnp.float32)
    lowered = jax.jit(infer).lower(x_spec)
    out_c = model.layer_shapes(spec)[-1]["c"]
    sig = dict(
        inputs=[dict(shape=[1, 3, input_hw, input_hw], dtype="f32")],
        outputs=[dict(shape=[1, out_c, input_hw, input_hw], dtype="f32")],
    )
    return lowered, sig


def lower_conv_block(seed: int = 1):
    """A single NCE conv layer: 64->64ch 3x3 SAME on 1x64x32x32."""
    key = jax.random.PRNGKey(seed)
    w = jax.random.normal(key, (64, 64, 3, 3), jnp.float32) * 0.06
    b = jnp.zeros((64,), jnp.float32)

    def block(x):
        return (conv_mxu.conv2d_pallas(x, w, b, bm=128, bk=128, bn=128),)

    x_spec = jax.ShapeDtypeStruct((1, 64, 32, 32), jnp.float32)
    sig = dict(
        inputs=[dict(shape=[1, 64, 32, 32], dtype="f32")],
        outputs=[dict(shape=[1, 64, 32, 32], dtype="f32")],
    )
    return jax.jit(block).lower(x_spec), sig


def lower_gemm_tile(m: int = 256, k: int = 256, n: int = 256):
    """The bare L1 GEMM kernel — isolated hot-spot for the runtime bench."""

    def gemm(a, b):
        return (conv_mxu.matmul_pallas(a, b, bm=128, bk=128, bn=128),)

    a_spec = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b_spec = jax.ShapeDtypeStruct((k, n), jnp.float32)
    sig = dict(
        inputs=[dict(shape=[m, k], dtype="f32"), dict(shape=[k, n], dtype="f32")],
        outputs=[dict(shape=[m, n], dtype="f32")],
    )
    return jax.jit(gemm).lower(a_spec, b_spec), sig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--input-hw", type=int, default=64,
                    help="functional model input size")
    ap.add_argument("--timing-hw", type=int, default=256,
                    help="paper-sized graph input size for timing simulation")
    args = ap.parse_args()
    out = pathlib.Path(args.outdir)
    out.mkdir(parents=True, exist_ok=True)

    manifest: dict[str, dict] = {}

    # --- DNN graph JSONs (compiler front-end input) -----------------------
    for spec in (
        model.dilated_vgg_spec(input_hw=args.timing_hw),
        model.dilated_vgg_tiny_spec(input_hw=args.input_hw),
    ):
        g = model.graph_dict(spec)
        path = out / f"{spec['name']}.graph.json"
        path.write_text(json.dumps(g, indent=1))
        print(f"wrote {path}")

    # --- HLO artifacts -----------------------------------------------------
    jobs = {
        "dilated_vgg_tiny": lambda: lower_tiny_model(args.input_hw),
        "conv_block": lower_conv_block,
        "gemm_tile": lower_gemm_tile,
    }
    for name, job in jobs.items():
        lowered, sig = job()
        text = to_hlo_text(lowered)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        manifest[name] = dict(file=path.name, **sig)
        print(f"wrote {path} ({len(text)} chars)")

    # --- golden vectors: rust integration tests replay these ---------------
    spec = model.dilated_vgg_tiny_spec(input_hw=args.input_hw)
    params = model.init_params(spec, jax.random.PRNGKey(0))
    hw = args.input_hw
    x0 = (jnp.arange(3 * hw * hw, dtype=jnp.float32).reshape(1, 3, hw, hw)
          / (3 * hw * hw) - 0.5)
    y0 = model.forward(params, x0, spec, use_pallas=False)
    import numpy as np

    np.asarray(x0, dtype="<f4").tofile(out / "tiny_input.bin")
    np.asarray(y0, dtype="<f4").tofile(out / "tiny_expected.bin")
    manifest["golden"] = dict(
        input="tiny_input.bin",
        expected="tiny_expected.bin",
        input_shape=list(x0.shape),
        output_shape=list(y0.shape),
        tolerance=1e-3,
    )
    print(f"wrote golden vectors ({y0.size} f32 outputs)")

    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {out / 'manifest.json'}")


if __name__ == "__main__":
    main()
