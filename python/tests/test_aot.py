"""AOT lowering checks: HLO text artifacts must be loadable by the rust
runtime — in particular all weight constants must be materialised
(regression: the HLO text printer elides large constants as `{...}` unless
`as_hlo_text(True)` is used, which the rust-side parser reads as zeros)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


class TestHloText:
    def test_no_elided_constants_in_conv_block(self):
        lowered, _ = aot.lower_conv_block()
        text = aot.to_hlo_text(lowered)
        assert "constant({...})" not in text
        assert "ENTRY" in text

    def test_tiny_model_constants_materialised(self):
        lowered, sig = aot.lower_tiny_model(input_hw=32)
        text = aot.to_hlo_text(lowered)
        assert "constant({...})" not in text
        # Entry signature: exactly one parameter (the image) — weights baked.
        import re
        entry = re.search(r"ENTRY \S+ \{(.*?)\n\}", text, re.S).group(1)
        params = re.findall(r"parameter\(\d+\)", entry)
        assert params == ["parameter(0)"]
        assert sig["inputs"][0]["shape"] == [1, 3, 32, 32]

    def test_gemm_tile_signature(self):
        lowered, sig = aot.lower_gemm_tile(64, 32, 16)
        text = aot.to_hlo_text(lowered)
        assert "f32[64,32]" in text and "f32[32,16]" in text
        assert sig["outputs"][0]["shape"] == [64, 16]


class TestGolden:
    def test_golden_vector_matches_fresh_forward(self):
        """The recipe used by aot.main() for the golden vectors must be
        reproducible (same PRNG seed -> same params -> same output)."""
        hw = 16
        spec = model.dilated_vgg_tiny_spec(input_hw=hw)
        params = model.init_params(spec, jax.random.PRNGKey(0))
        x0 = (jnp.arange(3 * hw * hw, dtype=jnp.float32).reshape(1, 3, hw, hw)
              / (3 * hw * hw) - 0.5)
        a = model.forward(params, x0, spec, use_pallas=False)
        params2 = model.init_params(model.dilated_vgg_tiny_spec(input_hw=hw),
                                    jax.random.PRNGKey(0))
        b = model.forward(params2, x0, spec, use_pallas=False)
        np.testing.assert_array_equal(a, b)
