"""L2 correctness: DilatedVGG spec, shapes, forward pass, graph export."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


class TestSpec:
    def test_paper_layer_names_present(self):
        """Fig 5/6/7 name Conv1_1, Conv4_0..Conv4_5, Dense1, Upscaling."""
        spec = model.dilated_vgg_spec()
        names = [l["name"] for l in spec["layers"]]
        for expected in ["conv1_1", "conv4_0", "conv4_5", "dense1", "upscaling"]:
            assert expected in names
        assert sum(n.startswith("conv4_") for n in names) == 6

    def test_conv4_is_dilated(self):
        spec = model.dilated_vgg_spec()
        for l in spec["layers"]:
            if l["name"].startswith("conv4_"):
                assert l["dilation"] == 2
            if l["name"] == "dense1":
                assert l["dilation"] == 4 and l["kh"] == 7

    def test_full_channels(self):
        spec = model.dilated_vgg_spec()
        by = {l["name"]: l for l in spec["layers"]}
        assert by["conv1_0"]["cout"] == 64
        assert by["conv4_0"]["cout"] == 512
        assert by["dense1"]["cout"] == 1024

    def test_tiny_scale_divides(self):
        spec = model.dilated_vgg_tiny_spec()
        by = {l["name"]: l for l in spec["layers"]}
        assert by["conv1_0"]["cout"] == 8
        assert by["dense1"]["cout"] == 128

    def test_bad_scale_rejected(self):
        with pytest.raises(ValueError):
            model.dilated_vgg_spec(scale=0)


class TestShapes:
    def test_static_shapes_match_traced(self):
        """layer_shapes() (mirrored in rust) must agree with real tracing."""
        spec = model.dilated_vgg_tiny_spec(input_hw=32)
        params = model.init_params(spec, jax.random.PRNGKey(0))
        static = {s["name"]: s for s in model.layer_shapes(spec)}

        x = jnp.zeros((1, 3, 32, 32))
        for layer in spec["layers"]:
            x = model._apply_layer(layer, x, params, model.ref.conv2d_ref)
            s = static[layer["name"]]
            assert x.shape == (s["n"], s["c"], s["h"], s["w"]), layer["name"]

    def test_output_is_input_resolution(self):
        """Segmentation head: upscaling restores input H/W."""
        spec = model.dilated_vgg_spec(input_hw=256)
        out = model.layer_shapes(spec)[-1]
        assert (out["h"], out["w"]) == (256, 256)


class TestForward:
    def test_pallas_matches_ref_forward(self):
        """Whole-net equivalence: every conv through the L1 kernel."""
        spec = model.dilated_vgg_tiny_spec(input_hw=16)
        params = model.init_params(spec, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 3, 16, 16))
        got = model.forward(params, x, spec, use_pallas=True, conv_block=(32, 32, 32))
        want = model.forward(params, x, spec, use_pallas=False)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_forward_deterministic(self):
        spec = model.dilated_vgg_tiny_spec(input_hw=16)
        params = model.init_params(spec, jax.random.PRNGKey(0))
        x = jnp.ones((1, 3, 16, 16))
        a = model.forward(params, x, spec, use_pallas=False)
        b = model.forward(params, x, spec, use_pallas=False)
        np.testing.assert_array_equal(a, b)


class TestGraphExport:
    def test_schema_fields(self):
        g = model.graph_dict(model.dilated_vgg_spec())
        assert g["schema"] == "avsm-dnn-graph-v1"
        assert g["dtype_bytes"] == 2
        assert all("out_shape" in l for l in g["layers"])

    def test_json_serializable_roundtrip(self):
        g = model.graph_dict(model.dilated_vgg_tiny_spec())
        assert json.loads(json.dumps(g)) == g

    def test_out_shapes_chain(self):
        """Each layer's channel count feeds the next conv's cin."""
        g = model.graph_dict(model.dilated_vgg_spec())
        prev_c = g["input"]["c"]
        for l in g["layers"]:
            if l["op"] == "conv2d":
                assert l["cin"] == prev_c, l["name"]
            prev_c = l["out_shape"]["c"]
