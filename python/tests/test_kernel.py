"""L1 correctness: Pallas NCE kernel vs the pure-jnp oracle.

Hypothesis sweeps GEMM/conv shapes and dtypes, including shapes that are not
multiples of the tile geometry (the padding path) — the CORE correctness
signal for the kernel the AOT artifacts embed.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv_mxu, ref

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape).astype(dtype)


# ---------------------------------------------------------------------------
# GEMM kernel
# ---------------------------------------------------------------------------

class TestMatmulPallas:
    def test_exact_tile_multiple(self):
        a, b = _rand(0, (128, 128)), _rand(1, (128, 128))
        got = conv_mxu.matmul_pallas(a, b)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_multi_tile_grid(self):
        a, b = _rand(2, (256, 384)), _rand(3, (384, 256))
        got = conv_mxu.matmul_pallas(a, b, bm=128, bk=128, bn=128)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)

    def test_ragged_needs_padding(self):
        a, b = _rand(4, (100, 70)), _rand(5, (70, 45))
        got = conv_mxu.matmul_pallas(a, b, bm=32, bk=32, bn=32)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_single_row_and_col(self):
        a, b = _rand(6, (1, 17)), _rand(7, (17, 1))
        got = conv_mxu.matmul_pallas(a, b, bm=8, bk=8, bn=8)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-5, atol=1e-5)

    def test_bf16_inputs_f32_accumulate(self):
        a = _rand(8, (64, 96), jnp.bfloat16)
        b = _rand(9, (96, 64), jnp.bfloat16)
        got = conv_mxu.matmul_pallas(a, b, bm=32, bk=32, bn=32)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=2e-2, atol=2e-2)

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            conv_mxu.matmul_pallas(_rand(0, (4, 5)), _rand(1, (6, 7)))

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(1, 96),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        bm=st.sampled_from([8, 16, 32]),
        bk=st.sampled_from([8, 16, 32]),
        bn=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_gemm_shapes(self, m, k, n, bm, bk, bn, seed):
        a = _rand(seed, (m, k))
        b = _rand(seed + 1, (k, n))
        got = conv_mxu.matmul_pallas(a, b, bm=bm, bk=bk, bn=bn)
        np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Conv kernel (im2col + GEMM path)
# ---------------------------------------------------------------------------

class TestConvPallas:
    def test_basic_3x3_same(self):
        x, w, b = _rand(0, (1, 8, 16, 16)), _rand(1, (12, 8, 3, 3)), _rand(2, (12,))
        got = conv_mxu.conv2d_pallas(x, w, b, bm=32, bk=32, bn=32)
        np.testing.assert_allclose(
            got, ref.conv2d_ref(x, w, b), rtol=1e-4, atol=1e-4
        )

    def test_dilated_conv_matches_ref(self):
        """Dilation 2 and 4 — the conv4_x / dense1 configurations."""
        for dil in (2, 4):
            x, w = _rand(3, (1, 6, 20, 20)), _rand(4, (10, 6, 3, 3))
            got = conv_mxu.conv2d_pallas(x, w, dilation=dil, bm=32, bk=32, bn=32)
            np.testing.assert_allclose(
                got, ref.conv2d_ref(x, w, dilation=dil), rtol=1e-4, atol=1e-4
            )

    def test_7x7_dense_as_conv(self):
        """The dense1 layer shape class: 7x7 kernel, dilation 4."""
        x, w = _rand(5, (1, 8, 8, 8)), _rand(6, (16, 8, 7, 7))
        got = conv_mxu.conv2d_pallas(x, w, dilation=4, bm=64, bk=64, bn=64)
        np.testing.assert_allclose(
            got, ref.conv2d_ref(x, w, dilation=4), rtol=1e-4, atol=1e-4
        )

    def test_1x1_pointwise(self):
        x, w = _rand(7, (2, 16, 9, 9)), _rand(8, (4, 16, 1, 1))
        got = conv_mxu.conv2d_pallas(x, w, bm=32, bk=32, bn=32)
        np.testing.assert_allclose(got, ref.conv2d_ref(x, w), rtol=1e-4, atol=1e-4)

    def test_stride_2(self):
        x, w = _rand(9, (1, 4, 16, 16)), _rand(10, (8, 4, 3, 3))
        got = conv_mxu.conv2d_pallas(x, w, stride=2, bm=32, bk=32, bn=32)
        np.testing.assert_allclose(
            got, ref.conv2d_ref(x, w, stride=2), rtol=1e-4, atol=1e-4
        )

    @settings(max_examples=15, deadline=None)
    @given(
        cin=st.integers(1, 8),
        cout=st.integers(1, 12),
        hw=st.integers(4, 14),
        k=st.sampled_from([1, 3, 5]),
        dilation=st.sampled_from([1, 2]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_conv_shapes(self, cin, cout, hw, k, dilation, seed):
        x = _rand(seed, (1, cin, hw, hw))
        w = _rand(seed + 1, (cout, cin, k, k))
        got = conv_mxu.conv2d_pallas(x, w, dilation=dilation, bm=16, bk=16, bn=16)
        np.testing.assert_allclose(
            got, ref.conv2d_ref(x, w, dilation=dilation), rtol=1e-4, atol=1e-4
        )


# ---------------------------------------------------------------------------
# Oracle self-consistency + VMEM budget
# ---------------------------------------------------------------------------

class TestOracle:
    def test_im2col_gemm_equals_direct_conv(self):
        x, w, b = _rand(0, (2, 5, 11, 11)), _rand(1, (7, 5, 3, 3)), _rand(2, (7,))
        np.testing.assert_allclose(
            ref.conv2d_via_gemm_ref(x, w, b, dilation=2),
            ref.conv2d_ref(x, w, b, dilation=2),
            rtol=1e-4, atol=1e-4,
        )

    def test_maxpool_halves_spatial(self):
        x = _rand(0, (1, 3, 8, 8))
        assert ref.maxpool2d_ref(x).shape == (1, 3, 4, 4)

    def test_upsample_factor(self):
        x = _rand(0, (1, 3, 4, 4))
        assert ref.upsample_bilinear_ref(x, 8).shape == (1, 3, 32, 32)

    def test_vmem_footprint_under_budget(self):
        """Default tile geometry must fit 16 MiB VMEM with 2x double-buffer
        headroom (DESIGN.md §Perf)."""
        fp = conv_mxu.vmem_footprint_bytes()
        assert 2 * fp < 16 * 1024 * 1024
