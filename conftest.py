# Allow `pytest python/tests/` from the repo root: the tests import the
# `compile` package that lives under python/.
import sys, pathlib

sys.path.insert(0, str(pathlib.Path(__file__).parent / "python"))
