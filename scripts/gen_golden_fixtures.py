#!/usr/bin/env python3
"""Regenerate the golden-file schema fixtures under rust/tests/fixtures/.

The fixtures pin the on-disk JSON schemas (`avsm-campaign-v1`,
`avsm-compile-cache-v1`, `avsm-compile-cache-neg-v1`,
`avsm-compile-cache-index-v1`, `avsm-campaign-journal-v1`,
`avsm-campaign-telemetry-v1`, `avsm-lint-v1`)
byte-for-byte: `rust/tests/golden.rs` parses
each fixture with the real parsers and asserts the real serializers emit the
fixture bytes back. This script exists only to produce those bytes in the
writers' canonical form (sorted object keys, compact separators, floats with
a decimal point) — the Rust serializers are the source of truth, and a
legitimate schema change means re-running this script *and* reviewing the
fixture diff as a schema-compatibility decision.
"""

import json
import pathlib

OUT = pathlib.Path(__file__).resolve().parent.parent / "rust" / "tests" / "fixtures"


def check_floats(doc):
    # Python renders floats outside roughly [1e-4, 1e16) in exponent
    # notation, which the Rust writer never emits — a fixture float in
    # that range would regenerate as bytes the serializer can't produce
    # and fail the golden tests spuriously. Walk the doc and refuse them.
    if isinstance(doc, float):
        rendered = json.dumps(doc)
        assert "e" not in rendered and "E" not in rendered, (
            f"fixture float {doc!r} renders as {rendered!r} (exponent "
            "notation) — keep fixture floats within [1e-4, 1e16)"
        )
    elif isinstance(doc, dict):
        for v in doc.values():
            check_floats(v)
    elif isinstance(doc, list):
        for v in doc:
            check_floats(v)


def dumps(doc):
    # Canonical form of the in-tree Rust writer's `to_string_compact`:
    # object keys sorted (BTreeMap), no whitespace, integral floats keep
    # their decimal point (json.dumps already prints 5.0 as "5.0").
    check_floats(doc)
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


KEY = {
    "net_name": "golden_net",
    "net_fingerprint": "00000000deadbeef",
    "dtype_bytes": 1,
    "array_rows": 16,
    "array_cols": 32,
    "task_setup_cycles": 10,
    "ifm_buffer_kib": 512,
    "weight_buffer_kib": 128,
    "ofm_buffer_kib": 128,
    "bus_bytes_per_cycle": 32,
    "mem_data_bytes_per_cycle": 8,
    "avsm_eff_bw_pct": 85,
    "double_buffer": True,
    "labels": False,
}

TASK_GRAPH = {
    "schema": "avsm-task-graph-v1",
    "name": "golden_net",
    "tasks": [
        {"id": 0, "layer": 0, "label": "t0/load_w", "deps": [],
         "kind": "dma_load", "bytes": 128, "buffer": "weights"},
        {"id": 1, "layer": 0, "label": "t0/load_ifm", "deps": [],
         "kind": "dma_load", "bytes": 256, "buffer": "ifm"},
        {"id": 2, "layer": 0, "label": "t0/mac", "deps": [0, 1],
         "kind": "compute", "cycles": 64, "macs": 2048},
        {"id": 3, "layer": 0, "label": "t0/store", "deps": [2],
         "kind": "dma_store", "bytes": 96},
        {"id": 4, "layer": 1, "label": "sync", "deps": [3], "kind": "barrier"},
    ],
}

ENTRY = {
    "schema": "avsm-compile-cache-v1",
    "key": KEY,
    "layers": [
        {"index": 0, "name": "conv0",
         "tiling": {"kind": "conv", "cin_t": 4, "cout_t": 8, "oh_t": 6,
                    "n_cin": 1, "n_cout": 2, "n_oh": 3, "ifm_resident": True},
         "compute_cycles": 64, "dma_bytes": 480, "macs": 2048, "barrier": 4},
        {"index": 1, "name": "pool1",
         "tiling": {"kind": "vector", "oh_t": 6, "n_oh": 2},
         "compute_cycles": 8, "dma_bytes": 96, "macs": 0, "barrier": 4},
    ],
    # Embedded exactly as the flow-boundary serializer renders it (compact,
    # sorted keys) — entry_to_json stores the string verbatim.
    "task_graph": dumps(TASK_GRAPH),
}

NEGATIVE = {
    "schema": "avsm-compile-cache-neg-v1",
    "key": KEY,
    "diagnostic": "tiling infeasible: golden fixture",
}

INDEX = {
    "schema": "avsm-compile-cache-index-v1",
    "clock": 3,
    "entries": {"0000000000000042": 3, "00000000deadbeef": 2},
}


def frontier_point(name, latency_ps, cost):
    return {
        "name": name,
        "latency_ps": latency_ps,
        "cost": float(cost),
        "throughput_per_sec": 1e12 / latency_ps,
    }


def net(name, frontier):
    return {
        "name": name,
        "base": "base_paper_virtex7",
        "axes": [{"axis": "nce_freq_mhz", "values": [125, 250]}],
        "legend": {"f": "NCE frequency (MHz)"},
        "evaluated": len(frontier) + 5,
        "feasible": len(frontier) + 1,
        "infeasible": 1,
        "errors": 1,
        "error_sample": "nce0x0_f0: invalid configuration",
        "panics": 1,
        "panic_sample": "nce0x0_f1: evaluation worker panicked",
        "bound": "max",
        "skipped_by_bound": 1,
        "skipped_by_occupancy": 0,
        "skipped_by_critical_path": 1,
        "dominated": 1,
        "pruned": 0,
        "compilations": 2,
        "disk_hits": 0,
        "negative_hits": 1,
        "memory_hits": 1,
        "frontier": frontier,
    }


CAMPAIGN = {
    "schema": "avsm-campaign-v1",
    "workloads": 2,
    "grid_points": 6,
    "threads": 2,
    "bound": "max",
    "skipped_by_bound": 2,
    "errors": 2,
    "panics": 2,
    "nets": [
        net("lenet", [frontier_point("a", 2_000_000, 5.0),
                      frontier_point("b", 4_000_000, 3.0)]),
        net("vgg", [frontier_point("a", 5_000_000, 5.0),
                    frontier_point("c", 8_000_000, 3.0)]),
    ],
    "cross_net": {
        "common_frontier": ["a"],
        "frontier_membership": {"a": 2, "b": 1, "c": 1},
    },
    "cache": {
        "compilations": 4,
        "memory_hits": 2,
        "disk_hits": 0,
        "negative_hits": 2,
        "rejected_entries": 0,
        "read_errors": 0,
    },
}


def kind_stats(count, total, mean, p50, p90, p99, mx, outcomes):
    return {
        "count": count,
        "total_ns": total,
        "mean_ns": float(mean),
        "p50_ns": p50,
        "p90_ns": p90,
        "p99_ns": p99,
        "max_ns": mx,
        "outcomes": outcomes,
    }


# Aggregates of the 19-span synthetic engine run built by
# `telemetry_fixture_spans()` in rust/tests/golden.rs — every span kind in
# the obs vocabulary, every outcome class, three workers (coordinator + 2),
# nearest-rank percentiles over the hand-picked durations.
TELEMETRY = {
    "schema": "avsm-campaign-telemetry-v1",
    "workers": 3,
    "spans_total": 19,
    "wall_ns": 6260,
    "kinds": {
        "bound": kind_stats(2, 200, 100.0, 100, 100, 100, 100, {"ok": 2}),
        "cache.read": kind_stats(2, 40, 20.0, 20, 20, 20, 20,
                                 {"absent": 1, "ok": 1}),
        "cache.write": kind_stats(1, 60, 60.0, 60, 60, 60, 60, {"ok": 1}),
        "compile": kind_stats(2, 700, 350.0, 100, 600, 600, 600,
                              {"infeasible": 1, "ok": 1}),
        "journal.append": kind_stats(2, 110, 55.0, 50, 60, 60, 60,
                                     {"error": 1, "ok": 1}),
        "lock.steal": kind_stats(1, 0, 0.0, 0, 0, 0, 0, {"ok": 1}),
        "lock.wait": kind_stats(1, 20, 20.0, 20, 20, 20, 20, {"acquired": 1}),
        "resolve": kind_stats(5, 5300, 1060.0, 600, 3000, 3000, 3000,
                              {"compiled": 2, "error": 1, "infeasible": 1,
                               "panicked": 1}),
        "simulate": kind_stats(2, 2500, 1250.0, 500, 2000, 2000, 2000,
                               {"feasible": 1, "panicked": 1}),
        "skipped": kind_stats(1, 10, 10.0, 10, 10, 10, 10, {"occupancy": 1}),
    },
    "counters": {"cache.compiles": 2, "cache.mem_hits": 3,
                 "cache.neg_hits": 1},
}


# One header plus one record per terminal unit class, in the writer's
# canonical line form. The golden test replays this file with the real
# `Journal::resume` and re-appends the records with the real writer,
# asserting the bytes come back identical.
JOURNAL = [
    {"schema": "avsm-campaign-journal-v1",
     "spec": "00000000deadbeef", "units": 6},
    {"class": "feasible", "latency_ps": 2400000, "unit": 0},
    {"class": "infeasible", "unit": 3},
    {"class": "error", "diag": "nce0x0: invalid configuration", "unit": 1},
    {"class": "panicked", "diag": "worker died", "unit": 4},
    {"by_occupancy": True, "class": "skipped", "unit": 2},
    {"by_occupancy": False, "class": "skipped", "unit": 5},
]


def lint_diag(code, severity, site, message, help=None):
    d = {"code": code, "message": message, "severity": severity, "site": site}
    if help is not None:
        d["help"] = help
    return d


# One diagnostic per pass family (net 00x, config 01x, campaign/axis 03x,
# cache fsck 04x, journal 05x), covering every severity, with and without
# a help line. Mirrored literally by `lint_report_schema_is_byte_stable`
# in rust/tests/golden.rs. ASCII only: the Rust writer emits raw UTF-8
# where json.dumps would escape it.
LINT = {
    "schema": "avsm-lint-v1",
    "diagnostics": [
        lint_diag("AVSM004", "error", 'layer "conv1" of net "golden_net"',
                  'layer "conv1": cin 16 != incoming channels 8'),
        lint_diag("AVSM011", "error", 'config "golden_sys"',
                  "all clock frequencies must be positive"),
        lint_diag("AVSM030", "error", "axis spec entry 1",
                  'axis "nce_freq_mhz" listed twice in axis spec',
                  help="merge the value lists into a single entry per axis"),
        lint_diag("AVSM033", "warning", "axis spec",
                  "cross-product expands to 22500 grid points (> 10000)"),
        lint_diag("AVSM043", "warning", "cache dir golden_cache/index.json",
                  "index holds 3 entries, over the LRU bound of 2"),
        lint_diag("AVSM056", "info", "journal golden.jsonl",
                  "replays 4 of 6 units; 2 re-simulate on resume"),
    ],
    "summary": {"errors": 3, "infos": 1, "warnings": 2},
}


def main():
    OUT.mkdir(parents=True, exist_ok=True)
    fixtures = {
        "compile_cache_v1.json": ENTRY,
        "compile_cache_neg_v1.json": NEGATIVE,
        "compile_cache_index_v1.json": INDEX,
        "campaign_v1.json": CAMPAIGN,
        "campaign_telemetry_v1.json": TELEMETRY,
        "lint_v1.json": LINT,
    }
    for name, doc in fixtures.items():
        path = OUT / name
        path.write_text(dumps(doc) + "\n")
        print(f"wrote {path}")
    path = OUT / "campaign_journal_v1.jsonl"
    path.write_text("".join(dumps(line) + "\n" for line in JOURNAL))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
