#!/usr/bin/env bash
# Repo check: build, tests, and a smoke run of the DSE sweep bench.
# Usable both locally and as the CI entrypoint:
#
#     scripts/check.sh
#
# AVSM_BENCH_FAST=1 puts benchkit into its quick mode (1 warmup, 3 iters)
# so the bench smoke finishes in CI time while still exercising the full
# parallel compile-cached sweep pipeline and writing BENCH_dse_sweep.json.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== dse_sweep bench (smoke mode)"
AVSM_BENCH_FAST=1 cargo bench --bench dse_sweep

# The campaign bench also smokes the bound-and-prune path: it runs the
# frontier-sparse grid pruned and unpruned, asserts the frontiers are
# byte-identical (lossless pruning) and that the bound actually skipped
# simulations, and reports points/sec for both regimes — plus the skip
# rate with and without bound-guided unit ordering.
echo "== campaign bench (smoke mode, incl. pruned vs unpruned + ordering)"
AVSM_BENCH_FAST=1 cargo bench --bench campaign

# CLI smoke: the paper's §2 top-down mode through the generic requirement
# solver — once on the default retime-only NCE-frequency axis, once on a
# structural axis via --axis.
echo "== avsm topdown (generic requirement solver)"
cargo run --release -q -p avsm -- topdown --net lenet --target-ms 1
cargo run --release -q -p avsm -- topdown --net lenet --target-ms 1 \
  --axis bus_bytes_per_cycle --lo 4 --hi 64

# CLI smoke: a heterogeneous campaign — per-net axis specs from a
# workloads file, fail-fast error policy on.
echo "== avsm campaign (heterogeneous workloads + fail-fast)"
WORKLOADS=$(mktemp /tmp/avsm_workloads.XXXXXX.json)
cat > "$WORKLOADS" <<'EOF'
[
  {"net": "lenet",
   "axes": [{"axis": "nce_freq_mhz", "values": [125, 250, 500]}]},
  {"net": "dilated_vgg_tiny",
   "axes": [{"axis": "array_geometry", "values": [[16, 32], [32, 64]]},
            {"axis": "nce_freq_mhz", "values": [250, 500]}]}
]
EOF
cargo run --release -q -p avsm -- campaign --workloads "$WORKLOADS" --fail-fast
rm -f "$WORKLOADS"

echo "== OK"
