#!/usr/bin/env bash
# Repo check: build, tests, and a smoke run of the DSE sweep bench.
# Usable both locally and as the CI entrypoint:
#
#     scripts/check.sh
#
# AVSM_BENCH_FAST=1 puts benchkit into its quick mode (1 warmup, 3 iters)
# so the bench smoke finishes in CI time while still exercising the full
# parallel compile-cached sweep pipeline and writing BENCH_dse_sweep.json.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! command -v cargo >/dev/null 2>&1; then
  echo "SKIPPED: cargo is not on PATH — install the Rust toolchain to run the repo checks" >&2
  exit 0
fi

echo "== cargo build --release"
cargo build --release

# Static gates first: warnings are errors, formatting is canonical. Both
# components are optional rustup installs, so their absence is a loud
# skip, never a silent pass.
echo "== cargo clippy --all-targets -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
else
  echo "SKIPPED: clippy not installed (rustup component add clippy to enable this gate)"
fi

echo "== cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
  cargo fmt --check
else
  echo "SKIPPED: rustfmt not installed (rustup component add rustfmt to enable this gate)"
fi

echo "== cargo test -q"
cargo test -q

echo "== dse_sweep bench (smoke mode)"
AVSM_BENCH_FAST=1 cargo bench --bench dse_sweep

# Streaming-JSON gates: the golden suite re-emits every pinned -v1 fixture
# through json::stream::Writer and diffs byte-for-byte against the
# checked-in files, and the differential suite pins the event reader and
# incremental writer against a copy of the recursive-descent
# implementation they replaced (same bytes, same error strings and byte
# offsets) over seeded random documents.
echo "== golden fixtures through the streaming writer (byte-for-byte)"
cargo test -q --release --test golden
echo "== streaming JSON differential suite (pinned AVSM_TEST_SEED)"
AVSM_TEST_SEED=20260801 cargo test -q --release --test json_diff

# The json bench smokes the hot-path claims: lazy partial-field index
# reads must beat full-tree parses, and streaming report emission must be
# byte-identical to (and no slower than) tree emission.
echo "== json bench (smoke mode, lazy vs tree parse + stream vs tree emit)"
AVSM_BENCH_FAST=1 cargo bench --bench json

# Deterministic-seed property smoke: re-run the randomized differential
# suite (lower-bound admissibility, pruned-vs-unpruned frontier identity,
# solver-vs-oracle, injected cache-fault degradation, resume-from-any-
# crash-point report identity, ...) under a pinned AVSM_TEST_SEED, so CI
# exercises a reproducible seed in addition to the defaults baked into
# each test — including the fault-injection harness, whose failpoint
# schedule is a pure function of the seed.
echo "== property tests (pinned AVSM_TEST_SEED, incl. fault injection)"
AVSM_TEST_SEED=20260801 cargo test -q --release --test property

# The campaign bench also smokes the bound-and-prune path: it runs the
# frontier-sparse grid pruned and unpruned, asserts the frontiers are
# byte-identical (lossless pruning) and that the bound actually skipped
# simulations, and reports points/sec for both regimes — plus the skip
# rate with and without bound-guided unit ordering, and the
# occupancy-vs-critical-path skip comparison on the deep-chain net.
echo "== campaign bench (smoke mode, incl. pruned vs unpruned + ordering + bounds)"
AVSM_BENCH_FAST=1 cargo bench --bench campaign

# CLI smoke: the paper's §2 top-down mode through the generic requirement
# solver — once on the default retime-only NCE-frequency axis, once on a
# structural axis via --axis.
echo "== avsm topdown (generic requirement solver)"
cargo run --release -q -p avsm -- topdown --net lenet --target-ms 1
cargo run --release -q -p avsm -- topdown --net lenet --target-ms 1 \
  --axis bus_bytes_per_cycle --lo 4 --hi 64

# CLI smoke: a heterogeneous campaign — per-net axis specs from a
# workloads file, fail-fast error policy on.
echo "== avsm campaign (heterogeneous workloads + fail-fast)"
WORKLOADS=$(mktemp /tmp/avsm_workloads.XXXXXX.json)
cat > "$WORKLOADS" <<'EOF'
[
  {"net": "lenet",
   "axes": [{"axis": "nce_freq_mhz", "values": [125, 250, 500]}]},
  {"net": "dilated_vgg_tiny",
   "axes": [{"axis": "array_geometry", "values": [[16, 32], [32, 64]]},
            {"axis": "nce_freq_mhz", "values": [250, 500]}]}
]
EOF
cargo run --release -q -p avsm -- campaign --workloads "$WORKLOADS" --fail-fast
rm -f "$WORKLOADS"

# Lint smoke: the static diagnostics subcommand must reject a bad spec
# with a nonzero exit carrying the stable code, accept a clean unit with
# exit 0, and emit a parseable avsm-lint-v1 report under --json.
echo "== avsm lint (static diagnostics smoke)"
LINTSPEC=$(mktemp /tmp/avsm_lint_axes.XXXXXX.json)
cat > "$LINTSPEC" <<'EOF'
[{"axis": "nce_freq_mhz", "values": [125, 250]},
 {"axis": "nce_freq_mhz", "values": [500]}]
EOF
if OUT=$(cargo run --release -q -p avsm -- lint --axes "@$LINTSPEC" 2>&1); then
  echo "lint accepted a duplicate-axis spec:"; echo "$OUT"; exit 1
fi
echo "$OUT" | grep -q "AVSM030" \
  || { echo "lint exited nonzero but without AVSM030:"; echo "$OUT"; exit 1; }
cargo run --release -q -p avsm -- lint --net lenet > /dev/null
cargo run --release -q -p avsm -- lint --axes "@$LINTSPEC" --json \
  > "$LINTSPEC.report" 2>/dev/null || true
python3 - "$LINTSPEC.report" <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == "avsm-lint-v1", doc["schema"]
assert doc["summary"]["errors"] >= 1, doc["summary"]
assert any(d["code"] == "AVSM030" for d in doc["diagnostics"]), doc["diagnostics"]
print(f'lint smoke OK: {doc["summary"]["errors"]} error(s) in the bad spec, clean unit exits 0')
EOF
rm -f "$LINTSPEC" "$LINTSPEC.report"

# Campaign determinism gate: the per-net Pareto frontiers in the exported
# avsm-campaign-v1 report must be byte-identical between a 1-thread and an
# N-thread run, so order-dependent frontier bugs fail CI here. (Only the
# frontiers are contractually order-independent — skip/dominated counters
# race benignly under parallel workers, by design.)
echo "== avsm campaign 1-thread vs N-thread frontier byte identity"
OUT1=$(mktemp -d /tmp/avsm_campaign_t1.XXXXXX)
OUTN=$(mktemp -d /tmp/avsm_campaign_tn.XXXXXX)
cargo run --release -q -p avsm -- campaign --nets lenet,dilated_vgg_tiny \
  --threads 1 --outdir "$OUT1" > /dev/null
cargo run --release -q -p avsm -- campaign --nets lenet,dilated_vgg_tiny \
  --outdir "$OUTN" > /dev/null
python3 - "$OUT1/campaign.json" "$OUTN/campaign.json" <<'EOF'
import json, sys
a, b = (json.load(open(p)) for p in sys.argv[1:3])
fa = [(n["name"], n["frontier"]) for n in a["nets"]]
fb = [(n["name"], n["frontier"]) for n in b["nets"]]
ja, jb = (json.dumps(f, sort_keys=True) for f in (fa, fb))
assert a["grid_points"] == b["grid_points"], "grid size differs"
assert ja == jb, f"frontiers differ between 1 and N threads:\n{ja}\nvs\n{jb}"
print(f"frontiers byte-identical across 1 and N threads ({len(fa)} nets)")
EOF
rm -rf "$OUT1" "$OUTN"

# Crash-safety gate: a journaled campaign "killed" partway through (the
# journal cut mid-line, exactly what a SIGKILL mid-append leaves behind)
# must resume to a report byte-identical to the uninterrupted run — cache
# statistics excluded, since replayed units never touch the cache.
echo "== avsm campaign kill-and-resume (crash-safe journal)"
JDIR=$(mktemp -d /tmp/avsm_campaign_journal.XXXXXX)
cargo run --release -q -p avsm -- campaign --nets lenet --threads 1 \
  --journal "$JDIR/full.jsonl" --outdir "$JDIR/clean" > /dev/null
python3 - "$JDIR/full.jsonl" "$JDIR/torn.jsonl" <<'EOF'
import sys
lines = open(sys.argv[1], "rb").read().split(b"\n")[:-1]
keep = 1 + (len(lines) - 1) // 2  # header + half the unit records
torn = b"\n".join(lines[:keep]) + b"\n" + lines[keep][: max(1, len(lines[keep]) // 2)]
open(sys.argv[2], "wb").write(torn)
print(f"kept {keep}/{len(lines)} journal lines + a torn tail")
EOF
cargo run --release -q -p avsm -- campaign --nets lenet --threads 1 \
  --journal "$JDIR/torn.jsonl" --resume --outdir "$JDIR/resumed" > /dev/null
python3 - "$JDIR/clean/campaign.json" "$JDIR/resumed/campaign.json" <<'EOF'
import json, sys
def normalize(path):
    d = json.load(open(path))
    d.pop("cache", None)
    for n in d["nets"]:
        for k in ("compilations", "disk_hits", "negative_hits", "memory_hits"):
            n.pop(k, None)
    return json.dumps(d, sort_keys=True)
a, b = (normalize(p) for p in sys.argv[1:3])
assert a == b, "resumed campaign report differs from the uninterrupted run"
print("kill-and-resume report identical (cache statistics excluded)")
EOF
rm -rf "$JDIR"

# Telemetry gate: a span-traced campaign must emit (a) a Chrome trace that
# parses, has no negative durations, and claims a dense worker tid range,
# and (b) an avsm-campaign-telemetry-v1 report whose span accounting
# matches the campaign report's own unit accounting: one resolve span per
# evaluated unit, simulate + skipped == evaluated on the all-feasible
# default grid, and panicked simulate spans == reported panics (0 here).
echo "== avsm campaign telemetry (span accounting vs campaign report)"
TDIR=$(mktemp -d /tmp/avsm_campaign_obs.XXXXXX)
cargo run --release -q -p avsm -- campaign --nets lenet,dilated_vgg_tiny \
  --threads 2 --outdir "$TDIR" --telemetry "$TDIR/telemetry.json" \
  --trace-out "$TDIR/engine.json" > /dev/null
python3 - "$TDIR/engine.json" "$TDIR/telemetry.json" "$TDIR/campaign.json" <<'EOF'
import json, sys
trace, tel, campaign = (json.load(open(p)) for p in sys.argv[1:4])

# Chrome trace: every duration event is non-negative, and the worker tids
# (thread_name metadata rows) are a dense contiguous range within the
# pool's id space 0..=threads (0 = coordinator; a journal-free run may
# record nothing on the coordinator, so the range need not start at 0).
xs = [e for e in trace if e.get("ph") == "X"]
assert xs, "trace has no duration events"
assert all(e["dur"] >= 0 for e in xs), "negative span duration in trace"
tids = sorted({e["tid"] for e in trace if e.get("ph") == "M"})
assert tids and tids == list(range(tids[0], tids[0] + len(tids))), \
    f"worker tids not dense: {tids}"
assert tids[-1] <= 2, f"worker tid beyond --threads 2: {tids}"

kinds = tel["kinds"]
count = lambda k: kinds.get(k, {}).get("count", 0)
evaluated = sum(n["evaluated"] for n in campaign["nets"])
skipped = sum(n["skipped_by_bound"] for n in campaign["nets"])
panics = sum(n["panics"] for n in campaign["nets"])
assert count("resolve") == evaluated, \
    f'resolve spans {count("resolve")} != evaluated {evaluated}'
assert count("simulate") + count("skipped") == evaluated, \
    "simulate + skipped spans != evaluated on the all-feasible default grid"
assert count("skipped") == skipped, \
    f'skipped spans {count("skipped")} != skipped_by_bound {skipped}'
panicked = kinds.get("simulate", {}).get("outcomes", {}).get("panicked", 0)
assert panicked == panics == 0, f"unexpected panics: {panicked} vs {panics}"
assert tel["spans_total"] == len(xs), "trace events != telemetry spans"
print(f"telemetry consistent: {evaluated} units, {tel['spans_total']} spans, "
      f"{len(tids)} trace threads")
EOF
rm -rf "$TDIR"

# Serve smoke: pipe a session through the resident daemon. A malformed
# job (duplicate axes) must be rejected pre-pool with an avsm-lint-v1
# payload carrying the stable AVSM03x code; a real 2-net campaign must
# stream back a report line whose spliced report bytes equal the one-shot
# CLI's --compact campaign.json; and resubmitting the identical campaign
# must be served entirely from the resident cache (zero compilations).
echo "== avsm serve (pipe mode: lint-gated admission + resident cache)"
SDIR=$(mktemp -d /tmp/avsm_serve.XXXXXX)
cargo run --release -q -p avsm -- campaign --nets lenet,tiny_resnet \
  --threads 1 --compact --outdir "$SDIR/oneshot" > /dev/null
cat > "$SDIR/requests.jsonl" <<'EOF'
{"id": 0, "kind": "campaign", "nets": ["lenet"], "axes": [{"axis": "nce_freq_mhz", "values": [125]}, {"axis": "nce_freq_mhz", "values": [250]}]}
{"id": 1, "kind": "campaign", "nets": ["lenet", "tiny_resnet"], "options": {"threads": 1}}
{"id": 2, "kind": "campaign", "nets": ["lenet", "tiny_resnet"], "options": {"threads": 1}}
EOF
cargo run --release -q -p avsm -- serve < "$SDIR/requests.jsonl" \
  > "$SDIR/responses.jsonl" 2> /dev/null
python3 - "$SDIR/responses.jsonl" "$SDIR/oneshot/campaign.json" <<'EOF'
import json, sys
lines = [l for l in open(sys.argv[1], "rb").read().split(b"\n") if l.strip()]
docs = [json.loads(l) for l in lines]
assert all(d["v"] == 1 for d in docs), "response without a v:1 envelope"

# The duplicate-axis job is rejected before it can reach the pool, and
# the payload is the same avsm-lint-v1 document `avsm lint` would emit.
rej = [d for d in docs if d["event"] == "rejected"]
assert len(rej) == 1 and rej[0]["id"] == 0, rej
lint = rej[0]["lint"]
assert lint["schema"] == "avsm-lint-v1", lint["schema"]
assert lint["summary"]["errors"] >= 1, lint["summary"]
assert any(d["code"] == "AVSM030" for d in lint["diagnostics"]), lint["diagnostics"]

# Jobs 1 and 2 are accepted, stream frontier points, and finish with a
# report line each.
acc = [d["id"] for d in docs if d["event"] == "accepted"]
assert acc == [1, 2], acc
assert any(d["event"] == "point" for d in docs), "no streamed frontier points"

# The served report bytes (spliced verbatim into the report line) equal
# the one-shot CLI's --compact campaign.json for the same spec.
raw1 = next(l for l in lines if l.startswith(b'{"event":"report","id":1,'))
report1 = raw1.split(b'"report":', 1)[1][: -len(b',"v":1}')]
oneshot = open(sys.argv[2], "rb").read().rstrip(b"\n")
assert report1 == oneshot, "served report differs from one-shot campaign.json"

# The resubmission is answered from the resident cache: zero compilations.
rep2 = next(d for d in docs if d["event"] == "report" and d["id"] == 2)
cache2 = rep2["report"]["cache"]
assert cache2["compilations"] == 0, cache2
assert cache2["memory_hits"] > 0, cache2
print(f"serve smoke OK: rejection carries AVSM030, report byte-identical "
      f"({len(report1)} bytes), resubmission compile-free")
EOF
rm -rf "$SDIR"

# Bench baselines: the bench smokes above wrote BENCH_*.json at the repo
# root. The first run on a new machine leaves them uncommitted — say so
# loudly, so pinning a baseline is a reviewed decision rather than an
# accident (CI never commits on its own).
if ls BENCH_*.json >/dev/null 2>&1; then
  UNTRACKED=$(git ls-files --others --exclude-standard 'BENCH_*.json' 2>/dev/null || true)
  if [ -n "$UNTRACKED" ]; then
    echo "NOTE: uncommitted bench baselines: $UNTRACKED — review and 'git add' to pin them"
  fi
fi

echo "== OK"
