#!/usr/bin/env bash
# Repo check: build, tests, and a smoke run of the DSE sweep bench.
# Usable both locally and as the CI entrypoint:
#
#     scripts/check.sh
#
# AVSM_BENCH_FAST=1 puts benchkit into its quick mode (1 warmup, 3 iters)
# so the bench smoke finishes in CI time while still exercising the full
# parallel compile-cached sweep pipeline and writing BENCH_dse_sweep.json.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release"
cargo build --release

echo "== cargo test -q"
cargo test -q

echo "== dse_sweep bench (smoke mode)"
AVSM_BENCH_FAST=1 cargo bench --bench dse_sweep

# The campaign bench also smokes the bound-and-prune path: it runs the
# frontier-sparse grid pruned and unpruned, asserts the frontiers are
# byte-identical (lossless pruning) and that the bound actually skipped
# simulations, and reports points/sec for both regimes.
echo "== campaign bench (smoke mode, incl. pruned vs unpruned)"
AVSM_BENCH_FAST=1 cargo bench --bench campaign

echo "== OK"
