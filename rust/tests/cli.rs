//! CLI smoke tests: spawn the built `avsm` binary the way a user would.

use std::process::Command;

fn avsm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_avsm"))
}

fn run_ok(args: &[&str]) -> String {
    let out = avsm().args(args).output().expect("spawn avsm");
    assert!(
        out.status.success(),
        "avsm {args:?} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn help_lists_commands() {
    let text = run_ok(&["help"]);
    for cmd in ["simulate", "compare", "roofline", "gantt", "flow", "sweep", "infer"] {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn simulate_prints_layers_and_energy() {
    let text = run_ok(&["simulate", "--net", "lenet"]);
    assert!(text.contains("TOTAL"));
    assert!(text.contains("energy/inference"));
    assert!(text.contains("compute-bound") || text.contains("communication-bound") || text.contains("neither"));
}

#[test]
fn compare_reports_accuracy() {
    let text = run_ok(&["compare", "--net", "dilated_vgg_tiny"]);
    assert!(text.contains("accuracy"));
    assert!(text.contains("deviation"));
}

#[test]
fn roofline_full_and_zoom() {
    let full = run_ok(&["roofline", "--net", "dilated_vgg_tiny"]);
    let zoom = run_ok(&["roofline", "--net", "dilated_vgg_tiny", "--zoom"]);
    assert!(full.contains("ridge"));
    assert!(zoom.lines().count() <= full.lines().count());
}

#[test]
fn gantt_formats() {
    let ascii = run_ok(&["gantt", "--net", "lenet"]);
    assert!(ascii.contains("nce") && ascii.contains('|'));
    let csv = run_ok(&["gantt", "--net", "lenet", "--format", "csv"]);
    assert!(csv.starts_with("resource,label,task,kind"));
    let chrome = run_ok(&["gantt", "--net", "lenet", "--format", "chrome"]);
    assert!(chrome.trim_start().starts_with('['));
    assert!(chrome.contains("\"ph\":\"X\""));
}

#[test]
fn flow_writes_reports() {
    let dir = std::env::temp_dir().join(format!("avsm_cli_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let text = run_ok(&["flow", "--net", "lenet", "--outdir", dir.to_str().unwrap()]);
    assert!(text.contains("Fig 3"));
    assert!(dir.join("fig3.json").exists());
    assert!(dir.join("task_graph.json").exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn config_roundtrips_through_file() {
    let dir = std::env::temp_dir().join(format!("avsm_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("sys.json");
    let dump = run_ok(&["config"]);
    std::fs::write(&cfg_path, &dump).unwrap();
    let text = run_ok(&["simulate", "--net", "lenet", "--system", cfg_path.to_str().unwrap()]);
    assert!(text.contains("base_paper_virtex7"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = avsm().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("USAGE"));
}

#[test]
fn mobilenet_workload_simulates() {
    let text = run_ok(&["simulate", "--net", "mobilenet", "--hw", "64"]);
    assert!(text.contains("dw0") && text.contains("pw0"));
}

#[test]
fn campaign_sweeps_portfolio_and_warm_cache_is_compile_free() {
    let dir = std::env::temp_dir().join(format!("avsm_cli_campaign_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_str().unwrap();
    let args = [
        "campaign",
        "--nets",
        "lenet,dilated_vgg_tiny",
        "--cache-dir",
        dir_s,
        "--outdir",
        dir_s,
    ];
    let cold = run_ok(&args);
    assert!(cold.contains("frontier"));
    assert!(cold.contains("cross-net summary"));
    assert!(dir.join("campaign.json").exists());
    // A second CLI invocation hits the persistent cache: no compilations.
    let warm = run_ok(&args);
    assert!(
        warm.contains("compilations: 0"),
        "warm campaign should be compile-free:\n{warm}"
    );
    assert!(warm.contains("disk hits: 6"), "2 nets x 3 structural keys:\n{warm}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn campaign_journal_and_resume_flags_round_trip() {
    let dir = std::env::temp_dir().join(format!("avsm_cli_journal_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("campaign.jsonl");
    let journal_s = journal.to_str().unwrap().to_owned();
    let args =
        ["campaign", "--nets", "lenet", "--threads", "1", "--journal", journal_s.as_str()];
    let first = run_ok(&args);
    let text = std::fs::read_to_string(&journal).unwrap();
    assert!(
        text.starts_with("{\"schema\":\"avsm-campaign-journal-v1\""),
        "journal header missing:\n{text}"
    );
    assert!(text.lines().count() > 1, "completed units must be journaled");

    // A full journal resumes to the identical report without simulating
    // anything: every line except the cache statistics matches.
    let mut resume_args = args.to_vec();
    resume_args.push("--resume");
    let resumed = run_ok(&resume_args);
    assert!(resumed.contains("compilations: 0"), "full replay must be compile-free:\n{resumed}");
    let strip = |s: &str| {
        s.lines().filter(|l| !l.starts_with("compilations:")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(&first), strip(&resumed), "resumed report drifted");

    // --resume without --journal is a descriptive error.
    let out = avsm().args(["campaign", "--nets", "lenet", "--resume"]).output().unwrap();
    assert!(!out.status.success(), "--resume without --journal must exit nonzero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--resume requires --journal"), "{err}");

    // A journal from a different campaign spec refuses loudly.
    let out = avsm()
        .args([
            "campaign", "--nets", "dilated_vgg_tiny", "--threads", "1",
            "--journal", journal_s.as_str(), "--resume",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "foreign journal must refuse");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("different campaign spec") || err.contains("units"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn topdown_answers() {
    let text = run_ok(&["topdown", "--net", "lenet", "--target-ms", "1"]);
    assert!(text.contains("minimum NCE frequency") || text.contains("not reachable"));
}

#[test]
fn topdown_rejects_inverted_range_through_generic_solver() {
    // Regression: an inverted --lo/--hi range must fail cleanly through
    // the generic requirement solver's range check — descriptive error,
    // nonzero exit — not bisect garbage or panic.
    let out = avsm()
        .args([
            "topdown", "--net", "lenet", "--target-ms", "1", "--lo", "1000", "--hi", "50",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "inverted range must exit nonzero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("lo <= hi"), "{err}");
    // A zero lower endpoint is rejected the same way.
    let out = avsm()
        .args(["topdown", "--net", "lenet", "--target-ms", "1", "--lo", "0", "--hi", "50"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("0 < lo"));
}

#[test]
fn campaign_bound_flag_selects_and_reports_the_bound() {
    // The report records the chosen bound...
    let text = run_ok(&[
        "campaign", "--nets", "lenet", "--bound", "occupancy", "--threads", "1",
    ]);
    assert!(text.contains("bound occupancy"), "{text}");
    // ...including the default.
    let text = run_ok(&["campaign", "--nets", "lenet", "--threads", "1"]);
    assert!(text.contains("bound max"), "{text}");
    // An invalid kind is a descriptive error and a nonzero exit.
    let out = avsm()
        .args(["campaign", "--nets", "lenet", "--bound", "tightest"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--bound tightest must exit nonzero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("unknown bound"), "{err}");
    assert!(err.contains("occupancy, critical-path, max"), "{err}");
}

#[test]
fn topdown_solves_any_scalar_axis() {
    let text = run_ok(&[
        "topdown", "--net", "lenet", "--target-ms", "1",
        "--axis", "bus_bytes_per_cycle", "--lo", "4", "--hi", "64",
    ]);
    assert!(
        text.contains("minimum bus width") || text.contains("not reachable"),
        "{text}"
    );
    // An unknown axis is a loud error listing the known ones.
    let out = avsm()
        .args(["topdown", "--net", "lenet", "--target-ms", "1", "--axis", "warp"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("known axes"));
}

#[test]
fn sweep_accepts_json_axis_specs() {
    let text = run_ok(&[
        "sweep",
        "--net",
        "lenet",
        "--axes",
        r#"[{"axis":"nce_freq_mhz","values":[125,250]},
            {"axis":"weight_buffer_kib","values":[128,256]}]"#,
    ]);
    // 2x2 grid; the non-canonical weight axis shows up in point names.
    assert!(text.contains("wbuf128"), "{text}");
    assert!(text.contains("wbuf256"), "{text}");
    assert!(text.contains("pareto frontier"), "{text}");

    // A spec containing an invalid point (0 MHz) must fail the command
    // with a diagnostic — never silently shrink the table.
    let out = avsm()
        .args([
            "sweep",
            "--net",
            "lenet",
            "--axes",
            r#"[{"axis":"nce_freq_mhz","values":[250,0]}]"#,
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "broken axis spec must exit non-zero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("failed evaluation"), "{err}");
}

#[test]
fn campaign_runs_heterogeneous_workloads_file_and_fail_fast_gates() {
    let dir = std::env::temp_dir().join(format!("avsm_cli_hetero_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let wl = dir.join("workloads.json");
    std::fs::write(
        &wl,
        r#"[
          {"net": "lenet",
           "axes": [{"axis": "nce_freq_mhz", "values": [125, 250]}]},
          {"net": "dilated_vgg_tiny",
           "axes": [{"axis": "array_geometry", "values": [[16, 32], [32, 64]]}]}
        ]"#,
    )
    .unwrap();
    let text = run_ok(&[
        "campaign", "--workloads", wl.to_str().unwrap(), "--fail-fast",
    ]);
    assert!(text.contains("2 workloads, 4 grid units"), "{text}");
    assert!(text.contains("axes nce_freq_mhz[2]"), "{text}");
    assert!(text.contains("axes array_geometry[2]"), "{text}");

    // A broken axis spec (0 MHz point) under --fail-fast aborts loudly.
    std::fs::write(
        &wl,
        r#"[{"net": "lenet", "axes": [{"axis": "nce_freq_mhz", "values": [250, 0]}]}]"#,
    )
    .unwrap();
    let out = avsm()
        .args(["campaign", "--workloads", wl.to_str().unwrap(), "--fail-fast"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "fail-fast campaign must exit non-zero");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fail_fast"), "{err}");
    assert!(err.contains("invalid configuration"), "{err}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn campaign_telemetry_and_trace_artifacts() {
    let dir = std::env::temp_dir().join(format!("avsm_cli_obs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let tel = dir.join("telemetry.json");
    let trace = dir.join("engine.json");
    let text = run_ok(&[
        "campaign", "--nets", "lenet", "--threads", "2",
        "--outdir", dir.to_str().unwrap(),
        "--telemetry", tel.to_str().unwrap(),
        "--trace-out", trace.to_str().unwrap(),
    ]);
    // The campaign report still leads; the telemetry table follows it.
    assert!(text.contains("frontier"), "{text}");
    assert!(text.contains("campaign telemetry:"), "{text}");
    assert!(text.contains("ui.perfetto.dev"), "{text}");

    // The machine-readable report parses and cross-checks the campaign's
    // own accounting: one resolve span per evaluated unit, and every
    // compiled unit either simulated or was pruned.
    let doc = avsm::json::parse(&std::fs::read_to_string(&tel).unwrap()).unwrap();
    assert_eq!(doc.get("schema").as_str(), Some("avsm-campaign-telemetry-v1"));
    let campaign =
        avsm::json::parse(&std::fs::read_to_string(dir.join("campaign.json")).unwrap()).unwrap();
    let evaluated: u64 = campaign
        .get("nets")
        .as_array()
        .unwrap()
        .iter()
        .map(|n| n.get("evaluated").as_u64().unwrap())
        .sum();
    let kind_count = |kind: &str| doc.get("kinds").get(kind).get("count").as_u64().unwrap_or(0);
    assert_eq!(kind_count("resolve"), evaluated, "one resolve span per unit");
    assert_eq!(
        kind_count("simulate") + kind_count("skipped"),
        evaluated,
        "lenet's default grid is all-feasible: every unit simulates or is pruned"
    );
    assert!(doc.get("counters").get("cache.compiles").as_u64().unwrap() > 0);

    // The Chrome trace is a JSON array of thread metadata + X events.
    let chrome = std::fs::read_to_string(&trace).unwrap();
    assert!(chrome.trim_start().starts_with('['), "{chrome}");
    assert!(chrome.contains("\"ph\":\"X\""), "{chrome}");
    assert!(chrome.contains("thread_name"), "{chrome}");
    // A journal-free run records nothing on the coordinator thread, so the
    // named timeline rows are the pool workers.
    assert!(chrome.contains("worker"), "{chrome}");

    // Without the flags the telemetry table never prints.
    let plain = run_ok(&["campaign", "--nets", "lenet", "--threads", "1"]);
    assert!(!plain.contains("campaign telemetry:"), "{plain}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn gantt_svg_axes_flag_captions_the_name_legend() {
    let axes = r#"[{"axis":"nce_freq_mhz","values":[125,250]}]"#;
    let svg = run_ok(&["gantt", "--net", "lenet", "--format", "svg", "--axes", axes]);
    assert!(svg.contains("name legend: f = NCE frequency (MHz)"), "{svg}");
    // Without --axes the SVG stays caption-free (byte-compatible output).
    let plain = run_ok(&["gantt", "--net", "lenet", "--format", "svg"]);
    assert!(!plain.contains("name legend"), "{plain}");
}
