//! End-to-end tests of the `serve` daemon over pipe-mode sessions (and
//! one real Unix-socket accept loop): protocol robustness (the daemon
//! never dies, every rejection is a well-formed `avsm-lint-v1` payload),
//! report fidelity (a served campaign's report bytes equal the one-shot
//! `campaign::run` output for the same spec), and cache residency (a
//! resubmitted job performs zero compilations).

use avsm::campaign::{self, CampaignOptions, CampaignSpec, WorkloadSpec};
use avsm::dse::SweepAxes;
use avsm::graph::models;
use avsm::json::{self, Value};
use avsm::report::CampaignReport;
use avsm::serve::{serve_session, Daemon, ServeOptions};

/// Run one pipe-mode session over `input`, returning the response lines.
fn session(daemon: &Daemon, input: &str) -> Vec<String> {
    let mut out = Vec::new();
    serve_session(daemon, input.as_bytes(), &mut out).expect("session must not die");
    let text = String::from_utf8(out).expect("responses are UTF-8");
    text.lines().map(str::to_string).collect()
}

/// Every response line must parse, carry the envelope, and — when it is
/// a rejection — wrap a well-formed `avsm-lint-v1` report with at least
/// one error-severity diagnostic.
fn check_response_line(line: &str) -> Value {
    let v = json::parse(line).unwrap_or_else(|e| panic!("unparseable response {line:?}: {e:#}"));
    assert_eq!(v.get("v").as_u64(), Some(1), "envelope v on {line:?}");
    let event = v.get("event").as_str().unwrap_or_else(|| panic!("no event on {line:?}"));
    if event == "rejected" {
        let lint = v.get("lint");
        assert_eq!(lint.get("schema").as_str(), Some("avsm-lint-v1"), "{line:?}");
        let errors = lint.get("summary").get("errors").as_u64().unwrap_or(0);
        assert!(errors >= 1, "rejection with no errors: {line:?}");
        assert!(
            lint.get("diagnostics").as_array().is_some_and(|d| !d.is_empty()),
            "{line:?}"
        );
    }
    v
}

/// The small two-net campaign request used throughout; `id` tags the
/// submission. Explicit axes keep it fast (4 units per net).
fn campaign_request(id: u64) -> String {
    format!(
        "{{\"id\":{id},\"kind\":\"campaign\",\"nets\":[\"lenet\",\"tiny_resnet\"],\
         \"axes\":[{{\"axis\":\"array_geometry\",\"values\":[[16,32],[32,64]]}},\
         {{\"axis\":\"nce_freq_mhz\",\"values\":[125,250]}}],\
         \"options\":{{\"threads\":1}}}}"
    )
}

/// The same spec built directly against the library — the one-shot
/// reference the served report must match byte for byte.
fn reference_spec() -> (CampaignSpec, CampaignOptions) {
    let axes = SweepAxes::new()
        .array_geometries(vec![(16, 32), (32, 64)])
        .nce_freqs_mhz(vec![125, 250]);
    let spec = CampaignSpec {
        workloads: vec![
            WorkloadSpec::new(models::by_name("lenet", 0).unwrap()),
            WorkloadSpec::new(models::by_name("tiny_resnet", 0).unwrap()),
        ],
        base: avsm::config::SystemConfig::base_paper(),
        axes,
    };
    let opts = CampaignOptions { threads: 1, ..Default::default() };
    (spec, opts)
}

#[test]
fn served_report_is_byte_identical_to_one_shot_run() {
    let daemon = Daemon::new(ServeOptions::default());
    let lines = session(&daemon, &campaign_request(1));
    for l in &lines {
        check_response_line(l);
    }
    assert_eq!(
        json::parse(&lines[0]).unwrap().get("event").as_str(),
        Some("accepted"),
        "{lines:?}"
    );
    let report_line = lines.last().expect("report line");
    let v = check_response_line(report_line);
    assert_eq!(v.get("event").as_str(), Some("report"));
    assert_eq!(v.get("id").as_u64(), Some(1));

    // Byte-level extraction: the report line is a splice around the
    // report's own `write_json` bytes (sorted keys pin the layout), so
    // stripping the envelope prefix/suffix must recover them verbatim.
    let prefix = "{\"event\":\"report\",\"id\":1,\"report\":";
    let suffix = ",\"v\":1}";
    assert!(report_line.starts_with(prefix), "{report_line:?}");
    assert!(report_line.ends_with(suffix), "{report_line:?}");
    let served = &report_line[prefix.len()..report_line.len() - suffix.len()];

    let (spec, opts) = reference_spec();
    let result = campaign::run(&spec, &opts).unwrap();
    let expected = CampaignReport::new(&result).write_json(Vec::new(), false).unwrap();
    assert_eq!(
        served,
        std::str::from_utf8(&expected).unwrap(),
        "served report bytes must equal the one-shot campaign report"
    );

    // The stream also delivered every feasible point before the report.
    let feasible: u64 = result.nets.iter().map(|n| n.feasible as u64).sum();
    let points = lines
        .iter()
        .filter(|l| json::parse(l).unwrap().get("event").as_str() == Some("point"))
        .count() as u64;
    assert_eq!(points, feasible, "one point event per feasible unit");
}

#[test]
fn resident_cache_makes_resubmission_compile_free() {
    let daemon = Daemon::new(ServeOptions::default());
    // Two identical submissions in one session (one line each).
    let input = format!("{}\n{}\n", campaign_request(1), campaign_request(2));
    let lines = session(&daemon, &input);
    let reports: Vec<Value> = lines
        .iter()
        .map(|l| check_response_line(l))
        .filter(|v| v.get("event").as_str() == Some("report"))
        .collect();
    assert_eq!(reports.len(), 2, "{lines:?}");
    let cache1 = reports[0].get("report").get("cache").clone();
    let cache2 = reports[1].get("report").get("cache").clone();
    let first = cache1.get("compilations").as_u64().unwrap();
    assert!(first > 0, "cold first job must compile: {cache1:?}");
    assert_eq!(
        cache2.get("compilations").as_u64(),
        Some(0),
        "resident cache: second job must compile nothing ({cache2:?})"
    );
    assert!(
        cache2.get("memory_hits").as_u64().unwrap() >= first,
        "second job served from the memory tier: {cache2:?}"
    );
    // And the resident counters never leak across reports: job 1's
    // compiles are not re-reported by job 2 (delta accounting).
    assert_eq!(cache2.get("disk_hits").as_u64(), Some(0));
}

#[test]
fn malformed_requests_are_rejected_with_lint_payloads_and_never_kill_the_daemon() {
    let daemon = Daemon::new(ServeOptions::default());
    // One of everything the admission gate must catch, then a real job
    // to prove the session survived it all.
    let deep_open = "[".repeat(80); // > MAX_DEPTH=64 nesting
    let cases: Vec<(String, &str)> = vec![
        ("{\"kind\": tru}".into(), "AVSM060"),                       // parse error
        ("[1,2,3]".into(), "AVSM060"),                               // not an object
        (deep_open, "AVSM060"),                                      // depth bomb
        ("{\"v\":2,\"kind\":\"ping\"}".into(), "AVSM061"),           // future version
        ("{\"v\":\"x\",\"kind\":\"ping\"}".into(), "AVSM061"),       // junk version
        ("{\"id\":9}".into(), "AVSM062"),                            // no kind
        ("{\"kind\":\"dance\"}".into(), "AVSM062"),                  // unknown kind
        ("{\"kind\":\"campaign\"}".into(), "AVSM064"),               // no workloads
        ("{\"kind\":\"campaign\",\"nets\":[\"no_such_net\"]}".into(), "AVSM064"),
        (
            // Duplicate axis: the standard AVSM030 campaign-spec pass.
            "{\"kind\":\"campaign\",\"nets\":[\"lenet\"],\"axes\":[\
             {\"axis\":\"nce_freq_mhz\",\"values\":[125]},\
             {\"axis\":\"nce_freq_mhz\",\"values\":[250]}]}"
                .into(),
            "AVSM030",
        ),
        (
            "{\"kind\":\"solve\",\"net\":\"lenet\"}".into(), // no target
            "AVSM064",
        ),
    ];
    let mut input = String::new();
    for (line, _) in &cases {
        input.push_str(line);
        input.push('\n');
    }
    input.push_str("{\"id\":77,\"kind\":\"ping\"}\n");
    let lines = session(&daemon, &input);
    assert_eq!(lines.len(), cases.len() + 1, "{lines:?}");
    for (i, (req, code)) in cases.iter().enumerate() {
        let v = check_response_line(&lines[i]);
        assert_eq!(v.get("event").as_str(), Some("rejected"), "{req:?} -> {}", lines[i]);
        let codes: Vec<String> = v
            .get("lint")
            .get("diagnostics")
            .as_array()
            .unwrap()
            .iter()
            .map(|d| d.get("code").as_str().unwrap().to_string())
            .collect();
        assert!(codes.iter().any(|c| c == code), "{req:?}: want {code} in {codes:?}");
    }
    let pong = check_response_line(lines.last().unwrap());
    assert_eq!(pong.get("event").as_str(), Some("pong"));
    assert_eq!(pong.get("id").as_u64(), Some(77), "id echoed after the gauntlet");
}

#[test]
fn oversized_lines_are_rejected_and_the_session_recovers() {
    let daemon = Daemon::new(ServeOptions { max_line: 256, ..Default::default() });
    let long = format!("{{\"kind\":\"ping\",\"pad\":\"{}\"}}", "x".repeat(4096));
    let input = format!("{long}\n{{\"id\":1,\"kind\":\"ping\"}}\n");
    let lines = session(&daemon, &input);
    assert_eq!(lines.len(), 2, "{lines:?}");
    let rej = check_response_line(&lines[0]);
    assert_eq!(rej.get("event").as_str(), Some("rejected"));
    let code = rej.get("lint").get("diagnostics").at(0).get("code").as_str();
    assert_eq!(code, Some("AVSM063"), "{lines:?}");
    let pong = check_response_line(&lines[1]);
    assert_eq!(pong.get("event").as_str(), Some("pong"));
}

#[test]
fn fuzzed_garbage_never_kills_the_session_and_always_gets_lint_rejections() {
    // Seeded structural fuzz: every line is garbage of a different
    // flavor; the session must answer each non-blank line with exactly
    // one well-formed rejection and then still serve a real request.
    let daemon = Daemon::new(ServeOptions { max_line: 512, ..Default::default() });
    let mut rng = avsm::testkit::Rng::new(avsm::testkit::seed_from_env(0xC0FFEE));
    let mut input = String::new();
    let mut expect = 0usize;
    for i in 0..200 {
        let flavor = rng.range(0, 7);
        let line = match flavor {
            0 => String::from_utf8_lossy(&[b'{', 0xFF, 0xFE, b'}']).into_owned(),
            1 => "{".repeat(1 + rng.range(0, 69) as usize),
            2 => format!("{{\"kind\":\"campaign\",\"nets\":{i}}}"),
            3 => format!("\"naked string {i}\""),
            4 => format!("{{\"v\":{},\"kind\":\"ping\"}}", 2 + rng.range(0, 99)),
            5 => format!("{{\"kind\":\"solve\",\"net\":\"lenet\",\"target_ms\":-{i}}}"),
            6 => "x".repeat(600), // over max_line
            7 => format!("{{\"kind\":\"sweep\",\"net\":{i}}}"),
            _ => unreachable!(),
        };
        assert!(!line.contains('\n'));
        input.push_str(&line);
        input.push('\n');
        expect += 1;
    }
    input.push_str("{\"id\":1,\"kind\":\"ping\"}\n");
    let lines = session(&daemon, &input);
    assert_eq!(lines.len(), expect + 1, "one response per line");
    for l in &lines[..expect] {
        let v = check_response_line(l);
        assert_eq!(v.get("event").as_str(), Some("rejected"), "{l}");
    }
    assert_eq!(
        check_response_line(lines.last().unwrap()).get("event").as_str(),
        Some("pong")
    );
}

#[test]
fn solve_requests_answer_and_scan_agrees_with_search() {
    let daemon = Daemon::new(ServeOptions::default());
    let input = "{\"id\":1,\"kind\":\"solve\",\"net\":\"lenet\",\"target_ms\":50,\
                 \"lo\":50,\"hi\":80}\n\
                 {\"id\":2,\"kind\":\"solve\",\"net\":\"lenet\",\"target_ms\":50,\
                 \"lo\":50,\"hi\":80,\"scan\":true}\n";
    let lines = session(&daemon, input);
    let solutions: Vec<Value> = lines
        .iter()
        .map(|l| check_response_line(l))
        .filter(|v| v.get("event").as_str() == Some("solution"))
        .collect();
    assert_eq!(solutions.len(), 2, "{lines:?}");
    assert_eq!(
        solutions[0].get("value").as_u64(),
        solutions[1].get("value").as_u64(),
        "scan and binary search agree on a monotone axis: {solutions:?}"
    );
    assert_eq!(solutions[1].get("compiles").as_u64(), Some(1), "retime axis compiles once");
}

#[test]
fn shutdown_request_ends_the_session() {
    let daemon = Daemon::new(ServeOptions::default());
    let input = "{\"id\":1,\"kind\":\"ping\"}\n\
                 {\"id\":2,\"kind\":\"shutdown\"}\n\
                 {\"id\":3,\"kind\":\"ping\"}\n";
    let lines = session(&daemon, input);
    assert_eq!(lines.len(), 2, "nothing after bye: {lines:?}");
    assert_eq!(check_response_line(&lines[1]).get("event").as_str(), Some("bye"));
    assert!(daemon.is_shutdown());
}

#[cfg(unix)]
#[test]
fn unix_socket_serves_interleaved_clients() {
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;

    let dir = std::env::temp_dir().join(format!("avsm_serve_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let sock = dir.join("avsm.sock");
    let sock_for_daemon = sock.clone();
    let daemon_thread = std::thread::spawn(move || {
        avsm::serve::serve_unix(&sock_for_daemon, ServeOptions::default()).unwrap()
    });
    // Wait for the socket to appear.
    let mut tries = 0;
    while !sock.exists() {
        std::thread::sleep(std::time::Duration::from_millis(10));
        tries += 1;
        assert!(tries < 500, "daemon never bound {sock:?}");
    }

    // Two concurrent clients, each pinging with its own id several
    // times: every client must get exactly its own echoes, in order.
    let clients: Vec<_> = (0..2)
        .map(|c| {
            let sock = sock.clone();
            std::thread::spawn(move || {
                let mut tx = UnixStream::connect(&sock).unwrap();
                let mut rx = BufReader::new(tx.try_clone().unwrap());
                for i in 0..5 {
                    let id = c * 100 + i;
                    writeln!(tx, "{{\"id\":{id},\"kind\":\"ping\"}}").unwrap();
                    let mut line = String::new();
                    rx.read_line(&mut line).unwrap();
                    let v = json::parse(&line).unwrap();
                    assert_eq!(v.get("event").as_str(), Some("pong"), "{line:?}");
                    assert_eq!(v.get("id").as_u64(), Some(id), "cross-talk: {line:?}");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    // A third client shuts the daemon down; the accept loop drains.
    let mut tx = UnixStream::connect(&sock).unwrap();
    let mut rx = BufReader::new(tx.try_clone().unwrap());
    writeln!(tx, "{{\"id\":9,\"kind\":\"shutdown\"}}").unwrap();
    let mut line = String::new();
    rx.read_line(&mut line).unwrap();
    assert_eq!(json::parse(&line).unwrap().get("event").as_str(), Some("bye"));
    let daemon = daemon_thread.join().unwrap();
    assert!(daemon.is_shutdown());
    assert!(!sock.exists(), "socket file removed on shutdown");
    let _ = std::fs::remove_dir_all(&dir);
}
