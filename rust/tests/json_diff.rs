//! Differential tests for the streaming JSON layer: the event-based
//! `json::stream` Reader/Writer (which `json::parse` and the `Value`
//! serializers are now built on) is checked against a test-local copy of
//! the recursive-descent parser and serializer it replaced. Seeded random
//! `Value` trees (`AVSM_TEST_SEED` pins the file) must serialize
//! byte-identically — via the tree API *and* via manual event-by-event
//! emission — and every corrupted document must fail with the exact error
//! string (message, byte offset, context window) the old parser produced.
//!
//! The one deliberate divergence from the historical code: the reference
//! `err_at` below clamps its "near" window to UTF-8 character boundaries,
//! matching the fix shipped with the streaming layer (the old window could
//! slice mid-codepoint; both implementations now clamp identically).

use avsm::json::{parse, stream, Value};
use avsm::testkit::Rng;
use std::collections::BTreeMap;

/// The pre-streaming recursive-descent implementation, copied verbatim
/// (modulo the documented `err_at` clamp) as the behavioural oracle.
mod reference {
    use super::{BTreeMap, Value};
    use anyhow::{anyhow, bail, Result};
    use std::fmt::Write;

    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err_at(p.pos, "trailing characters"));
        }
        Ok(v)
    }

    struct Parser<'a> {
        bytes: &'a [u8],
        pos: usize,
    }

    impl<'a> Parser<'a> {
        fn peek(&self) -> Option<u8> {
            self.bytes.get(self.pos).copied()
        }

        fn err_at(&self, pos: usize, msg: impl std::fmt::Display) -> anyhow::Error {
            const WINDOW: usize = 12;
            let is_continuation = |b: u8| matches!(b, 0x80..=0xBF);
            let mut start = pos.saturating_sub(WINDOW);
            let mut end = (pos + WINDOW).min(self.bytes.len());
            for _ in 0..3 {
                if start < pos && is_continuation(self.bytes[start]) {
                    start += 1;
                }
            }
            for _ in 0..3 {
                if end > pos && end < self.bytes.len() && is_continuation(self.bytes[end]) {
                    end -= 1;
                }
            }
            let mut near = String::new();
            if start > 0 {
                near.push_str("...");
            }
            near.push_str(&String::from_utf8_lossy(&self.bytes[start..end]));
            if end < self.bytes.len() {
                near.push_str("...");
            }
            anyhow!("{msg} at byte {pos} (near {near:?})")
        }

        fn bump(&mut self) -> Result<u8> {
            let b = self
                .peek()
                .ok_or_else(|| self.err_at(self.pos, "unexpected end of input"))?;
            self.pos += 1;
            Ok(b)
        }

        fn skip_ws(&mut self) {
            while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
                self.pos += 1;
            }
        }

        fn expect(&mut self, b: u8) -> Result<()> {
            let at = self.pos;
            let got = self.bump()?;
            if got != b {
                return Err(self.err_at(
                    at,
                    format!("expected {:?}, got {:?}", b as char, got as char),
                ));
            }
            Ok(())
        }

        fn value(&mut self) -> Result<Value> {
            match self
                .peek()
                .ok_or_else(|| self.err_at(self.pos, "unexpected end of input"))?
            {
                b'{' => self.object(),
                b'[' => self.array(),
                b'"' => Ok(Value::Str(self.string()?)),
                b't' => self.literal("true", Value::Bool(true)),
                b'f' => self.literal("false", Value::Bool(false)),
                b'n' => self.literal("null", Value::Null),
                b'-' | b'0'..=b'9' => self.number(),
                other => Err(self
                    .err_at(self.pos, format!("unexpected character {:?}", other as char))),
            }
        }

        fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
            if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
                self.pos += lit.len();
                Ok(v)
            } else {
                Err(self.err_at(self.pos, format!("invalid literal (expected {lit:?})")))
            }
        }

        fn object(&mut self) -> Result<Value> {
            self.expect(b'{')?;
            let mut map = BTreeMap::new();
            self.skip_ws();
            if self.peek() == Some(b'}') {
                self.pos += 1;
                return Ok(Value::Object(map));
            }
            loop {
                self.skip_ws();
                let key = self.string()?;
                self.skip_ws();
                self.expect(b':')?;
                self.skip_ws();
                let val = self.value()?;
                map.insert(key, val);
                self.skip_ws();
                let at = self.pos;
                match self.bump()? {
                    b',' => continue,
                    b'}' => return Ok(Value::Object(map)),
                    other => {
                        return Err(self.err_at(
                            at,
                            format!("expected ',' or '}}', got {:?}", other as char),
                        ))
                    }
                }
            }
        }

        fn array(&mut self) -> Result<Value> {
            self.expect(b'[')?;
            let mut items = Vec::new();
            self.skip_ws();
            if self.peek() == Some(b']') {
                self.pos += 1;
                return Ok(Value::Array(items));
            }
            loop {
                self.skip_ws();
                items.push(self.value()?);
                self.skip_ws();
                let at = self.pos;
                match self.bump()? {
                    b',' => continue,
                    b']' => return Ok(Value::Array(items)),
                    other => {
                        return Err(self.err_at(
                            at,
                            format!("expected ',' or ']', got {:?}", other as char),
                        ))
                    }
                }
            }
        }

        fn string(&mut self) -> Result<String> {
            self.expect(b'"')?;
            let mut s = String::new();
            loop {
                let at = self.pos;
                match self.bump()? {
                    b'"' => return Ok(s),
                    b'\\' => match self.bump()? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            if (0xD800..0xDC00).contains(&cp) {
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err_at(at, "invalid low surrogate"));
                                }
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                s.push(
                                    char::from_u32(c)
                                        .ok_or_else(|| self.err_at(at, "bad surrogate pair"))?,
                                );
                            } else {
                                s.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err_at(at, "bad unicode escape"))?,
                                );
                            }
                        }
                        other => {
                            return Err(
                                self.err_at(at, format!("bad escape \\{:?}", other as char))
                            )
                        }
                    },
                    b if b < 0x20 => {
                        return Err(self.err_at(at, "raw control character in string"))
                    }
                    b if b < 0x80 => s.push(b as char),
                    b => {
                        let start = self.pos - 1;
                        let len = utf8_len(b).map_err(|e| self.err_at(start, e))?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err_at(start, "truncated UTF-8 sequence"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err_at(start, "invalid UTF-8 in string"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }

        fn hex4(&mut self) -> Result<u32> {
            let mut v = 0u32;
            for _ in 0..4 {
                let at = self.pos;
                let b = self.bump()?;
                let d = (b as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err_at(at, "bad hex digit"))?;
                v = v * 16 + d;
            }
            Ok(v)
        }

        fn number(&mut self) -> Result<Value> {
            let start = self.pos;
            if self.peek() == Some(b'-') {
                self.pos += 1;
            }
            let mut is_float = false;
            while let Some(b) = self.peek() {
                match b {
                    b'0'..=b'9' => self.pos += 1,
                    b'.' | b'e' | b'E' | b'+' | b'-' => {
                        is_float = true;
                        self.pos += 1;
                    }
                    _ => break,
                }
            }
            let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
            if !is_float {
                if let Ok(i) = text.parse::<i64>() {
                    return Ok(Value::Int(i));
                }
            }
            text.parse::<f64>()
                .map(Value::Num)
                .map_err(|_| self.err_at(start, format!("invalid number {text:?}")))
        }
    }

    fn utf8_len(first: u8) -> Result<usize> {
        match first {
            0xC0..=0xDF => Ok(2),
            0xE0..=0xEF => Ok(3),
            0xF0..=0xF7 => Ok(4),
            _ => bail!("invalid UTF-8 lead byte"),
        }
    }

    pub fn serialize(v: &Value, indent: Option<usize>) -> String {
        let mut out = String::new();
        write_value(&mut out, v, indent, 0);
        out
    }

    fn write_value(out: &mut String, v: &Value, indent: Option<usize>, depth: usize) {
        match v {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Value::Num(f) => {
                if !f.is_finite() {
                    out.push_str("null");
                } else if f.fract() == 0.0 {
                    let _ = write!(out, "{f:.1}");
                } else {
                    let _ = write!(out, "{f}");
                }
            }
            Value::Str(s) => write_string(out, s),
            Value::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_value(out, item, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Value::Object(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, val)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_string(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    write_value(out, val, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
        if let Some(w) = indent {
            out.push('\n');
            for _ in 0..w * depth {
                out.push(' ');
            }
        }
    }

    fn write_string(out: &mut String, s: &str) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out.push('"');
    }
}

// ---------------------------------------------------------------------------
// Seeded Value generator
// ---------------------------------------------------------------------------

/// String atoms covering every serializer branch: plain ASCII, every
/// short escape, a control character, and 2/3/4-byte UTF-8 sequences.
const STR_ATOMS: &[&str] =
    &["a", "Z9", "\"", "\\", "\n", "\t", "\r", "\u{0007}", "é", "Ω", "\u{2014}", "🚀", " ", "/"];

fn gen_string(rng: &mut Rng) -> String {
    let n = rng.range(0, 6);
    let mut s = String::new();
    for _ in 0..n {
        s.push_str(rng.pick(STR_ATOMS));
    }
    s
}

/// A random tree of bounded depth. Floats are multiples of 1/64 so every
/// one re-parses exactly; non-finite floats are excluded (both serializers
/// map them to `null`, which breaks re-parse equality by design).
fn gen_value(rng: &mut Rng, depth: usize) -> Value {
    match rng.range(0, if depth == 0 { 4 } else { 6 }) {
        0 => Value::Null,
        1 => Value::Bool(rng.bool()),
        2 => Value::Int((rng.next_u64() as i64) >> rng.range(0, 32)),
        3 => Value::Num((rng.next_u64() % 2_000_000) as f64 / 64.0 - 10_000.0),
        4 => Value::Str(gen_string(rng)),
        5 => Value::Array((0..rng.range(0, 4)).map(|_| gen_value(rng, depth - 1)).collect()),
        _ => Value::Object(
            (0..rng.range(0, 4))
                .map(|i| (format!("{}_{i}", gen_string(rng)), gen_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Emit `v` through the streaming writer one event at a time — the manual
/// incremental path a streaming producer (report emitter, journal) uses,
/// as opposed to the `Writer::value` convenience the tree serializer uses.
fn emit_events<W: std::io::Write>(w: &mut stream::Writer<W>, v: &Value) -> anyhow::Result<()> {
    match v {
        Value::Null => w.null(),
        Value::Bool(b) => w.bool(*b),
        Value::Int(i) => w.int(*i),
        Value::Num(f) => w.num(*f),
        Value::Str(s) => w.str(s),
        Value::Array(items) => {
            w.begin_arr()?;
            for item in items {
                emit_events(w, item)?;
            }
            w.end_arr()
        }
        Value::Object(map) => {
            w.begin_obj()?;
            for (k, val) in map {
                w.key(k)?;
                emit_events(w, val)?;
            }
            w.end_obj()
        }
    }
}

const CASES: usize = 200;

fn seeded_docs() -> Vec<Value> {
    let mut rng = Rng::new(avsm::testkit::seed_from_env(0x5EED_1509));
    (0..CASES).map(|_| gen_value(&mut rng, 6)).collect()
}

// ---------------------------------------------------------------------------
// Differential properties
// ---------------------------------------------------------------------------

#[test]
fn random_trees_serialize_identically_to_the_reference() {
    for (i, v) in seeded_docs().iter().enumerate() {
        for (indent, tree) in
            [(None, v.to_string_compact()), (Some(1), v.to_string_pretty())]
        {
            let want = reference::serialize(v, indent);
            assert_eq!(tree, want, "case {i}: tree serializer drifted from the reference");
            let mut bytes = Vec::new();
            let mut w = stream::Writer::with_indent(&mut bytes, indent);
            emit_events(&mut w, v).unwrap();
            w.finish().unwrap();
            assert_eq!(
                String::from_utf8(bytes).unwrap(),
                want,
                "case {i}: event-by-event emission drifted from the reference"
            );
        }
    }
}

#[test]
fn random_trees_reparse_identically_to_the_reference() {
    for (i, v) in seeded_docs().iter().enumerate() {
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            let ours = parse(&text).unwrap_or_else(|e| panic!("case {i}: {e}"));
            let theirs = reference::parse(&text).unwrap();
            assert_eq!(ours, theirs, "case {i}: parse disagrees with the reference");
            assert_eq!(&ours, v, "case {i}: round-trip lost information");
        }
    }
}

#[test]
fn corrupted_docs_fail_with_the_reference_error_byte_for_byte() {
    let mut rng = Rng::new(avsm::testkit::seed_from_env(0xBAD_D0C));
    let mut checked = 0usize;
    for v in seeded_docs().iter().take(60) {
        let text = v.to_string_compact();
        // Truncation at every char boundary: the torn-journal-line shape.
        for cut in 0..text.len() {
            if !text.is_char_boundary(cut) {
                continue;
            }
            compare_outcomes(&text[..cut], &mut checked);
        }
        // Point mutations at random ASCII positions: the corrupted-cache
        // shape. Only ASCII positions are touched so the input stays valid
        // UTF-8 (the parsers take `&str`).
        for _ in 0..16 {
            let at = rng.range(0, text.len() as u64 - 1) as usize;
            if !text.as_bytes()[at].is_ascii() {
                continue;
            }
            let mut mutated = text.clone().into_bytes();
            mutated[at] = *rng.pick(b"{}[]:,\"x0!");
            let mutated = String::from_utf8(mutated).unwrap();
            compare_outcomes(&mutated, &mut checked);
        }
    }
    assert!(checked > 1000, "only {checked} corrupted documents exercised");
}

/// Both parsers must agree Ok/Err; on Err the *entire* rendered error —
/// message, byte offset, context window — must match.
fn compare_outcomes(text: &str, checked: &mut usize) {
    *checked += 1;
    match (parse(text), reference::parse(text)) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "parsers disagree on {text:?}"),
        (Err(a), Err(b)) => {
            assert_eq!(a.to_string(), b.to_string(), "error drifted on {text:?}")
        }
        (a, b) => panic!(
            "outcome disagreement on {text:?}: ours {:?} vs reference {:?}",
            a.map(|_| ()),
            b.map(|_| ())
        ),
    }
}

#[test]
fn skip_value_errors_where_parse_errors() {
    // The lazy skip path must be exactly as strict as the tree parser on
    // syntax (it never decodes escapes or numbers it skips, but it lexes
    // them), so a corrupted document can't sneak past a lazy fingerprint
    // check only to explode later in a full decode.
    for v in seeded_docs().iter().take(40) {
        let text = v.to_string_compact();
        for cut in (0..text.len()).filter(|&c| text.is_char_boundary(c)) {
            let doc = &text[..cut];
            let mut r = stream::Reader::new(doc.as_bytes());
            let skipped = r.skip_value().and_then(|()| r.next().map(|_| ()));
            assert_eq!(
                skipped.is_err(),
                parse(doc).is_err(),
                "skip_value strictness drifted on {doc:?}"
            );
        }
    }
}

#[test]
fn lazy_extraction_agrees_with_the_tree_on_random_objects() {
    let mut rng = Rng::new(avsm::testkit::seed_from_env(0x1A2_EE));
    for _ in 0..CASES {
        let map: BTreeMap<String, Value> = (0..rng.range(1, 6))
            .map(|i| (format!("{}_{i}", gen_string(&mut rng)), gen_value(&mut rng, 4)))
            .collect();
        let doc = Value::Object(map.clone());
        let text = doc.to_string_compact();
        for (key, want) in &map {
            let raw = stream::path_raw(text.as_bytes(), &[key.as_str()])
                .unwrap()
                .unwrap_or_else(|| panic!("field {key:?} not found in {text}"));
            let got = parse(std::str::from_utf8(raw).unwrap()).unwrap();
            assert_eq!(&got, want, "lazy extraction of {key:?} disagrees with the tree");
        }
    }
}
