//! Property-based tests over randomly generated networks and system
//! configurations (DESIGN.md §10), drawn from the shared seeded generator
//! [`avsm::testkit::NetGen`] in place of the unavailable proptest crate.
//! Every test that needs random nets/configs/retimes pulls them from one
//! `NetGen` — the distribution is defined once, a failing seed reproduces
//! everywhere, and `AVSM_TEST_SEED` pins the whole file for deterministic
//! CI smoke runs (`scripts/check.sh`).
//!
//! Invariants checked per random case:
//! * the compiler's MAC/byte accounting is exact vs the graph IR;
//! * OFM bytes are stored exactly once per layer;
//! * the task graph is a DAG whose simulation completes all tasks;
//! * makespan lies between the critical-path lower bound and the serial
//!   upper bound (+ HKP dispatch overhead);
//! * every member of the latency lower-bound family (occupancy,
//!   critical-path, max) is admissible — `LB <= simulated` — across
//!   hundreds of seeded cases and clock retimes, with
//!   `LB_max >= LB_occupancy` everywhere;
//! * campaign pruning under the max bound is lossless: pruned frontiers
//!   are byte-identical to unpruned `dse::pareto(dse::sweep(..))` at 1
//!   and N worker threads;
//! * layer windows partition the run; busy time never exceeds the window;
//! * simulation is deterministic;
//! * task-graph and DNN-graph JSON round-trip losslessly;
//! * injected cache I/O faults (error/torn reads and writes) cost at most
//!   recompiles — campaign results are byte-identical to the clean run;
//! * a journaled campaign crash-truncated at ANY byte boundary resumes to
//!   the byte-identical report (cache statistics excluded);
//! * telemetry recording never changes campaign results — frontiers are
//!   byte-identical with the obs recorder on vs. off at 1 and N threads,
//!   and the full `avsm-campaign-v1` report JSON byte-identical
//!   single-threaded — while the recorded spans account for every unit
//!   (`resolve == evaluated`, `simulate + skipped == evaluated` on
//!   all-feasible grids);
//! * an injected `sim.evaluate` panic is contained to its unit, classified
//!   with the failpoint diagnostic, and visible as a `simulate` span with
//!   outcome `panicked`;
//! * the static lint pre-flight is observation-only: campaign results are
//!   byte-identical with it on vs. off at 1 and N threads, and classified
//!   sweep outcomes identical even on a net the pre-flight rejects;
//! * lint never lies, across hundreds of seeded (net, config) units with
//!   deterministic corruptions: a lint-clean unit is never a runtime
//!   `Error`, validity lint errors are exactly runtime `Error` units, and
//!   an AVSM022-only unit is exactly a runtime `Infeasible`;
//! * every on-disk corruption a torn `store.write` fault leaves behind is
//!   surfaced by `avsm lint --cache-dir` with a distinct code (AVSM040
//!   artifacts / AVSM048 negatives), while fault kinds that leave the
//!   store consistent fsck clean;
//! * a `--resume` against a journal from a different spec refuses with a
//!   diagnostic naming exactly which spec parts differ.

use avsm::campaign::{self, CampaignOptions, CampaignSpec, StreamingFrontier};
use avsm::compiler::{
    compile, critical_path_lower_bound, latency_lower_bound, occupancy_lower_bound,
    BoundKind, CompileOptions,
};
use avsm::config::SystemConfig;
use avsm::dse::{self, DesignPoint};
use avsm::graph::{graph_from_json, graph_to_json, DnnGraph};
use avsm::hw::{simulate_avsm, AvsmTiming, TimingModel};
use avsm::sim::{ClockDomain, TraceRecorder};
use avsm::taskgraph::{serialize, TaskKind};
use avsm::testkit::{NetGen, Rng};

fn duration_model(sys: &SystemConfig) -> impl FnMut(&avsm::taskgraph::Task) -> u64 {
    let mut t = AvsmTiming::new(sys);
    move |task: &avsm::taskgraph::Task| match task.kind {
        TaskKind::Compute { .. } => t.compute_ps(&task.kind),
        TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
            t.dma_pre_ps(&task.kind) + t.dma_bus_ps(&task.kind, task.kind.bytes(), 0)
        }
        TaskKind::Barrier => 0,
    }
}

#[test]
fn compiled_accounting_matches_graph_ir() {
    let mut gen = NetGen::from_env(0xA11CE);
    for case in 0..40 {
        let net = gen.net();
        let sys = gen.sys();
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue; // tiny buffers can be infeasible for a random net: fine
        };
        compiled.graph.validate().unwrap();
        // MACs exact.
        let macs: u64 = compiled.layers.iter().map(|l| l.macs).sum();
        assert_eq!(macs, net.total_macs(), "case {case} net {}", net.name);
        // OFM stored exactly once per layer.
        let shapes = net.layer_shapes();
        for (li, shape) in shapes.iter().enumerate() {
            let stored: u64 = compiled
                .graph
                .tasks()
                .iter()
                .filter(|t| t.layer == li as u32)
                .map(|t| match t.kind {
                    TaskKind::DmaStore { bytes } => bytes,
                    _ => 0,
                })
                .sum();
            assert_eq!(
                stored,
                shape.bytes(net.dtype_bytes),
                "case {case} layer {li} of {}",
                net.name
            );
        }
    }
}

#[test]
fn makespan_bounds_hold_for_random_cases() {
    let mut gen = NetGen::from_env(0xBEEF);
    let mut checked = 0;
    for _ in 0..30 {
        let net = gen.net();
        let sys = gen.sys();
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&compiled, &sys, &mut tr);
        assert_eq!(sim.tasks, compiled.graph.len() as u64, "all tasks must finish");

        let cp = compiled.graph.critical_path(duration_model(&sys));
        let serial = compiled.graph.serial_sum(duration_model(&sys));
        let hkp = ClockDomain::from_mhz(sys.hkp.freq_mhz)
            .cycles_to_ps(sys.hkp.dispatch_cycles)
            * compiled.graph.len() as u64;
        assert!(
            sim.total_ps >= cp,
            "{}: makespan {} < critical path {cp}",
            net.name,
            sim.total_ps
        );
        assert!(
            sim.total_ps <= serial + hkp,
            "{}: makespan {} > serial bound {}",
            net.name,
            sim.total_ps,
            serial + hkp
        );
        checked += 1;
    }
    assert!(checked >= 20, "too few feasible random cases ({checked})");
}

#[test]
fn layer_windows_partition_and_bound_busy_time() {
    let mut gen = NetGen::from_env(0xC0FFEE);
    for _ in 0..25 {
        let net = gen.net();
        let sys = gen.sys();
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&compiled, &sys, &mut tr);
        let mut prev = 0;
        for l in &sim.layers {
            assert_eq!(l.start_ps, prev, "{}: windows must be contiguous", net.name);
            assert!(l.end_ps >= l.start_ps);
            assert!(l.nce_busy_ps <= l.duration_ps());
            assert!(l.bus_busy_ps <= l.duration_ps());
            prev = l.end_ps;
        }
        assert_eq!(prev, sim.total_ps);
    }
}

#[test]
fn simulation_is_deterministic_for_random_cases() {
    let mut gen = NetGen::from_env(0xD00D);
    for _ in 0..15 {
        let net = gen.net();
        let sys = gen.sys();
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let run = || {
            let mut tr = TraceRecorder::disabled();
            simulate_avsm(&compiled, &sys, &mut tr)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.total_ps, b.total_ps);
        assert_eq!(a.events, b.events);
    }
}

#[test]
fn double_buffering_never_hurts() {
    let mut gen = NetGen::from_env(0x5EED);
    for _ in 0..20 {
        let net = gen.net();
        let sys = gen.sys();
        let db = compile(&net, &sys, CompileOptions { double_buffer: true, labels: false });
        let sb = compile(&net, &sys, CompileOptions { double_buffer: false, labels: false });
        let (Ok(db), Ok(sb)) = (db, sb) else { continue };
        let mut tr = TraceRecorder::disabled();
        let t_db = simulate_avsm(&db, &sys, &mut tr).total_ps;
        let mut tr = TraceRecorder::disabled();
        let t_sb = simulate_avsm(&sb, &sys, &mut tr).total_ps;
        assert!(
            t_db <= t_sb,
            "{}: double buffering slowed the net ({t_db} vs {t_sb})",
            net.name
        );
    }
}

#[test]
fn lower_bound_family_is_admissible_across_hundreds_of_seeds() {
    // The bound-and-prune soundness contract, differential form: for every
    // (net, config, retime) the simulator is the reference and every
    // member of the lower-bound family must stay at or below it —
    // otherwise campaign pruning could drop genuine frontier members.
    // Alongside admissibility: LB_max >= LB_occupancy everywhere (the max
    // bound can only tighten), and LB_max == max(LB_occ, LB_cp).
    //
    // >= 200 generated cases (mixed general CNNs and adversarial deep
    // chains), 3 clock annotations each — every retime legally reuses the
    // one compiled artifact, exactly as a campaign does.
    let mut gen = NetGen::from_env(0x10B0);
    let mut checked = 0;
    let mut attempts = 0;
    while checked < 200 {
        attempts += 1;
        assert!(
            attempts <= 500,
            "too few feasible random cases ({checked} after {attempts} attempts)"
        );
        // Every 4th case is a deep chain — the region where the
        // critical-path half dominates and occupancy is loose.
        let net = if attempts % 4 == 0 { gen.chain_net() } else { gen.net() };
        let sys = gen.sys();
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let retimes = [sys.clone(), gen.retime(&sys), gen.retime(&sys)];
        for retimed in &retimes {
            let occ = occupancy_lower_bound(&compiled, retimed);
            let cp = critical_path_lower_bound(&compiled, retimed);
            let max = latency_lower_bound(&compiled, retimed);
            assert_eq!(
                max,
                occ.max(cp),
                "case {checked} ({}): max bound must be the pointwise max",
                net.name
            );
            assert!(max >= occ, "case {checked} ({}): LB_max < LB_occupancy", net.name);
            let mut tr = TraceRecorder::disabled();
            let sim = simulate_avsm(&compiled, retimed, &mut tr);
            for (tag, lb) in [("occupancy", occ), ("critical-path", cp), ("max", max)] {
                assert!(
                    lb <= sim.total_ps,
                    "case {checked} ({} @ {} MHz): {tag} bound {lb} > simulated {}",
                    net.name,
                    retimed.nce.freq_mhz,
                    sim.total_ps
                );
            }
            assert!(max > 0, "case {checked}: bound must be non-trivial");
        }
        checked += 1;
    }
}

#[test]
fn max_bound_pruned_campaigns_match_unpruned_batch_sweeps_at_1_and_n_threads() {
    // Lossless-pruning, differential form: for random portfolios over
    // random grids, a campaign pruned with the (tightest) max bound must
    // produce per-net frontiers byte-identical to the unpruned batch
    // reference `dse::pareto(dse::sweep(..))` — sequentially and under
    // parallel workers, where skip sets may differ run to run but the
    // frontier may not.
    let mut gen = NetGen::from_env(0xF407);
    for case in 0..5 {
        // A general net plus, on odd cases, a deep chain — the shape the
        // critical-path half of the bound actually prunes.
        let nets = if case % 2 == 1 {
            vec![gen.net(), gen.chain_net()]
        } else {
            vec![gen.net(), gen.net()]
        };
        let mut freqs = vec![1000u64, 500, 250, 125, 50];
        // Random rotation varies which frequency is enumerated first (and
        // thus the arrival order pruning races against).
        let rot = gen.rng().range(0, freqs.len() as u64 - 1) as usize;
        freqs.rotate_left(rot);
        let axes = dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(freqs);
        let spec = CampaignSpec::homogeneous(nets, SystemConfig::base_paper(), axes);
        for threads in [1usize, 0] {
            let pruned = campaign::run(
                &spec,
                &CampaignOptions { threads, bound: BoundKind::Max, ..Default::default() },
            )
            .unwrap();
            let unpruned = campaign::run(
                &spec,
                &CampaignOptions { threads, prune: false, ..Default::default() },
            )
            .unwrap();
            assert_eq!(unpruned.skipped_by_bound, 0);
            for (ni, w) in spec.workloads.iter().enumerate() {
                let batch = dse::pareto(&dse::sweep(&w.net, &spec.base, &spec.axes));
                for (tag, result) in [("pruned", &pruned), ("unpruned", &unpruned)] {
                    let got = &result.nets[ni];
                    assert_eq!(
                        got.frontier.len(),
                        batch.len(),
                        "case {case} {tag}/{threads}t: {}",
                        w.net.name
                    );
                    for (a, b) in got.frontier.iter().zip(&batch) {
                        assert_eq!(a.name, b.name, "case {case} {tag}/{threads}t");
                        assert_eq!(
                            a.latency_ps, b.latency_ps,
                            "case {case} {tag}/{threads}t: {}",
                            a.name
                        );
                        assert_eq!(
                            a.cost.to_bits(),
                            b.cost.to_bits(),
                            "case {case} {tag}/{threads}t"
                        );
                        assert_eq!(a.sys, b.sys, "case {case} {tag}/{threads}t");
                    }
                    assert_eq!(
                        got.evaluated,
                        got.feasible
                            + got.infeasible
                            + got.errors
                            + got.panics
                            + got.skipped_by_bound,
                        "case {case} {tag}/{threads}t: {}",
                        w.net.name
                    );
                    assert_eq!(
                        got.skipped_by_bound,
                        got.skipped_by_occupancy + got.skipped_by_critical_path,
                        "case {case} {tag}/{threads}t"
                    );
                }
            }
        }
    }
}

#[test]
fn frontier_admits_is_consistent_with_insertion() {
    // If `admits(lb, cost)` refuses, then *no* point with latency >= lb at
    // that cost may ever join the frontier — across later insertions too.
    let mut rng = Rng::new(0xADA117);
    let sys = SystemConfig::base_paper();
    let pt = |lat: u64, cost: f64, i: usize| DesignPoint {
        name: format!("p{i}"),
        sys: sys.clone(),
        latency_ps: lat,
        cost,
        throughput: 0.0,
    };
    for case in 0..40 {
        let mut frontier = StreamingFrontier::new();
        let n = rng.range(1, 30) as usize;
        for i in 0..n {
            frontier.insert_with_seq(pt(rng.range(1, 20), rng.range(1, 12) as f64, i), i);
        }
        for probe in 0..30 {
            let lb = rng.range(1, 20);
            let cost = rng.range(1, 12) as f64;
            if !frontier.admits(lb, cost) {
                // The tightest realizable candidate (latency == lb) must be
                // rejected as dominated, leaving the frontier untouched.
                let before: Vec<u64> =
                    frontier.points().map(|p| p.latency_ps).collect();
                assert!(
                    !frontier.insert_with_seq(pt(lb, cost, n + probe), n + probe),
                    "case {case}: refused candidate ({lb}, {cost}) joined"
                );
                let after: Vec<u64> = frontier.points().map(|p| p.latency_ps).collect();
                assert_eq!(before, after, "case {case}: refusal mutated the frontier");
            }
        }
    }
}

#[test]
fn streaming_frontier_equals_batch_pareto_on_random_point_sets() {
    // The campaign's online frontier must reproduce `dse::pareto` exactly
    // — same members, same duplicate handling, same tie order — for any
    // point set and ANY arrival order, as long as each point carries its
    // stable enumeration index as the sequence number.
    let mut rng = Rng::new(0xF407);
    let sys = SystemConfig::base_paper();
    for case in 0..60 {
        let n = rng.range(0, 50) as usize;
        // Small value ranges force heavy tie/duplicate traffic — the cases
        // where tie order and duplicate retention can diverge.
        let points: Vec<DesignPoint> = (0..n)
            .map(|i| DesignPoint {
                name: format!("p{i}"),
                sys: sys.clone(),
                latency_ps: rng.range(1, 15),
                cost: rng.range(1, 10) as f64,
                throughput: 0.0,
            })
            .collect();
        // Random arrival order (Fisher-Yates on the index vector).
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            order.swap(i, j);
        }
        let mut frontier = StreamingFrontier::new();
        for &i in &order {
            frontier.insert_with_seq(points[i].clone(), i);
        }
        assert_eq!(frontier.inserted(), n, "case {case}");
        assert_eq!(
            frontier.len() + frontier.dominated() + frontier.pruned(),
            n,
            "case {case}: accounting must cover every insertion"
        );
        let stream = frontier.into_points();
        let batch = dse::pareto(&points);
        assert_eq!(stream.len(), batch.len(), "case {case}: frontier size");
        for (s, b) in stream.iter().zip(&batch) {
            assert_eq!(s.name, b.name, "case {case}: member/tie-order mismatch");
            assert_eq!(s.latency_ps, b.latency_ps, "case {case}");
            assert_eq!(s.cost.to_bits(), b.cost.to_bits(), "case {case}");
        }
    }
}

/// The historical `topdown_min_nce_freq` implementation, preserved
/// verbatim as the oracle: hand-rolled over the NCE-frequency field, one
/// shared compile cache, probe `hi`, probe `lo`, bisect.
fn topdown_oracle(
    net: &DnnGraph,
    base: &SystemConfig,
    target_latency_ps: u64,
    freq_range_mhz: (u64, u64),
) -> anyhow::Result<Option<u64>> {
    let (mut lo, mut hi) = freq_range_mhz;
    if lo == 0 || lo > hi {
        anyhow::bail!("topdown frequency range must satisfy 0 < lo <= hi");
    }
    let cache = avsm::compiler::CompileCache::new(dse::DSE_COMPILE_OPTS);
    let latency_at = |mhz: u64| -> anyhow::Result<u64> {
        let mut sys = base.clone();
        sys.nce.freq_mhz = mhz;
        Ok(dse::evaluate_cached(net, &sys, "probe", &cache)?.latency_ps)
    };
    if latency_at(hi)? > target_latency_ps {
        return Ok(None);
    }
    if latency_at(lo)? <= target_latency_ps {
        return Ok(Some(lo));
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if latency_at(mid)? <= target_latency_ps {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok(Some(hi))
}

#[test]
fn solve_requirement_reproduces_historical_topdown_exactly() {
    // The generic solver on the NCE-frequency axis must be byte-identical
    // to the old hand-rolled binary search across random nets, configs,
    // targets and ranges — answers, unreachability, and the rejection of
    // degenerate ranges alike — while compiling exactly once (the axis is
    // retime-only).
    let mut gen = NetGen::from_env(0x70BD0);
    let mut compared = 0;
    for case in 0..12 {
        let net = gen.net();
        let base = gen.sys();
        let Ok(baseline) =
            dse::evaluate(&net, &base, "b").map(|p| p.latency_ps)
        else {
            continue; // infeasible tiling for this random pair: fine
        };
        let rng = gen.rng();
        let targets = [1, baseline, baseline + baseline / 2];
        let ranges = [
            (rng.range(1, 400), rng.range(401, 2000)),
            (rng.range(50, 250), rng.range(250, 600)),
            (250, 250),            // degenerate single-point range
            (0, 1000),             // rejected: zero lo
            (rng.range(500, 900), rng.range(1, 400)), // rejected: inverted
        ];
        for &target in &targets {
            for &range in &ranges {
                let oracle = topdown_oracle(&net, &base, target, range);
                let solver =
                    dse::solve_requirement(&net, &base, dse::Axis::NceFreqMhz, target, range);
                match (&oracle, &solver) {
                    (Err(_), Err(_)) => {} // both reject the degenerate range
                    (Ok(expect), Ok(sol)) => {
                        assert_eq!(
                            sol.value, *expect,
                            "case {case} {} target {target} range {range:?}",
                            net.name
                        );
                        assert_eq!(
                            sol.compiles, 1,
                            "case {case}: NCE frequency is retime-only"
                        );
                        compared += 1;
                    }
                    (o, s) => panic!(
                        "case {case} {} target {target} range {range:?}: \
                         oracle {o:?} vs solver {s:?} disagree on rejection",
                        net.name
                    ),
                }
            }
        }
        // The public wrapper is the same code path: spot-check it once per
        // case against the oracle.
        let range = (50, 1000);
        assert_eq!(
            dse::topdown_min_nce_freq(&net, &base, baseline, range).unwrap(),
            topdown_oracle(&net, &base, baseline, range).unwrap(),
            "case {case} wrapper"
        );
    }
    assert!(compared >= 40, "too few comparable random cases ({compared})");
}

/// Two campaign results must agree on every report-visible field; cache
/// statistics (compiles / hit counters) are excluded — they legitimately
/// differ when a fault forces a recompile or a resume skips one.
fn assert_same_outcomes(a: &campaign::CampaignResult, b: &campaign::CampaignResult, tag: &str) {
    assert_eq!(a.grid_points, b.grid_points, "{tag}: grid_points");
    assert_eq!(a.skipped_by_bound, b.skipped_by_bound, "{tag}: skipped_by_bound");
    assert_eq!(a.errors, b.errors, "{tag}: errors");
    assert_eq!(a.panics, b.panics, "{tag}: panics");
    assert_eq!(a.nets.len(), b.nets.len(), "{tag}: net count");
    for (x, y) in a.nets.iter().zip(&b.nets) {
        let net = &x.net;
        assert_eq!(x.evaluated, y.evaluated, "{tag} {net}: evaluated");
        assert_eq!(x.feasible, y.feasible, "{tag} {net}: feasible");
        assert_eq!(x.infeasible, y.infeasible, "{tag} {net}: infeasible");
        assert_eq!(x.errors, y.errors, "{tag} {net}: errors");
        assert_eq!(x.error_sample, y.error_sample, "{tag} {net}: error_sample");
        assert_eq!(x.panics, y.panics, "{tag} {net}: panics");
        assert_eq!(x.panic_sample, y.panic_sample, "{tag} {net}: panic_sample");
        assert_eq!(x.skipped_by_bound, y.skipped_by_bound, "{tag} {net}: skipped");
        assert_eq!(x.skipped_by_occupancy, y.skipped_by_occupancy, "{tag} {net}: skip/occ");
        assert_eq!(
            x.skipped_by_critical_path, y.skipped_by_critical_path,
            "{tag} {net}: skip/cp"
        );
        assert_eq!(x.dominated, y.dominated, "{tag} {net}: dominated");
        assert_eq!(x.pruned, y.pruned, "{tag} {net}: pruned");
        assert_eq!(x.frontier.len(), y.frontier.len(), "{tag} {net}: frontier size");
        for (p, q) in x.frontier.iter().zip(&y.frontier) {
            assert_eq!(p.name, q.name, "{tag} {net}: frontier member");
            assert_eq!(p.latency_ps, q.latency_ps, "{tag} {net} {}: latency", p.name);
            assert_eq!(p.cost.to_bits(), q.cost.to_bits(), "{tag} {net} {}: cost", p.name);
            assert_eq!(
                p.throughput.to_bits(),
                q.throughput.to_bits(),
                "{tag} {net} {}: throughput",
                p.name
            );
            assert_eq!(p.sys, q.sys, "{tag} {net} {}: sys", p.name);
        }
    }
}

#[test]
fn injected_cache_faults_never_change_campaign_results() {
    // Fault-injection property: persistent-cache I/O faults — failed
    // reads, failed writes, torn writes on either side — may cost
    // recompiles (counted in the cache statistics) but must NEVER change
    // what a campaign reports. Differential form across seeded random
    // portfolios × fault site × fault kind × arrival count.
    use avsm::testkit::faults::{self, FaultKind};
    let mut gen = NetGen::from_env(0xFA017);
    let root = std::env::temp_dir().join(format!("avsm_prop_faults_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for case in 0..3 {
        let nets = vec![gen.net()];
        let axes = dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![500, 125]);
        let spec = CampaignSpec::homogeneous(nets, SystemConfig::base_paper(), axes);
        let opts = |dir: std::path::PathBuf| CampaignOptions {
            threads: 1,
            bound: BoundKind::Max,
            cache_dir: Some(dir),
            ..Default::default()
        };
        let clean = campaign::run(&spec, &opts(root.join(format!("clean{case}")))).unwrap();
        for (site, kind, label) in [
            ("store.read", FaultKind::IoError, "read-err"),
            ("store.read", FaultKind::Torn, "read-torn"),
            ("store.write", FaultKind::IoError, "write-err"),
            ("store.write", FaultKind::Torn, "write-torn"),
        ] {
            for hits in [1usize, 2, usize::MAX] {
                let dir = root.join(format!("{label}_{case}_{hits}"));
                if site == "store.read" {
                    // Warm the cache first so read-side faults have files
                    // to fail on, then re-run the same campaign under
                    // fault: every failed read degrades to a recompile.
                    campaign::run(&spec, &opts(dir.clone())).unwrap();
                }
                // Write-side faults fire on the cold first run instead,
                // while entries are being persisted.
                let tag = format!("case {case} {label} hits {hits}");
                let faulted = {
                    let _g = faults::arm(site, &dir, kind, hits);
                    campaign::run(&spec, &opts(dir.clone())).unwrap()
                };
                assert_same_outcomes(&clean, &faulted, &tag);
                // A fault-free run over whatever the faulted run left on
                // disk (missing entries, torn corpses) must reject/heal
                // and still agree.
                let after = campaign::run(&spec, &opts(dir)).unwrap();
                assert_same_outcomes(&clean, &after, &format!("{tag} (after)"));
            }
        }
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn resume_from_any_crash_point_reproduces_the_uninterrupted_campaign() {
    // Crash-model property: a journaled campaign killed at ANY byte of the
    // journal — every prefix length is some SIGKILL instant — must resume
    // to the byte-identical report: same frontier bits, same counts, same
    // skip attribution, with cache statistics the only fields allowed to
    // differ. >= 100 crash points per random net.
    let mut gen = NetGen::from_env(0x10AD);
    let root = std::env::temp_dir().join(format!("avsm_prop_resume_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let mut crash_points = 0usize;
    for case in 0..2 {
        let nets = vec![gen.net()];
        let axes = dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![500, 250, 125, 50]);
        let spec = CampaignSpec::homogeneous(nets, SystemConfig::base_paper(), axes);
        let journal = root.join(format!("case{case}.jsonl"));
        let opts = |resume: bool| CampaignOptions {
            threads: 1,
            bound: BoundKind::Max,
            cache_dir: Some(root.join("cache")),
            journal: Some(journal.clone()),
            resume,
            ..Default::default()
        };
        let clean = campaign::run(&spec, &opts(false)).unwrap();
        let full = std::fs::read(&journal).unwrap();
        let lines = full.iter().filter(|&&b| b == b'\n').count();
        assert_eq!(lines, clean.grid_points + 1, "case {case}: header + one line per unit");
        for cut in 0..=full.len() {
            std::fs::write(&journal, &full[..cut]).unwrap();
            let resumed = campaign::run(&spec, &opts(true)).unwrap();
            assert_same_outcomes(&clean, &resumed, &format!("case {case} cut {cut}"));
            crash_points += 1;
        }
        // After a full-journal resume the file replays every unit again:
        // nothing re-simulates, nothing re-compiles.
        std::fs::write(&journal, &full).unwrap();
        let resumed = campaign::run(&spec, &opts(true)).unwrap();
        assert_eq!(resumed.compiles, 0, "case {case}: full journal must replay everything");
        assert_same_outcomes(&clean, &resumed, &format!("case {case} full"));
    }
    assert!(crash_points >= 100, "crash grid too small ({crash_points} points)");
    std::fs::remove_dir_all(&root).unwrap();
}

/// Order-insensitive projection of an [`dse::EvalOutcome`] for equality
/// checks (the enum deliberately does not implement `PartialEq` — costs
/// are compared by bits here, as everywhere in this file).
fn outcome_key(o: &dse::EvalOutcome) -> (u8, String, u64, u64, String) {
    match o {
        dse::EvalOutcome::Feasible(p) => {
            (0, p.name.clone(), p.latency_ps, p.cost.to_bits(), String::new())
        }
        dse::EvalOutcome::Infeasible { name, reason } => {
            (1, name.clone(), 0, 0, reason.clone())
        }
        dse::EvalOutcome::Error { name, reason } => (2, name.clone(), 0, 0, reason.clone()),
    }
}

#[test]
fn preflight_lint_is_observation_only() {
    // Tentpole contract, half one: the static pre-flight at the top of
    // `campaign::run` and `dse::sweep_outcomes` observes and never steers.
    // A clean-lint campaign produces byte-identical results with the
    // pre-flight on vs. off, sequentially and under parallel workers; on
    // the sweep surface the classified outcomes are identical even for a
    // net the pre-flight rejects (the short-circuit must fabricate
    // exactly the rows evaluation would have produced).
    use avsm::analysis::{passes, Severity};
    let mut gen = NetGen::from_env(0x11A7E);
    for case in 0..3 {
        let nets = vec![gen.net(), gen.chain_net()];
        let axes = dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![500, 125]);
        let spec =
            CampaignSpec::homogeneous(nets, SystemConfig::base_paper(), axes.clone());
        for w in &spec.workloads {
            assert!(
                passes::lint_net(&w.net).iter().all(|d| d.severity != Severity::Error),
                "case {case}: generated nets must lint clean"
            );
        }
        for threads in [1usize, 0] {
            let tag = format!("case {case}, {threads} threads");
            let on =
                campaign::run(&spec, &CampaignOptions { threads, ..Default::default() })
                    .unwrap();
            let off = campaign::run(
                &spec,
                &CampaignOptions { threads, preflight: false, ..Default::default() },
            )
            .unwrap();
            assert_same_outcomes(&on, &off, &tag);
        }
        let mut rejected = gen.net();
        rejected.dtype_bytes = 0; // fails the pre-flight AND net.validate()
        for net in [&spec.workloads[0].net, &rejected] {
            let run = |no_preflight: bool| {
                dse::sweep_outcomes(
                    net,
                    &spec.base,
                    &axes,
                    &dse::SweepOptions { threads: 1, no_preflight },
                )
            };
            let (with, without) = (run(false), run(true));
            assert_eq!(with.len(), without.len(), "case {case} {}", net.name);
            for (a, b) in with.iter().zip(&without) {
                assert_eq!(
                    outcome_key(a),
                    outcome_key(b),
                    "case {case} {}: pre-flight changed a sweep outcome",
                    net.name
                );
            }
        }
    }
}

#[test]
fn lint_never_lies_across_hundreds_of_seeded_units() {
    // Tentpole contract, half two, differential form over >= 200 seeded
    // (net, config) units with deterministic corruptions on a rotating
    // schedule: validity lint Errors (AVSM001-016) are exactly the units
    // the runtime classifier reports `Error`; an AVSM022-only unit is
    // exactly a runtime `Infeasible`; a lint-clean unit is never a
    // runtime `Error`.
    use avsm::analysis::{passes, Severity};
    use avsm::compiler::CompileCache;
    use avsm::graph::models;
    let mut gen = NetGen::from_env(0xD81F7);
    let (mut clean_units, mut validity_errors, mut tiling_errors) = (0usize, 0usize, 0usize);
    for case in 0..220usize {
        let mut net = if case % 5 == 0 { gen.chain_net() } else { gen.net() };
        let mut sys = gen.sys();
        match case % 8 {
            1 => net.dtype_bytes = 0,
            2 => {
                let last = net.layers.len() - 1;
                net.layers[last].skip_from = Some(last);
            }
            3 => sys.nce.freq_mhz = 0,
            4 => sys.memory.avsm_eff_bw_pct = 0,
            5 => {
                sys.nce.ifm_buffer_kib = 1;
                sys.nce.weight_buffer_kib = 1;
                sys.nce.ofm_buffer_kib = 1;
            }
            6 => {
                let dup = net.layers[0].clone();
                net.layers.push(dup);
            }
            _ => {}
        }
        let errors: Vec<&str> = passes::lint_unit(&net, &sys)
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.code)
            .collect();
        let cache = CompileCache::new(dse::DSE_COMPILE_OPTS);
        let outcome = dse::evaluate_outcome(&net, &sys, "unit", &cache);
        let tag = format!("case {case} ({}): lint {errors:?}", net.name);
        if errors.is_empty() {
            clean_units += 1;
            assert!(
                !matches!(outcome, dse::EvalOutcome::Error { .. }),
                "{tag} was clean but evaluated to {outcome:?}"
            );
        } else if errors.iter().all(|&c| c == "AVSM022") {
            tiling_errors += 1;
            assert!(
                matches!(outcome, dse::EvalOutcome::Infeasible { .. }),
                "{tag} predicted infeasible, got {outcome:?}"
            );
        } else {
            validity_errors += 1;
            assert!(
                matches!(outcome, dse::EvalOutcome::Error { .. }),
                "{tag} predicted an error unit, got {outcome:?}"
            );
        }
    }
    assert!(clean_units >= 20, "too few clean random units ({clean_units})");
    assert!(validity_errors >= 100, "too few corrupted units ({validity_errors})");
    // The rotating schedule cannot guarantee an AVSM022 case (random nets
    // can fit 1 KiB buffers), so pin the known statically-infeasible pair.
    let net = models::dilated_vgg(512, 4, 16);
    let mut tiny = SystemConfig::base_paper();
    tiny.nce.ifm_buffer_kib = 1;
    tiny.nce.weight_buffer_kib = 1;
    tiny.nce.ofm_buffer_kib = 1;
    let diags = passes::lint_unit(&net, &tiny);
    assert!(
        diags.iter().any(|d| d.code == "AVSM022")
            && diags
                .iter()
                .all(|d| d.severity != Severity::Error || d.code == "AVSM022"),
        "pinned pair must lint AVSM022-only: {diags:?}"
    );
    let cache = CompileCache::new(dse::DSE_COMPILE_OPTS);
    assert!(
        matches!(
            dse::evaluate_outcome(&net, &tiny, "pinned", &cache),
            dse::EvalOutcome::Infeasible { .. }
        ),
        "pinned AVSM022 pair must be runtime-infeasible"
    );
    let _ = tiling_errors; // counted for the curious; coverage is pinned above
}

#[test]
fn fsck_surfaces_every_torn_store_write_with_a_distinct_code() {
    // Fault-harness coverage: the corruptions `testkit::faults` can leave
    // in a cache directory are exactly the ones `avsm lint --cache-dir`
    // must surface. Torn writes leave truncated corpses at the final
    // artifact/negative paths — fsck reports each with its own code
    // (AVSM040 vs AVSM048). IoError writes and any read-side fault leave
    // the store consistent, so fsck must stay quiet about them: a lint
    // error there would be a false positive.
    use avsm::analysis::{fsck, Severity};
    use avsm::graph::models;
    use avsm::testkit::faults::{self, FaultKind};
    let root = std::env::temp_dir().join(format!("avsm_prop_fsck_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    // One feasible unit (persists an artifact) and one statically
    // infeasible unit (persists a negative record) per run.
    let mut tiny = SystemConfig::base_paper();
    tiny.nce.ifm_buffer_kib = 1;
    tiny.nce.weight_buffer_kib = 1;
    tiny.nce.ofm_buffer_kib = 1;
    let spec = CampaignSpec {
        workloads: vec![
            campaign::WorkloadSpec::new(models::lenet(28)),
            campaign::WorkloadSpec::new(models::dilated_vgg(512, 4, 16)).with_base(tiny),
        ],
        base: SystemConfig::base_paper(),
        axes: dse::SweepAxes::new().nce_freqs_mhz(vec![250]),
    };
    let opts = |dir: std::path::PathBuf| CampaignOptions {
        threads: 1,
        cache_dir: Some(dir),
        ..Default::default()
    };
    let errors = |diags: &[avsm::analysis::Diagnostic]| -> Vec<&'static str> {
        let mut codes: Vec<_> = diags
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .map(|d| d.code)
            .collect();
        codes.sort_unstable();
        codes.dedup();
        codes
    };

    // Control: a clean campaign's store fscks with no errors at all.
    let clean_dir = root.join("clean");
    campaign::run(&spec, &opts(clean_dir.clone())).unwrap();
    let diags = fsck::lint_cache_dir(&clean_dir, None);
    assert!(errors(&diags).is_empty(), "clean store must fsck clean: {diags:?}");

    // Torn writes: every artifact and negative is a truncated corpse, and
    // fsck attributes each corruption class its own code.
    let torn_dir = root.join("torn");
    {
        let _g = faults::arm("store.write", &torn_dir, FaultKind::Torn, usize::MAX);
        campaign::run(&spec, &opts(torn_dir.clone())).unwrap();
    }
    let codes = errors(&fsck::lint_cache_dir(&torn_dir, None));
    assert!(codes.contains(&"AVSM040"), "torn artifact must surface as AVSM040: {codes:?}");
    assert!(codes.contains(&"AVSM048"), "torn negative must surface as AVSM048: {codes:?}");

    // IoError writes persist nothing; read faults touch nothing. Both
    // leave a store fsck finds no errors in.
    for (site, kind, label) in [
        ("store.write", FaultKind::IoError, "werr"),
        ("store.read", FaultKind::IoError, "rerr"),
        ("store.read", FaultKind::Torn, "rtorn"),
    ] {
        let dir = root.join(label);
        if site == "store.read" {
            campaign::run(&spec, &opts(dir.clone())).unwrap(); // warm first
        }
        {
            let _g = faults::arm(site, &dir, kind, usize::MAX);
            campaign::run(&spec, &opts(dir.clone())).unwrap();
        }
        let diags = fsck::lint_cache_dir(&dir, None);
        assert!(
            errors(&diags).is_empty(),
            "{label}: fault left the store consistent, fsck must not cry wolf: {diags:?}"
        );
    }
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn resume_mismatch_names_the_differing_spec_parts() {
    // Satellite contract: `--resume` against a journal from a different
    // campaign spec refuses loudly AND says which part of the spec
    // differs, through the lint diagnostic renderer.
    let mut gen = NetGen::from_env(0x9A875);
    let net = gen.net();
    let root = std::env::temp_dir().join(format!("avsm_prop_parts_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).unwrap();
    let journal = root.join("journal.jsonl");
    let spec_of = |freqs: Vec<u64>| {
        CampaignSpec::homogeneous(
            vec![net.clone()],
            SystemConfig::base_paper(),
            dse::SweepAxes::new().nce_freqs_mhz(freqs),
        )
    };
    let opts = |resume: bool| CampaignOptions {
        threads: 1,
        journal: Some(journal.clone()),
        resume,
        ..Default::default()
    };
    campaign::run(&spec_of(vec![500, 250]), &opts(false)).unwrap();
    // Same nets, same base, same unit count — only the axis values differ.
    let err = campaign::run(&spec_of(vec![500, 125]), &opts(true)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("different campaign spec"), "{msg}");
    assert!(msg.contains("the axes differ"), "{msg}");
    assert!(msg.contains("AVSM051"), "refusal must carry the lint code: {msg}");
    assert!(!msg.contains("nets differ") && !msg.contains("options differ"), "{msg}");
    // Matching spec still resumes fine (the journal replays fully).
    let resumed = campaign::run(&spec_of(vec![500, 250]), &opts(true)).unwrap();
    assert_eq!(resumed.compiles, 0, "matching spec must replay, not re-run");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn json_roundtrips_for_random_graphs() {
    let mut gen = NetGen::from_env(0xFACADE);
    for _ in 0..30 {
        let net = gen.net();
        let back = graph_from_json(&graph_to_json(&net)).unwrap();
        assert_eq!(net, back);

        let sys = gen.sys();
        if let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) {
            let tg = serialize::from_json(&serialize::to_json(&compiled.graph)).unwrap();
            assert_eq!(compiled.graph, tg);
        }
    }
}

#[test]
fn system_config_json_roundtrips_for_random_configs() {
    let mut gen = NetGen::from_env(0xCAFE);
    for _ in 0..30 {
        let sys = gen.sys();
        let back = SystemConfig::from_json(&sys.to_json()).unwrap();
        assert_eq!(sys, back);
    }
}

/// The obs recorder is process-global and tests in this binary run
/// concurrently, so the telemetry tests serialize among themselves —
/// otherwise one test's "telemetry-off" control run would execute under
/// the other's recording guard and record spans after all. (They still
/// filter snapshots by test-unique net names: spans accumulate across
/// recordings within the process.)
static OBS_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[test]
fn telemetry_recording_never_changes_campaign_results_and_accounts_every_unit() {
    // Tentpole zero-interference property: with the recorder on, a
    // campaign produces byte-identical outcomes AND byte-identical
    // `avsm-campaign-v1` report JSON to the same campaign with it off,
    // at 1 and N threads — while the spans account for every unit: one
    // `resolve` per grid point and `simulate + skipped == evaluated`.
    // The accounting identity needs an all-feasible grid, so the axes
    // are retime-only (every point shares the base structural compile
    // key and hence the base config's feasibility).
    use avsm::report::CampaignReport;
    let _obs = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut gen = NetGen::from_env(0x0B5E7);
    for case in 0..3u32 {
        let mut net = gen.net();
        if compile(&net, &SystemConfig::base_paper(), CompileOptions::default()).is_err() {
            continue; // base config can't tile this net: nothing to account
        }
        let axes = dse::SweepAxes::new().nce_freqs_mhz(vec![1000, 500, 250, 125, 50]);
        for threads in [1usize, 4] {
            // Unique name per iteration: the global snapshot may hold
            // spans from earlier iterations and other telemetry tests.
            net.name = format!("obsnet_{}_{case}_{threads}", std::process::id());
            let spec = CampaignSpec::homogeneous(
                vec![net.clone()],
                SystemConfig::base_paper(),
                axes.clone(),
            );
            let opts =
                CampaignOptions { threads, bound: BoundKind::Max, ..Default::default() };
            let off = campaign::run(&spec, &opts).unwrap();
            let (on, snap) = {
                let _rec = avsm::obs::recording();
                let on = campaign::run(&spec, &opts).unwrap();
                (on, avsm::obs::snapshot())
            };
            let tag = format!("case {case}, {threads} threads");
            // The frontier is the engine's order-independent contract:
            // byte-identical off vs. on at any thread count. The *full*
            // report is only run-to-run stable single-threaded — under
            // parallel workers the skip/dominated counters race benignly
            // (by design, see scripts/check.sh) with or without
            // telemetry — so the byte-for-byte report comparison pins
            // the 1-thread runs.
            let fr = |r: &campaign::CampaignResult| {
                r.nets[0]
                    .frontier
                    .iter()
                    .map(|p| (p.name.clone(), p.latency_ps, p.cost.to_bits()))
                    .collect::<Vec<_>>()
            };
            assert_eq!(fr(&off), fr(&on), "{tag}: recording changed the frontier");
            if threads == 1 {
                assert_same_outcomes(&off, &on, &tag);
                assert_eq!(
                    CampaignReport::new(&off).to_json().to_string_compact(),
                    CampaignReport::new(&on).to_json().to_string_compact(),
                    "{tag}: recording changed the avsm-campaign-v1 report bytes"
                );
            }

            let spans: Vec<_> = snap
                .spans
                .iter()
                .filter(|s| s.net.as_deref() == Some(net.name.as_str()))
                .collect();
            let count = |kind: &str| spans.iter().filter(|s| s.kind == kind).count();
            let n = &on.nets[0];
            assert_eq!(count("resolve"), n.evaluated, "{tag}: one resolve span per unit");
            assert_eq!(
                count("simulate") + count("skipped"),
                n.evaluated,
                "{tag}: on an all-feasible grid every unit simulates or is pruned"
            );
            assert_eq!(count("simulate"), n.feasible, "{tag}: simulate spans");
            assert_eq!(count("skipped"), n.skipped_by_bound, "{tag}: skipped spans");
            for s in &spans {
                assert!(
                    s.end_ns >= s.start_ns,
                    "{tag}: span {} runs backwards ({} > {})",
                    s.kind,
                    s.start_ns,
                    s.end_ns
                );
                assert!(
                    (s.worker as usize) <= threads,
                    "{tag}: worker id {} out of range for {threads} threads",
                    s.worker
                );
                assert_ne!(s.outcome, "panicked", "{tag}: clean run recorded a panic");
                assert!(s.unit.is_some(), "{tag}: unit-tagged span lost its sequence number");
            }
        }
    }
}

#[test]
fn injected_simulate_panic_is_contained_classified_and_visible_in_telemetry() {
    // `sim.evaluate` failpoint: a worker panics *inside*
    // `dse::evaluate_compiled`, past all the cache machinery. The engine
    // must (a) contain the panic to that unit — every other unit
    // completes, (b) classify it with the injected diagnostic, and
    // (c) expose the dead unit as a `simulate` span with outcome
    // `panicked` (the guard's unwind override, not a site annotation).
    use avsm::report::CampaignReport;
    use avsm::testkit::faults::{self, FaultKind};
    let _obs = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let mut gen = NetGen::from_env(0x51AF0);
    for (case, threads) in [(0u32, 1usize), (1, 4)] {
        let mut net = gen.net();
        if compile(&net, &SystemConfig::base_paper(), CompileOptions::default()).is_err() {
            continue;
        }
        net.name = format!("obspanic_{}_{case}_{threads}", std::process::id());
        let axes = dse::SweepAxes::new().nce_freqs_mhz(vec![1000, 500, 250]);
        let spec = CampaignSpec::homogeneous(
            vec![net.clone()],
            SystemConfig::base_paper(),
            axes,
        );
        // No pruning: every unit must reach the simulate path, so the
        // single armed hit fires deterministically.
        let opts = CampaignOptions { threads, prune: false, ..Default::default() };
        let clean = campaign::run(&spec, &opts).unwrap();

        let (faulted, snap) = {
            let _rec = avsm::obs::recording();
            let _g = faults::arm(
                "sim.evaluate",
                std::path::Path::new(&net.name),
                FaultKind::Panic,
                1,
            );
            let r = campaign::run(&spec, &opts).unwrap();
            (r, avsm::obs::snapshot())
        };
        let tag = format!("case {case}, {threads} threads");
        let n = &faulted.nets[0];
        assert_eq!(faulted.panics, 1, "{tag}: exactly the faulted unit died");
        assert_eq!(n.panics, 1, "{tag}: the panic is attributed to its net");
        assert_eq!(
            n.feasible,
            clean.nets[0].feasible - 1,
            "{tag}: every other unit completed normally"
        );
        assert_eq!(
            n.evaluated,
            n.feasible + n.infeasible + n.errors + n.panics + n.skipped_by_bound,
            "{tag}: unit accounting still adds up"
        );
        let sample = n.panic_sample.as_deref().expect("panic diagnostic retained");
        assert!(
            sample.contains("injected panic at sim.evaluate"),
            "{tag}: diagnostic should carry the failpoint site, got: {sample}"
        );
        // The report renders without tripping on the dead unit.
        let _ = CampaignReport::new(&faulted).to_json().to_string_compact();

        let sims: Vec<_> = snap
            .spans
            .iter()
            .filter(|s| s.net.as_deref() == Some(net.name.as_str()) && s.kind == "simulate")
            .collect();
        assert_eq!(
            sims.iter().filter(|s| s.outcome == "panicked").count(),
            1,
            "{tag}: the dead unit is visible as exactly one panicked simulate span"
        );
        assert_eq!(
            sims.iter().filter(|s| s.outcome == "feasible").count(),
            n.feasible,
            "{tag}: surviving units record feasible simulate spans"
        );
    }
}
