//! Property-based tests over randomly generated networks and system
//! configurations (DESIGN.md §10), using the in-tree SplitMix64 generator
//! in place of proptest.
//!
//! Invariants checked per random case:
//! * the compiler's MAC/byte accounting is exact vs the graph IR;
//! * OFM bytes are stored exactly once per layer;
//! * the task graph is a DAG whose simulation completes all tasks;
//! * makespan lies between the critical-path lower bound and the serial
//!   upper bound (+ HKP dispatch overhead);
//! * layer windows partition the run; busy time never exceeds the window;
//! * simulation is deterministic;
//! * task-graph and DNN-graph JSON round-trip losslessly.

use avsm::campaign::StreamingFrontier;
use avsm::compiler::{compile, latency_lower_bound, CompileOptions};
use avsm::config::SystemConfig;
use avsm::dse::{self, DesignPoint};
use avsm::graph::{graph_from_json, graph_to_json, Activation, DnnGraph, Layer, Op, Padding, TensorShape};
use avsm::hw::{simulate_avsm, AvsmTiming, TimingModel};
use avsm::sim::{ClockDomain, TraceRecorder};
use avsm::taskgraph::{serialize, TaskKind};
use avsm::testkit::Rng;

/// Random small CNN: 1–6 layers of conv/pool/upsample with consistent
/// channel chains.
fn random_net(rng: &mut Rng) -> DnnGraph {
    let hw = *rng.pick(&[8u32, 12, 16, 24, 32]);
    let cin = *rng.pick(&[1u32, 3, 4, 8]);
    let mut g = DnnGraph::new(
        format!("rand{}", rng.next_u64() % 1000),
        TensorShape::new(1, cin, hw, hw),
        *rng.pick(&[1u32, 2, 4]),
    );
    let n_layers = rng.range(1, 6) as usize;
    let mut c = cin;
    let mut h = hw;
    for i in 0..n_layers {
        // Keep pooling legal (h must stay >= 4). Rng::range is inclusive.
        let can_pool = h >= 8;
        let kind = rng.range(0, if can_pool { 2 } else { 1 });
        match kind {
            0 | 1 => {
                let cout = *rng.pick(&[2u32, 4, 8, 16, 24]);
                let k = *rng.pick(&[1u32, 3, 5]);
                let dilation = if k > 1 { *rng.pick(&[1u32, 2]) } else { 1 };
                g.push(Layer::new(
                    format!("conv{i}"),
                    Op::Conv2d {
                        cin: c,
                        cout,
                        kh: k,
                        kw: k,
                        stride: 1,
                        dilation,
                        padding: Padding::Same,
                        activation: if rng.bool() { Activation::Relu } else { Activation::None },
                    },
                ));
                c = cout;
            }
            2 => {
                g.push(Layer::new(format!("pool{i}"), Op::MaxPool { window: 2, stride: 2 }));
                h /= 2;
            }
            _ => unreachable!(),
        }
    }
    g.validate().expect("generator produced an invalid net");
    g
}

/// Random feasible system config around the base point.
fn random_sys(rng: &mut Rng) -> SystemConfig {
    let mut sys = SystemConfig::base_paper();
    sys.nce.array_rows = *rng.pick(&[8u32, 16, 32, 64]);
    sys.nce.array_cols = *rng.pick(&[16u32, 32, 64, 128]);
    sys.nce.freq_mhz = *rng.pick(&[100u64, 250, 500]);
    sys.nce.ifm_buffer_kib = *rng.pick(&[64u32, 256, 1536]);
    sys.nce.weight_buffer_kib = *rng.pick(&[64u32, 128, 256]);
    sys.nce.ofm_buffer_kib = *rng.pick(&[64u32, 128, 256]);
    sys.bus.bytes_per_cycle = *rng.pick(&[8u64, 16, 32, 64]);
    sys.dma.channels = rng.range_u32(1, 3);
    sys.validate().unwrap();
    sys
}

fn duration_model(sys: &SystemConfig) -> impl FnMut(&avsm::taskgraph::Task) -> u64 {
    let mut t = AvsmTiming::new(sys);
    move |task: &avsm::taskgraph::Task| match task.kind {
        TaskKind::Compute { .. } => t.compute_ps(&task.kind),
        TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
            t.dma_pre_ps(&task.kind) + t.dma_bus_ps(&task.kind, task.kind.bytes(), 0)
        }
        TaskKind::Barrier => 0,
    }
}

#[test]
fn compiled_accounting_matches_graph_ir() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..40 {
        let net = random_net(&mut rng);
        let sys = random_sys(&mut rng);
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue; // tiny buffers can be infeasible for a random net: fine
        };
        compiled.graph.validate().unwrap();
        // MACs exact.
        let macs: u64 = compiled.layers.iter().map(|l| l.macs).sum();
        assert_eq!(macs, net.total_macs(), "case {case} net {}", net.name);
        // OFM stored exactly once per layer.
        let shapes = net.layer_shapes();
        for (li, shape) in shapes.iter().enumerate() {
            let stored: u64 = compiled
                .graph
                .tasks()
                .iter()
                .filter(|t| t.layer == li as u32)
                .map(|t| match t.kind {
                    TaskKind::DmaStore { bytes } => bytes,
                    _ => 0,
                })
                .sum();
            assert_eq!(
                stored,
                shape.bytes(net.dtype_bytes),
                "case {case} layer {li} of {}",
                net.name
            );
        }
    }
}

#[test]
fn makespan_bounds_hold_for_random_cases() {
    let mut rng = Rng::new(0xBEEF);
    let mut checked = 0;
    for _ in 0..30 {
        let net = random_net(&mut rng);
        let sys = random_sys(&mut rng);
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&compiled, &sys, &mut tr);
        assert_eq!(sim.tasks, compiled.graph.len() as u64, "all tasks must finish");

        let cp = compiled.graph.critical_path(duration_model(&sys));
        let serial = compiled.graph.serial_sum(duration_model(&sys));
        let hkp = ClockDomain::from_mhz(sys.hkp.freq_mhz)
            .cycles_to_ps(sys.hkp.dispatch_cycles)
            * compiled.graph.len() as u64;
        assert!(
            sim.total_ps >= cp,
            "{}: makespan {} < critical path {cp}",
            net.name,
            sim.total_ps
        );
        assert!(
            sim.total_ps <= serial + hkp,
            "{}: makespan {} > serial bound {}",
            net.name,
            sim.total_ps,
            serial + hkp
        );
        checked += 1;
    }
    assert!(checked >= 20, "too few feasible random cases ({checked})");
}

#[test]
fn layer_windows_partition_and_bound_busy_time() {
    let mut rng = Rng::new(0xC0FFEE);
    for _ in 0..25 {
        let net = random_net(&mut rng);
        let sys = random_sys(&mut rng);
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&compiled, &sys, &mut tr);
        let mut prev = 0;
        for l in &sim.layers {
            assert_eq!(l.start_ps, prev, "{}: windows must be contiguous", net.name);
            assert!(l.end_ps >= l.start_ps);
            assert!(l.nce_busy_ps <= l.duration_ps());
            assert!(l.bus_busy_ps <= l.duration_ps());
            prev = l.end_ps;
        }
        assert_eq!(prev, sim.total_ps);
    }
}

#[test]
fn simulation_is_deterministic_for_random_cases() {
    let mut rng = Rng::new(0xD00D);
    for _ in 0..15 {
        let net = random_net(&mut rng);
        let sys = random_sys(&mut rng);
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        let run = || {
            let mut tr = TraceRecorder::disabled();
            simulate_avsm(&compiled, &sys, &mut tr)
        };
        let (a, b) = (run(), run());
        assert_eq!(a.total_ps, b.total_ps);
        assert_eq!(a.events, b.events);
    }
}

#[test]
fn double_buffering_never_hurts() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..20 {
        let net = random_net(&mut rng);
        let sys = random_sys(&mut rng);
        let db = compile(&net, &sys, CompileOptions { double_buffer: true, labels: false });
        let sb = compile(&net, &sys, CompileOptions { double_buffer: false, labels: false });
        let (Ok(db), Ok(sb)) = (db, sb) else { continue };
        let mut tr = TraceRecorder::disabled();
        let t_db = simulate_avsm(&db, &sys, &mut tr).total_ps;
        let mut tr = TraceRecorder::disabled();
        let t_sb = simulate_avsm(&sb, &sys, &mut tr).total_ps;
        assert!(
            t_db <= t_sb,
            "{}: double buffering slowed the net ({t_db} vs {t_sb})",
            net.name
        );
    }
}

#[test]
fn latency_lower_bound_is_admissible_for_random_cases() {
    // The bound-and-prune contract: for every (net, config) the analytical
    // lower bound must never exceed the simulated latency — otherwise
    // campaign pruning could drop genuine frontier members. Random nets x
    // random structural configs x random clock retimes of one compilation.
    let mut rng = Rng::new(0x10B0);
    let mut checked = 0;
    for case in 0..30 {
        let net = random_net(&mut rng);
        let sys = random_sys(&mut rng);
        let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) else {
            continue;
        };
        // The compiled artifact is clock-free: probe several frequency
        // annotations of the same compilation, as a campaign retime does.
        for mhz in [50u64, sys.nce.freq_mhz, 4 * sys.nce.freq_mhz] {
            let mut retimed = sys.clone();
            retimed.nce.freq_mhz = mhz;
            let lb = latency_lower_bound(&compiled, &retimed);
            let mut tr = TraceRecorder::disabled();
            let sim = simulate_avsm(&compiled, &retimed, &mut tr);
            assert!(
                lb <= sim.total_ps,
                "case {case} ({} @ {mhz} MHz): lower bound {lb} > simulated {}",
                net.name,
                sim.total_ps
            );
            assert!(lb > 0, "case {case}: bound must be non-trivial");
        }
        checked += 1;
    }
    assert!(checked >= 15, "too few feasible random cases ({checked})");
}

#[test]
fn frontier_admits_is_consistent_with_insertion() {
    // If `admits(lb, cost)` refuses, then *no* point with latency >= lb at
    // that cost may ever join the frontier — across later insertions too.
    let mut rng = Rng::new(0xADA117);
    let sys = SystemConfig::base_paper();
    let pt = |lat: u64, cost: f64, i: usize| DesignPoint {
        name: format!("p{i}"),
        sys: sys.clone(),
        latency_ps: lat,
        cost,
        throughput: 0.0,
    };
    for case in 0..40 {
        let mut frontier = StreamingFrontier::new();
        let n = rng.range(1, 30) as usize;
        for i in 0..n {
            frontier.insert_with_seq(pt(rng.range(1, 20), rng.range(1, 12) as f64, i), i);
        }
        for probe in 0..30 {
            let lb = rng.range(1, 20);
            let cost = rng.range(1, 12) as f64;
            if !frontier.admits(lb, cost) {
                // The tightest realizable candidate (latency == lb) must be
                // rejected as dominated, leaving the frontier untouched.
                let before: Vec<u64> =
                    frontier.points().map(|p| p.latency_ps).collect();
                assert!(
                    !frontier.insert_with_seq(pt(lb, cost, n + probe), n + probe),
                    "case {case}: refused candidate ({lb}, {cost}) joined"
                );
                let after: Vec<u64> = frontier.points().map(|p| p.latency_ps).collect();
                assert_eq!(before, after, "case {case}: refusal mutated the frontier");
            }
        }
    }
}

#[test]
fn streaming_frontier_equals_batch_pareto_on_random_point_sets() {
    // The campaign's online frontier must reproduce `dse::pareto` exactly
    // — same members, same duplicate handling, same tie order — for any
    // point set and ANY arrival order, as long as each point carries its
    // stable enumeration index as the sequence number.
    let mut rng = Rng::new(0xF407);
    let sys = SystemConfig::base_paper();
    for case in 0..60 {
        let n = rng.range(0, 50) as usize;
        // Small value ranges force heavy tie/duplicate traffic — the cases
        // where tie order and duplicate retention can diverge.
        let points: Vec<DesignPoint> = (0..n)
            .map(|i| DesignPoint {
                name: format!("p{i}"),
                sys: sys.clone(),
                latency_ps: rng.range(1, 15),
                cost: rng.range(1, 10) as f64,
                throughput: 0.0,
            })
            .collect();
        // Random arrival order (Fisher-Yates on the index vector).
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = (rng.next_u64() % (i as u64 + 1)) as usize;
            order.swap(i, j);
        }
        let mut frontier = StreamingFrontier::new();
        for &i in &order {
            frontier.insert_with_seq(points[i].clone(), i);
        }
        assert_eq!(frontier.inserted(), n, "case {case}");
        assert_eq!(
            frontier.len() + frontier.dominated() + frontier.pruned(),
            n,
            "case {case}: accounting must cover every insertion"
        );
        let stream = frontier.into_points();
        let batch = dse::pareto(&points);
        assert_eq!(stream.len(), batch.len(), "case {case}: frontier size");
        for (s, b) in stream.iter().zip(&batch) {
            assert_eq!(s.name, b.name, "case {case}: member/tie-order mismatch");
            assert_eq!(s.latency_ps, b.latency_ps, "case {case}");
            assert_eq!(s.cost.to_bits(), b.cost.to_bits(), "case {case}");
        }
    }
}

/// The historical `topdown_min_nce_freq` implementation, preserved
/// verbatim as the oracle: hand-rolled over the NCE-frequency field, one
/// shared compile cache, probe `hi`, probe `lo`, bisect.
fn topdown_oracle(
    net: &DnnGraph,
    base: &SystemConfig,
    target_latency_ps: u64,
    freq_range_mhz: (u64, u64),
) -> anyhow::Result<Option<u64>> {
    let (mut lo, mut hi) = freq_range_mhz;
    if lo == 0 || lo > hi {
        anyhow::bail!("topdown frequency range must satisfy 0 < lo <= hi");
    }
    let cache = avsm::compiler::CompileCache::new(dse::DSE_COMPILE_OPTS);
    let latency_at = |mhz: u64| -> anyhow::Result<u64> {
        let mut sys = base.clone();
        sys.nce.freq_mhz = mhz;
        Ok(dse::evaluate_cached(net, &sys, "probe", &cache)?.latency_ps)
    };
    if latency_at(hi)? > target_latency_ps {
        return Ok(None);
    }
    if latency_at(lo)? <= target_latency_ps {
        return Ok(Some(lo));
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if latency_at(mid)? <= target_latency_ps {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok(Some(hi))
}

#[test]
fn solve_requirement_reproduces_historical_topdown_exactly() {
    // The generic solver on the NCE-frequency axis must be byte-identical
    // to the old hand-rolled binary search across random nets, configs,
    // targets and ranges — answers, unreachability, and the rejection of
    // degenerate ranges alike — while compiling exactly once (the axis is
    // retime-only).
    let mut rng = Rng::new(0x70BD0);
    let mut compared = 0;
    for case in 0..12 {
        let net = random_net(&mut rng);
        let base = random_sys(&mut rng);
        let Ok(baseline) =
            dse::evaluate(&net, &base, "b").map(|p| p.latency_ps)
        else {
            continue; // infeasible tiling for this random pair: fine
        };
        let targets = [1, baseline, baseline + baseline / 2];
        let ranges = [
            (rng.range(1, 400), rng.range(401, 2000)),
            (rng.range(50, 250), rng.range(250, 600)),
            (250, 250),            // degenerate single-point range
            (0, 1000),             // rejected: zero lo
            (rng.range(500, 900), rng.range(1, 400)), // rejected: inverted
        ];
        for &target in &targets {
            for &range in &ranges {
                let oracle = topdown_oracle(&net, &base, target, range);
                let solver =
                    dse::solve_requirement(&net, &base, dse::Axis::NceFreqMhz, target, range);
                match (&oracle, &solver) {
                    (Err(_), Err(_)) => {} // both reject the degenerate range
                    (Ok(expect), Ok(sol)) => {
                        assert_eq!(
                            sol.value, *expect,
                            "case {case} {} target {target} range {range:?}",
                            net.name
                        );
                        assert_eq!(
                            sol.compiles, 1,
                            "case {case}: NCE frequency is retime-only"
                        );
                        compared += 1;
                    }
                    (o, s) => panic!(
                        "case {case} {} target {target} range {range:?}: \
                         oracle {o:?} vs solver {s:?} disagree on rejection",
                        net.name
                    ),
                }
            }
        }
        // The public wrapper is the same code path: spot-check it once per
        // case against the oracle.
        let range = (50, 1000);
        assert_eq!(
            dse::topdown_min_nce_freq(&net, &base, baseline, range).unwrap(),
            topdown_oracle(&net, &base, baseline, range).unwrap(),
            "case {case} wrapper"
        );
    }
    assert!(compared >= 40, "too few comparable random cases ({compared})");
}

#[test]
fn json_roundtrips_for_random_graphs() {
    let mut rng = Rng::new(0xFACADE);
    for _ in 0..30 {
        let net = random_net(&mut rng);
        let back = graph_from_json(&graph_to_json(&net)).unwrap();
        assert_eq!(net, back);

        let sys = random_sys(&mut rng);
        if let Ok(compiled) = compile(&net, &sys, CompileOptions::default()) {
            let tg = serialize::from_json(&serialize::to_json(&compiled.graph)).unwrap();
            assert_eq!(compiled.graph, tg);
        }
    }
}

#[test]
fn system_config_json_roundtrips_for_random_configs() {
    let mut rng = Rng::new(0xCAFE);
    for _ in 0..30 {
        let sys = random_sys(&mut rng);
        let back = SystemConfig::from_json(&sys.to_json()).unwrap();
        assert_eq!(sys, back);
    }
}
