//! Integration tests: the whole flow across modules, multiple workloads and
//! multiple system design points — compile -> task graph -> both simulators
//! -> reports, plus the shipped system description files.

use avsm::campaign::{self, CampaignOptions, CampaignSpec, WorkloadSpec};
use avsm::compiler::{compile, CompileOptions};
use avsm::config::SystemConfig;
use avsm::coordinator::{run_flow, FlowOptions};
use avsm::detailed::simulate_prototype;
use avsm::dse;
use avsm::graph::{graph_from_json, graph_to_json, models, DnnGraph};
use avsm::hw::simulate_avsm;
use avsm::report::Fig5Report;
use avsm::roofline::RooflineModel;
use avsm::sim::TraceRecorder;

fn all_nets() -> Vec<DnnGraph> {
    vec![
        models::lenet(28),
        models::dilated_vgg_tiny(),
        models::dilated_vgg(128, 2, 16),
        models::vgg16(64, 10),
        models::tiny_resnet(32, 16, 3),
    ]
}

#[test]
fn every_builtin_net_flows_end_to_end() {
    let sys = SystemConfig::base_paper();
    for net in all_nets() {
        let out = run_flow(&net, &sys, &FlowOptions::default(), None)
            .unwrap_or_else(|e| panic!("{}: {e}", net.name));
        assert!(out.sim.total_ps > 0, "{}", net.name);
        assert_eq!(out.sim.layers.len(), net.layers.len(), "{}", net.name);
        // Layer windows partition the run.
        let sum: u64 = out.sim.layers.iter().map(|l| l.duration_ps()).sum();
        assert_eq!(sum, out.sim.total_ps, "{}", net.name);
    }
}

#[test]
fn every_shipped_config_simulates_dilated_vgg() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/configs");
    let mut tested = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let sys = SystemConfig::from_file(&path)
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let net = models::dilated_vgg_tiny();
        let compiled = compile(&net, &sys, CompileOptions::default())
            .unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&compiled, &sys, &mut tr);
        assert!(sim.total_ps > 0, "{path:?}");
        tested += 1;
    }
    assert!(tested >= 3, "expected at least 3 shipped configs, found {tested}");
}

#[test]
fn avsm_tracks_prototype_on_all_workloads() {
    // The Fig 5 property is not DilatedVGG-specific: the AVSM must stay
    // within ~12 % of the detailed model on every built-in workload.
    let sys = SystemConfig::base_paper();
    for net in all_nets() {
        let compiled = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let a = simulate_avsm(&compiled, &sys, &mut tr);
        let mut tr = TraceRecorder::disabled();
        let p = simulate_prototype(&compiled, &sys, &mut tr);
        let dev = (a.total_ps as f64 - p.total_ps as f64).abs() / p.total_ps as f64;
        assert!(dev < 0.12, "{}: deviation {:.1}%", net.name, dev * 100.0);
    }
}

#[test]
fn fig5_report_on_paper_workload_meets_band() {
    let sys = SystemConfig::base_paper();
    let compiled =
        compile(&models::dilated_vgg_paper(), &sys, CompileOptions::default()).unwrap();
    let r = Fig5Report::compute(&compiled, &sys);
    assert!(r.accuracy_pct() >= 91.7, "accuracy {:.2}%", r.accuracy_pct());
    assert!(r.max_abs_layer_deviation() <= 12.0);
}

#[test]
fn mxu_like_config_changes_bound_structure() {
    // On a 128x128 array the conv4 layers stop being compute-bound at this
    // workload size — the cross-config behaviour DSE relies on.
    let base = SystemConfig::base_paper();
    let mxu = SystemConfig::from_file(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/configs/mxu_like.json"
    ))
    .unwrap();
    let net = models::dilated_vgg_paper();
    let eval = |sys: &SystemConfig| {
        let compiled = compile(&net, sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        simulate_avsm(&compiled, sys, &mut tr).total_ps
    };
    let t_base = eval(&base);
    let t_mxu = eval(&mxu);
    assert!(
        t_mxu < t_base / 3,
        "128x128 @940MHz should be >3x faster: {t_mxu} vs {t_base}"
    );
}

#[test]
fn roofline_consistent_with_sim_utilization() {
    let sys = SystemConfig::base_paper();
    let net = models::dilated_vgg_paper();
    let compiled = compile(&net, &sys, CompileOptions::default()).unwrap();
    let mut tr = TraceRecorder::disabled();
    let sim = simulate_avsm(&compiled, &sys, &mut tr);
    let ops: Vec<u64> = net.layer_costs().iter().map(|c| c.arith_ops).collect();
    let model = RooflineModel::from_sim(&sys, &sim, &ops);
    // A layer whose roofline says compute-bound must show high NCE
    // occupancy in the simulation.
    for (p, l) in model.points.iter().zip(&sim.layers) {
        if p.bound == avsm::roofline::RoofBound::Compute && l.macs > 0 {
            assert!(
                l.nce_utilization() > 0.7,
                "{}: roofline compute-bound but NCE util {:.2}",
                l.name,
                l.nce_utilization()
            );
        }
    }
}

#[test]
fn graph_json_cross_checks_python_export() {
    // If `make artifacts` ran, the python-exported DNN graph must equal the
    // rust builder exactly (the two front-ends share DESIGN.md §7).
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/dilated_vgg.graph.json");
    let Ok(text) = std::fs::read_to_string(path) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let imported = graph_from_json(&text).unwrap();
    assert_eq!(imported, models::dilated_vgg(256, 1, 16));
    // And our own export round-trips through their schema.
    let re = graph_from_json(&graph_to_json(&imported)).unwrap();
    assert_eq!(re, imported);
}

#[test]
fn flow_export_files_parse_back() {
    let dir = std::env::temp_dir().join(format!("avsm_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sys = SystemConfig::base_paper();
    let net = models::dilated_vgg_tiny();
    run_flow(&net, &sys, &FlowOptions::default(), Some(&dir)).unwrap();
    // Task graph re-imports.
    let tg = std::fs::read_to_string(dir.join("task_graph.json")).unwrap();
    let graph = avsm::taskgraph::serialize::from_json(&tg).unwrap();
    graph.validate().unwrap();
    // Gantt CSV has the expected schema.
    let csv = std::fs::read_to_string(dir.join("gantt.csv")).unwrap();
    assert!(csv.starts_with("resource,label,task,kind,start_ps,end_ps"));
    // layers.csv rows = layer count.
    let layers = std::fs::read_to_string(dir.join("layers.csv")).unwrap();
    assert_eq!(layers.lines().count(), 1 + net.layers.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn campaign_matches_per_net_sweeps_and_warm_cache_compiles_nothing() {
    // The campaign acceptance contract: >= 3 nets x a >= 9-point grid,
    // per-net frontiers byte-identical to per-net sweep + pareto, and a
    // second run against the warm disk cache performing zero compilations.
    let spec = CampaignSpec::homogeneous(
        vec![
            models::lenet(28),
            models::dilated_vgg_tiny(),
            models::tiny_resnet(32, 16, 2),
        ],
        SystemConfig::base_paper(),
        dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64), (64, 64)])
            .nce_freqs_mhz(vec![125, 250, 500]),
    );
    let dir = std::env::temp_dir().join(format!("avsm_campaign_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = CampaignOptions {
        cache_dir: Some(dir.clone()),
        keep_points: true,
        ..Default::default()
    };

    let assert_identical = |result: &campaign::CampaignResult, tag: &str| {
        assert_eq!(result.grid_points, 27, "{tag}: 3 nets x 9 grid points");
        for (ni, w) in spec.workloads.iter().enumerate() {
            let net = &w.net;
            assert_eq!(result.nets[ni].evaluated, 9, "{tag}");
            let sweep = dse::sweep(net, &spec.base, &spec.axes);
            let batch = dse::pareto(&sweep);
            let got = &result.nets[ni];
            // The whole grid must be feasible here, or the warm-cache
            // zero-compile assertion below would be vacuous.
            assert_eq!(got.feasible, 9, "{tag}: {} grid not fully feasible", net.name);
            assert_eq!(got.points.len(), sweep.len(), "{tag}: {}", net.name);
            for (a, b) in got.points.iter().zip(&sweep) {
                assert_eq!(a.name, b.name, "{tag}");
                assert_eq!(a.latency_ps, b.latency_ps, "{tag}: {}", a.name);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}");
                assert_eq!(a.throughput.to_bits(), b.throughput.to_bits(), "{tag}");
            }
            assert_eq!(got.frontier.len(), batch.len(), "{tag}: {}", net.name);
            for (a, b) in got.frontier.iter().zip(&batch) {
                assert_eq!(a.name, b.name, "{tag}");
                assert_eq!(a.latency_ps, b.latency_ps, "{tag}: {}", a.name);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}");
                assert_eq!(a.sys, b.sys, "{tag}");
            }
        }
    };

    // Cold run: one compile per structural key (3 geometries) per net.
    let cold = campaign::run(&spec, &opts).unwrap();
    assert_identical(&cold, "cold");
    assert_eq!(cold.compiles, 9, "3 nets x 3 geometries");
    assert_eq!(cold.disk_hits, 0);
    // keep_points implies no pruning, and this grid is error-free.
    assert_eq!((cold.errors, cold.skipped_by_bound), (0, 0));

    // Warm run (fresh caches, same directory): zero compilations, every
    // structural key served from disk, identical results.
    let warm = campaign::run(&spec, &opts).unwrap();
    assert_identical(&warm, "warm");
    assert_eq!(warm.compiles, 0, "warm disk cache must be compile-free");
    assert_eq!(warm.disk_hits, 9);

    // Corrupt one entry: the next run detects it, recompiles just that
    // key, heals the file, and still produces identical frontiers.
    let victim = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().and_then(|e| e.to_str()) == Some("json"))
        .expect("cache directory should hold entries");
    std::fs::write(&victim, "{ definitely not a cache entry").unwrap();
    let healed = campaign::run(&spec, &opts).unwrap();
    assert_identical(&healed, "healed");
    assert_eq!(healed.rejected_entries, 1);
    assert_eq!(healed.compiles, 1, "only the corrupted key recompiles");
    assert_eq!(healed.disk_hits, 8);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_campaign_skips_tiling_of_persisted_infeasible_keys() {
    // A grid whose every structural key is infeasible (512-px 4-byte rows
    // cannot fit 1 KiB buffers): the cold run attempts each tiling once
    // and persists negative records; the warm run performs *zero* tiling
    // attempts, answering every corner from disk.
    let mut base = SystemConfig::base_paper();
    base.nce.ifm_buffer_kib = 1;
    base.nce.weight_buffer_kib = 1;
    base.nce.ofm_buffer_kib = 1;
    let spec = CampaignSpec::homogeneous(
        vec![models::dilated_vgg(512, 4, 16)],
        base,
        dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![125, 250]),
    );
    let dir = std::env::temp_dir().join(format!("avsm_neg_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = CampaignOptions { cache_dir: Some(dir.clone()), ..Default::default() };

    let cold = campaign::run(&spec, &opts).unwrap();
    let got = &cold.nets[0];
    assert_eq!((got.feasible, got.infeasible, got.errors), (0, 4, 0));
    assert!(got.frontier.is_empty());
    assert_eq!(cold.compiles, 2, "one tiling attempt per structural key");
    assert_eq!(cold.neg_hits, 0);

    // Warm run, fresh caches: the 2 structural keys resolve from negative
    // records (zero tiling attempts); the other 2 units are memory hits.
    let warm = campaign::run(&spec, &opts).unwrap();
    let got = &warm.nets[0];
    assert_eq!((got.feasible, got.infeasible), (0, 4));
    assert_eq!(warm.compiles, 0, "warm campaign must not re-tile infeasible keys");
    assert_eq!(warm.neg_hits, 2);
    assert_eq!(warm.read_errors, 0);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn heterogeneous_campaign_matches_independent_per_net_sweeps() {
    // The heterogeneous acceptance contract: two workloads with *distinct*
    // bases and axes in one campaign must produce frontiers byte-identical
    // to running each net's own sweep + pareto independently — while the
    // campaign still shares one persistent cache directory, and a warm
    // rerun compiles nothing.
    let mut small = SystemConfig::base_paper();
    small.name = "small_buffers".into();
    small.nce.ifm_buffer_kib = 512;
    small.nce.weight_buffer_kib = 128;
    let spec = CampaignSpec {
        workloads: vec![
            WorkloadSpec::new(models::lenet(28)).with_axes(
                dse::SweepAxes::new()
                    .array_geometries(vec![(16, 32), (32, 64)])
                    .nce_freqs_mhz(vec![125, 500]),
            ),
            WorkloadSpec::new(models::dilated_vgg_tiny())
                .with_base(small.clone())
                .with_axes(
                    dse::SweepAxes::new()
                        .nce_freqs_mhz(vec![250, 500])
                        .ifm_buffer_kib(vec![256, 512]),
                ),
        ],
        base: SystemConfig::base_paper(),
        axes: dse::SweepAxes::new().nce_freqs_mhz(vec![125]),
    };
    let dir = std::env::temp_dir().join(format!("avsm_hetero_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = CampaignOptions { cache_dir: Some(dir.clone()), ..Default::default() };

    let cold = campaign::run(&spec, &opts).unwrap();
    assert_eq!(cold.grid_points, 4 + 4);
    for (ni, w) in spec.workloads.iter().enumerate() {
        let sweep = dse::sweep(&w.net, spec.base_of(ni), spec.axes_of(ni));
        let batch = dse::pareto(&sweep);
        let got = &cold.nets[ni];
        assert_eq!(got.evaluated, 4, "{}", w.net.name);
        assert_eq!(got.base, spec.base_of(ni).name, "{}", w.net.name);
        assert_eq!(got.axes, *spec.axes_of(ni), "{}", w.net.name);
        assert_eq!(got.frontier.len(), batch.len(), "{}", w.net.name);
        for (a, b) in got.frontier.iter().zip(&batch) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.latency_ps, b.latency_ps, "{}", a.name);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.sys, b.sys);
        }
        assert_eq!(
            got.evaluated,
            got.feasible + got.infeasible + got.errors + got.panics + got.skipped_by_bound,
            "{}",
            w.net.name
        );
    }
    // Distinct structural keys: lenet has 2 geometries (freq axis shares),
    // dilated_vgg_tiny has 2 IFM sizes on the small-buffer base.
    assert_eq!(cold.compiles, 4, "2 + 2 structural keys");

    // Warm rerun against the shared directory: compile-free, identical
    // frontiers.
    let warm = campaign::run(&spec, &opts).unwrap();
    assert_eq!(warm.compiles, 0, "warm heterogeneous campaign must be compile-free");
    assert_eq!(warm.disk_hits, 4);
    for (c, w) in cold.nets.iter().zip(&warm.nets) {
        assert_eq!(c.frontier.len(), w.frontier.len());
        for (a, b) in c.frontier.iter().zip(&w.frontier) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.latency_ps, b.latency_ps);
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn deep_chain_campaign_bounds_are_lossless_cold_and_warm() {
    // End-to-end over the persistent cache: a deep-chain workload swept
    // along a dense frequency axis, run under every bound kind plus
    // unpruned — all four frontiers must be byte-identical to the batch
    // sweep, the critical-path/max bounds must skip strictly more than
    // occupancy (the latency-dominated region occupancy admits), and a
    // warm rerun must be compile-free with the same skip behaviour
    // (bounds are computed from the deserialized artifact).
    use avsm::compiler::BoundKind;
    let spec = CampaignSpec::homogeneous(
        vec![avsm::testkit::deep_chain("deep_chain_it", 10, 16, 8)],
        SystemConfig::base_paper(),
        dse::SweepAxes::new().nce_freqs_mhz(vec![1000, 800, 600, 500, 400, 300, 250, 200]),
    );
    let dir = std::env::temp_dir().join(format!("avsm_bound_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let batch = dse::pareto(&dse::sweep(&spec.workloads[0].net, &spec.base, &spec.axes));
    let run_with = |bound: BoundKind, prune: bool| {
        campaign::run(
            &spec,
            &CampaignOptions {
                threads: 1,
                prune,
                bound,
                cache_dir: Some(dir.clone()),
                ..Default::default()
            },
        )
        .unwrap()
    };

    // Cold run populates the cache; the three pruned runs + the unpruned
    // reference all resolve from it afterwards.
    let unpruned = run_with(BoundKind::Max, false);
    assert_eq!(unpruned.compiles, 1, "one structural key on a frequency axis");
    assert_eq!(unpruned.skipped_by_bound, 0);
    let occ = run_with(BoundKind::Occupancy, true);
    let cp = run_with(BoundKind::CriticalPath, true);
    let max = run_with(BoundKind::Max, true);
    assert_eq!(occ.compiles + cp.compiles + max.compiles, 0, "warm runs are compile-free");
    for (tag, result) in
        [("unpruned", &unpruned), ("occupancy", &occ), ("critical-path", &cp), ("max", &max)]
    {
        let got = &result.nets[0];
        assert_eq!(got.frontier.len(), batch.len(), "{tag}");
        for (a, b) in got.frontier.iter().zip(&batch) {
            assert_eq!(a.name, b.name, "{tag}");
            assert_eq!(a.latency_ps, b.latency_ps, "{tag}: {}", a.name);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}");
        }
        assert_eq!(
            got.evaluated,
            got.feasible + got.infeasible + got.errors + got.panics + got.skipped_by_bound,
            "{tag}"
        );
        assert_eq!(
            got.skipped_by_bound,
            got.skipped_by_occupancy + got.skipped_by_critical_path,
            "{tag}"
        );
    }
    // The tentpole property: the tighter bounds prune the deep chain
    // strictly harder than occupancy alone.
    assert!(
        max.skipped_by_bound > occ.skipped_by_bound,
        "max must out-skip occupancy on the deep chain: {} vs {}",
        max.skipped_by_bound,
        occ.skipped_by_bound
    );
    assert!(cp.skipped_by_bound >= max.nets[0].skipped_by_critical_path);
    assert!(max.nets[0].skipped_by_critical_path > 0);
    assert_eq!(occ.nets[0].skipped_by_critical_path, 0);
    // Provenance fields survive the report serialization.
    let report = avsm::report::CampaignReport::new(&max);
    let j = report.to_json();
    assert_eq!(j.get("bound").as_str(), Some("max"));
    assert_eq!(
        j.get("nets").at(0).get("skipped_by_critical_path").as_u64(),
        Some(max.nets[0].skipped_by_critical_path as u64)
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn concurrent_campaigns_share_a_bounded_cache_without_corruption() {
    // Two whole campaigns racing on one LRU-bounded cache directory: the
    // cross-process index lock must serialize the read-modify-write index
    // updates so both runs complete, neither corrupts the index, the lock
    // file is released, and a follow-up run still answers from a coherent
    // cache with results identical to an uncontended run.
    let spec = CampaignSpec::homogeneous(
        vec![models::lenet(28)],
        SystemConfig::base_paper(),
        dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64), (64, 64)])
            .nce_freqs_mhz(vec![125, 500]),
    );
    let dir = std::env::temp_dir().join(format!("avsm_lock_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // Bound below the 3 structural keys so every run churns the eviction
    // path — the contended critical section.
    let opts = CampaignOptions {
        threads: 1,
        cache_dir: Some(dir.clone()),
        cache_max_entries: Some(2),
        ..Default::default()
    };
    let reference = campaign::run(&spec, &opts).unwrap();

    let results: Vec<campaign::CampaignResult> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| s.spawn(|| campaign::run(&spec, &opts).unwrap()))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.grid_points, reference.grid_points, "racer {i}");
        assert_eq!((r.errors, r.panics), (0, 0), "racer {i}");
        let (a, b) = (&r.nets[0].frontier, &reference.nets[0].frontier);
        assert_eq!(a.len(), b.len(), "racer {i}: frontier size");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.name, y.name, "racer {i}");
            assert_eq!(x.latency_ps, y.latency_ps, "racer {i}: {}", x.name);
        }
    }
    // The advisory lock is gone and the index survived the race intact:
    // parseable, within bound, and serving a coherent warm run.
    assert!(!avsm::campaign::store::lock_path(&dir).exists(), "lock file must be released");
    let index_text = std::fs::read_to_string(dir.join("index.json")).unwrap();
    let index = avsm::campaign::store::CacheIndex::from_json(&index_text).unwrap();
    assert!(index.entries().len() <= 2, "LRU bound violated: {}", index.entries().len());
    let warm = campaign::run(&spec, &opts).unwrap();
    assert_eq!(warm.nets[0].frontier.len(), reference.nets[0].frontier.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn killed_journaled_campaign_resumes_to_the_byte_identical_report() {
    // End-to-end crash drill: a journaled campaign is killed mid-run (a
    // torn journal append fails the process partway through, exactly as a
    // SIGKILL mid-write would), then resumed with `resume: true`. The
    // resumed report must match the uninterrupted run on every
    // result-visible field.
    use avsm::testkit::faults::{self, FaultKind};
    let spec = CampaignSpec::homogeneous(
        vec![models::lenet(28)],
        SystemConfig::base_paper(),
        dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![500, 250, 125]),
    );
    let dir = std::env::temp_dir().join(format!("avsm_kill_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let opts = |journal: std::path::PathBuf, resume: bool| CampaignOptions {
        threads: 1,
        cache_dir: Some(dir.join("cache")),
        journal: Some(journal),
        resume,
        ..Default::default()
    };

    let clean = campaign::run(&spec, &opts(dir.join("clean.jsonl"), false)).unwrap();
    let appends =
        std::fs::read_to_string(dir.join("clean.jsonl")).unwrap().matches('\n').count();
    assert_eq!(appends, clean.grid_points + 1, "header + one line per unit");

    // Kill the run halfway through its journal appends: the header and the
    // first few records land, the next one tears mid-line.
    let journal = dir.join("killed.jsonl");
    let survive = appends / 2;
    let killed = {
        let _g = faults::arm_after("journal.append", &dir, FaultKind::Torn, survive, 1);
        campaign::run(&spec, &opts(journal.clone(), false))
    };
    let err = killed.expect_err("the torn append must kill the campaign");
    assert!(format!("{err:#}").contains("injected torn journal append"), "{err:#}");
    let left = std::fs::read_to_string(&journal).unwrap();
    assert!(!left.ends_with('\n'), "the kill must leave a torn tail");
    assert_eq!(left.matches('\n').count(), survive, "intact lines before the tear");

    // Resume: the journaled units replay, the rest re-simulate, and every
    // result-visible field matches the uninterrupted run (cache statistics
    // may differ — replayed units never touch the cache).
    let resumed = campaign::run(&spec, &opts(journal, true)).unwrap();
    assert_eq!(resumed.grid_points, clean.grid_points);
    assert_eq!(resumed.skipped_by_bound, clean.skipped_by_bound);
    assert_eq!((resumed.errors, resumed.panics), (clean.errors, clean.panics));
    let (a, b) = (&resumed.nets[0], &clean.nets[0]);
    assert_eq!(a.evaluated, b.evaluated);
    assert_eq!(a.feasible, b.feasible);
    assert_eq!(a.infeasible, b.infeasible);
    assert_eq!(a.dominated, b.dominated);
    assert_eq!(a.pruned, b.pruned);
    assert_eq!(a.skipped_by_occupancy, b.skipped_by_occupancy);
    assert_eq!(a.skipped_by_critical_path, b.skipped_by_critical_path);
    assert_eq!(a.frontier.len(), b.frontier.len());
    for (x, y) in a.frontier.iter().zip(&b.frontier) {
        assert_eq!(x.name, y.name);
        assert_eq!(x.latency_ps, y.latency_ps, "{}", x.name);
        assert_eq!(x.cost.to_bits(), y.cost.to_bits(), "{}", x.name);
        assert_eq!(x.throughput.to_bits(), y.throughput.to_bits(), "{}", x.name);
        assert_eq!(x.sys, y.sys, "{}", x.name);
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn single_channel_and_rr_arbitration_variants_work() {
    let net = models::dilated_vgg_tiny();
    for (channels, policy) in [
        (1u32, avsm::config::ArbPolicy::FixedPriority),
        (4, avsm::config::ArbPolicy::RoundRobin),
    ] {
        let mut sys = SystemConfig::base_paper();
        sys.dma.channels = channels;
        sys.bus.arbitration = policy;
        let compiled = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&compiled, &sys, &mut tr);
        assert!(sim.total_ps > 0);
    }
}
