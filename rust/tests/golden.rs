//! Golden-file schema regression tests: the on-disk/report JSON schemas
//! are pinned byte-for-byte by checked-in fixtures
//! (`rust/tests/fixtures/*.json`, canonical compact form — sorted keys, no
//! whitespace). Each test parses the fixture with the *real* parser and
//! asserts the *real* serializer emits the fixture bytes back, so any
//! accidental field rename, type change, or format drift in
//! `avsm-campaign-v1`, `avsm-compile-cache-v1`, `avsm-compile-cache-neg-v1`,
//! `avsm-compile-cache-index-v1`, `avsm-campaign-journal-v1`,
//! `avsm-campaign-telemetry-v1` or `avsm-lint-v1` fails loudly
//! here instead of silently breaking warm caches, stale resume journals and
//! downstream report consumers.
//!
//! A *deliberate* schema change is made by bumping the schema and
//! regenerating the fixtures (`scripts/gen_golden_fixtures.py`), with the
//! fixture diff reviewed as a compatibility decision.

use avsm::campaign::store::{
    entry_from_json, entry_to_json, negative_from_json, negative_to_json, CacheIndex,
};
use avsm::campaign::{CampaignResult, NetOutcome};
use avsm::compiler::{BoundKind, CompileKey};
use avsm::config::SystemConfig;
use avsm::dse::{DesignPoint, SweepAxes};
use avsm::json;
use avsm::report::CampaignReport;

/// A fixture's canonical bytes (trailing newline stripped).
fn fixture(text: &'static str) -> &'static str {
    text.trim_end()
}

#[test]
fn compile_cache_entry_schema_is_byte_stable() {
    let text = fixture(include_str!("fixtures/compile_cache_v1.json"));
    let doc = json::parse(text).expect("fixture must stay parseable");
    assert_eq!(doc.get("schema").as_str(), Some("avsm-compile-cache-v1"));

    // The embedded key reconstructs exactly (CompileKey::from_json is the
    // inverse of to_json), and the entry loads under it.
    let key = CompileKey::from_json(doc.get("key")).expect("fixture key must parse");
    assert_eq!(&key.to_json(), doc.get("key"), "key JSON must round-trip");
    let compiled = entry_from_json(text, &key).expect("fixture entry must load");
    assert_eq!(compiled.layers.len(), 2);
    assert_eq!(compiled.graph.len(), 5);
    compiled.graph.validate().unwrap();

    // Byte-compatibility: re-serializing the loaded artifact under the
    // reconstructed key reproduces the checked-in bytes exactly.
    assert_eq!(
        entry_to_json(&key, &compiled),
        text,
        "avsm-compile-cache-v1 serializer drifted from the golden fixture"
    );
}

#[test]
fn negative_entry_schema_is_byte_stable() {
    let text = fixture(include_str!("fixtures/compile_cache_neg_v1.json"));
    let doc = json::parse(text).unwrap();
    assert_eq!(doc.get("schema").as_str(), Some("avsm-compile-cache-neg-v1"));
    let key = CompileKey::from_json(doc.get("key")).unwrap();
    let diag = negative_from_json(text, &key).expect("fixture negative record must load");
    assert_eq!(diag, "tiling infeasible: golden fixture");
    assert_eq!(
        negative_to_json(&key, &diag),
        text,
        "avsm-compile-cache-neg-v1 serializer drifted from the golden fixture"
    );
}

#[test]
fn cache_index_schema_is_byte_stable() {
    let text = fixture(include_str!("fixtures/compile_cache_index_v1.json"));
    let index = CacheIndex::from_json(text).expect("fixture index must parse");
    assert_eq!(index.clock(), 3);
    assert_eq!(index.entries().len(), 2);
    assert_eq!(index.entries().get(&0xdead_beef), Some(&2));
    assert_eq!(index.entries().get(&0x42), Some(&3));
    assert_eq!(
        index.to_json(),
        text,
        "avsm-compile-cache-index-v1 serializer drifted from the golden fixture"
    );
}

fn golden_point(name: &str, latency_ps: u64, cost: f64) -> DesignPoint {
    DesignPoint {
        name: name.into(),
        sys: SystemConfig::base_paper(),
        latency_ps,
        cost,
        throughput: 1e12 / latency_ps as f64,
    }
}

fn golden_net(name: &str, frontier: Vec<DesignPoint>) -> NetOutcome {
    NetOutcome {
        net: name.into(),
        base: "base_paper_virtex7".into(),
        axes: SweepAxes::new().nce_freqs_mhz(vec![125, 250]),
        evaluated: frontier.len() + 5,
        feasible: frontier.len() + 1,
        infeasible: 1,
        errors: 1,
        error_sample: Some("nce0x0_f0: invalid configuration".into()),
        panics: 1,
        panic_sample: Some("nce0x0_f1: evaluation worker panicked".into()),
        bound: BoundKind::Max,
        skipped_by_bound: 1,
        skipped_by_occupancy: 0,
        skipped_by_critical_path: 1,
        dominated: 1,
        pruned: 0,
        compiles: 2,
        disk_hits: 0,
        neg_hits: 1,
        mem_hits: 1,
        rejected: 0,
        read_errors: 0,
        points: Vec::new(),
        frontier,
    }
}

#[test]
fn campaign_report_schema_is_byte_stable() {
    let result = CampaignResult {
        nets: vec![
            golden_net(
                "lenet",
                vec![golden_point("a", 2_000_000, 5.0), golden_point("b", 4_000_000, 3.0)],
            ),
            golden_net(
                "vgg",
                vec![golden_point("a", 5_000_000, 5.0), golden_point("c", 8_000_000, 3.0)],
            ),
        ],
        grid_points: 6,
        threads: 2,
        compiles: 4,
        disk_hits: 0,
        neg_hits: 2,
        mem_hits: 2,
        rejected_entries: 0,
        read_errors: 0,
        bound: BoundKind::Max,
        skipped_by_bound: 2,
        errors: 2,
        panics: 2,
    };
    let text = fixture(include_str!("fixtures/campaign_v1.json"));
    let doc = json::parse(text).unwrap();
    assert_eq!(doc.get("schema").as_str(), Some("avsm-campaign-v1"));

    let emitted = CampaignReport::new(&result).to_json();
    assert_eq!(emitted, doc, "avsm-campaign-v1 fields drifted from the golden fixture");
    assert_eq!(
        emitted.to_string_compact(),
        text,
        "avsm-campaign-v1 serializer bytes drifted from the golden fixture"
    );
}

/// The synthetic 19-span engine run whose aggregates the telemetry fixture
/// pins: every span kind in the obs vocabulary, every outcome class, three
/// recording threads (coordinator + workers 1 and 2). Mirrored literally by
/// `TELEMETRY` in `scripts/gen_golden_fixtures.py`.
fn telemetry_fixture_spans() -> Vec<avsm::obs::Span> {
    fn span(
        kind: &'static str,
        worker: u32,
        unit: Option<u64>,
        outcome: &'static str,
        start_ns: u64,
        end_ns: u64,
    ) -> avsm::obs::Span {
        avsm::obs::Span {
            kind,
            worker,
            net: unit.map(|_| "lenet".to_string()),
            unit,
            outcome,
            start_ns,
            end_ns,
        }
    }
    vec![
        span("cache.read", 1, None, "absent", 20, 40),
        span("compile", 1, None, "ok", 100, 700),
        span("cache.write", 1, None, "ok", 700, 760),
        span("lock.wait", 1, None, "acquired", 760, 780),
        span("lock.steal", 2, None, "ok", 770, 770),
        span("bound", 1, Some(0), "ok", 800, 900),
        span("resolve", 1, Some(0), "compiled", 0, 1000),
        span("resolve", 1, Some(2), "infeasible", 1_000, 1_500),
        span("resolve", 1, Some(4), "panicked", 2_000, 2_600),
        span("bound", 2, Some(1), "ok", 2_800, 2_900),
        span("cache.read", 2, None, "ok", 3_000, 3_020),
        span("resolve", 2, Some(1), "compiled", 0, 3_000),
        span("compile", 2, None, "infeasible", 3_050, 3_150),
        span("resolve", 2, Some(3), "error", 3_000, 3_200),
        span("simulate", 1, Some(0), "feasible", 4_000, 6_000),
        span("simulate", 2, Some(1), "panicked", 4_000, 4_500),
        span("skipped", 1, Some(5), "occupancy", 6_000, 6_010),
        span("journal.append", 0, None, "ok", 6_100, 6_150),
        span("journal.append", 0, None, "error", 6_200, 6_260),
    ]
}

#[test]
fn telemetry_report_schema_is_byte_stable() {
    use avsm::obs::Telemetry;
    use avsm::report::TelemetryReport;

    let t = Telemetry {
        spans: telemetry_fixture_spans(),
        counters: [
            ("cache.compiles".to_string(), 2u64),
            ("cache.mem_hits".to_string(), 3),
            ("cache.neg_hits".to_string(), 1),
        ]
        .into_iter()
        .collect(),
    };
    let text = fixture(include_str!("fixtures/campaign_telemetry_v1.json"));
    let doc = json::parse(text).unwrap();
    assert_eq!(doc.get("schema").as_str(), Some("avsm-campaign-telemetry-v1"));

    let emitted = TelemetryReport::new(&t).to_json();
    assert_eq!(
        emitted, doc,
        "avsm-campaign-telemetry-v1 fields drifted from the golden fixture"
    );
    assert_eq!(
        emitted.to_string_compact(),
        text,
        "avsm-campaign-telemetry-v1 serializer bytes drifted from the golden fixture"
    );
}

#[test]
fn lint_report_schema_is_byte_stable() {
    use avsm::analysis::{Diagnostic, Report};

    // Mirrored literally by `LINT` in scripts/gen_golden_fixtures.py: one
    // diagnostic per pass family, every severity, help present and absent.
    let report = Report::new(vec![
        Diagnostic::error(
            "AVSM004",
            "layer \"conv1\" of net \"golden_net\"",
            "layer \"conv1\": cin 16 != incoming channels 8",
        ),
        Diagnostic::error("AVSM011", "config \"golden_sys\"", "all clock frequencies must be positive"),
        Diagnostic::error("AVSM030", "axis spec entry 1", "axis \"nce_freq_mhz\" listed twice in axis spec")
            .with_help("merge the value lists into a single entry per axis"),
        Diagnostic::warn("AVSM033", "axis spec", "cross-product expands to 22500 grid points (> 10000)"),
        Diagnostic::warn(
            "AVSM043",
            "cache dir golden_cache/index.json",
            "index holds 3 entries, over the LRU bound of 2",
        ),
        Diagnostic::info("AVSM056", "journal golden.jsonl", "replays 4 of 6 units; 2 re-simulate on resume"),
    ]);

    let text = fixture(include_str!("fixtures/lint_v1.json"));
    let doc = json::parse(text).unwrap();
    assert_eq!(doc.get("schema").as_str(), Some("avsm-lint-v1"));

    // The pinned document exercises every severity and every pass family
    // (net 00x, config 01x, campaign/axis 03x, cache fsck 04x, journal 05x).
    let diags = doc.get("diagnostics").as_array().unwrap();
    for severity in ["error", "warning", "info"] {
        assert!(
            diags.iter().any(|d| d.get("severity").as_str() == Some(severity)),
            "fixture must pin a {severity}-severity diagnostic"
        );
    }
    for family in ["AVSM00", "AVSM01", "AVSM03", "AVSM04", "AVSM05"] {
        assert!(
            diags.iter().any(|d| d.get("code").as_str().unwrap().starts_with(family)),
            "fixture must pin a {family}x diagnostic"
        );
    }

    let emitted = report.to_json();
    assert_eq!(emitted, doc, "avsm-lint-v1 fields drifted from the golden fixture");
    assert_eq!(
        emitted.to_string_compact(),
        text,
        "avsm-lint-v1 serializer bytes drifted from the golden fixture"
    );
}

#[test]
fn campaign_journal_schema_is_byte_stable() {
    use avsm::campaign::journal::{Journal, UnitRecord};

    let text = include_str!("fixtures/campaign_journal_v1.jsonl");
    let dir = std::env::temp_dir().join(format!("avsm_golden_journal_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("campaign_journal_v1.jsonl");
    std::fs::write(&path, text).unwrap();

    // The real reader replays the fixture: spec fingerprint and unit count
    // come from the pinned header, every record class is represented, and
    // the append order is preserved.
    let (_, replay) = Journal::resume(&path, 0xdead_beef, 6).expect("fixture journal must replay");
    assert_eq!(
        replay,
        vec![
            (0, UnitRecord::Feasible { latency_ps: 2_400_000 }),
            (3, UnitRecord::Infeasible),
            (1, UnitRecord::Error { diag: "nce0x0: invalid configuration".into() }),
            (4, UnitRecord::Panicked { diag: "worker died".into() }),
            (2, UnitRecord::Skipped { by_occupancy: true }),
            (5, UnitRecord::Skipped { by_occupancy: false }),
        ],
        "avsm-campaign-journal-v1 reader drifted from the golden fixture"
    );

    // Byte-compatibility: the real writer re-emits the fixture bytes from
    // the replayed records.
    let rewritten = dir.join("rewritten.jsonl");
    let mut j = Journal::create(&rewritten, 0xdead_beef, 6).unwrap();
    for (unit, rec) in &replay {
        j.append(*unit, rec).unwrap();
    }
    drop(j);
    assert_eq!(
        std::fs::read_to_string(&rewritten).unwrap(),
        text,
        "avsm-campaign-journal-v1 writer drifted from the golden fixture"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Every pinned `-v1` document: the five compact `.json` fixtures plus
/// each line of the journal fixture (header + all six record classes).
fn all_fixture_docs() -> Vec<(&'static str, &'static str)> {
    let mut docs = vec![
        ("compile_cache_v1.json", fixture(include_str!("fixtures/compile_cache_v1.json"))),
        (
            "compile_cache_neg_v1.json",
            fixture(include_str!("fixtures/compile_cache_neg_v1.json")),
        ),
        (
            "compile_cache_index_v1.json",
            fixture(include_str!("fixtures/compile_cache_index_v1.json")),
        ),
        ("campaign_v1.json", fixture(include_str!("fixtures/campaign_v1.json"))),
        (
            "campaign_telemetry_v1.json",
            fixture(include_str!("fixtures/campaign_telemetry_v1.json")),
        ),
        ("lint_v1.json", fixture(include_str!("fixtures/lint_v1.json"))),
    ];
    for line in include_str!("fixtures/campaign_journal_v1.jsonl").lines() {
        docs.push(("campaign_journal_v1.jsonl", line));
    }
    docs
}

/// Tentpole gate: round-tripping every golden fixture through the
/// streaming `json::stream::Writer` reproduces the checked-in bytes —
/// the incremental emitter and the tree serializer are interchangeable
/// on every schema the repo pins.
#[test]
fn streaming_writer_reemits_every_fixture_byte_for_byte() {
    for (name, text) in all_fixture_docs() {
        let doc = json::parse(text).expect(name);
        let mut bytes = Vec::new();
        let mut w = json::stream::Writer::compact(&mut bytes);
        w.value(&doc).expect(name);
        w.finish().expect(name);
        assert_eq!(
            std::str::from_utf8(&bytes).unwrap(),
            text,
            "{name}: streaming writer drifted from the golden fixture"
        );
    }
}

/// Lazy partial-field extraction agrees with the tree on every top-level
/// field of every fixture: `path_raw` hands back exactly the byte span the
/// tree parser decodes to the same value, without reading past it.
#[test]
fn lazy_extraction_agrees_with_the_tree_on_every_fixture() {
    for (name, text) in all_fixture_docs() {
        let tree = json::parse(text).expect(name);
        let map = tree.as_object().unwrap_or_else(|| panic!("{name}: fixtures are objects"));
        for (key, want) in map {
            let raw = json::stream::path_raw(text.as_bytes(), &[key.as_str()])
                .expect(name)
                .unwrap_or_else(|| panic!("{name}: field {key:?} not found lazily"));
            let got = json::parse(std::str::from_utf8(raw).unwrap())
                .unwrap_or_else(|e| panic!("{name}.{key}: lazy span unparseable: {e}"));
            assert_eq!(&got, want, "{name}: lazy extraction of {key:?} disagrees with the tree");
        }
    }
}
