//! First-class sweep axes — the design space as a *value*, not a struct
//! layout.
//!
//! Every sweepable knob of a [`SystemConfig`] is one [`Axis`] variant. An
//! axis knows three things about itself:
//!
//! 1. **How to read and apply its value** ([`Axis::read`] /
//!    [`Axis::apply`]) — so sweep expansion, the requirement solver and the
//!    CLI all manipulate configs through one vocabulary instead of
//!    hand-rolled per-field loops.
//! 2. **Whether changing it is *structural* or *retime-only***
//!    ([`Axis::is_structural`]): structural axes (array geometry, buffer
//!    capacities, datapath widths) are part of
//!    [`crate::compiler::CompileKey`] — changing them forces a re-tile;
//!    retime-only axes (clock frequencies) are deliberately absent from the
//!    key, so every value of such an axis shares **one** cached
//!    [`crate::compiler::CompiledNet`] and costs only a re-simulation. This
//!    split is what makes frequency sweeps and `topdown` binary searches
//!    compile-once, and the solver/campaign exploit it through the axis
//!    rather than through special-cased field knowledge.
//! 3. **How to serialize itself** ([`AxisValues::to_json`] /
//!    [`AxisValues::from_json`]): the CLI accepts whole design spaces as
//!    JSON axis specs (`[{"axis": "nce_freq_mhz", "values": [125, 250]},
//!    ...]`), so a new study needs no new code, only a new spec.
//!
//! [`SweepAxes`] is an ordered list of `(axis, values)` pairs whose
//! cartesian expansion (first axis outermost) *is* the sweep grid — the
//! named-field struct it replaces survives as thin builder shims
//! ([`SweepAxes::array_geometries`] etc.) so existing call sites read the
//! same and produce byte-identical grids, names included.

use crate::config::SystemConfig;
use crate::json::{self, obj, Value};
use anyhow::{bail, Context, Result};

/// One sweepable knob of a [`SystemConfig`] — the closed set of design-space
/// dimensions the DSE layers understand.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Axis {
    /// NCE MAC-array geometry `(rows, cols)` — the only pair-valued axis.
    ArrayGeometry,
    /// NCE clock in MHz (retime-only).
    NceFreqMhz,
    /// Bus clock in MHz (retime-only).
    BusFreqMhz,
    /// Bus payload width in bytes per beat.
    BusBytesPerCycle,
    /// IFM on-chip buffer capacity in KiB.
    IfmBufferKib,
    /// Weight on-chip buffer capacity in KiB.
    WeightBufferKib,
    /// OFM on-chip buffer capacity in KiB.
    OfmBufferKib,
}

/// A value on one axis: a scalar for every axis except the pair-valued
/// array geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AxisValue {
    Scalar(u64),
    Pair(u32, u32),
}

impl AxisValue {
    /// The scalar payload, if this is a scalar value.
    pub fn scalar(self) -> Option<u64> {
        match self {
            AxisValue::Scalar(v) => Some(v),
            AxisValue::Pair(..) => None,
        }
    }

    fn to_json(self) -> Value {
        match self {
            AxisValue::Scalar(v) => v.into(),
            AxisValue::Pair(r, c) => Value::Array(vec![r.into(), c.into()]),
        }
    }

    fn from_json(v: &Value) -> Result<AxisValue> {
        if let Some(n) = v.as_u64() {
            return Ok(AxisValue::Scalar(n));
        }
        if let Some(a) = v.as_array() {
            if a.len() == 2 {
                let r = a[0].as_u64().context("pair value must be unsigned")?;
                let c = a[1].as_u64().context("pair value must be unsigned")?;
                let r = u32::try_from(r).context("pair value exceeds u32")?;
                let c = u32::try_from(c).context("pair value exceeds u32")?;
                return Ok(AxisValue::Pair(r, c));
            }
        }
        bail!("axis value must be an unsigned integer or a [rows, cols] pair, got {v:?}");
    }
}

impl Axis {
    /// Every axis, in the canonical enumeration order.
    pub const ALL: [Axis; 7] = [
        Axis::ArrayGeometry,
        Axis::NceFreqMhz,
        Axis::BusFreqMhz,
        Axis::BusBytesPerCycle,
        Axis::IfmBufferKib,
        Axis::WeightBufferKib,
        Axis::OfmBufferKib,
    ];

    /// Stable JSON/CLI identifier.
    pub fn key(self) -> &'static str {
        match self {
            Axis::ArrayGeometry => "array_geometry",
            Axis::NceFreqMhz => "nce_freq_mhz",
            Axis::BusFreqMhz => "bus_freq_mhz",
            Axis::BusBytesPerCycle => "bus_bytes_per_cycle",
            Axis::IfmBufferKib => "ifm_buffer_kib",
            Axis::WeightBufferKib => "weight_buffer_kib",
            Axis::OfmBufferKib => "ofm_buffer_kib",
        }
    }

    /// Human-readable axis name for reports and CLI output.
    pub fn label(self) -> &'static str {
        match self {
            Axis::ArrayGeometry => "NCE array geometry",
            Axis::NceFreqMhz => "NCE frequency",
            Axis::BusFreqMhz => "bus frequency",
            Axis::BusBytesPerCycle => "bus width",
            Axis::IfmBufferKib => "IFM buffer",
            Axis::WeightBufferKib => "weight buffer",
            Axis::OfmBufferKib => "OFM buffer",
        }
    }

    /// Unit suffix for scalar values of this axis.
    pub fn unit(self) -> &'static str {
        match self {
            Axis::ArrayGeometry => "",
            Axis::NceFreqMhz | Axis::BusFreqMhz => "MHz",
            Axis::BusBytesPerCycle => "B/cycle",
            Axis::IfmBufferKib | Axis::WeightBufferKib | Axis::OfmBufferKib => "KiB",
        }
    }

    /// Resolve a JSON/CLI identifier.
    pub fn from_key(key: &str) -> Result<Axis> {
        Axis::ALL
            .into_iter()
            .find(|a| a.key() == key)
            .with_context(|| {
                let known: Vec<&str> = Axis::ALL.iter().map(|a| a.key()).collect();
                format!("unknown axis {key:?} (known axes: {})", known.join(", "))
            })
    }

    /// Whether changing this axis changes the structural compile key —
    /// forcing a re-tile — or is a pure retime of the cached compilation.
    /// Must agree with the field set of [`crate::compiler::CompileKey`];
    /// the test suite cross-checks the two.
    pub fn is_structural(self) -> bool {
        !matches!(self, Axis::NceFreqMhz | Axis::BusFreqMhz)
    }

    /// Whether this axis carries scalar values (everything except the
    /// pair-valued array geometry) — the precondition for the requirement
    /// solver, which needs a totally ordered axis.
    pub fn is_scalar(self) -> bool {
        !matches!(self, Axis::ArrayGeometry)
    }

    /// Read this axis's current value from a config.
    pub fn read(self, sys: &SystemConfig) -> AxisValue {
        match self {
            Axis::ArrayGeometry => AxisValue::Pair(sys.nce.array_rows, sys.nce.array_cols),
            Axis::NceFreqMhz => AxisValue::Scalar(sys.nce.freq_mhz),
            Axis::BusFreqMhz => AxisValue::Scalar(sys.bus.freq_mhz),
            Axis::BusBytesPerCycle => AxisValue::Scalar(sys.bus.bytes_per_cycle),
            Axis::IfmBufferKib => AxisValue::Scalar(sys.nce.ifm_buffer_kib as u64),
            Axis::WeightBufferKib => AxisValue::Scalar(sys.nce.weight_buffer_kib as u64),
            Axis::OfmBufferKib => AxisValue::Scalar(sys.nce.ofm_buffer_kib as u64),
        }
    }

    /// Check that `v` is a legal value for this axis (kind match, and u32
    /// range for the u32-backed buffer fields). [`AxisValues::new`] runs
    /// this on every value, which is what lets grid expansion apply values
    /// infallibly.
    pub fn check(self, v: AxisValue) -> Result<()> {
        match (self, v) {
            (Axis::ArrayGeometry, AxisValue::Pair(..)) => Ok(()),
            (Axis::ArrayGeometry, AxisValue::Scalar(s)) => {
                bail!("axis array_geometry takes [rows, cols] pairs, got scalar {s}")
            }
            (axis, AxisValue::Pair(r, c)) => {
                bail!("axis {} takes scalar values, got pair [{r}, {c}]", axis.key())
            }
            (
                Axis::IfmBufferKib | Axis::WeightBufferKib | Axis::OfmBufferKib,
                AxisValue::Scalar(s),
            ) => {
                u32::try_from(s)
                    .map(|_| ())
                    .map_err(|_| anyhow::anyhow!("axis {}: value {s} exceeds u32", self.key()))
            }
            (_, AxisValue::Scalar(_)) => Ok(()),
        }
    }

    /// Write `v` into `sys`. Fails exactly when [`Axis::check`] would.
    pub fn apply(self, sys: &mut SystemConfig, v: AxisValue) -> Result<()> {
        self.check(v)?;
        match (self, v) {
            (Axis::ArrayGeometry, AxisValue::Pair(r, c)) => {
                sys.nce.array_rows = r;
                sys.nce.array_cols = c;
            }
            (Axis::NceFreqMhz, AxisValue::Scalar(s)) => sys.nce.freq_mhz = s,
            (Axis::BusFreqMhz, AxisValue::Scalar(s)) => sys.bus.freq_mhz = s,
            (Axis::BusBytesPerCycle, AxisValue::Scalar(s)) => sys.bus.bytes_per_cycle = s,
            (Axis::IfmBufferKib, AxisValue::Scalar(s)) => sys.nce.ifm_buffer_kib = s as u32,
            (Axis::WeightBufferKib, AxisValue::Scalar(s)) => sys.nce.weight_buffer_kib = s as u32,
            (Axis::OfmBufferKib, AxisValue::Scalar(s)) => sys.nce.ofm_buffer_kib = s as u32,
            _ => unreachable!("check() rejected the kind mismatch"),
        }
        Ok(())
    }

    /// The token this axis contributes to a design-point name — the key a
    /// report legend decodes point names with. The canonical four axes use
    /// the historical `nce{r}x{c}_f{f}_bus{w}_ifm{k}` prefix tokens; the
    /// rest append `_<token><value>` fragments ([`Axis::extra_fragment`]).
    pub fn name_key(self) -> &'static str {
        match self {
            Axis::ArrayGeometry => "nce",
            Axis::NceFreqMhz => "f",
            Axis::BusBytesPerCycle => "bus",
            Axis::IfmBufferKib => "ifm",
            Axis::BusFreqMhz => "busf",
            Axis::WeightBufferKib => "wbuf",
            Axis::OfmBufferKib => "obuf",
        }
    }

    /// Whether [`Axis::name_key`] appears in the canonical
    /// `nce{r}x{c}_f{f}_bus{w}_ifm{k}` name prefix (always emitted, from
    /// the expanded config) rather than as an appended fragment.
    pub fn is_canonical_name_axis(self) -> bool {
        matches!(
            self,
            Axis::ArrayGeometry | Axis::NceFreqMhz | Axis::BusBytesPerCycle | Axis::IfmBufferKib
        )
    }

    /// Point-name fragment for axes *not* covered by the canonical
    /// `nce{r}x{c}_f{f}_bus{w}_ifm{k}` prefix (which is always derived from
    /// the expanded config, keeping classic sweep names byte-identical).
    /// Returns `None` for the canonical four.
    fn extra_fragment(self, v: AxisValue) -> Option<String> {
        if self.is_canonical_name_axis() {
            return None;
        }
        Some(format!("{}{}", self.name_key(), v.scalar()?))
    }
}

/// One axis with the values it sweeps. Values are validated against the
/// axis at construction, so downstream grid expansion cannot fail.
#[derive(Debug, Clone, PartialEq)]
pub struct AxisValues {
    axis: Axis,
    values: Vec<AxisValue>,
}

impl AxisValues {
    pub fn new(axis: Axis, values: Vec<AxisValue>) -> Result<Self> {
        for v in &values {
            axis.check(*v)?;
        }
        Ok(Self { axis, values })
    }

    pub fn axis(&self) -> Axis {
        self.axis
    }

    pub fn values(&self) -> &[AxisValue] {
        &self.values
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// `{"axis": "<key>", "values": [...]}` — scalars as integers, the
    /// geometry axis as `[rows, cols]` pairs.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("axis", self.axis.key().into()),
            (
                "values",
                Value::Array(self.values.iter().map(|v| v.to_json()).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let axis = Axis::from_key(v.req_str("axis")?)?;
        let mut values = Vec::new();
        for raw in v.req_array("values")? {
            values.push(AxisValue::from_json(raw)?);
        }
        AxisValues::new(axis, values)
            .with_context(|| format!("axis spec for {:?}", axis.key()))
    }
}

/// The design space of a sweep: an ordered list of axes (first axis
/// outermost in the cartesian expansion). An axis absent from the list —
/// or present with no values — keeps the base config's value, exactly like
/// the empty named fields of the struct this replaces.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SweepAxes {
    axes: Vec<AxisValues>,
}

impl SweepAxes {
    pub fn new() -> Self {
        Self::default()
    }

    /// The active axes, in sweep order.
    pub fn axes(&self) -> &[AxisValues] {
        &self.axes
    }

    /// No axes — the grid is just the base config.
    pub fn is_empty(&self) -> bool {
        self.axes.is_empty()
    }

    /// Number of grid points the cartesian expansion will produce.
    pub fn grid_size(&self) -> usize {
        self.axes.iter().map(|a| a.len()).product()
    }

    /// Append (or replace) one axis. Validates every value against the
    /// axis; an empty value list removes the axis (keep the base value).
    pub fn with_axis(self, axis: Axis, values: Vec<AxisValue>) -> Result<Self> {
        Ok(self.set(AxisValues::new(axis, values)?))
    }

    /// Append a pre-validated axis, replacing any previous entry for the
    /// same axis (in place, preserving its sweep position).
    pub fn set(mut self, av: AxisValues) -> Self {
        if av.is_empty() {
            self.axes.retain(|a| a.axis != av.axis);
            return self;
        }
        match self.axes.iter_mut().find(|a| a.axis == av.axis) {
            Some(slot) => *slot = av,
            None => self.axes.push(av),
        }
        self
    }

    // --- compat shims: the old named-field constructors -----------------
    // Typed, hence infallible; call order = axis order = expansion order
    // (geometry outermost, then frequency, bus width, IFM buffer — the
    // order the old hand-rolled loops nested in).

    pub fn array_geometries(self, geoms: Vec<(u32, u32)>) -> Self {
        self.set(AxisValues {
            axis: Axis::ArrayGeometry,
            values: geoms.into_iter().map(|(r, c)| AxisValue::Pair(r, c)).collect(),
        })
    }

    pub fn nce_freqs_mhz(self, freqs: Vec<u64>) -> Self {
        self.set(AxisValues {
            axis: Axis::NceFreqMhz,
            values: freqs.into_iter().map(AxisValue::Scalar).collect(),
        })
    }

    pub fn bus_bytes_per_cycle(self, widths: Vec<u64>) -> Self {
        self.set(AxisValues {
            axis: Axis::BusBytesPerCycle,
            values: widths.into_iter().map(AxisValue::Scalar).collect(),
        })
    }

    pub fn ifm_buffer_kib(self, sizes: Vec<u32>) -> Self {
        self.set(AxisValues {
            axis: Axis::IfmBufferKib,
            values: sizes.into_iter().map(|k| AxisValue::Scalar(k as u64)).collect(),
        })
    }

    // --- JSON ------------------------------------------------------------

    /// JSON axis spec: an array of [`AxisValues::to_json`] objects.
    pub fn to_json(&self) -> Value {
        Value::Array(self.axes.iter().map(|a| a.to_json()).collect())
    }

    /// Parse a JSON axis-spec value (duplicate axes are rejected — a spec
    /// listing one axis twice is ambiguous, not a silent override).
    pub fn from_value(v: &Value) -> Result<Self> {
        let raw = v
            .as_array()
            .context("axis spec must be a JSON array of {axis, values} objects")?;
        let mut axes = SweepAxes::new();
        for entry in raw {
            let av = AxisValues::from_json(entry)?;
            if axes.axes.iter().any(|a| a.axis == av.axis) {
                bail!("{}", duplicate_axis_message(av.axis));
            }
            axes = axes.set(av);
        }
        Ok(axes)
    }

    /// Parse a JSON axis-spec document.
    pub fn from_json(text: &str) -> Result<Self> {
        Self::from_value(&json::parse(text).context("axis spec parse")?)
    }
}

/// The one message for a duplicated axis kind, shared by
/// [`SweepAxes::from_value`] and the lint pass (`AVSM030`) so the two can
/// never drift apart.
pub fn duplicate_axis_message(axis: Axis) -> String {
    format!("axis {:?} listed twice in axis spec", axis.key())
}

/// Enumerate the cartesian grid of configs for `axes` around `base`, in
/// deterministic axis order (first axis outermost). Every point's name is
/// the canonical `nce{r}x{c}_f{f}_bus{w}_ifm{k}` prefix (read from the
/// expanded config, so classic sweeps keep their exact historical names)
/// plus a fragment per additionally swept axis — unique within any one
/// grid, since points only differ along swept axes.
pub fn expand_configs(base: &SystemConfig, axes: &SweepAxes) -> Vec<SystemConfig> {
    let active = axes.axes();
    let mut configs = Vec::with_capacity(axes.grid_size());
    let mut idx = vec![0usize; active.len()];
    loop {
        let mut sys = base.clone();
        for (ai, av) in active.iter().enumerate() {
            av.axis()
                .apply(&mut sys, av.values()[idx[ai]])
                .expect("axis values are validated at construction");
        }
        sys.name = point_name(&sys, active, &idx);
        configs.push(sys);
        // Odometer increment, last axis innermost.
        let mut ai = active.len();
        loop {
            if ai == 0 {
                return configs;
            }
            ai -= 1;
            idx[ai] += 1;
            if idx[ai] < active[ai].len() {
                break;
            }
            idx[ai] = 0;
        }
    }
}

fn point_name(sys: &SystemConfig, active: &[AxisValues], idx: &[usize]) -> String {
    let mut name = format!(
        "nce{}x{}_f{}_bus{}_ifm{}",
        sys.nce.array_rows,
        sys.nce.array_cols,
        sys.nce.freq_mhz,
        sys.bus.bytes_per_cycle,
        sys.nce.ifm_buffer_kib
    );
    for (ai, av) in active.iter().enumerate() {
        if let Some(frag) = av.axis().extra_fragment(av.values()[idx[ai]]) {
            name.push('_');
            name.push_str(&frag);
        }
    }
    name
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::CompileKey;
    use crate::dse::DSE_COMPILE_OPTS;
    use crate::graph::models;

    fn base() -> SystemConfig {
        SystemConfig::base_paper()
    }

    #[test]
    fn every_axis_round_trips_through_read_apply() {
        let b = base();
        for axis in Axis::ALL {
            let v = axis.read(&b);
            let mut sys = b.clone();
            axis.apply(&mut sys, v).unwrap();
            assert_eq!(sys, b, "{}: applying the read value must be identity", axis.key());
        }
    }

    #[test]
    fn axis_keys_round_trip() {
        for axis in Axis::ALL {
            assert_eq!(Axis::from_key(axis.key()).unwrap(), axis);
        }
        let err = Axis::from_key("nope").unwrap_err();
        assert!(format!("{err:#}").contains("known axes"), "{err:#}");
    }

    #[test]
    fn kind_mismatches_are_rejected() {
        assert!(Axis::ArrayGeometry.check(AxisValue::Scalar(32)).is_err());
        assert!(Axis::NceFreqMhz.check(AxisValue::Pair(16, 32)).is_err());
        // u32-backed buffer axes reject oversized scalars instead of
        // wrapping.
        let err = Axis::IfmBufferKib.check(AxisValue::Scalar(1 << 40)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds u32"), "{err:#}");
        // ...while genuinely u64-backed axes take them.
        Axis::BusBytesPerCycle.check(AxisValue::Scalar(1 << 40)).unwrap();
    }

    #[test]
    fn structural_classification_matches_compile_key() {
        // The axis's own claim about structurality must agree with the
        // compile cache's key: applying a *changed* value to the base
        // config changes the CompileKey iff the axis says structural.
        let net = models::lenet(28);
        let b = base();
        let key_base = CompileKey::new(&net, &b, DSE_COMPILE_OPTS);
        for axis in Axis::ALL {
            let changed = match axis.read(&b) {
                AxisValue::Scalar(s) => AxisValue::Scalar(s * 2),
                AxisValue::Pair(r, c) => AxisValue::Pair(r * 2, c * 2),
            };
            let mut sys = b.clone();
            axis.apply(&mut sys, changed).unwrap();
            let key = CompileKey::new(&net, &sys, DSE_COMPILE_OPTS);
            assert_eq!(
                key != key_base,
                axis.is_structural(),
                "{}: is_structural() disagrees with CompileKey",
                axis.key()
            );
        }
    }

    #[test]
    fn axis_spec_json_round_trips() {
        let axes = SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![125, 250, 500])
            .bus_bytes_per_cycle(vec![16, 32])
            .ifm_buffer_kib(vec![512, 1536]);
        let text = axes.to_json().to_string_pretty();
        let back = SweepAxes::from_json(&text).unwrap();
        assert_eq!(back, axes);
        // Order is part of the spec (it fixes the grid enumeration).
        assert_eq!(back.axes()[0].axis(), Axis::ArrayGeometry);
        assert_eq!(back.axes()[1].axis(), Axis::NceFreqMhz);
        assert_eq!(back.grid_size(), 2 * 3 * 2 * 2);
    }

    #[test]
    fn axis_spec_rejects_duplicates_and_bad_values() {
        let dup = r#"[{"axis":"nce_freq_mhz","values":[125]},
                      {"axis":"nce_freq_mhz","values":[250]}]"#;
        let err = SweepAxes::from_json(dup).unwrap_err();
        assert!(format!("{err:#}").contains("twice"), "{err:#}");

        let bad = r#"[{"axis":"array_geometry","values":[125]}]"#;
        assert!(SweepAxes::from_json(bad).is_err());

        let unknown = r#"[{"axis":"warp_factor","values":[9]}]"#;
        let err = SweepAxes::from_json(unknown).unwrap_err();
        assert!(format!("{err:#}").contains("unknown axis"), "{err:#}");
    }

    #[test]
    fn empty_axis_keeps_base_value_and_replacement_is_in_place() {
        let axes = SweepAxes::new()
            .nce_freqs_mhz(vec![125, 250])
            .array_geometries(vec![(16, 32)])
            .nce_freqs_mhz(vec![500]); // replaces, stays first
        assert_eq!(axes.axes()[0].axis(), Axis::NceFreqMhz);
        assert_eq!(axes.axes()[0].len(), 1);
        assert_eq!(axes.grid_size(), 1);
        // Emptying an axis removes it entirely.
        let axes = axes.nce_freqs_mhz(vec![]);
        assert_eq!(axes.axes().len(), 1);
        assert_eq!(axes.axes()[0].axis(), Axis::ArrayGeometry);
    }

    #[test]
    fn expansion_matches_historical_grid_order_and_names() {
        // The exact grid the old named-field expansion produced: geometry
        // outermost, then frequency, bus width, IFM buffer; canonical
        // names.
        let axes = SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![125, 250])
            .bus_bytes_per_cycle(vec![32])
            .ifm_buffer_kib(vec![512]);
        let configs = expand_configs(&base(), &axes);
        let names: Vec<&str> = configs.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "nce16x32_f125_bus32_ifm512",
                "nce16x32_f250_bus32_ifm512",
                "nce32x64_f125_bus32_ifm512",
                "nce32x64_f250_bus32_ifm512",
            ]
        );
        assert_eq!(configs[2].nce.array_rows, 32);
        assert_eq!(configs[2].nce.freq_mhz, 125);
    }

    #[test]
    fn expansion_of_empty_axes_is_the_base_point() {
        let configs = expand_configs(&base(), &SweepAxes::default());
        assert_eq!(configs.len(), 1);
        assert_eq!(configs[0].name, "nce32x64_f250_bus32_ifm1536");
        assert_eq!(configs[0].nce.freq_mhz, base().nce.freq_mhz);
    }

    #[test]
    fn name_keys_are_distinct_and_match_emitted_names() {
        // Every axis's name token is unique (a legend keyed on them is
        // unambiguous), and the token actually appears in the names of a
        // grid swept along that axis.
        let mut keys: Vec<&str> = Axis::ALL.iter().map(|a| a.name_key()).collect();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), Axis::ALL.len(), "name keys must be distinct");
        for axis in Axis::ALL {
            let changed = match axis.read(&base()) {
                AxisValue::Scalar(s) => AxisValue::Scalar(s * 2),
                AxisValue::Pair(r, c) => AxisValue::Pair(r * 2, c * 2),
            };
            let axes = SweepAxes::new().with_axis(axis, vec![changed]).unwrap();
            let configs = expand_configs(&base(), &axes);
            assert!(
                configs[0].name.contains(axis.name_key()),
                "{}: name {:?} lacks token {:?}",
                axis.key(),
                configs[0].name,
                axis.name_key()
            );
        }
    }

    #[test]
    fn non_canonical_axes_get_name_fragments() {
        let axes = SweepAxes::new()
            .with_axis(
                Axis::BusFreqMhz,
                vec![AxisValue::Scalar(125), AxisValue::Scalar(250)],
            )
            .unwrap()
            .with_axis(
                Axis::WeightBufferKib,
                vec![AxisValue::Scalar(128), AxisValue::Scalar(256)],
            )
            .unwrap();
        let configs = expand_configs(&base(), &axes);
        assert_eq!(configs.len(), 4);
        let names: Vec<&str> = configs.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "nce32x64_f250_bus32_ifm1536_busf125_wbuf128",
                "nce32x64_f250_bus32_ifm1536_busf125_wbuf256",
                "nce32x64_f250_bus32_ifm1536_busf250_wbuf128",
                "nce32x64_f250_bus32_ifm1536_busf250_wbuf256",
            ]
        );
        assert_eq!(configs[0].bus.freq_mhz, 125);
        assert_eq!(configs[3].nce.weight_buffer_kib, 256);
        // Names stay unique even though the canonical prefix is constant.
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 4);
    }
}
