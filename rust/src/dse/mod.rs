//! Design-space exploration — the paper's motivating use case: evaluating
//! many hardware/software design points "by a click of a button" instead of
//! one physical prototype per point.
//!
//! # The design space is a value: [`Axis`]
//!
//! Every sweepable knob of a [`SystemConfig`] is one variant of the closed
//! [`Axis`] enum ([`axis`] module): array geometry, NCE/bus clocks, bus
//! width, and the three on-chip buffer capacities. An axis knows how to
//! read/apply its value, whether it is **structural** or **retime-only**,
//! and how to serialize itself — so sweeps ([`SweepAxes`] is an ordered
//! list of `(axis, values)` pairs), the requirement solver and the CLI's
//! JSON axis specs all share one vocabulary, and adding a knob means adding
//! one variant, not editing every layer.
//!
//! # Compile-reuse rules
//!
//! Evaluating a design point is `compile` (tiling + lowering) followed by
//! `simulate`. The compiler's output depends only on the *structural*
//! subset of the config — the fields of [`crate::compiler::CompileKey`] —
//! never on clock frequencies: the tiler's objective runs at pinned
//! reference clocks (see `compiler::tiling`) and the emitted task graph
//! carries frequency-free NCE cycle counts and DMA byte counts. The rules,
//! as the axis abstraction states them:
//!
//! * Moving along a **retime-only** axis ([`Axis::is_structural`] =
//!   `false`: the clock axes) keeps the [`CompileKey`] fixed — every value
//!   shares one cached [`CompiledNet`] and costs one re-simulation.
//! * Moving along a **structural** axis (geometry, widths, buffers)
//!   changes the key — one compilation per distinct value, memoized in a
//!   [`CompileCache`] shared by reference across sweep workers.
//!
//! A grid over G structural values x F frequencies therefore costs G
//! compilations, not G x F, and every probe of a requirement solve after
//! the first structural value is compile-free.
//!
//! # Entry points
//!
//! * [`sweep`] — cartesian sweep of [`SweepAxes`] around a base config,
//!   parallel across points on the shared worker pool
//!   (`crate::campaign::pool`), byte-identical to the sequential
//!   [`sweep_seq`] (enforced by tests). [`sweep_outcomes`] is the
//!   classified form (feasible / infeasible / error per grid point).
//! * [`solve_requirement`] — the paper's §2 "top-down" mode, generalized:
//!   given a target latency, binary-search *any monotone scalar axis* for
//!   the minimum value that meets it, with a monotonicity pre-check and a
//!   per-solution compile/probe accounting ([`RequirementSolution`]).
//!   [`topdown_min_nce_freq`] is the NCE-frequency instance, kept as a
//!   compatibility wrapper; [`bottomup`] is the ordinary estimate for
//!   annotated components.
//! * [`pareto`] — extract the latency/cost frontier (sort-based,
//!   O(n log n)).
//!
//! Sweeping a whole *portfolio* of nets — each optionally against its own
//! base config and axes — with streaming Pareto frontiers and a
//! disk-persistent compile cache is `crate::campaign::run`.
//!
//! [`CompileKey`]: crate::compiler::CompileKey

pub mod axis;

pub use axis::{expand_configs, Axis, AxisValue, AxisValues, SweepAxes};

use crate::compiler::{CompileCache, CompileOptions, CompiledNet};
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::hw::simulate_avsm;
use crate::json::{obj, Value};
use crate::sim::TraceRecorder;
use anyhow::{bail, Result};

/// Compiler options used for every DSE evaluation: double buffering on (the
/// base software design point), labels off (never read on the fast path).
/// Public because the campaign engine (`crate::campaign`) must evaluate
/// with byte-identical options for its frontiers to equal per-net sweeps.
pub const DSE_COMPILE_OPTS: CompileOptions =
    CompileOptions { double_buffer: true, labels: false };

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub name: String,
    pub sys: SystemConfig,
    /// Simulated end-to-end inference latency.
    pub latency_ps: u64,
    /// Crude area/cost proxy: number of multipliers + KiB of on-chip RAM.
    pub cost: f64,
    /// Simulated inferences per second.
    pub throughput: f64,
}

/// Execution policy for [`sweep_with`].
#[derive(Debug, Clone, Copy, Default)]
pub struct SweepOptions {
    /// Worker threads; 0 (the default) = one per available CPU, capped by
    /// the point count.
    pub threads: usize,
    /// Skip the static pre-flight lint (`analysis::passes`) that
    /// short-circuits a sweep whose net can never evaluate. Observation
    /// only — outcomes are byte-identical either way; the pre-flight just
    /// avoids fanning a doomed grid out to the worker pool.
    pub no_preflight: bool,
}

/// Crude area/cost proxy of a design point: multipliers + 2x KiB of on-chip
/// RAM. Public because the campaign's bound-and-prune check must price a
/// candidate *before* simulating it, with the exact value its
/// [`DesignPoint`] would carry.
pub fn cost_proxy(sys: &SystemConfig) -> f64 {
    let mults = sys.nce.macs_per_cycle() as f64;
    let ram_kib = (sys.nce.ifm_buffer_kib + sys.nce.weight_buffer_kib + sys.nce.ofm_buffer_kib)
        as f64;
    mults + 2.0 * ram_kib
}

/// Tabulate a design point from its simulated end-to-end latency — cost
/// and throughput are pure functions of `(sys, total_ps)`. Public because
/// campaign journal replay (`campaign::journal`) reconstructs finished
/// feasible points from their persisted latencies without re-simulating,
/// and the reconstruction must be byte-identical to the original.
pub fn point_from_latency(sys: &SystemConfig, name: String, total_ps: u64) -> DesignPoint {
    DesignPoint {
        name,
        sys: sys.clone(),
        latency_ps: total_ps,
        cost: cost_proxy(sys),
        // Guard the degenerate zero-latency simulation (empty task graph):
        // report zero throughput instead of +inf, which would poison JSON
        // exports and any averaging downstream.
        throughput: if total_ps == 0 { 0.0 } else { 1e12 / total_ps as f64 },
    }
}

/// Evaluate one design point from scratch (compile + simulate, fast path).
pub fn evaluate(net: &DnnGraph, sys: &SystemConfig, name: impl Into<String>) -> Result<DesignPoint> {
    let compiled = crate::compiler::compile(net, sys, DSE_COMPILE_OPTS)?;
    Ok(evaluate_compiled(&compiled, sys, name))
}

/// Simulate an already-compiled net under `sys`'s annotations and tabulate
/// the design point (the retime step shared by [`evaluate`],
/// [`evaluate_cached`] and the campaign engine, which resolves `compiled`
/// through its own persistent cache).
pub fn evaluate_compiled(
    compiled: &CompiledNet,
    sys: &SystemConfig,
    name: impl Into<String>,
) -> DesignPoint {
    let name = name.into();
    // `sim.evaluate` failpoint: lets tests kill a worker mid-simulation,
    // scoped by the `<net>/<point>` pseudo-path so only the arming test's
    // uniquely named net trips it.
    crate::testkit::faults::before_op(
        "sim.evaluate",
        &std::path::Path::new(&compiled.graph.name).join(&name),
    );
    let mut trace = TraceRecorder::disabled();
    let sim = simulate_avsm(compiled, sys, &mut trace);
    point_from_latency(sys, name, sim.total_ps)
}

/// Evaluate one design point through a [`CompileCache`]: points that differ
/// only in clock frequencies reuse one compilation and just re-simulate
/// (retime). Produces byte-identical results to [`evaluate`].
pub fn evaluate_cached(
    net: &DnnGraph,
    sys: &SystemConfig,
    name: impl Into<String>,
    cache: &CompileCache,
) -> Result<DesignPoint> {
    // `get_or_compile` validates the full config on every call (hits
    // included), so an invalid swept point is rejected, never simulated.
    let compiled: std::sync::Arc<CompiledNet> = cache.get_or_compile(net, sys)?;
    Ok(evaluate_compiled(&compiled, sys, name))
}

/// Classified outcome of evaluating one design point. An evaluation can
/// fail for two *very* different reasons, and a sweep must never conflate
/// them: "this tiling cannot fit the buffers" is a property of the design
/// point (a legitimate hole in the grid), while "the swept config is
/// invalid" is a defect in the sweep itself that would otherwise vanish
/// silently from the results.
#[derive(Debug, Clone)]
pub enum EvalOutcome {
    /// Compiled and simulated.
    Feasible(DesignPoint),
    /// Structurally infeasible: the tiler proved no legal tiling exists for
    /// this (net, geometry, buffers) combination. Carries the compiler's
    /// diagnostic.
    Infeasible { name: String, reason: String },
    /// Not a statement about the design point: invalid swept configuration
    /// or a poisoned cache slot. Must be surfaced, never counted as
    /// "infeasible tiling".
    Error { name: String, reason: String },
}

impl EvalOutcome {
    /// The feasible design point, if any.
    pub fn point(self) -> Option<DesignPoint> {
        match self {
            EvalOutcome::Feasible(p) => Some(p),
            _ => None,
        }
    }
}

/// Validate `(net, sys)` and resolve its compiled artifact through
/// `resolve`, classifying every failure: validation problems and poisoned
/// cache slots ([`crate::compiler::POISONED_SOURCE_DIAG`]) are
/// [`EvalOutcome::Error`]; anything else `resolve` reports is, by the
/// compile cache's invariant, structural tiling infeasibility. `Ok` hands
/// the artifact back to the caller — to simulate, or to bound-check first
/// the way the campaign's pruning pipeline does. The single classifier
/// shared by [`evaluate_outcome`] and `campaign::run`, so the sweep and
/// campaign surfaces can never drift apart on the same grid.
pub fn resolve_classified(
    net: &DnnGraph,
    sys: &SystemConfig,
    name: &str,
    resolve: impl FnOnce() -> Result<std::sync::Arc<CompiledNet>>,
) -> Result<std::sync::Arc<CompiledNet>, EvalOutcome> {
    if let Err(e) = net.validate().and_then(|_| sys.validate()) {
        return Err(EvalOutcome::Error {
            name: name.to_string(),
            reason: format!("invalid configuration: {e:#}"),
        });
    }
    match resolve() {
        Ok(compiled) => Ok(compiled),
        Err(e) => {
            let reason = format!("{e:#}");
            if reason.contains(crate::compiler::POISONED_SOURCE_DIAG) {
                // A worker unwound mid-compile and poisoned the slot: not a
                // property of the design point, never "infeasible".
                Err(EvalOutcome::Error { name: name.to_string(), reason })
            } else {
                Err(EvalOutcome::Infeasible { name: name.to_string(), reason })
            }
        }
    }
}

/// Evaluate one design point and classify the outcome (see
/// [`resolve_classified`] for the failure taxonomy).
pub fn evaluate_outcome(
    net: &DnnGraph,
    sys: &SystemConfig,
    name: impl Into<String>,
    cache: &CompileCache,
) -> EvalOutcome {
    let name = name.into();
    match resolve_classified(net, sys, &name, || cache.get_or_compile(net, sys)) {
        Ok(compiled) => EvalOutcome::Feasible(evaluate_compiled(&compiled, sys, name)),
        Err(outcome) => outcome,
    }
}

/// Cartesian sweep around a base system, parallel across design points with
/// one shared compile cache. Infeasible points (tiling fails) are skipped.
/// Result order is deterministic and identical to [`sweep_seq`]. Callers
/// that must tell infeasible holes apart from evaluation *errors* (invalid
/// swept configs) should use [`sweep_outcomes`], which classifies every
/// grid point instead of silently dropping the failures.
pub fn sweep(net: &DnnGraph, base: &SystemConfig, axes: &SweepAxes) -> Vec<DesignPoint> {
    sweep_with(net, base, axes, &SweepOptions::default())
}

/// Sequential reference sweep (one worker, same cache, same results).
pub fn sweep_seq(net: &DnnGraph, base: &SystemConfig, axes: &SweepAxes) -> Vec<DesignPoint> {
    sweep_with(net, base, axes, &SweepOptions { threads: 1, ..Default::default() })
}

/// Sweep with an explicit execution policy.
///
/// Fan-out runs on the shared campaign worker pool
/// (`crate::campaign::pool`): worker `w` of `T` evaluates points
/// `w, w + T, w + 2T, ...` against one shared compile cache, and results
/// scatter back by point index, so the output order matches the sequential
/// enumeration exactly regardless of worker timing.
pub fn sweep_with(
    net: &DnnGraph,
    base: &SystemConfig,
    axes: &SweepAxes,
    opts: &SweepOptions,
) -> Vec<DesignPoint> {
    sweep_outcomes(net, base, axes, opts)
        .into_iter()
        .filter_map(EvalOutcome::point)
        .collect()
}

/// Like [`sweep_with`], but returns every grid point's *classified* outcome
/// (one entry per enumerated config, in grid order): feasible points carry
/// their [`DesignPoint`], infeasible tilings and genuine errors each carry
/// a diagnostic. This is the honest form of the sweep — [`sweep`] is the
/// feasible-only projection of it, so callers that must distinguish "hole
/// in the design space" from "broken sweep" (the campaign engine, reports)
/// use this.
pub fn sweep_outcomes(
    net: &DnnGraph,
    base: &SystemConfig,
    axes: &SweepAxes,
    opts: &SweepOptions,
) -> Vec<EvalOutcome> {
    let configs = expand_configs(base, axes);
    // Static pre-flight: when the lint passes prove the *net* can never
    // evaluate, every grid point is the same validation error — classify
    // the whole grid without waking the worker pool. Byte-identical to
    // the evaluated path: `resolve_classified` runs `net.validate()`
    // first, so each point's reason is exactly what evaluation would have
    // produced. The double-check of `net.validate()` keeps this a pure
    // short-circuit even if the lint pass ever over-approximates.
    if !opts.no_preflight
        && crate::analysis::passes::lint_net(net)
            .iter()
            .any(|d| d.severity == crate::analysis::Severity::Error)
    {
        if let Err(e) = net.validate() {
            return configs
                .into_iter()
                .map(|sys| EvalOutcome::Error {
                    name: sys.name.clone(),
                    reason: format!("invalid configuration: {e:#}"),
                })
                .collect();
        }
    }
    let cache = CompileCache::new(DSE_COMPILE_OPTS);
    crate::campaign::pool::parallel_map(configs.len(), opts.threads, |i| {
        let sys = &configs[i];
        evaluate_outcome(net, sys, sys.name.clone(), &cache)
    })
    .into_iter()
    .enumerate()
    .map(|(i, r)| {
        // A worker that panicked mid-evaluation degrades to an error row
        // for that point — the rest of the grid is unaffected.
        r.unwrap_or_else(|died| EvalOutcome::Error {
            name: configs[i].name.clone(),
            reason: format!("evaluation worker panicked: {}", died.message),
        })
    })
    .collect()
}

/// Pareto frontier: points not dominated in (latency, cost), sorted by
/// latency. Sort-based O(n log n): after ordering by (latency, cost,
/// input index), a point is on the frontier iff its cost is the minimum of
/// its latency group and strictly below every cheaper-latency group's
/// minimum — a single forward scan.
pub fn pareto(points: &[DesignPoint]) -> Vec<&DesignPoint> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_unstable_by(|&a, &b| {
        points[a]
            .latency_ps
            .cmp(&points[b].latency_ps)
            .then_with(|| points[a].cost.total_cmp(&points[b].cost))
            .then_with(|| a.cmp(&b))
    });
    let mut front: Vec<&DesignPoint> = Vec::new();
    // Min cost over all strictly-faster points seen so far.
    let mut best_faster_cost = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        let lat = points[idx[i]].latency_ps;
        let group_min = points[idx[i]].cost;
        let mut j = i;
        while j < idx.len() && points[idx[j]].latency_ps == lat {
            j += 1;
        }
        if group_min < best_faster_cost {
            // Frontier members of the group are exactly the (possibly
            // duplicated) minimum-cost points; ties keep input order.
            for &k in &idx[i..j] {
                if points[k].cost > group_min {
                    break;
                }
                front.push(&points[k]);
            }
            best_faster_cost = group_min;
        }
        i = j;
    }
    front
}

/// Bottom-up assessment (paper §2): annotated component -> system
/// performance. Alias of [`evaluate`] for readability at call sites.
pub fn bottomup(net: &DnnGraph, sys: &SystemConfig) -> Result<DesignPoint> {
    evaluate(net, sys, format!("{}_bottomup", sys.name))
}

/// Result of one [`solve_requirement`] call: the answer plus the work it
/// took, so callers (benches, the CLI) can assert the compile-reuse
/// contract — exactly one compilation on a retime-only axis.
#[derive(Debug, Clone)]
pub struct RequirementSolution {
    pub axis: Axis,
    /// Minimum axis value meeting the target, `None` if the target is
    /// unreachable even at the top of the range.
    pub value: Option<u64>,
    /// Latency probes performed (simulations).
    pub probes: usize,
    /// Compiler invocations across all probes: 1 for a retime-only axis,
    /// one per distinct probed value for a structural axis.
    pub compiles: u64,
}

/// Top-down assessment (paper §2), generalized over any scalar axis: given
/// a target end-to-end latency, derive the minimum axis value in
/// `range = (lo, hi)` that meets it, by binary search over the simulated
/// system (all other annotations fixed).
///
/// The search assumes latency is **non-increasing** in the axis value
/// (more frequency / bus width / buffer never hurts); a pre-check probes
/// both endpoints and returns a descriptive error if the range is visibly
/// non-monotone (latency strictly better at `lo` than at `hi`), instead of
/// silently bisecting garbage.
///
/// Probes share one [`CompileCache`], so the structural/retime split of
/// the axis decides the cost: a retime-only axis ([`Axis::NceFreqMhz`],
/// [`Axis::BusFreqMhz`]) compiles **once** and every probe is a pure
/// re-simulation; a structural axis compiles once per distinct probed
/// value. [`RequirementSolution::compiles`] reports the actual count.
pub fn solve_requirement(
    net: &DnnGraph,
    base: &SystemConfig,
    axis: Axis,
    target_latency_ps: u64,
    range: (u64, u64),
) -> Result<RequirementSolution> {
    if !axis.is_scalar() {
        bail!(
            "axis {} is not scalar-valued; the requirement solver needs a \
             totally ordered axis",
            axis.key()
        );
    }
    let (mut lo, mut hi) = range;
    // An inverted or zero range would not fail loudly: the two boundary
    // probes alone would "answer" with a value that means nothing.
    if lo == 0 || lo > hi {
        bail!(
            "{} range must satisfy 0 < lo <= hi, got ({lo}, {hi})",
            axis.key()
        );
    }
    let cache = CompileCache::new(DSE_COMPILE_OPTS);
    let probes = std::cell::Cell::new(0usize);
    let latency_at = |v: u64| -> Result<u64> {
        let mut sys = base.clone();
        axis.apply(&mut sys, AxisValue::Scalar(v))?;
        probes.set(probes.get() + 1);
        Ok(evaluate_cached(net, &sys, "probe", &cache)?.latency_ps)
    };
    let solution = |value: Option<u64>| RequirementSolution {
        axis,
        value,
        probes: probes.get(),
        compiles: cache.misses(),
    };
    let lat_hi = latency_at(hi)?;
    let lat_lo = if lo == hi { lat_hi } else { latency_at(lo)? };
    if lat_lo < lat_hi {
        bail!(
            "axis {} is not monotone over ({lo}, {hi}): latency {lat_lo} ps \
             at {lo} is below {lat_hi} ps at {hi}; the requirement solver \
             needs latency non-increasing in the axis value (the grid-scan \
             fallback — solve_requirement_scan, `avsm topdown --scan` — \
             handles non-monotone axes at O(range) probes)",
            axis.key()
        );
    }
    if lat_hi > target_latency_ps {
        return Ok(solution(None)); // unreachable even at the top of the range
    }
    if lat_lo <= target_latency_ps {
        return Ok(solution(Some(lo)));
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if latency_at(mid)? <= target_latency_ps {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok(solution(Some(hi)))
}

/// Exhaustive counterpart of [`solve_requirement`] for axes the binary
/// search refuses (carried ROADMAP item): an ascending O(range) grid scan
/// that returns the **smallest** axis value meeting the target with no
/// monotonicity assumption at all — correct on any latency shape, at
/// linear probe cost. `avsm topdown --scan` selects it.
///
/// Same [`RequirementSolution`] shape and the same compile-sharing
/// contract: all probes share one [`CompileCache`], so a retime-only axis
/// still compiles exactly once no matter how many values are probed. On a
/// monotone axis the answer equals the binary search's (property-tested);
/// the scan just pays `O(hi - lo)` probes for it instead of `O(log)`.
pub fn solve_requirement_scan(
    net: &DnnGraph,
    base: &SystemConfig,
    axis: Axis,
    target_latency_ps: u64,
    range: (u64, u64),
) -> Result<RequirementSolution> {
    if !axis.is_scalar() {
        bail!(
            "axis {} is not scalar-valued; the requirement solver needs a \
             totally ordered axis",
            axis.key()
        );
    }
    let (lo, hi) = range;
    if lo == 0 || lo > hi {
        bail!(
            "{} range must satisfy 0 < lo <= hi, got ({lo}, {hi})",
            axis.key()
        );
    }
    let cache = CompileCache::new(DSE_COMPILE_OPTS);
    let probes = std::cell::Cell::new(0usize);
    let latency_at = |v: u64| -> Result<u64> {
        let mut sys = base.clone();
        axis.apply(&mut sys, AxisValue::Scalar(v))?;
        probes.set(probes.get() + 1);
        Ok(evaluate_cached(net, &sys, "probe", &cache)?.latency_ps)
    };
    let solution = |value: Option<u64>| RequirementSolution {
        axis,
        value,
        probes: probes.get(),
        compiles: cache.misses(),
    };
    for v in lo..=hi {
        if latency_at(v)? <= target_latency_ps {
            return Ok(solution(Some(v)));
        }
    }
    Ok(solution(None))
}

/// The NCE-frequency instance of [`solve_requirement`], kept as a
/// compatibility wrapper: byte-identical answers to the historical
/// hand-rolled binary search (property-tested against it), one compilation
/// total.
pub fn topdown_min_nce_freq(
    net: &DnnGraph,
    base: &SystemConfig,
    target_latency_ps: u64,
    freq_range_mhz: (u64, u64),
) -> Result<Option<u64>> {
    Ok(solve_requirement(net, base, Axis::NceFreqMhz, target_latency_ps, freq_range_mhz)?.value)
}

/// JSON export of a sweep (plot data).
pub fn sweep_to_json(points: &[DesignPoint]) -> Value {
    Value::Array(points.iter().map(point_to_json).collect())
}

/// One design point's report object — shared by the tree serializer above
/// and the streaming report emitter, so the two cannot drift.
pub fn point_to_json(p: &DesignPoint) -> Value {
    obj(vec![
        ("name", p.name.as_str().into()),
        ("latency_ps", p.latency_ps.into()),
        ("cost", p.cost.into()),
        ("throughput_per_sec", p.throughput.into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn base() -> SystemConfig {
        SystemConfig::base_paper()
    }

    #[test]
    fn sweep_covers_grid_and_skips_infeasible() {
        let net = models::lenet(28);
        let axes = SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![125, 250]);
        let pts = sweep(&net, &base(), &axes);
        assert_eq!(pts.len(), 4);
        // All feasible here; distinct names.
        let mut names: Vec<&str> = pts.iter().map(|p| p.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), 4);
    }

    #[test]
    fn bigger_array_is_not_slower() {
        let net = models::dilated_vgg_tiny();
        let axes = SweepAxes::new().array_geometries(vec![(16, 32), (32, 64), (64, 64)]);
        let pts = sweep(&net, &base(), &axes);
        assert_eq!(pts.len(), 3);
        assert!(pts[0].latency_ps >= pts[1].latency_ps);
        assert!(pts[1].latency_ps >= pts[2].latency_ps);
    }

    #[test]
    fn faster_clock_reduces_latency_until_memory_bound() {
        let net = models::dilated_vgg_tiny();
        let axes = SweepAxes::new().nce_freqs_mhz(vec![125, 250, 500]);
        let pts = sweep(&net, &base(), &axes);
        assert!(pts[0].latency_ps > pts[1].latency_ps);
        assert!(pts[1].latency_ps >= pts[2].latency_ps);
    }

    #[test]
    fn parallel_sweep_is_byte_identical_to_sequential() {
        let net = models::lenet(28);
        let axes = SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64)])
            .nce_freqs_mhz(vec![125, 250, 500])
            .ifm_buffer_kib(vec![512, 1536]);
        let b = base();
        let par = sweep_with(&net, &b, &axes, &SweepOptions { threads: 4, ..Default::default() });
        let seq = sweep_seq(&net, &b, &axes);
        assert_eq!(par.len(), seq.len());
        assert_eq!(par.len(), 12);
        for (p, s) in par.iter().zip(&seq) {
            assert_eq!(p.name, s.name);
            assert_eq!(p.latency_ps, s.latency_ps, "{}", p.name);
            assert_eq!(p.sys, s.sys);
            assert_eq!(p.cost.to_bits(), s.cost.to_bits());
            assert_eq!(p.throughput.to_bits(), s.throughput.to_bits());
        }
    }

    #[test]
    fn cached_frequency_point_matches_from_scratch_compile() {
        // Warm the cache at the base clocks, then evaluate a point that
        // differs only in frequency annotations: it must hit the cache and
        // still produce exactly what a from-scratch compile+simulate does.
        let net = models::dilated_vgg_tiny();
        let b = base();
        let cache = CompileCache::new(DSE_COMPILE_OPTS);
        evaluate_cached(&net, &b, "warm", &cache).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));

        let mut sys = b.clone();
        sys.nce.freq_mhz = 425;
        sys.bus.freq_mhz = 300;
        sys.hkp.freq_mhz = 125;
        let cached = evaluate_cached(&net, &sys, "p", &cache).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        let scratch = evaluate(&net, &sys, "p").unwrap();
        assert_eq!(cached.latency_ps, scratch.latency_ps);
        assert_eq!(cached.cost.to_bits(), scratch.cost.to_bits());
        assert_eq!(cached.throughput.to_bits(), scratch.throughput.to_bits());
    }

    #[test]
    fn frequency_only_sweep_compiles_once() {
        let net = models::lenet(28);
        let axes = SweepAxes::new().nce_freqs_mhz(vec![125, 250, 500, 1000]);
        // The public sweep shares one cache internally; verify the same
        // sharing property directly through the cache it is built on.
        let cache = CompileCache::new(DSE_COMPILE_OPTS);
        for sys in expand_configs(&base(), &axes) {
            evaluate_cached(&net, &sys, sys.name.clone(), &cache).unwrap();
        }
        assert_eq!(cache.misses(), 1, "frequency axis must not recompile");
        assert_eq!(cache.hits(), 3);
    }

    #[test]
    fn pareto_front_is_monotone() {
        let net = models::lenet(28);
        let axes = SweepAxes::new()
            .array_geometries(vec![(8, 16), (16, 32), (32, 64)])
            .nce_freqs_mhz(vec![125, 250]);
        let pts = sweep(&net, &base(), &axes);
        let front = pareto(&pts);
        assert!(!front.is_empty());
        // Along the frontier, latency decreases while cost increases.
        for w in front.windows(2) {
            assert!(w[0].latency_ps <= w[1].latency_ps);
            assert!(w[0].cost >= w[1].cost);
        }
    }

    /// The O(n^2) dominance definition, kept as the reference oracle.
    fn naive_pareto(points: &[DesignPoint]) -> Vec<&DesignPoint> {
        let mut front: Vec<&DesignPoint> = Vec::new();
        for p in points {
            let dominated = points.iter().any(|q| {
                (q.latency_ps < p.latency_ps && q.cost <= p.cost)
                    || (q.latency_ps <= p.latency_ps && q.cost < p.cost)
            });
            if !dominated {
                front.push(p);
            }
        }
        front.sort_by_key(|p| p.latency_ps);
        front
    }

    #[test]
    fn pareto_matches_naive_reference_with_ties_and_duplicates() {
        let mk = |lat: u64, cost: f64, i: usize| DesignPoint {
            name: format!("p{i}"),
            sys: base(),
            latency_ps: lat,
            cost,
            throughput: 0.0,
        };
        let grid: &[(u64, f64)] = &[
            (10, 5.0),
            (10, 5.0),
            (10, 4.0),
            (20, 3.0),
            (20, 6.0),
            (5, 9.0),
            (30, 3.0),
            (30, 2.0),
            (40, 2.0),
            (7, 9.0),
            (20, 3.0), // duplicate frontier point
        ];
        let pts: Vec<DesignPoint> =
            grid.iter().enumerate().map(|(i, &(l, c))| mk(l, c, i)).collect();
        let fast = pareto(&pts);
        let slow = naive_pareto(&pts);
        assert_eq!(fast.len(), slow.len());
        for (a, b) in fast.iter().zip(&slow) {
            assert!(std::ptr::eq(*a, *b), "frontier mismatch: {} vs {}", a.name, b.name);
        }
    }

    #[test]
    fn pareto_of_empty_is_empty() {
        assert!(pareto(&[]).is_empty());
    }

    #[test]
    fn topdown_finds_minimum_frequency() {
        let net = models::lenet(28);
        let b = base();
        // Latency at 250 MHz is the baseline; ask for 1.5x that.
        let baseline = evaluate(&net, &b, "b").unwrap().latency_ps;
        let found = topdown_min_nce_freq(&net, &b, baseline * 3 / 2, (50, 1000))
            .unwrap()
            .expect("target should be reachable");
        assert!(found <= 250, "found {found} MHz");
        // Verify the answer actually meets the target.
        let mut sys = b.clone();
        sys.nce.freq_mhz = found;
        assert!(evaluate(&net, &sys, "v").unwrap().latency_ps <= baseline * 3 / 2);
        // And 20% below it does not (minimality, modulo memory-bound floor).
        if found > 60 {
            let mut sys = b.clone();
            sys.nce.freq_mhz = found * 4 / 5;
            assert!(evaluate(&net, &sys, "v").unwrap().latency_ps > baseline * 3 / 2);
        }
    }

    #[test]
    fn topdown_reports_unreachable_targets() {
        let net = models::lenet(28);
        let got = topdown_min_nce_freq(&net, &base(), 1, (50, 1000)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn topdown_rejects_inverted_and_zero_ranges() {
        let net = models::lenet(28);
        let b = base();
        // lo > hi: previously returned a silently-wrong answer from the two
        // boundary probes; must now be a descriptive error.
        let err = topdown_min_nce_freq(&net, &b, 1_000_000, (1000, 50)).unwrap_err();
        assert!(format!("{err:#}").contains("lo <= hi"), "{err:#}");
        // lo == 0 is not a probe-able frequency.
        let err = topdown_min_nce_freq(&net, &b, 1_000_000, (0, 1000)).unwrap_err();
        assert!(format!("{err:#}").contains("0 < lo"), "{err:#}");
        // Degenerate single-point range stays legal.
        assert!(topdown_min_nce_freq(&net, &b, 1, (250, 250)).is_ok());
    }

    #[test]
    fn sweep_outcomes_tell_errors_apart_from_infeasible() {
        let net = models::lenet(28);
        // One valid frequency, one invalid (0 MHz fails validation).
        let axes = SweepAxes::new().nce_freqs_mhz(vec![250, 0]);
        let outs = sweep_outcomes(&net, &base(), &axes, &SweepOptions { threads: 1, ..Default::default() });
        assert_eq!(outs.len(), 2);
        assert!(matches!(outs[0], EvalOutcome::Feasible(_)), "{:?}", outs[0]);
        match &outs[1] {
            EvalOutcome::Error { reason, .. } => {
                assert!(reason.contains("invalid configuration"), "{reason}")
            }
            other => panic!("0 MHz must classify as Error, got {other:?}"),
        }
        // The feasible-only projection drops it, as before.
        assert_eq!(sweep(&net, &base(), &axes).len(), 1);
    }

    #[test]
    fn sweep_outcomes_classify_true_tiling_infeasibility() {
        // The 512-wide 4-byte input row cannot fit a 1 KiB IFM buffer (see
        // compiler::cache tests) — a genuine hole in the design space.
        let net = models::dilated_vgg(512, 4, 16);
        let mut tiny = base();
        tiny.nce.ifm_buffer_kib = 1;
        tiny.nce.weight_buffer_kib = 1;
        tiny.nce.ofm_buffer_kib = 1;
        let outs =
            sweep_outcomes(&net, &tiny, &SweepAxes::default(), &SweepOptions { threads: 1, ..Default::default() });
        assert_eq!(outs.len(), 1);
        assert!(
            matches!(outs[0], EvalOutcome::Infeasible { .. }),
            "tiny buffers must classify as Infeasible, got {:?}",
            outs[0]
        );
    }

    #[test]
    fn solver_compiles_once_on_retime_only_axes() {
        // The compile-reuse contract the axis abstraction exists to
        // state: every binary-search probe of a retime-only axis shares
        // one compilation.
        let net = models::lenet(28);
        let b = base();
        let baseline = evaluate(&net, &b, "b").unwrap().latency_ps;
        for axis in [Axis::NceFreqMhz, Axis::BusFreqMhz] {
            let sol =
                solve_requirement(&net, &b, axis, baseline * 2, (50, 1000)).unwrap();
            assert_eq!(sol.compiles, 1, "{}: retime-only axis must compile once", axis.key());
            assert!(sol.probes >= 2, "{}", axis.key());
            assert!(sol.value.is_some(), "{}: 2x baseline must be reachable", axis.key());
        }
    }

    #[test]
    fn solver_answers_match_direct_evaluation_on_structural_axis() {
        // Bus width is structural: each probed value re-tiles. The answer
        // must still be the minimal width meeting the target, and the
        // compile count must equal the distinct probed values.
        let net = models::dilated_vgg_tiny();
        let b = base();
        let baseline = evaluate(&net, &b, "b").unwrap().latency_ps;
        let sol = solve_requirement(
            &net,
            &b,
            Axis::BusBytesPerCycle,
            baseline * 11 / 10,
            (4, 64),
        )
        .unwrap();
        let w = sol.value.expect("10% above baseline reachable at base width or below");
        assert!(w <= 32, "base width already meets an easier target, got {w}");
        assert_eq!(sol.compiles as usize, sol.probes, "structural axis: compile per probe");
        // The answer actually meets the target...
        let mut sys = b.clone();
        sys.bus.bytes_per_cycle = w;
        assert!(evaluate(&net, &sys, "v").unwrap().latency_ps <= baseline * 11 / 10);
        // ...and one step below does not (minimality).
        if w > 4 {
            let mut sys = b.clone();
            sys.bus.bytes_per_cycle = w - 1;
            assert!(evaluate(&net, &sys, "v").unwrap().latency_ps > baseline * 11 / 10);
        }
    }

    #[test]
    fn solver_rejects_pair_valued_axes() {
        let net = models::lenet(28);
        let err = solve_requirement(&net, &base(), Axis::ArrayGeometry, 1, (1, 2)).unwrap_err();
        assert!(format!("{err:#}").contains("not scalar"), "{err:#}");
        let err =
            solve_requirement_scan(&net, &base(), Axis::ArrayGeometry, 1, (1, 2)).unwrap_err();
        assert!(format!("{err:#}").contains("not scalar"), "{err:#}");
    }

    #[test]
    fn grid_scan_agrees_with_binary_search_on_monotone_axes() {
        // The fallback's correctness anchor: wherever the binary search is
        // willing to answer, the O(range) scan must return the same
        // minimal value — at several targets, including an unreachable
        // one (both must say None).
        let net = models::lenet(28);
        let b = base();
        let baseline = evaluate(&net, &b, "b").unwrap().latency_ps;
        let range = (50, 80); // small: the scan probes every value
        for target in [baseline / 4, baseline, baseline * 2, baseline * 100] {
            let fast = solve_requirement(&net, &b, Axis::NceFreqMhz, target, range);
            let slow = solve_requirement_scan(&net, &b, Axis::NceFreqMhz, target, range)
                .unwrap();
            match fast {
                Ok(fast) => assert_eq!(fast.value, slow.value, "target {target}"),
                // The binary search may refuse a range it can't certify;
                // the scan never refuses. No cross-check possible then.
                Err(e) => panic!("monotone axis refused: {e:#}"),
            }
        }
    }

    #[test]
    fn grid_scan_shares_one_compile_on_retime_axes() {
        // Same compile-reuse contract as the binary search: a retime-only
        // axis pays one compilation no matter how many values the scan
        // probes (here: the whole range, for an unreachable target).
        let net = models::lenet(28);
        let b = base();
        let sol = solve_requirement_scan(&net, &b, Axis::NceFreqMhz, 1, (50, 70)).unwrap();
        assert_eq!(sol.value, None, "1 ps is unreachable");
        assert_eq!(sol.probes, 21, "scan probes every value in range");
        assert_eq!(sol.compiles, 1, "retime-only axis compiles once");
        // And the scan validates its range like the search does.
        let err = solve_requirement_scan(&net, &b, Axis::NceFreqMhz, 1, (10, 5)).unwrap_err();
        assert!(format!("{err:#}").contains("0 < lo <= hi"), "{err:#}");
    }

    #[test]
    fn sweep_json_export() {
        let net = models::lenet(28);
        let pts = sweep(&net, &base(), &SweepAxes::default());
        let j = sweep_to_json(&pts);
        assert_eq!(j.as_array().unwrap().len(), pts.len());
    }
}
