//! Design-space exploration — the paper's motivating use case: evaluating
//! many hardware/software design points "by a click of a button" instead of
//! one physical prototype per point.
//!
//! * [`sweep`] — cartesian sweeps over NCE geometry, frequencies, bus
//!   widths and buffer sizes, simulating each point (traces disabled,
//!   labels off: the fast path).
//! * [`topdown`] — the paper's §2 "top-down" mode: given a target
//!   performance, derive the physical requirement (e.g. minimum NCE
//!   frequency); `bottomup` is the ordinary estimate for annotated
//!   components.
//! * [`pareto`] — extract the latency/cost frontier.

use crate::compiler::{compile, CompileOptions};
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::hw::simulate_avsm;
use crate::json::{obj, Value};
use crate::sim::TraceRecorder;
use anyhow::Result;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub name: String,
    pub sys: SystemConfig,
    /// Simulated end-to-end inference latency.
    pub latency_ps: u64,
    /// Crude area/cost proxy: number of multipliers + KiB of on-chip RAM.
    pub cost: f64,
    /// Simulated inferences per second.
    pub throughput: f64,
}

/// Parameter axes for a sweep. Empty axes keep the base value.
#[derive(Debug, Clone, Default)]
pub struct SweepAxes {
    pub array_geometries: Vec<(u32, u32)>,
    pub nce_freqs_mhz: Vec<u64>,
    pub bus_bytes_per_cycle: Vec<u64>,
    pub ifm_buffer_kib: Vec<u32>,
}

impl SweepAxes {
    fn or_base<'a, T: Clone>(axis: &'a [T], base: &'a T) -> Vec<T> {
        if axis.is_empty() {
            vec![base.clone()]
        } else {
            axis.to_vec()
        }
    }
}

fn cost_proxy(sys: &SystemConfig) -> f64 {
    let mults = sys.nce.macs_per_cycle() as f64;
    let ram_kib = (sys.nce.ifm_buffer_kib + sys.nce.weight_buffer_kib + sys.nce.ofm_buffer_kib)
        as f64;
    mults + 2.0 * ram_kib
}

/// Evaluate one design point (compile + simulate, fast path).
pub fn evaluate(net: &DnnGraph, sys: &SystemConfig, name: impl Into<String>) -> Result<DesignPoint> {
    let compiled = compile(
        net,
        sys,
        CompileOptions { double_buffer: true, labels: false },
    )?;
    let mut trace = TraceRecorder::disabled();
    let sim = simulate_avsm(&compiled, sys, &mut trace);
    Ok(DesignPoint {
        name: name.into(),
        sys: sys.clone(),
        latency_ps: sim.total_ps,
        cost: cost_proxy(sys),
        throughput: 1e12 / sim.total_ps as f64,
    })
}

/// Cartesian sweep around a base system. Infeasible points (tiling fails)
/// are skipped.
pub fn sweep(net: &DnnGraph, base: &SystemConfig, axes: &SweepAxes) -> Vec<DesignPoint> {
    let geoms = SweepAxes::or_base(
        &axes.array_geometries,
        &(base.nce.array_rows, base.nce.array_cols),
    );
    let freqs = SweepAxes::or_base(&axes.nce_freqs_mhz, &base.nce.freq_mhz);
    let widths = SweepAxes::or_base(&axes.bus_bytes_per_cycle, &base.bus.bytes_per_cycle);
    let ifms = SweepAxes::or_base(&axes.ifm_buffer_kib, &base.nce.ifm_buffer_kib);
    let mut points = Vec::new();
    for &(rows, cols) in &geoms {
        for &f in &freqs {
            for &w in &widths {
                for &ifm in &ifms {
                    let mut sys = base.clone();
                    sys.nce.array_rows = rows;
                    sys.nce.array_cols = cols;
                    sys.nce.freq_mhz = f;
                    sys.bus.bytes_per_cycle = w;
                    sys.nce.ifm_buffer_kib = ifm;
                    sys.name = format!("nce{rows}x{cols}_f{f}_bus{w}_ifm{ifm}");
                    if let Ok(p) = evaluate(net, &sys, sys.name.clone()) {
                        points.push(p);
                    }
                }
            }
        }
    }
    points
}

/// Pareto frontier: points not dominated in (latency, cost).
pub fn pareto(points: &[DesignPoint]) -> Vec<&DesignPoint> {
    let mut front: Vec<&DesignPoint> = Vec::new();
    for p in points {
        let dominated = points.iter().any(|q| {
            (q.latency_ps < p.latency_ps && q.cost <= p.cost)
                || (q.latency_ps <= p.latency_ps && q.cost < p.cost)
        });
        if !dominated {
            front.push(p);
        }
    }
    front.sort_by_key(|p| p.latency_ps);
    front
}

/// Bottom-up assessment (paper §2): annotated component -> system
/// performance. Alias of [`evaluate`] for readability at call sites.
pub fn bottomup(net: &DnnGraph, sys: &SystemConfig) -> Result<DesignPoint> {
    evaluate(net, sys, format!("{}_bottomup", sys.name))
}

/// Top-down assessment (paper §2): given a target end-to-end latency,
/// derive the minimum NCE frequency that meets it (binary search over the
/// simulated system; other annotations fixed).
pub fn topdown_min_nce_freq(
    net: &DnnGraph,
    base: &SystemConfig,
    target_latency_ps: u64,
    freq_range_mhz: (u64, u64),
) -> Result<Option<u64>> {
    let (mut lo, mut hi) = freq_range_mhz;
    let latency_at = |mhz: u64| -> Result<u64> {
        let mut sys = base.clone();
        sys.nce.freq_mhz = mhz;
        Ok(evaluate(net, &sys, "probe")?.latency_ps)
    };
    if latency_at(hi)? > target_latency_ps {
        return Ok(None); // unreachable even at the top of the range
    }
    if latency_at(lo)? <= target_latency_ps {
        return Ok(Some(lo));
    }
    while lo + 1 < hi {
        let mid = (lo + hi) / 2;
        if latency_at(mid)? <= target_latency_ps {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Ok(Some(hi))
}

/// JSON export of a sweep (plot data).
pub fn sweep_to_json(points: &[DesignPoint]) -> Value {
    Value::Array(
        points
            .iter()
            .map(|p| {
                obj(vec![
                    ("name", p.name.as_str().into()),
                    ("latency_ps", p.latency_ps.into()),
                    ("cost", p.cost.into()),
                    ("throughput_per_sec", p.throughput.into()),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn base() -> SystemConfig {
        SystemConfig::base_paper()
    }

    #[test]
    fn sweep_covers_grid_and_skips_infeasible() {
        let net = models::lenet(28);
        let axes = SweepAxes {
            array_geometries: vec![(16, 32), (32, 64)],
            nce_freqs_mhz: vec![125, 250],
            ..Default::default()
        };
        let pts = sweep(&net, &base(), &axes);
        assert_eq!(pts.len(), 4);
        // All feasible here; distinct names.
        let mut names: Vec<&str> = pts.iter().map(|p| p.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), 4);
    }

    #[test]
    fn bigger_array_is_not_slower() {
        let net = models::dilated_vgg_tiny();
        let axes = SweepAxes {
            array_geometries: vec![(16, 32), (32, 64), (64, 64)],
            ..Default::default()
        };
        let pts = sweep(&net, &base(), &axes);
        assert_eq!(pts.len(), 3);
        assert!(pts[0].latency_ps >= pts[1].latency_ps);
        assert!(pts[1].latency_ps >= pts[2].latency_ps);
    }

    #[test]
    fn faster_clock_reduces_latency_until_memory_bound() {
        let net = models::dilated_vgg_tiny();
        let axes = SweepAxes { nce_freqs_mhz: vec![125, 250, 500], ..Default::default() };
        let pts = sweep(&net, &base(), &axes);
        assert!(pts[0].latency_ps > pts[1].latency_ps);
        assert!(pts[1].latency_ps >= pts[2].latency_ps);
    }

    #[test]
    fn pareto_front_is_monotone() {
        let net = models::lenet(28);
        let axes = SweepAxes {
            array_geometries: vec![(8, 16), (16, 32), (32, 64)],
            nce_freqs_mhz: vec![125, 250],
            ..Default::default()
        };
        let pts = sweep(&net, &base(), &axes);
        let front = pareto(&pts);
        assert!(!front.is_empty());
        // Along the frontier, latency decreases while cost increases.
        for w in front.windows(2) {
            assert!(w[0].latency_ps <= w[1].latency_ps);
            assert!(w[0].cost >= w[1].cost);
        }
    }

    #[test]
    fn topdown_finds_minimum_frequency() {
        let net = models::lenet(28);
        let b = base();
        // Latency at 250 MHz is the baseline; ask for 1.5x that.
        let baseline = evaluate(&net, &b, "b").unwrap().latency_ps;
        let found = topdown_min_nce_freq(&net, &b, baseline * 3 / 2, (50, 1000))
            .unwrap()
            .expect("target should be reachable");
        assert!(found <= 250, "found {found} MHz");
        // Verify the answer actually meets the target.
        let mut sys = b.clone();
        sys.nce.freq_mhz = found;
        assert!(evaluate(&net, &sys, "v").unwrap().latency_ps <= baseline * 3 / 2);
        // And 20% below it does not (minimality, modulo memory-bound floor).
        if found > 60 {
            let mut sys = b.clone();
            sys.nce.freq_mhz = found * 4 / 5;
            assert!(evaluate(&net, &sys, "v").unwrap().latency_ps > baseline * 3 / 2);
        }
    }

    #[test]
    fn topdown_reports_unreachable_targets() {
        let net = models::lenet(28);
        let got = topdown_min_nce_freq(&net, &base(), 1, (50, 1000)).unwrap();
        assert!(got.is_none());
    }

    #[test]
    fn sweep_json_export() {
        let net = models::lenet(28);
        let pts = sweep(&net, &base(), &SweepAxes::default());
        let j = sweep_to_json(&pts);
        assert_eq!(j.as_array().unwrap().len(), pts.len());
    }
}
