//! Roofline analysis (paper Fig 6/7, after Williams et al.).
//!
//! Per layer: operational intensity (ops moved per byte of external
//! traffic) on the x-axis, achieved performance (ops/s over the simulated
//! layer window) on the y-axis, bounded by the bandwidth slope and the NCE
//! peak. Dot "size" is the layer's share of total inference time, as in the
//! paper's figures. Layers close to the vertical compute roof are
//! compute-bound (Conv4_0–Conv4_5 in Fig 7); layers on the bandwidth slope
//! are communication-bound; layers well below both roofs are "neither" —
//! limited by array under-utilization or dependency stalls, the cases the
//! paper calls out as needing compiler/architecture changes rather than
//! more peak compute or bandwidth.

use crate::config::SystemConfig;
use crate::hw::SimResult;
use crate::json::{obj, Value};

/// One dot of the roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub layer: String,
    /// Operational intensity, ops/byte (compiled traffic, not ideal).
    pub intensity: f64,
    /// Achieved performance over the layer window, ops/s.
    pub achieved_ops: f64,
    /// Attainable at this intensity: min(peak, intensity * bandwidth).
    pub attainable_ops: f64,
    /// Share of total inference time (the dot size in Fig 6).
    pub time_share: f64,
    pub bound: RoofBound,
}

/// Which roof limits the layer (the paper's taxonomy).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoofBound {
    /// At ≥ `NEAR` of the compute roof.
    Compute,
    /// At ≥ `NEAR` of the bandwidth slope (and below the ridge).
    Bandwidth,
    /// Below both — array under-utilization / latency / dependencies.
    Neither,
}

impl std::fmt::Display for RoofBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            RoofBound::Compute => "compute-bound",
            RoofBound::Bandwidth => "bandwidth-bound",
            RoofBound::Neither => "neither",
        })
    }
}

/// Fraction of the limiting roof a layer must reach to be "bound" by it.
pub const NEAR: f64 = 0.75;

/// The whole model: roofs plus one point per layer.
#[derive(Debug, Clone)]
pub struct RooflineModel {
    pub peak_ops: f64,
    pub bandwidth_bytes: f64,
    pub ridge: f64,
    pub points: Vec<RooflinePoint>,
}

impl RooflineModel {
    /// Build from a simulation result. Uses arithmetic ops (2/MAC for conv,
    /// vector-op counts otherwise) so non-conv layers land at honest spots.
    pub fn from_sim(sys: &SystemConfig, sim: &SimResult, arith_ops: &[u64]) -> Self {
        let peak = sys.nce.peak_ops_per_sec();
        // The attainable slope is the *system* streaming bandwidth: the
        // slower of bus and memory interface.
        let mem_bw = sys.memory.data_bytes_per_cycle as f64 * sys.memory.freq_mhz as f64 * 1e6;
        let bw = sys.bus.peak_bytes_per_sec().min(mem_bw);
        let ridge = peak / bw;
        let total: u64 = sim.total_ps.max(1);
        let points = sim
            .layers
            .iter()
            .zip(arith_ops)
            .map(|(l, &ops)| {
                let secs = l.duration_ps() as f64 / 1e12;
                let achieved = ops as f64 / secs.max(1e-15);
                let intensity = ops as f64 / l.dma_bytes.max(1) as f64;
                let attainable = peak.min(intensity * bw);
                let bound = if achieved >= NEAR * peak {
                    RoofBound::Compute
                } else if intensity < ridge && achieved >= NEAR * intensity * bw {
                    RoofBound::Bandwidth
                } else {
                    RoofBound::Neither
                };
                RooflinePoint {
                    layer: l.name.clone(),
                    intensity,
                    achieved_ops: achieved,
                    attainable_ops: attainable,
                    time_share: l.duration_ps() as f64 / total as f64,
                    bound,
                }
            })
            .collect();
        Self { peak_ops: peak, bandwidth_bytes: bw, ridge, points }
    }

    pub fn point(&self, layer: &str) -> Option<&RooflinePoint> {
        self.points.iter().find(|p| p.layer == layer)
    }

    /// Points with intensity ≥ `min_intensity` — the Fig 7 zoom onto the
    /// compute-bound cluster.
    pub fn zoom(&self, min_intensity: f64) -> Vec<&RooflinePoint> {
        self.points.iter().filter(|p| p.intensity >= min_intensity).collect()
    }

    /// Text rendering (log-x) for terminals; also the Fig 6 artifact.
    pub fn render_text(&self, zoom: Option<f64>) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "roofline: peak {:.3} Tops/s, bw {:.2} GB/s, ridge {:.1} ops/B\n",
            self.peak_ops / 1e12,
            self.bandwidth_bytes / 1e9,
            self.ridge
        ));
        out.push_str(&format!(
            "{:<12} {:>12} {:>14} {:>14} {:>7} {:>6}  bound\n",
            "layer", "ops/B", "achieved", "attainable", "%roof", "share"
        ));
        let pts: Vec<&RooflinePoint> = match zoom {
            Some(z) => self.zoom(z),
            None => self.points.iter().collect(),
        };
        for p in pts {
            out.push_str(&format!(
                "{:<12} {:>12.2} {:>11.1} Gops {:>11.1} Gops {:>6.1}% {:>5.1}%  {}\n",
                p.layer,
                p.intensity,
                p.achieved_ops / 1e9,
                p.attainable_ops / 1e9,
                100.0 * p.achieved_ops / p.attainable_ops.max(1.0),
                100.0 * p.time_share,
                p.bound
            ));
        }
        out
    }

    /// JSON export (plot data for Fig 6/7).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("peak_ops_per_sec", self.peak_ops.into()),
            ("bandwidth_bytes_per_sec", self.bandwidth_bytes.into()),
            ("ridge_ops_per_byte", self.ridge.into()),
            (
                "points",
                Value::Array(
                    self.points
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("layer", p.layer.as_str().into()),
                                ("intensity", p.intensity.into()),
                                ("achieved_ops", p.achieved_ops.into()),
                                ("attainable_ops", p.attainable_ops.into()),
                                ("time_share", p.time_share.into()),
                                ("bound", p.bound.to_string().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// SVG rendering of the roofline plot (log-log), dots sized by time
    /// share — the shape of the paper's Fig 6/7.
    pub fn render_svg(&self, zoom: Option<f64>) -> String {
        self.render_svg_with_legend(zoom, &[])
    }

    /// [`render_svg`](Self::render_svg) plus a trailing axis-name legend
    /// caption (see `report::campaign::axis_legend`) decoding swept-axis
    /// name tokens for readers of campaign artifacts. An empty legend
    /// renders byte-identically to the plain form.
    pub fn render_svg_with_legend(
        &self,
        zoom: Option<f64>,
        legend: &[(&'static str, String)],
    ) -> String {
        let w = 720.0;
        let h = 480.0;
        let hsvg = h + if legend.is_empty() { 0.0 } else { 16.0 };
        let ml = 70.0;
        let mb = 50.0;
        let pts: Vec<&RooflinePoint> = match zoom {
            Some(z) => self.zoom(z),
            None => self.points.iter().collect(),
        };
        // Axis bounds. An empty point set (zoom filtered everything out, or
        // a model with no layers) must not fold to `f64::MAX * 0.5 > xmax`
        // — that yields NaN/degenerate coordinates. Fall back to a window
        // around the ridge so the roofs alone still render, and keep
        // `xmin < xmax` under every zoom value.
        let xmin: f64 = zoom
            .unwrap_or_else(|| {
                if pts.is_empty() {
                    (self.ridge * 0.25).max(0.1)
                } else {
                    pts.iter().map(|p| p.intensity).fold(f64::MAX, f64::min).max(0.1) * 0.5
                }
            })
            .max(1e-6);
        let xmax = (pts
            .iter()
            .map(|p| p.intensity)
            .fold(self.ridge, f64::max)
            * 4.0)
            .max(xmin * 2.0);
        let ymax = self.peak_ops * 2.0;
        let ymin = (pts
            .iter()
            .map(|p| p.achieved_ops)
            .fold(self.peak_ops, f64::min)
            * 0.3)
            .max(f64::MIN_POSITIVE);
        let x = |v: f64| ml + (v.ln() - xmin.ln()) / (xmax.ln() - xmin.ln()) * (w - ml - 20.0);
        let y = |v: f64| {
            h - mb - (v.ln() - ymin.ln()) / (ymax.ln() - ymin.ln()) * (h - mb - 20.0)
        };
        let mut s = format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{hsvg}" font-family="monospace" font-size="11">"#
        );
        s.push_str(&format!(
            r#"<rect width="{w}" height="{hsvg}" fill="white"/>"#
        ));
        // Bandwidth slope from xmin to ridge, then flat peak roof.
        let ridge_x = x(self.ridge);
        s.push_str(&format!(
            r#"<polyline fill="none" stroke="black" stroke-width="1.5" points="{:.1},{:.1} {:.1},{:.1} {:.1},{:.1}"/>"#,
            x(xmin),
            y(xmin * self.bandwidth_bytes),
            ridge_x,
            y(self.peak_ops),
            x(xmax),
            y(self.peak_ops),
        ));
        s.push_str(&format!(
            r#"<line x1="{rx:.1}" y1="{:.1}" x2="{rx:.1}" y2="{:.1}" stroke="gray" stroke-dasharray="4"/>"#,
            y(ymin),
            y(self.peak_ops),
            rx = ridge_x,
        ));
        for p in &pts {
            let r = 3.0 + 22.0 * p.time_share.sqrt();
            let color = match p.bound {
                RoofBound::Compute => "#c0392b",
                RoofBound::Bandwidth => "#2980b9",
                RoofBound::Neither => "#7f8c8d",
            };
            s.push_str(&format!(
                r#"<circle cx="{:.1}" cy="{:.1}" r="{:.1}" fill="{color}" fill-opacity="0.55"/>"#,
                x(p.intensity.max(xmin)),
                y(p.achieved_ops.max(ymin)),
                r
            ));
            s.push_str(&format!(
                r#"<text x="{:.1}" y="{:.1}">{}</text>"#,
                x(p.intensity.max(xmin)) + r + 2.0,
                y(p.achieved_ops.max(ymin)) + 4.0,
                p.layer
            ));
        }
        s.push_str(&format!(
            r#"<text x="{}" y="{}">operational intensity [ops/B] (log)</text>"#,
            w / 2.0 - 100.0,
            h - 12.0
        ));
        s.push_str(&format!(
            r#"<text x="14" y="{}" transform="rotate(-90 14 {})">performance [ops/s] (log)</text>"#,
            h / 2.0 + 60.0,
            h / 2.0 + 60.0
        ));
        if !legend.is_empty() {
            let entries: Vec<String> =
                legend.iter().map(|(key, desc)| format!("{key} = {desc}")).collect();
            s.push_str(&format!(
                r#"<text x="4" y="{:.0}">name legend: {}</text>"#,
                hsvg - 6.0,
                entries.join(", ")
            ));
        }
        s.push_str("</svg>");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::models;
    use crate::hw::simulate_avsm;
    use crate::sim::TraceRecorder;

    fn model_for(net: &crate::graph::DnnGraph) -> RooflineModel {
        let sys = SystemConfig::base_paper();
        let c = compile(net, &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&c, &sys, &mut tr);
        let ops: Vec<u64> = net.layer_costs().iter().map(|c| c.arith_ops).collect();
        RooflineModel::from_sim(&sys, &sim, &ops)
    }

    #[test]
    fn conv4_cluster_is_compute_bound_near_roof() {
        // Fig 7: Conv4_0–Conv4_5 sit close to the vertical threshold.
        let m = model_for(&models::dilated_vgg_paper());
        for i in 0..6 {
            let p = m.point(&format!("conv4_{i}")).unwrap();
            assert_eq!(p.bound, RoofBound::Compute, "conv4_{i}: {p:?}");
            assert!(p.intensity > m.ridge * 0.8, "conv4_{i} intensity {}", p.intensity);
        }
    }

    #[test]
    fn pools_sit_on_bandwidth_slope() {
        let m = model_for(&models::dilated_vgg_paper());
        for name in ["pool1", "pool2", "pool3"] {
            let p = m.point(name).unwrap();
            assert_eq!(p.bound, RoofBound::Bandwidth, "{name}: {p:?}");
        }
    }

    #[test]
    fn some_layers_are_neither_bound() {
        // Fig 6's point: some layers would not speed up from more peak
        // compute or more bandwidth.
        let m = model_for(&models::dilated_vgg_paper());
        let neither: Vec<&str> = m
            .points
            .iter()
            .filter(|p| p.bound == RoofBound::Neither)
            .map(|p| p.layer.as_str())
            .collect();
        assert!(!neither.is_empty(), "expected at least one neither-bound layer");
    }

    #[test]
    fn time_shares_sum_to_one() {
        let m = model_for(&models::dilated_vgg_paper());
        let sum: f64 = m.points.iter().map(|p| p.time_share).sum();
        assert!((sum - 1.0).abs() < 1e-9, "shares sum {sum}");
    }

    #[test]
    fn achieved_never_exceeds_peak() {
        let m = model_for(&models::dilated_vgg_paper());
        for p in &m.points {
            assert!(
                p.achieved_ops <= m.peak_ops * 1.001,
                "{} exceeds peak: {:.2e}", p.layer, p.achieved_ops
            );
        }
    }

    #[test]
    fn zoom_filters_low_intensity() {
        let m = model_for(&models::dilated_vgg_paper());
        let zoomed = m.zoom(m.ridge * 0.8);
        assert!(zoomed.len() < m.points.len());
        assert!(zoomed.iter().all(|p| p.intensity >= m.ridge * 0.8));
    }

    #[test]
    fn empty_zoom_still_renders_finite_svg_and_text() {
        // A zoom threshold above every layer's intensity filters out all
        // points; the renders must stay finite (previously the empty fold
        // produced xmin = f64::MAX * 0.5 > xmax and NaN coordinates).
        let m = model_for(&models::dilated_vgg_tiny());
        let huge = m.points.iter().map(|p| p.intensity).fold(0.0, f64::max) * 10.0;
        assert!(m.zoom(huge).is_empty(), "fixture zoom must filter everything");
        let svg = m.render_svg(Some(huge));
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>"));
        assert!(!svg.contains("NaN") && !svg.contains("inf"), "{svg}");
        // The roofs themselves still render.
        assert!(svg.contains("polyline"));
        let txt = m.render_text(Some(huge));
        assert!(txt.contains("roofline"));
    }

    #[test]
    fn renders_text_svg_json() {
        let m = model_for(&models::dilated_vgg_tiny());
        let txt = m.render_text(None);
        assert!(txt.contains("roofline") && txt.contains("conv4_0"));
        let svg = m.render_svg(None);
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>"));
        assert!(svg.contains("circle"));
        let json = m.to_json();
        assert!(json.get("points").as_array().unwrap().len() == m.points.len());
    }

    #[test]
    fn svg_legend_caption_decodes_axis_tokens() {
        let m = model_for(&models::dilated_vgg_tiny());
        let legend = vec![
            ("f", "NCE frequency (MHz)".to_string()),
            ("g", "array geometry (rows x cols)".to_string()),
        ];
        let svg = m.render_svg_with_legend(None, &legend);
        assert!(
            svg.contains("name legend: f = NCE frequency (MHz), g = array geometry (rows x cols)"),
            "{svg}"
        );
        // The legend-free form is byte-identical to plain render_svg.
        assert_eq!(m.render_svg_with_legend(None, &[]), m.render_svg(None));
        assert!(!m.render_svg(None).contains("name legend"));
    }
}
