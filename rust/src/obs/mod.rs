//! obs — lightweight span/counter telemetry for the exploration engine.
//!
//! The simulator can already render a *workload's* schedule as a Chrome
//! trace; this module gives the campaign engine the same treatment for
//! its *own* execution. Every unit's lifecycle is recorded as spans
//! (`resolve`, `compile`, `cache.read`, `cache.write`, `lock.wait`,
//! `lock.steal`, `bound`, `simulate`, `skipped`, `journal.append`) tagged
//! with the recording worker, the net, the unit sequence number, and an
//! outcome class. A process-global recorder aggregates them; snapshots
//! feed the `avsm-campaign-telemetry-v1` report
//! ([`crate::report::TelemetryReport`]) and the per-worker engine
//! timeline ([`crate::trace::spans_to_chrome_trace`]).
//!
//! Design constraints, in order:
//!
//! 1. **Zero-cost when disabled.** The hot campaign loops are
//!    monomorphized over an `OBS` const (the same idiom as the
//!    simulator's `TRACED` fast path), so the disabled build of the
//!    per-unit path contains no telemetry code at all. The deeper,
//!    colder sites (cache I/O, lock acquisition, journal appends) guard
//!    on one relaxed atomic load — the same fast path as
//!    [`crate::testkit::faults`].
//! 2. **Zero interference when enabled.** Recording never changes what a
//!    campaign computes: spans are observations only, and the property
//!    suite pins frontiers byte-identical with telemetry on vs. off at
//!    1 and N threads (and the full report single-threaded, where it is
//!    run-to-run deterministic to begin with).
//! 3. **No seeded clock.** Timestamps are nanoseconds since a
//!    process-wide [`Instant`] epoch captured at first enable —
//!    monotonic, comparable across threads, and never consulted unless
//!    recording is on (determinism elsewhere stays clock-free).
//!
//! Enabling is refcounted ([`recording`] returns an RAII guard) so
//! concurrently running tests can each record without clobbering one
//! another; they isolate by filtering snapshots on their own net names.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// One recorded interval (or instant, when `start_ns == end_ns`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Span {
    /// Span kind from the fixed vocabulary (`resolve`, `simulate`, ...).
    pub kind: &'static str,
    /// Recording thread: 0 is the coordinating thread (also the inline
    /// single-thread path), pool workers are 1..=threads.
    pub worker: u32,
    /// Net name, for per-unit spans.
    pub net: Option<String>,
    /// Campaign unit sequence number, for per-unit spans.
    pub unit: Option<u64>,
    /// Outcome class (`ok`, `compiled`, `feasible`, `panicked`, ...).
    /// Spans dropped during a panic unwind are marked `panicked`
    /// regardless of what the site set.
    pub outcome: &'static str,
    /// Nanoseconds since the recorder epoch.
    pub start_ns: u64,
    pub end_ns: u64,
}

/// A snapshot of everything recorded so far: raw spans plus named
/// monotonic counters (cache tier totals, pushed by the campaign).
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    pub spans: Vec<Span>,
    pub counters: BTreeMap<String, u64>,
}

#[derive(Default)]
struct State {
    spans: Vec<Span>,
    counters: BTreeMap<String, u64>,
}

/// Fast-path gate: one relaxed load on every guarded site.
static ENABLED: AtomicBool = AtomicBool::new(false);
/// Refcount behind [`ENABLED`], so overlapping recordings compose.
static REFS: AtomicU64 = AtomicU64::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static STATE: Mutex<State> = Mutex::new(State {
    spans: Vec::new(),
    counters: BTreeMap::new(),
});

thread_local! {
    /// Worker id of the current thread; 0 (the coordinator) unless the
    /// campaign pool claimed this thread via [`set_worker`].
    static WORKER: Cell<u32> = const { Cell::new(0) };
}

/// Is recording currently on? One relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn recording on (refcounted, never turned off by this call — the
/// CLI enables once for the process). Prefer [`recording`] in tests.
pub fn enable() {
    EPOCH.get_or_init(Instant::now);
    REFS.fetch_add(1, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
}

fn disable() {
    if REFS.fetch_sub(1, Ordering::SeqCst) == 1 {
        ENABLED.store(false, Ordering::SeqCst);
    }
}

/// RAII recording scope: recording stays on until every outstanding
/// guard has dropped.
#[must_use = "recording stops when the guard drops"]
pub struct RecordingGuard(());

impl Drop for RecordingGuard {
    fn drop(&mut self) {
        disable();
    }
}

pub fn recording() -> RecordingGuard {
    enable();
    RecordingGuard(())
}

fn now_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

fn state() -> std::sync::MutexGuard<'static, State> {
    // A panicking span drop poisons the state mutex by design of std;
    // telemetry must keep working after a contained worker panic.
    STATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Claim a worker id for the current thread (campaign pool workers call
/// this once at spawn; ids are 1..=threads, 0 stays the coordinator).
pub fn set_worker(w: u32) {
    WORKER.with(|c| c.set(w));
}

pub fn worker() -> u32 {
    WORKER.with(|c| c.get())
}

/// An open span, recorded when dropped. Inactive guards (recording off
/// at open) are inert: no clock read, no lock, a single branch on drop.
pub struct SpanGuard {
    active: bool,
    kind: &'static str,
    net: Option<String>,
    unit: Option<u64>,
    outcome: &'static str,
    start_ns: u64,
}

impl SpanGuard {
    /// A guard that records nothing — the disabled arm of monomorphized
    /// call sites.
    pub fn inactive() -> Self {
        SpanGuard { active: false, kind: "", net: None, unit: None, outcome: "ok", start_ns: 0 }
    }

    /// Set the outcome class recorded at drop. No-op on inactive guards;
    /// overridden by `panicked` if the guard drops during an unwind.
    pub fn set_outcome(&mut self, outcome: &'static str) {
        self.outcome = outcome;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let outcome = if std::thread::panicking() { "panicked" } else { self.outcome };
        let span = Span {
            kind: self.kind,
            worker: worker(),
            net: self.net.take(),
            unit: self.unit,
            outcome,
            start_ns: self.start_ns,
            end_ns: now_ns(),
        };
        state().spans.push(span);
    }
}

/// Open a span with outcome `ok`; returns an inactive guard when
/// recording is off.
pub fn span(kind: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard::inactive();
    }
    SpanGuard { active: true, kind, net: None, unit: None, outcome: "ok", start_ns: now_ns() }
}

/// Open a span tagged with the unit it belongs to.
pub fn span_tagged(kind: &'static str, net: &str, unit: u64) -> SpanGuard {
    let mut g = span(kind);
    if g.active {
        g.net = Some(net.to_string());
        g.unit = Some(unit);
    }
    g
}

/// Record a zero-duration marker (e.g. `lock.steal`).
pub fn instant(kind: &'static str) {
    if !enabled() {
        return;
    }
    let t = now_ns();
    state().spans.push(Span {
        kind,
        worker: worker(),
        net: None,
        unit: None,
        outcome: "ok",
        start_ns: t,
        end_ns: t,
    });
}

/// Add `delta` to a named counter (no-op while recording is off).
pub fn count(name: &str, delta: u64) {
    if !enabled() || delta == 0 {
        return;
    }
    *state().counters.entry(name.to_string()).or_insert(0) += delta;
}

/// Copy out everything recorded so far. Concurrent recordings interleave;
/// consumers isolate by filtering on their own net names.
pub fn snapshot() -> Telemetry {
    let st = state();
    Telemetry { spans: st.spans.clone(), counters: st.counters.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The recorder is process-global, so these tests serialize among
    /// themselves (other lib tests never enable recording) and filter
    /// snapshots by test-unique span kinds.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn spans_of(kind: &str) -> Vec<Span> {
        snapshot().spans.into_iter().filter(|s| s.kind == kind).collect()
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let _l = lock();
        assert!(!enabled());
        {
            let mut g = span("obs.test.inert");
            g.set_outcome("whatever");
        }
        instant("obs.test.inert");
        count("obs.test.inert", 3);
        assert!(spans_of("obs.test.inert").is_empty());
        assert!(!snapshot().counters.contains_key("obs.test.inert"));
    }

    #[test]
    fn span_records_kind_tags_and_outcome() {
        let _l = lock();
        let _r = recording();
        {
            let mut g = span_tagged("obs.test.tagged", "netx", 7);
            g.set_outcome("compiled");
        }
        let got = spans_of("obs.test.tagged");
        assert_eq!(got.len(), 1);
        let s = &got[0];
        assert_eq!(s.net.as_deref(), Some("netx"));
        assert_eq!(s.unit, Some(7));
        assert_eq!(s.outcome, "compiled");
        assert!(s.end_ns >= s.start_ns);
        assert_eq!(s.worker, 0, "coordinator thread records as worker 0");
    }

    #[test]
    fn panicking_drop_marks_span_panicked_and_recorder_survives() {
        let _l = lock();
        let _r = recording();
        let err = std::panic::catch_unwind(|| {
            let mut g = span("obs.test.panic");
            g.set_outcome("feasible"); // overridden by the unwind
            panic!("boom");
        });
        assert!(err.is_err());
        let got = spans_of("obs.test.panic");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].outcome, "panicked");
        // The recorder still works after a panic poisoned nothing.
        instant("obs.test.panic.after");
        assert_eq!(spans_of("obs.test.panic.after").len(), 1);
    }

    #[test]
    fn refcounted_recording_and_counters() {
        let _l = lock();
        let g1 = recording();
        let g2 = recording();
        drop(g1);
        assert!(enabled(), "still on while one guard lives");
        count("obs.test.ctr", 2);
        count("obs.test.ctr", 3);
        assert_eq!(snapshot().counters.get("obs.test.ctr"), Some(&5));
        drop(g2);
        assert!(!enabled());
    }

    #[test]
    fn worker_id_is_per_thread() {
        let _l = lock();
        let _r = recording();
        set_worker(0); // in case a previous test on this thread set it
        std::thread::scope(|scope| {
            scope.spawn(|| {
                set_worker(3);
                instant("obs.test.worker");
            });
        });
        let got = spans_of("obs.test.worker");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].worker, 3);
        assert_eq!(worker(), 0, "spawned thread's id never leaks to the coordinator");
    }

    #[test]
    fn instant_spans_have_zero_duration() {
        let _l = lock();
        let _r = recording();
        instant("obs.test.instant");
        let got = spans_of("obs.test.instant");
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].start_ns, got[0].end_ns);
    }
}
