//! Tiny argument parser (the offline environment has no clap): subcommand
//! plus `--flag value` / `--switch` options, with generated usage text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()`-style input (first element = program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self> {
        let mut it = argv.into_iter().skip(1);
        let mut args = Args { command: it.next().unwrap_or_default(), ..Default::default() };
        let mut rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = std::mem::take(&mut rest[i]);
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    let v = std::mem::take(&mut rest[i + 1]);
                    args.opts.insert(name.to_string(), v);
                    i += 1;
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch) || self.opts.contains_key(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_opts_switches() {
        let a = Args::parse(argv("avsm simulate --net dilated_vgg --hw 128 --zoom out.json")).unwrap();
        assert_eq!(a.command, "simulate");
        assert_eq!(a.get("net"), Some("dilated_vgg"));
        assert_eq!(a.get_u64("hw", 0).unwrap(), 128);
        // --zoom consumed "out.json" as its value (not a switch).
        assert_eq!(a.get("zoom"), Some("out.json"));
    }

    #[test]
    fn equals_form_and_trailing_switch() {
        let a = Args::parse(argv("avsm roofline --net=vgg16 --zoom")).unwrap();
        assert_eq!(a.get("net"), Some("vgg16"));
        assert!(a.has("zoom"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn positionals_kept_in_order() {
        let a = Args::parse(argv("avsm compare a.json b.json --out c")).unwrap();
        assert_eq!(a.positional, vec!["a.json", "b.json"]);
        assert_eq!(a.get("out"), Some("c"));
    }

    #[test]
    fn bad_integer_reported() {
        let a = Args::parse(argv("avsm x --n abc")).unwrap();
        assert!(a.get_u64("n", 1).is_err());
    }

    #[test]
    fn empty_argv_is_fine() {
        let a = Args::parse(argv("avsm")).unwrap();
        assert_eq!(a.command, "");
    }
}
