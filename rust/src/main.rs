//! `avsm` — command-line front-end to the AVSM co-design framework.
//!
//! The virtual-system-based prototyping flow of the paper, end to end:
//! DNN graph -> deep-learning compiler -> hardware-adapted task graph ->
//! AVSM simulation -> Fig 3/4/5/6/7 reports, plus functional inference of
//! the AOT JAX/Pallas artifacts over PJRT.

use anyhow::{bail, Context, Result};
use avsm::campaign;
use avsm::cli::Args;
use avsm::compiler::{analytical_estimate, compile, CompileOptions};
use avsm::config::SystemConfig;
use avsm::coordinator::{run_flow, FlowOptions};
use avsm::dse;
use avsm::graph::{graph_from_json, models, DnnGraph};
use avsm::hw::simulate_avsm;
use avsm::metrics::{fmt_bytes, fmt_ps};
use avsm::report::{axis_legend, CampaignReport, Fig5Report, TelemetryReport};
use avsm::roofline::RooflineModel;
use avsm::runtime::{self, Manifest, Runtime};
use avsm::sim::TraceRecorder;
use avsm::trace::{Gantt, GanttOptions};
use std::io::Write as _;
use std::path::PathBuf;

const USAGE: &str = "\
avsm — HW/SW co-design of DNN systems with abstract virtual system models
(reproduction of Klaiber et al., ESWEEK 2019)

USAGE: avsm <COMMAND> [OPTIONS]

COMMANDS:
  simulate   run the AVSM timing simulation, print the per-layer table
  compare    Fig 5: AVSM vs detailed 'hardware' prototype, with deviations
  roofline   Fig 6/7: roofline of the simulated system (--zoom for Fig 7)
  gantt      Fig 4: resource Gantt chart (--format ascii|csv|svg)
  flow       full flow with the Fig 3 runtime breakdown (--outdir DIR)
  sweep      design-space exploration over NCE/bus/buffer axes
             (--axes SPEC to sweep any axis combination)
  campaign   multi-workload co-design sweep: per-net config grids vs a net
             portfolio, streaming per-net Pareto frontiers + cross-net
             summary (--nets A,B,C | --workloads FILE, --axes SPEC,
             --cache-dir DIR --threads N --fail-fast
             --journal FILE --resume
             --telemetry FILE --trace-out FILE)
  lint       static diagnostics (stable AVSM0xx codes) over any mix of
             --net/--system units, --axes specs, --workloads files,
             --axis --lo --hi solver ranges, --cache-dir stores and
             --journal files, without simulating anything; exits nonzero
             iff an error-severity diagnostic fired (--json writes the
             machine-readable avsm-lint-v1 report instead of text)
  topdown    minimum axis value for a latency target (--target-ms X
             --axis NAME --lo N --hi N; default axis nce_freq_mhz —
             the paper's §2 top-down mode, generalized; --scan swaps the
             binary search for an exhaustive O(range) grid scan that also
             handles non-monotone axes, compile-shared like the search)
  serve      resident campaign daemon: keeps the two-tier compile cache
             warm across requests and answers campaign/sweep/solve jobs
             over a line-delimited JSON protocol — stdin/stdout by
             default, --socket PATH for a Unix socket accept loop
             (--cache-dir DIR --cache-max-entries N --threads N
             --max-line BYTES). Every request is lint-gated before it
             costs a worker; see README \"Campaign service\" for the
             protocol and the envelope versioning rule
  analytical static (Zhang'15-style) estimate — the no-causality baseline
  infer      functional inference of the AOT artifact over PJRT
  config     print the (validated) system description JSON
  graph      print the DNN graph JSON

COMMON OPTIONS:
  --net NAME|PATH     dilated_vgg (default) | dilated_vgg_tiny | vgg16 |
                      lenet | mobilenet | tiny_resnet | path to .graph.json
  --system PATH       system description JSON (default: built-in base
                      config = the paper's 32x64 @ 250 MHz Virtex7 point)
  --hw N              input H=W for built-in nets (default per net)
  --outdir DIR        where to write artifacts/reports
  --artifacts DIR     AOT artifact dir for `infer` (default: artifacts/)
  --nets A,B,C        workload portfolio for `campaign` (default:
                      lenet,dilated_vgg_tiny,tiny_resnet)
  --cache-dir DIR     persistent compile cache for `campaign`: a second
                      invocation against a warm directory compiles nothing
                      (feasible *and* infeasible keys are both persisted)
  --cache-max-entries N  bound the disk cache to N structural keys with
                      LRU eviction (index sidecar avsm-compile-cache-index-v1;
                      default: unbounded)
  --threads N         worker threads for `campaign` (default: all CPUs)
  --no-prune          disable the campaign's lower-bound early termination
                      and simulate every grid point (pruning is lossless —
                      frontiers are identical either way — so this is a
                      diagnostic/benchmark escape hatch)
  --bound KIND        which admissible lower bound gates the pruning:
                      occupancy (exclusive-resource totals), critical-path
                      (longest dependency chain), or max (default: the
                      tighter of the two). Every kind is lossless; this is
                      the A/B escape hatch for comparing skip rates. The
                      report records the chosen bound and attributes each
                      skip to the half that produced it
  --no-order          evaluate grid units in plain grid order instead of
                      ascending lower-bound order (ordering is a lossless
                      scheduling heuristic that maximizes bound-skips)
  --no-preflight      skip the static lint pre-flight that `campaign` and
                      `sweep` run by default before any simulation; the
                      pre-flight is observation-only (a clean spec produces
                      byte-identical results either way), so this is purely
                      a diagnostic escape hatch
  --fail-fast         abort `campaign` on the first error- or panic-
                      classified unit (invalid swept config, dead worker),
                      reporting its diagnostic — the CI co-design-gate
                      mode; infeasible tilings never trigger it
  --journal FILE      append every completed `campaign` unit to a crash-
                      safe resume journal (avsm-campaign-journal-v1): a
                      killed run loses at most the unit mid-append
  --resume            replay the --journal file before running: completed
                      units are folded in without re-simulation and the
                      report comes out byte-identical to the uninterrupted
                      run; an absent journal is a fresh start, a journal
                      from a different spec refuses loudly
  --telemetry FILE    record engine telemetry during `campaign` and write
                      the avsm-campaign-telemetry-v1 report (per-span-kind
                      counts, p50/p90/p99 latencies, cache-tier counters)
                      there; a text summary table prints either way.
                      Recording never changes the campaign's results
  --compact           write `campaign`'s campaign.json compact (single
                      line) instead of pretty — the exact bytes the serve
                      daemon streams in its report line, so the two can be
                      compared byte for byte
  --socket PATH       `serve`: accept connections on a Unix socket instead
                      of the stdin/stdout pipe session
  --max-line BYTES    `serve`: per-request line cap (default 4 MiB); an
                      over-cap line is rejected (AVSM063) and the
                      connection continues
  --scan              `topdown`: exhaustive grid scan instead of binary
                      search (works on non-monotone axes)
  --trace-out FILE    write the engine's own per-worker timeline as a
                      Chrome trace-event JSON (one thread per pool worker;
                      load in chrome://tracing or ui.perfetto.dev) —
                      the exploration engine's Gantt, sibling to
                      `gantt --format chrome`'s simulated-schedule view

AXIS SPECS (--axes, and \"axes\" inside --workloads entries):
  JSON array of {\"axis\": NAME, \"values\": [..]} objects, swept first-
  axis-outermost. Scalar axes take integers; array_geometry takes
  [rows, cols] pairs. Prefix the argument with @ to read it from a file.
  `roofline` and `gantt --format svg` accept --axes purely to caption the
  SVG with the axis name legend decoding swept-point name tokens.
    axes: array_geometry, nce_freq_mhz, bus_freq_mhz (retime-only),
          bus_bytes_per_cycle, ifm_buffer_kib, weight_buffer_kib,
          ofm_buffer_kib
    example: --axes '[{\"axis\":\"array_geometry\",\"values\":[[16,32],[32,64]]},
                      {\"axis\":\"nce_freq_mhz\",\"values\":[125,250,500]}]'

WORKLOAD FILES (--workloads): JSON array of per-net entries, each
  {\"net\": NAME|PATH, \"hw\": N?, \"base\": SYSTEM_JSON_PATH?, \"axes\": SPEC?}
  — base/axes default to the campaign-wide --system/--axes, so one
  campaign can sweep a heterogeneous portfolio (each DNN against its own
  accelerator grid) while sharing the worker pool and caches.
";

fn load_sys(args: &Args) -> Result<SystemConfig> {
    match args.get("system") {
        Some(path) => SystemConfig::from_file(path),
        None => Ok(SystemConfig::base_paper()),
    }
}

fn load_net(args: &Args) -> Result<DnnGraph> {
    named_net(args.get_or("net", "dilated_vgg"), args.get_u64("hw", 0)? as u32)
}

/// Resolve one workload by builder name or `.graph.json` path.
fn named_net(name: &str, hw: u32) -> Result<DnnGraph> {
    let net = build_net(name, hw)?;
    net.validate()?;
    Ok(net)
}

/// The same resolution without the validity gate: `lint` exists to look
/// at broken nets, so it must be able to load them.
fn build_net(name: &str, hw: u32) -> Result<DnnGraph> {
    match models::by_name(name, hw) {
        Some(net) => Ok(net),
        None => {
            let text = std::fs::read_to_string(name)
                .with_context(|| format!("reading DNN graph {name:?}"))?;
            graph_from_json(&text)
        }
    }
}

/// Parse an `--axes` argument: inline JSON, or `@path` to read a file.
fn parse_axes(arg: &str) -> Result<dse::SweepAxes> {
    let text = match arg.strip_prefix('@') {
        Some(path) => std::fs::read_to_string(path)
            .with_context(|| format!("reading axis spec {path:?}"))?,
        None => arg.to_string(),
    };
    dse::SweepAxes::from_json(&text)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args())?;
    match args.command.as_str() {
        "simulate" => cmd_simulate(&args),
        "compare" => cmd_compare(&args),
        "roofline" => cmd_roofline(&args),
        "gantt" => cmd_gantt(&args),
        "flow" => cmd_flow(&args),
        "sweep" => cmd_sweep(&args),
        "campaign" => cmd_campaign(&args),
        "serve" => cmd_serve(&args),
        "lint" => cmd_lint(&args),
        "topdown" => cmd_topdown(&args),
        "analytical" => cmd_analytical(&args),
        "infer" => cmd_infer(&args),
        "config" => cmd_config(&args),
        "graph" => cmd_graph(&args),
        "" | "help" | "-h" | "--help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let compiled = compile(&net, &sys, CompileOptions::default())?;
    let mut trace = TraceRecorder::disabled();
    let sim = simulate_avsm(&compiled, &sys, &mut trace);
    println!(
        "{} on {} — {} tasks, {} events",
        net.name, sys.name, compiled.graph.len(), sim.events
    );
    println!(
        "{:<12} {:>14} {:>8} {:>8}  {:>12} {:>10}  bound",
        "layer", "time", "NCE", "bus", "MACs", "DMA"
    );
    for l in &sim.layers {
        println!(
            "{:<12} {:>14} {:>7.1}% {:>7.1}%  {:>12} {:>10}  {}",
            l.name,
            fmt_ps(l.duration_ps()),
            100.0 * l.nce_utilization(),
            100.0 * l.bus_utilization(),
            l.macs,
            fmt_bytes(l.dma_bytes),
            l.bound_class()
        );
    }
    println!(
        "TOTAL        {:>14}   ({:.2} inferences/s, {:.1} GMAC/s)",
        fmt_ps(sim.total_ps),
        1e12 / sim.total_ps as f64,
        sim.macs_per_sec() / 1e9
    );
    let energy = avsm::energy::energy_of(&sim, &sys, &avsm::energy::EnergyConfig::default());
    print!("{}", energy.render_text());
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let compiled = compile(&net, &sys, CompileOptions::default())?;
    let report = Fig5Report::compute(&compiled, &sys);
    print!("{}", report.render_text());
    if let Some(dir) = args.get("outdir") {
        std::fs::create_dir_all(dir)?;
        let dir = PathBuf::from(dir);
        std::fs::write(dir.join("fig5.json"), report.to_json().to_string_pretty())?;
        std::fs::write(dir.join("fig5.svg"), report.render_svg())?;
        println!("wrote {}/fig5.{{json,svg}}", dir.display());
    }
    Ok(())
}

/// Optional `--axes` legend for SVG captions: decodes the swept-axis name
/// tokens (`f250`, `g32x64`, ...) that campaign design-point names carry.
fn svg_legend(args: &Args) -> Result<Vec<(&'static str, String)>> {
    Ok(match args.get("axes") {
        Some(spec) => axis_legend(&parse_axes(spec)?),
        None => Vec::new(),
    })
}

fn cmd_roofline(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let compiled = compile(&net, &sys, CompileOptions::default())?;
    let mut trace = TraceRecorder::disabled();
    let sim = simulate_avsm(&compiled, &sys, &mut trace);
    let ops: Vec<u64> = net.layer_costs().iter().map(|c| c.arith_ops).collect();
    let model = RooflineModel::from_sim(&sys, &sim, &ops);
    let zoom = if args.has("zoom") { Some(model.ridge * 0.8) } else { None };
    let legend = svg_legend(args)?;
    print!("{}", model.render_text(zoom));
    if let Some(dir) = args.get("outdir") {
        std::fs::create_dir_all(dir)?;
        let dir = PathBuf::from(dir);
        let tag = if zoom.is_some() { "fig7" } else { "fig6" };
        std::fs::write(dir.join(format!("{tag}.json")), model.to_json().to_string_pretty())?;
        std::fs::write(
            dir.join(format!("{tag}.svg")),
            model.render_svg_with_legend(zoom, &legend),
        )?;
        println!("wrote {}/{tag}.{{json,svg}}", dir.display());
    }
    Ok(())
}

fn cmd_gantt(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let compiled = compile(&net, &sys, CompileOptions::default())?;
    let mut trace = TraceRecorder::new();
    let sim = simulate_avsm(&compiled, &sys, &mut trace);
    // Optional layer window: --layer NAME zooms Fig 4 onto one layer.
    let window = match args.get("layer") {
        Some(name) => {
            let l = sim
                .layer(name)
                .with_context(|| format!("no layer named {name:?}"))?;
            Some((l.start_ps, l.end_ps))
        }
        None => None,
    };
    let g = Gantt::new(
        &trace,
        GanttOptions { window, width: args.get_u64("width", 100)? as usize },
    );
    match args.get_or("format", "ascii") {
        "ascii" => print!("{}", g.render_ascii()),
        "csv" => print!("{}", g.render_csv()),
        "svg" => println!("{}", g.render_svg_with_legend(&svg_legend(args)?)),
        // chrome://tracing / ui.perfetto.dev interactive view.
        "chrome" => println!("{}", avsm::trace::to_chrome_trace(&trace)),
        other => bail!("unknown gantt format {other:?}"),
    }
    Ok(())
}

fn cmd_flow(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let outdir = args.get("outdir").map(PathBuf::from);
    let out = run_flow(&net, &sys, &FlowOptions::default(), outdir.as_deref())?;
    println!(
        "flow complete: {} tasks simulated, inference latency {}",
        out.sim.tasks,
        fmt_ps(out.sim.total_ps)
    );
    println!("\nFig 3 — distribution of flow run-time:");
    print!("{}", out.breakdown.render_text());
    if let Some(dir) = &outdir {
        std::fs::write(dir.join("fig3.json"), out.breakdown.to_json().to_string_pretty())?;
        println!("wrote {}/fig3.json (+ task_graph.json, layers.csv, gantt.*)", dir.display());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let axes = match args.get("axes") {
        Some(spec) => parse_axes(spec)?,
        None => dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 32), (32, 64), (64, 64), (128, 128)])
            .nce_freqs_mhz(vec![125, 250, 500]),
    };
    // Classify every grid point: infeasible tilings are legitimate holes
    // (reported, not fatal), but an error-classified point — an invalid
    // value in a user-supplied --axes spec — must fail the command, not
    // silently shrink the table.
    let outcomes = dse::sweep_outcomes(
        &net,
        &sys,
        &axes,
        &dse::SweepOptions { no_preflight: args.has("no-preflight"), ..Default::default() },
    );
    let mut points = Vec::new();
    let (mut infeasible, mut errors) = (0usize, 0usize);
    let mut error_sample: Option<String> = None;
    for outcome in outcomes {
        match outcome {
            dse::EvalOutcome::Feasible(p) => points.push(p),
            dse::EvalOutcome::Infeasible { .. } => infeasible += 1,
            dse::EvalOutcome::Error { name, reason } => {
                errors += 1;
                error_sample.get_or_insert(format!("{name}: {reason}"));
            }
        }
    }
    if infeasible > 0 {
        println!("({infeasible} grid points structurally infeasible — skipped)");
    }
    println!("{:<28} {:>14} {:>12} {:>10}", "design point", "latency", "infer/s", "cost");
    for p in &points {
        println!(
            "{:<28} {:>14} {:>12.2} {:>10.0}",
            p.name,
            fmt_ps(p.latency_ps),
            p.throughput,
            p.cost
        );
    }
    let front = dse::pareto(&points);
    println!("\npareto frontier ({} of {} points):", front.len(), points.len());
    for p in front {
        println!("  {:<28} {:>14} cost {:>8.0}", p.name, fmt_ps(p.latency_ps), p.cost);
    }
    if let Some(dir) = args.get("outdir") {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            PathBuf::from(dir).join("sweep.json"),
            dse::sweep_to_json(&points).to_string_pretty(),
        )?;
    }
    if errors > 0 {
        bail!(
            "{errors} grid point(s) failed evaluation — first: {}",
            error_sample.as_deref().unwrap_or("(no diagnostic)")
        );
    }
    Ok(())
}

/// Parse one `--workloads` file entry into a [`campaign::WorkloadSpec`].
fn workload_from_value(v: &avsm::json::Value, default_hw: u32) -> Result<campaign::WorkloadSpec> {
    let name = v.req_str("net")?;
    let hw = match v.get("hw").as_u64() {
        // Checked narrowing: a corrupt oversized value must read as
        // rejection, never wrap into a plausible input size.
        Some(h) => u32::try_from(h)
            .map_err(|_| anyhow::anyhow!("workload {name:?}: hw {h} exceeds u32"))?,
        None => default_hw,
    };
    let mut w = campaign::WorkloadSpec::new(named_net(name, hw)?);
    if let Some(path) = v.get("base").as_str() {
        w = w.with_base(
            SystemConfig::from_file(path)
                .with_context(|| format!("workload {name:?} base config"))?,
        );
    }
    if !matches!(v.get("axes"), avsm::json::Value::Null) {
        w = w.with_axes(
            dse::SweepAxes::from_value(v.get("axes"))
                .with_context(|| format!("workload {name:?} axis spec"))?,
        );
    }
    Ok(w)
}

fn cmd_campaign(args: &Args) -> Result<()> {
    let base = load_sys(args)?;
    let hw = args.get_u64("hw", 0)? as u32;
    let workloads: Vec<campaign::WorkloadSpec> = match args.get("workloads") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading workloads file {path:?}"))?;
            let doc = avsm::json::parse(&text).context("workloads file parse")?;
            let entries = doc
                .as_array()
                .context("workloads file must be a JSON array of {net, ...} entries")?;
            entries
                .iter()
                .map(|v| workload_from_value(v, hw))
                .collect::<Result<_>>()?
        }
        None => args
            .get_or("nets", "lenet,dilated_vgg_tiny,tiny_resnet")
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|name| Ok(campaign::WorkloadSpec::new(named_net(name, hw)?)))
            .collect::<Result<_>>()?,
    };
    let axes = match args.get("axes") {
        Some(spec) => parse_axes(spec)?,
        None => dse::SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64), (64, 64)])
            .nce_freqs_mhz(vec![125, 250, 500]),
    };
    let spec = campaign::CampaignSpec { workloads, base, axes };
    let cache_max_entries = match args.get_u64("cache-max-entries", 0)? {
        0 => None,
        n => Some(n as usize),
    };
    let bound = match args.get("bound") {
        Some(key) => avsm::compiler::BoundKind::from_key(key)?,
        None => avsm::compiler::BoundKind::Max,
    };
    let journal = args.get("journal").map(PathBuf::from);
    if args.has("resume") && journal.is_none() {
        bail!("--resume requires --journal FILE (there is nothing to replay)");
    }
    let opts = campaign::CampaignOptions {
        threads: args.get_u64("threads", 0)? as usize,
        cache_dir: args.get("cache-dir").map(PathBuf::from),
        cache_max_entries,
        keep_points: false,
        prune: !args.has("no-prune"),
        bound,
        order_by_bound: !args.has("no-order"),
        fail_fast: args.has("fail-fast"),
        journal,
        resume: args.has("resume"),
        preflight: !args.has("no-preflight"),
    };
    // Telemetry is opt-in: either artifact flag turns the recorder on for
    // the whole run. Recording never changes the campaign's results (the
    // property suite pins frontiers byte-identical on vs. off).
    let telemetry = args.get("telemetry").map(PathBuf::from);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let observe = telemetry.is_some() || trace_out.is_some();
    if observe {
        avsm::obs::enable();
    }
    let result = campaign::run(&spec, &opts)?;
    let report = CampaignReport::new(&result);
    print!("{}", report.render_text());
    if let Some(dir) = args.get("outdir") {
        std::fs::create_dir_all(dir)?;
        let path = PathBuf::from(dir).join("campaign.json");
        // Stream the report to disk — frontier points are emitted as they
        // are visited, never materialized as one big string.
        let out = std::io::BufWriter::new(std::fs::File::create(&path)?);
        report.write_json(out, !args.has("compact"))?.flush()?;
        println!("wrote {}", path.display());
    }
    if observe {
        let t = avsm::obs::snapshot();
        let tel = TelemetryReport::new(&t);
        print!("\n{}", tel.render_text());
        if let Some(path) = &telemetry {
            let out = std::io::BufWriter::new(std::fs::File::create(path)?);
            tel.write_json(out, true)?.flush()?;
            println!("wrote {}", path.display());
        }
        if let Some(path) = &trace_out {
            std::fs::write(path, avsm::trace::spans_to_chrome_trace(&t.spans))?;
            println!("wrote {} (load in chrome://tracing or ui.perfetto.dev)", path.display());
        }
    }
    Ok(())
}

/// `avsm serve` — the resident campaign daemon. Pipe mode (default)
/// serves exactly one session over stdin/stdout and exits when stdin
/// closes or a `shutdown` request arrives; `--socket PATH` runs the Unix
/// accept loop until a client sends `shutdown`. Either way the compile
/// caches live for the process lifetime, so repeated questions about the
/// same workload are compile-free.
fn cmd_serve(args: &Args) -> Result<()> {
    let cache_max_entries = match args.get_u64("cache-max-entries", 0)? {
        0 => None,
        n => Some(n as usize),
    };
    let opts = avsm::serve::ServeOptions {
        cache_dir: args.get("cache-dir").map(PathBuf::from),
        cache_max_entries,
        threads: args.get_u64("threads", 0)? as usize,
        max_line: match args.get_u64("max-line", 0)? {
            0 => avsm::json::stream::DEFAULT_MAX_FRAME,
            n => n as usize,
        },
    };
    match args.get("socket") {
        Some(path) => {
            #[cfg(unix)]
            {
                eprintln!("avsm serve: listening on {path}");
                avsm::serve::serve_unix(std::path::Path::new(path), opts)?;
                eprintln!("avsm serve: shut down");
                Ok(())
            }
            #[cfg(not(unix))]
            {
                let _ = path;
                bail!("--socket requires a Unix platform; use pipe mode instead")
            }
        }
        None => {
            let daemon = avsm::serve::Daemon::new(opts);
            let stats = avsm::serve::serve_session(
                &daemon,
                std::io::stdin().lock(),
                std::io::stdout().lock(),
            )?;
            eprintln!(
                "avsm serve: session closed ({} served, {} rejected, {} failed)",
                stats.served, stats.rejected, stats.failed
            );
            Ok(())
        }
    }
}

/// `avsm lint` — run the static diagnostics passes over whatever targets
/// the flags name, render the report, and exit nonzero iff any
/// error-severity diagnostic fired. Pure observation: nothing is
/// simulated, compiled, or mutated (the cache/journal passes only read).
fn cmd_lint(args: &Args) -> Result<()> {
    use avsm::analysis::{fsck, passes, Diagnostic, Report};
    let mut report = Report::new(Vec::new());
    let mut targets = 0usize;

    // Unit passes: a net (checked against the base config so the static
    // tiling probe can run), or a config alone.
    let sys = match args.get("system") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading system config {path:?}"))?;
            Some(SystemConfig::from_json_unvalidated(&text)?)
        }
        None => None,
    };
    let net = match args.get("net") {
        Some(name) => Some(build_net(name, args.get_u64("hw", 0)? as u32)?),
        None => None,
    };
    match (&net, &sys) {
        (Some(net), Some(sys)) => {
            targets += 1;
            report.extend(passes::lint_unit(net, sys));
        }
        (Some(net), None) => {
            targets += 1;
            report.extend(passes::lint_unit(net, &SystemConfig::base_paper()));
        }
        (None, Some(sys)) => {
            targets += 1;
            report.extend(passes::lint_config(sys));
        }
        (None, None) => {}
    }

    // Axis-spec passes: the raw JSON document first (duplicates, unknown
    // axes, empty value lists), then the parsed-form checks (grid size,
    // swept values vs. the base config) when it parses at all.
    if let Some(spec) = args.get("axes") {
        targets += 1;
        let text = match spec.strip_prefix('@') {
            Some(path) => std::fs::read_to_string(path)
                .with_context(|| format!("reading axis spec {path:?}"))?,
            None => spec.to_string(),
        };
        match avsm::json::parse(&text) {
            Err(e) => report.push(Diagnostic::error(
                "AVSM032",
                "axis spec",
                format!("axis spec is not valid JSON: {e:#}"),
            )),
            Ok(v) => {
                report.extend(passes::lint_axis_spec_value(&v));
                if let Ok(axes) = dse::SweepAxes::from_value(&v) {
                    let base = sys.clone().unwrap_or_else(SystemConfig::base_paper);
                    report.extend(passes::lint_axes(&base, &axes));
                }
            }
        }
    }

    if let Some(path) = args.get("workloads") {
        targets += 1;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading workloads file {path:?}"))?;
        match avsm::json::parse(&text) {
            Err(e) => report.push(Diagnostic::error(
                "AVSM036",
                "workloads file",
                format!("workloads file is not valid JSON: {e:#}"),
            )),
            Ok(v) => report.extend(passes::lint_workloads_value(&v)),
        }
    }

    if let Some(key) = args.get("axis") {
        targets += 1;
        let axis = dse::Axis::from_key(key)?;
        report.extend(passes::lint_requirement_range(
            axis,
            args.get_u64("lo", 25)?,
            args.get_u64("hi", 2000)?,
        ));
    }

    if let Some(dir) = args.get("cache-dir") {
        targets += 1;
        let max = match args.get_u64("cache-max-entries", 0)? {
            0 => None,
            n => Some(n as usize),
        };
        report.extend(fsck::lint_cache_dir(std::path::Path::new(dir), max));
    }

    if let Some(path) = args.get("journal") {
        targets += 1;
        report.extend(fsck::lint_journal(std::path::Path::new(path), None));
    }

    if targets == 0 {
        bail!(
            "lint needs at least one target: --net/--system, --axes, --workloads, \
             --axis [--lo --hi], --cache-dir, or --journal"
        );
    }
    if args.has("json") {
        println!("{}", report.to_json().to_string_compact());
    } else if report.is_empty() {
        println!("lint: clean ({targets} target(s), no diagnostics)");
    } else {
        println!("{}", report.render_text());
    }
    if report.has_errors() {
        bail!("lint found {} error(s)", report.errors());
    }
    Ok(())
}

fn cmd_topdown(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let target_ms: f64 = args
        .get("target-ms")
        .context("topdown requires --target-ms")?
        .parse()
        .context("--target-ms expects a number")?;
    let target_ps = (target_ms * 1e9) as u64;
    let axis = dse::Axis::from_key(args.get_or("axis", "nce_freq_mhz"))?;
    let range = (args.get_u64("lo", 25)?, args.get_u64("hi", 2000)?);
    let sol = if args.has("scan") {
        dse::solve_requirement_scan(&net, &sys, axis, target_ps, range)?
    } else {
        dse::solve_requirement(&net, &sys, axis, target_ps, range)?
    };
    match sol.value {
        Some(v) => println!(
            "target {target_ms} ms/inference on {}: minimum {} {} {} \
             ({} probes, {} compilation{})",
            net.name,
            axis.label(),
            v,
            axis.unit(),
            sol.probes,
            sol.compiles,
            if sol.compiles == 1 { "" } else { "s" }
        ),
        None => println!(
            "target {target_ms} ms/inference is not reachable by scaling {} alone \
             within ({}, {}) {}; widen another axis instead",
            axis.label(),
            range.0,
            range.1,
            axis.unit()
        ),
    }
    Ok(())
}

fn cmd_analytical(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    let net = load_net(args)?;
    let est = analytical_estimate(&net, &sys);
    let compiled = compile(&net, &sys, CompileOptions::default())?;
    let mut trace = TraceRecorder::disabled();
    let sim = simulate_avsm(&compiled, &sys, &mut trace);
    println!(
        "{:<12} {:>14} {:>14} {:>10}",
        "layer", "analytical", "simulated", "underest."
    );
    for (i, l) in sim.layers.iter().enumerate() {
        let a = est.layer_ps[i];
        println!(
            "{:<12} {:>14} {:>14} {:>+9.1}%",
            l.name,
            fmt_ps(a),
            fmt_ps(l.duration_ps()),
            100.0 * (a as f64 - l.duration_ps() as f64) / l.duration_ps() as f64
        );
    }
    println!(
        "TOTAL        {:>14} {:>14}   (analytical misses blocking/arbitration: paper §1)",
        fmt_ps(est.total_ps()),
        fmt_ps(sim.total_ps)
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let name = args.get_or("model", "dilated_vgg_tiny");
    let sig = manifest
        .artifact(name)
        .with_context(|| format!("artifact {name:?} not in manifest"))?;
    let model = rt.load(sig)?;
    println!("loaded {} ({:?} -> {:?})", name, sig.input_shapes, sig.output_shapes);

    if name == "dilated_vgg_tiny" {
        let golden = manifest.golden.as_ref().context("manifest has no golden vectors")?;
        let input = runtime::read_f32_bin(&golden.input)?;
        let expected = runtime::read_f32_bin(&golden.expected)?;
        let t0 = std::time::Instant::now();
        let out = model.run_f32(&[&input])?;
        let dt = t0.elapsed();
        let diff = runtime::max_abs_diff(&out[0], &expected);
        println!(
            "inference: {:.1} ms wall, max |Δ| vs JAX reference = {diff:.2e} (tol {:.0e})",
            dt.as_secs_f64() * 1e3,
            golden.tolerance
        );
        if diff as f64 > golden.tolerance {
            bail!("functional mismatch vs golden output");
        }
        println!("functional inference OK — rust/PJRT matches the JAX model");
    } else {
        // Zero input smoke run.
        let inputs: Vec<Vec<f32>> = sig
            .input_shapes
            .iter()
            .map(|s| vec![0.1f32; s.iter().product()])
            .collect();
        let refs: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
        let out = model.run_f32(&refs)?;
        println!("ran {name}: {} output tensor(s), first has {} elems", out.len(), out[0].len());
    }
    Ok(())
}

fn cmd_config(args: &Args) -> Result<()> {
    let sys = load_sys(args)?;
    println!("{}", sys.to_json());
    Ok(())
}

fn cmd_graph(args: &Args) -> Result<()> {
    let net = load_net(args)?;
    println!("{}", avsm::graph::graph_to_json(&net));
    Ok(())
}
