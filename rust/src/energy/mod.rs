//! Energy model — the "efficient" in the paper's title.
//!
//! The AVSM methodology prices design points not only in time but in
//! energy: with per-operation energy annotations (the same kind of physical
//! annotation as clock frequencies, paper §2), the simulator's MAC/byte
//! accounting turns directly into energy per inference, average power and
//! energy-delay product — the quantities a co-design loop actually ranks
//! design points by.
//!
//! Defaults are representative 28 nm-class numbers (Horowitz, ISSCC'14
//! ballpark): a 16-bit MAC ≈ 1 pJ, on-chip SRAM access ≈ 0.1 pJ/B, external
//! DRAM access ≈ 20 pJ/B, plus a static/leakage floor.

use crate::config::SystemConfig;
use crate::hw::SimResult;
use crate::json::{obj, Value};

/// Per-operation energy annotations (picojoules).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyConfig {
    /// Energy per MAC at the datapath width.
    pub pj_per_mac: f64,
    /// On-chip buffer traffic per MAC operand set (amortized).
    pub pj_per_sram_byte: f64,
    /// External memory traffic (the dominant term — the reason the paper's
    /// compiler minimizes DRAM traffic).
    pub pj_per_dram_byte: f64,
    /// Static power of the whole system in mW (leakage + clocking).
    pub static_mw: f64,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        Self {
            pj_per_mac: 1.0,
            pj_per_sram_byte: 0.1,
            pj_per_dram_byte: 20.0,
            static_mw: 150.0,
        }
    }
}

/// Energy report for one simulated inference.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyReport {
    pub dynamic_compute_mj: f64,
    pub dynamic_memory_mj: f64,
    pub static_mj: f64,
    pub total_mj: f64,
    /// Average power over the inference, mW.
    pub avg_power_mw: f64,
    /// Energy-delay product, mJ·ms.
    pub edp: f64,
    /// Efficiency: effective GMAC/s per watt.
    pub gmacs_per_watt: f64,
    pub per_layer_mj: Vec<(String, f64)>,
}

/// Price a simulation result with an energy model.
pub fn energy_of(sim: &SimResult, _sys: &SystemConfig, cfg: &EnergyConfig) -> EnergyReport {
    let secs = sim.total_ps as f64 / 1e12;
    let mut compute_pj = 0.0;
    let mut memory_pj = 0.0;
    let mut per_layer = Vec::with_capacity(sim.layers.len());
    for l in &sim.layers {
        // SRAM traffic approximation: each MAC reads two operands and the
        // accumulator path, heavily amortized by the register/array reuse —
        // folded into pj_per_sram_byte per *buffer* byte moved, which we
        // approximate by the DMA bytes (each DMA byte is written to and
        // later read from an on-chip buffer).
        let c = l.macs as f64 * cfg.pj_per_mac;
        let m = l.dma_bytes as f64 * (cfg.pj_per_dram_byte + 2.0 * cfg.pj_per_sram_byte);
        compute_pj += c;
        memory_pj += m;
        let layer_secs = l.duration_ps() as f64 / 1e12;
        per_layer.push((
            l.name.clone(),
            (c + m) * 1e-9 + cfg.static_mw * layer_secs,
        ));
    }
    let static_mj = cfg.static_mw * secs; // mW * s = mJ
    let dynamic_compute_mj = compute_pj * 1e-9;
    let dynamic_memory_mj = memory_pj * 1e-9;
    let total_mj = dynamic_compute_mj + dynamic_memory_mj + static_mj;
    let avg_power_mw = total_mj / secs.max(1e-12);
    let total_macs: u64 = sim.layers.iter().map(|l| l.macs).sum();
    EnergyReport {
        dynamic_compute_mj,
        dynamic_memory_mj,
        static_mj,
        total_mj,
        avg_power_mw,
        edp: total_mj * (sim.total_ps as f64 / 1e9),
        gmacs_per_watt: (total_macs as f64 / secs / 1e9) / (avg_power_mw / 1e3),
        per_layer_mj: per_layer,
    }
}

impl EnergyReport {
    pub fn render_text(&self) -> String {
        format!(
            "energy/inference: {:.3} mJ (compute {:.3}, memory {:.3}, static {:.3})\n\
             avg power {:.1} mW, EDP {:.3} mJ·ms, efficiency {:.1} GMAC/s/W\n",
            self.total_mj,
            self.dynamic_compute_mj,
            self.dynamic_memory_mj,
            self.static_mj,
            self.avg_power_mw,
            self.edp,
            self.gmacs_per_watt
        )
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("total_mj", self.total_mj.into()),
            ("dynamic_compute_mj", self.dynamic_compute_mj.into()),
            ("dynamic_memory_mj", self.dynamic_memory_mj.into()),
            ("static_mj", self.static_mj.into()),
            ("avg_power_mw", self.avg_power_mw.into()),
            ("edp_mj_ms", self.edp.into()),
            ("gmacs_per_watt", self.gmacs_per_watt.into()),
            (
                "per_layer_mj",
                Value::Array(
                    self.per_layer_mj
                        .iter()
                        .map(|(n, e)| obj(vec![("layer", n.as_str().into()), ("mj", (*e).into())]))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::models;
    use crate::hw::simulate_avsm;
    use crate::sim::TraceRecorder;

    fn sim_of(net: &crate::graph::DnnGraph, sys: &SystemConfig) -> SimResult {
        let c = compile(net, sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        simulate_avsm(&c, sys, &mut tr)
    }

    #[test]
    fn components_add_up() {
        let sys = SystemConfig::base_paper();
        let sim = sim_of(&models::dilated_vgg_tiny(), &sys);
        let e = energy_of(&sim, &sys, &EnergyConfig::default());
        let sum = e.dynamic_compute_mj + e.dynamic_memory_mj + e.static_mj;
        assert!((e.total_mj - sum).abs() < 1e-12);
        assert!(e.total_mj > 0.0 && e.avg_power_mw > 0.0 && e.gmacs_per_watt > 0.0);
        // Per-layer energies are each positive and roughly total (static
        // is apportioned by layer windows, so the sum matches closely).
        let layer_sum: f64 = e.per_layer_mj.iter().map(|(_, v)| v).sum();
        assert!((layer_sum - e.total_mj).abs() / e.total_mj < 1e-6);
    }

    #[test]
    fn memory_traffic_dominates_comm_bound_nets(){
        // With 20 pJ/B DRAM vs 1 pJ/MAC, a pooling-heavy workload must be
        // memory-energy dominated.
        let sys = SystemConfig::base_paper();
        let sim = sim_of(&models::lenet(28), &sys);
        let e = energy_of(&sim, &sys, &EnergyConfig::default());
        assert!(e.dynamic_memory_mj > e.dynamic_compute_mj);
    }

    #[test]
    fn faster_system_lowers_static_share() {
        let base = SystemConfig::base_paper();
        let mut fast = base.clone();
        fast.nce.freq_mhz *= 2;
        let net = models::dilated_vgg_tiny();
        let e_base = energy_of(&sim_of(&net, &base), &base, &EnergyConfig::default());
        let e_fast = energy_of(&sim_of(&net, &fast), &fast, &EnergyConfig::default());
        assert!(e_fast.static_mj < e_base.static_mj);
        // Dynamic compute energy is workload-determined, not time-determined.
        assert!((e_fast.dynamic_compute_mj - e_base.dynamic_compute_mj).abs() < 1e-9);
    }

    #[test]
    fn render_and_json() {
        let sys = SystemConfig::base_paper();
        let sim = sim_of(&models::lenet(28), &sys);
        let e = energy_of(&sim, &sys, &EnergyConfig::default());
        assert!(e.render_text().contains("mJ"));
        assert!(e.to_json().get("total_mj").as_f64().unwrap() > 0.0);
    }
}
