//! Minimal JSON parser and writer.
//!
//! The offline build environment has no serde_json, so the project carries
//! its own implementation: a [`Value`] tree with a parser and
//! pretty/compact writer, built on the streaming layer in
//! [`stream`]. Covers the full JSON grammar (RFC 8259) including escapes
//! and \uXXXX (with surrogate pairs); numbers are kept as f64 plus an i64
//! fast path (ids, shapes and byte counts round-trip exactly).
//!
//! # Tree vs. stream — which to use
//!
//! Use the **tree** API (`parse` + `Value` + `to_string_*`) when the code
//! manipulates the document as data: building reports, comparing embedded
//! keys structurally, test fixtures. It materializes everything and is the
//! ergonomic default.
//!
//! Use the **stream** API ([`stream::Reader`] / [`stream::Writer`] /
//! `stream::path_*`) on hot I/O paths where the document is large, only a
//! few fields are needed, or output should not be buffered whole:
//! cache-entry fingerprint prechecks, LRU-index touches, journal replay,
//! and multi-thousand-point campaign report emission all live there. The
//! two layers share one lexer and one emitter, so diagnostics and bytes
//! are identical — switching a path between them never changes what lands
//! on disk.

pub mod stream;

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// A JSON document node. Object keys are sorted (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integer fast path: preserves u64/i64 exactly.
    Int(i64),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns Null for missing keys / non-objects
    /// (ergonomic chaining like serde_json's index).
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element access; Null when out of range.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers with path-style error messages.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .as_u64()
            .ok_or_else(|| anyhow!("missing/invalid unsigned field {key:?}"))
    }

    /// Like [`Value::req_u64`] but additionally requires the value to fit
    /// `u32` — checked narrowing that reads as rejection, never as a
    /// silent wrap (`as u32` on an oversized value would).
    pub fn req_u32(&self, key: &str) -> Result<u32> {
        let v = self.req_u64(key)?;
        u32::try_from(v).map_err(|_| anyhow!("field {key:?}: {v} exceeds u32 range"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow!("missing/invalid number field {key:?}"))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.get(key)
            .as_array()
            .ok_or_else(|| anyhow!("missing/invalid array field {key:?}"))
    }

    /// Compact single-line serialization. Drives [`stream::Writer`] — the
    /// incremental emitter and this method produce identical bytes by
    /// construction.
    pub fn to_string_compact(&self) -> String {
        self.serialize(None)
    }

    /// Pretty serialization with 1-space indent (matches python's
    /// `json.dumps(..., indent=1)` closely enough for diffing).
    pub fn to_string_pretty(&self) -> String {
        self.serialize(Some(1))
    }

    fn serialize(&self, indent: Option<usize>) -> String {
        let mut bytes = Vec::new();
        let mut w = stream::Writer::with_indent(&mut bytes, indent);
        w.value(self)
            .and_then(|_| w.finish().map(|_| ()))
            .expect("serializing a Value to memory cannot fail");
        String::from_utf8(bytes).expect("writer emits UTF-8")
    }
}

/// Build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        // Values beyond i64 lose the integer fast path but keep magnitude.
        i64::try_from(v).map(Value::Int).unwrap_or(Value::Num(v as f64))
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::from(v as u64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document. Trailing non-whitespace is an error.
///
/// Every parse error carries the byte offset it was detected at plus a
/// short snippet of the surrounding input, so a corrupted cache artifact
/// or a torn journal line is diagnosable straight from a report's error
/// sample instead of a bare "unexpected character".
///
/// Implemented as an iterative fold over [`stream::Reader`] events (no
/// recursion; nesting bounded at [`stream::MAX_DEPTH`]), so pull-parsing
/// and tree-parsing agree on every accept/reject decision, error message,
/// and byte offset.
pub fn parse(text: &str) -> Result<Value> {
    enum Frame {
        Obj(BTreeMap<String, Value>, Option<String>),
        Arr(Vec<Value>),
    }
    let mut r = stream::Reader::new(text.as_bytes());
    let mut stack: Vec<Frame> = Vec::new();
    let mut root: Option<Value> = None;
    while let Some(ev) = r.next()? {
        let completed: Option<Value> = match ev {
            stream::Event::ObjBegin => {
                stack.push(Frame::Obj(BTreeMap::new(), None));
                None
            }
            stream::Event::ArrBegin => {
                stack.push(Frame::Arr(Vec::new()));
                None
            }
            stream::Event::Key(k) => {
                if let Some(Frame::Obj(_, slot)) = stack.last_mut() {
                    *slot = Some(k.into_owned());
                }
                None
            }
            stream::Event::ObjEnd => match stack.pop() {
                Some(Frame::Obj(map, _)) => Some(Value::Object(map)),
                _ => unreachable!("reader only ends an object it began"),
            },
            stream::Event::ArrEnd => match stack.pop() {
                Some(Frame::Arr(items)) => Some(Value::Array(items)),
                _ => unreachable!("reader only ends an array it began"),
            },
            stream::Event::Str(s) => Some(Value::Str(s.into_owned())),
            stream::Event::Int(i) => Some(Value::Int(i)),
            stream::Event::Num(f) => Some(Value::Num(f)),
            stream::Event::Bool(b) => Some(Value::Bool(b)),
            stream::Event::Null => Some(Value::Null),
        };
        if let Some(v) = completed {
            match stack.last_mut() {
                None => root = Some(v),
                Some(Frame::Obj(map, slot)) => {
                    let key = slot.take().expect("reader emits Key before each object value");
                    map.insert(key, v);
                }
                Some(Frame::Arr(items)) => items.push(v),
            }
        }
    }
    Ok(root.expect("reader yields a root value or an error"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quote-and-escape `s` into `out` via the shared emitter in
    /// [`stream`] — the historical tree-side helper, kept in the tests as
    /// the escape-roundtrip harness.
    fn write_string(out: &mut String, s: &str) {
        let mut bytes = Vec::with_capacity(s.len() + 2);
        stream::write_escaped(&mut bytes, s).expect("escaping into memory cannot fail");
        out.push_str(std::str::from_utf8(&bytes).expect("escaped JSON is UTF-8"));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-17").unwrap(), Value::Int(-17));
        assert_eq!(parse("2.5").unwrap(), Value::Num(2.5));
        assert_eq!(parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(0).as_u64(), Some(1));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = "line1\nline2\t\"quoted\" \\ slash é 漢 🚀";
        let mut s = String::new();
        write_string(&mut s, original);
        let back = parse(&s).unwrap();
        assert_eq!(back.as_str(), Some(original));
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse(r#""🚀""#).unwrap().as_str(), Some("🚀"));
        assert!(parse(r#""\ud83d""#).is_err()); // lone high surrogate
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "[] []", "{'a':1}"] {
            assert!(parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn whole_document_roundtrip() {
        let doc = obj(vec![
            ("name", "dilated_vgg".into()),
            ("layers", Value::Array(vec![
                obj(vec![("cin", 3u32.into()), ("cout", 64u32.into())]),
            ])),
            ("frac", 0.25.into()),
            ("big", Value::Int(1_234_567_890_123)),
            ("neg", (-5i64).into()),
            ("flag", true.into()),
            ("none", Value::Null),
        ]);
        for text in [doc.to_string_compact(), doc.to_string_pretty()] {
            assert_eq!(parse(&text).unwrap(), doc);
        }
    }

    #[test]
    fn integral_floats_round_trip_as_floats() {
        // Num(2.0) must not serialize as "2" — that re-parses as Int and
        // silently changes the Value. The writer keeps the decimal point,
        // exactly like python's json.dumps.
        for f in [2.0f64, 1e11, -3.0, 0.0] {
            let v = Value::Num(f);
            let text = v.to_string_compact();
            assert!(text.contains('.'), "{f}: serialized {text:?} lost the decimal point");
            assert_eq!(parse(&text).unwrap(), v, "{f}");
        }
        // Non-integral floats keep the shortest form.
        assert_eq!(Value::Num(0.25).to_string_compact(), "0.25");
        assert_eq!(parse("0.25").unwrap(), Value::Num(0.25));
    }

    #[test]
    fn int_precision_preserved() {
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_i64(), Some(9007199254740993));
    }

    #[test]
    fn helper_accessors_fail_gracefully() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.req_str("a").is_err());
        assert!(v.req_u64("missing").is_err());
        assert_eq!(v.get("nope").get("deeper").at(3), &Value::Null);
    }

    #[test]
    fn req_u32_rejects_oversized_values_instead_of_wrapping() {
        let v = parse(r#"{"ok": 42, "big": 4294967296, "neg": -1}"#).unwrap();
        assert_eq!(v.req_u32("ok").unwrap(), 42);
        // 2^32 would silently wrap to 0 under `as u32`; it must error.
        let err = v.req_u32("big").unwrap_err();
        assert!(format!("{err:#}").contains("exceeds u32"));
        assert!(v.req_u32("neg").is_err());
        assert!(v.req_u32("missing").is_err());
    }

    #[test]
    fn parse_errors_carry_byte_offset_and_context_snippet() {
        // Mid-document defect: the diagnostic names the byte offset and
        // shows a window of the surrounding input.
        let err = parse(r#"{"a": 1, "b": ?}"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("at byte 14"), "{msg}");
        assert!(msg.contains("near"), "{msg}");
        assert!(msg.contains("?}"), "{msg}");
        // A truncated document — the torn-journal-line shape — says so,
        // with the tail of what *was* there.
        let err = parse(r#"{"unit":3,"class":"feas"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unexpected end of input"), "{msg}");
        assert!(msg.contains("at byte 23"), "{msg}");
        // Bad separators point at the offending byte, not just "malformed".
        let err = parse(r#"[1; 2]"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("expected ',' or ']'"), "{msg}");
        assert!(msg.contains("at byte 2"), "{msg}");
    }

    #[test]
    fn compact_is_single_line() {
        let v = parse(r#"{"a": [1, 2]}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":[1,2]}"#);
    }

    #[test]
    fn python_json_dumps_output_parses() {
        // Shape of python/compile's graph export.
        let text = "{\n \"schema\": \"avsm-dnn-graph-v1\",\n \"dtype_bytes\": 2,\n \"layers\": [\n  {\n   \"name\": \"conv1_0\"\n  }\n ]\n}";
        let v = parse(text).unwrap();
        assert_eq!(v.get("schema").as_str(), Some("avsm-dnn-graph-v1"));
    }
}
