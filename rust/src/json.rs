//! Minimal JSON parser and writer.
//!
//! The offline build environment has no serde_json, so the project carries
//! its own implementation: a recursive-descent parser producing a [`Value`]
//! tree and a pretty/compact writer. Covers the full JSON grammar (RFC 8259)
//! including escapes and \uXXXX (with surrogate pairs); numbers are kept as
//! f64 plus an i64 fast path (ids, shapes and byte counts round-trip
//! exactly).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON document node. Object keys are sorted (BTreeMap) so output is
/// deterministic — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integer fast path: preserves u64/i64 exactly.
    Int(i64),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns Null for missing keys / non-objects
    /// (ergonomic chaining like serde_json's index).
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Object(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element access; Null when out of range.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Array(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers with path-style error messages.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key)
            .as_str()
            .ok_or_else(|| anyhow!("missing/invalid string field {key:?}"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.get(key)
            .as_u64()
            .ok_or_else(|| anyhow!("missing/invalid unsigned field {key:?}"))
    }

    /// Like [`Value::req_u64`] but additionally requires the value to fit
    /// `u32` — checked narrowing that reads as rejection, never as a
    /// silent wrap (`as u32` on an oversized value would).
    pub fn req_u32(&self, key: &str) -> Result<u32> {
        let v = self.req_u64(key)?;
        u32::try_from(v).map_err(|_| anyhow!("field {key:?}: {v} exceeds u32 range"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| anyhow!("missing/invalid number field {key:?}"))
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.get(key)
            .as_array()
            .ok_or_else(|| anyhow!("missing/invalid array field {key:?}"))
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches python's
    /// `json.dumps(..., indent=1)` closely enough for diffing).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_value(&mut s, self, Some(1), 0);
        s
    }
}

/// Build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}
impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}
impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        // Values beyond i64 lose the integer fast path but keep magnitude.
        i64::try_from(v).map(Value::Int).unwrap_or(Value::Num(v as f64))
    }
}
impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Self {
        Value::from(v as u64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Num(v)
    }
}
impl<T: Into<Value>> From<Vec<T>> for Value {
    fn from(v: Vec<T>) -> Self {
        Value::Array(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse a JSON document. Trailing non-whitespace is an error.
///
/// Every parse error carries the byte offset it was detected at plus a
/// short snippet of the surrounding input, so a corrupted cache artifact
/// or a torn journal line is diagnosable straight from a report's error
/// sample instead of a bare "unexpected character".
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err_at(p.pos, "trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    /// Diagnostic anchored at `pos`: the message, the byte offset, and a
    /// short window of the raw input around it (lossy-decoded, so binary
    /// garbage still renders).
    fn err_at(&self, pos: usize, msg: impl std::fmt::Display) -> anyhow::Error {
        const WINDOW: usize = 12;
        let start = pos.saturating_sub(WINDOW);
        let end = (pos + WINDOW).min(self.bytes.len());
        let mut near = String::new();
        if start > 0 {
            near.push_str("...");
        }
        near.push_str(&String::from_utf8_lossy(&self.bytes[start..end]));
        if end < self.bytes.len() {
            near.push_str("...");
        }
        anyhow!("{msg} at byte {pos} (near {near:?})")
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| self.err_at(self.pos, "unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let at = self.pos;
        let got = self.bump()?;
        if got != b {
            return Err(self.err_at(
                at,
                format!("expected {:?}, got {:?}", b as char, got as char),
            ));
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self
            .peek()
            .ok_or_else(|| self.err_at(self.pos, "unexpected end of input"))?
        {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            b'-' | b'0'..=b'9' => self.number(),
            other => {
                Err(self.err_at(self.pos, format!("unexpected character {:?}", other as char)))
            }
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err_at(self.pos, format!("invalid literal (expected {lit:?})")))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            let at = self.pos;
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Object(map)),
                other => {
                    return Err(
                        self.err_at(at, format!("expected ',' or '}}', got {:?}", other as char))
                    )
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            let at = self.pos;
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Array(items)),
                other => {
                    return Err(
                        self.err_at(at, format!("expected ',' or ']', got {:?}", other as char))
                    )
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let at = self.pos;
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err_at(at, "invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err_at(at, "bad surrogate pair"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err_at(at, "bad unicode escape"))?,
                            );
                        }
                    }
                    other => {
                        return Err(
                            self.err_at(at, format!("bad escape \\{:?}", other as char))
                        )
                    }
                },
                b if b < 0x20 => {
                    return Err(self.err_at(at, "raw control character in string"))
                }
                b if b < 0x80 => s.push(b as char),
                b => {
                    // Multi-byte UTF-8: re-decode from the source slice.
                    let start = self.pos - 1;
                    let len = utf8_len(b)
                        .map_err(|e| self.err_at(start, e))?;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err_at(start, "truncated UTF-8 sequence"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err_at(start, "invalid UTF-8 in string"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let at = self.pos;
            let b = self.bump()?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err_at(at, "bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err_at(start, format!("invalid number {text:?}")))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte"),
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

fn write_value(out: &mut String, v: &Value, indent: Option<usize>, depth: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Int(i) => {
            let _ = write!(out, "{i}");
        }
        Value::Num(f) => {
            if !f.is_finite() {
                out.push_str("null"); // JSON has no Inf/NaN
            } else if f.fract() == 0.0 {
                // Keep the decimal point (python-json style "2.0"): a bare
                // "2" would re-parse as Int and break Value round-trips
                // for integral floats (report throughputs, bench medians).
                let _ = write!(out, "{f:.1}");
            } else {
                let _ = write!(out, "{f}");
            }
        }
        Value::Str(s) => write_string(out, s),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(out, item, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(out, k);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(out, val, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Int(42));
        assert_eq!(parse("-17").unwrap(), Value::Int(-17));
        assert_eq!(parse("2.5").unwrap(), Value::Num(2.5));
        assert_eq!(parse("1e3").unwrap(), Value::Num(1000.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("a").at(0).as_u64(), Some(1));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = "line1\nline2\t\"quoted\" \\ slash é 漢 🚀";
        let mut s = String::new();
        write_string(&mut s, original);
        let back = parse(&s).unwrap();
        assert_eq!(back.as_str(), Some(original));
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
        assert_eq!(parse(r#""🚀""#).unwrap().as_str(), Some("🚀"));
        assert!(parse(r#""\ud83d""#).is_err()); // lone high surrogate
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "[] []", "{'a':1}"] {
            assert!(parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn whole_document_roundtrip() {
        let doc = obj(vec![
            ("name", "dilated_vgg".into()),
            ("layers", Value::Array(vec![
                obj(vec![("cin", 3u32.into()), ("cout", 64u32.into())]),
            ])),
            ("frac", 0.25.into()),
            ("big", Value::Int(1_234_567_890_123)),
            ("neg", (-5i64).into()),
            ("flag", true.into()),
            ("none", Value::Null),
        ]);
        for text in [doc.to_string_compact(), doc.to_string_pretty()] {
            assert_eq!(parse(&text).unwrap(), doc);
        }
    }

    #[test]
    fn integral_floats_round_trip_as_floats() {
        // Num(2.0) must not serialize as "2" — that re-parses as Int and
        // silently changes the Value. The writer keeps the decimal point,
        // exactly like python's json.dumps.
        for f in [2.0f64, 1e11, -3.0, 0.0] {
            let v = Value::Num(f);
            let text = v.to_string_compact();
            assert!(text.contains('.'), "{f}: serialized {text:?} lost the decimal point");
            assert_eq!(parse(&text).unwrap(), v, "{f}");
        }
        // Non-integral floats keep the shortest form.
        assert_eq!(Value::Num(0.25).to_string_compact(), "0.25");
        assert_eq!(parse("0.25").unwrap(), Value::Num(0.25));
    }

    #[test]
    fn int_precision_preserved() {
        let v = parse("9007199254740993").unwrap(); // 2^53 + 1
        assert_eq!(v.as_i64(), Some(9007199254740993));
    }

    #[test]
    fn helper_accessors_fail_gracefully() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.req_str("a").is_err());
        assert!(v.req_u64("missing").is_err());
        assert_eq!(v.get("nope").get("deeper").at(3), &Value::Null);
    }

    #[test]
    fn req_u32_rejects_oversized_values_instead_of_wrapping() {
        let v = parse(r#"{"ok": 42, "big": 4294967296, "neg": -1}"#).unwrap();
        assert_eq!(v.req_u32("ok").unwrap(), 42);
        // 2^32 would silently wrap to 0 under `as u32`; it must error.
        let err = v.req_u32("big").unwrap_err();
        assert!(format!("{err:#}").contains("exceeds u32"));
        assert!(v.req_u32("neg").is_err());
        assert!(v.req_u32("missing").is_err());
    }

    #[test]
    fn parse_errors_carry_byte_offset_and_context_snippet() {
        // Mid-document defect: the diagnostic names the byte offset and
        // shows a window of the surrounding input.
        let err = parse(r#"{"a": 1, "b": ?}"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("at byte 14"), "{msg}");
        assert!(msg.contains("near"), "{msg}");
        assert!(msg.contains("?}"), "{msg}");
        // A truncated document — the torn-journal-line shape — says so,
        // with the tail of what *was* there.
        let err = parse(r#"{"unit":3,"class":"feas"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unexpected end of input"), "{msg}");
        assert!(msg.contains("at byte 23"), "{msg}");
        // Bad separators point at the offending byte, not just "malformed".
        let err = parse(r#"[1; 2]"#).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("expected ',' or ']'"), "{msg}");
        assert!(msg.contains("at byte 2"), "{msg}");
    }

    #[test]
    fn compact_is_single_line() {
        let v = parse(r#"{"a": [1, 2]}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":[1,2]}"#);
    }

    #[test]
    fn python_json_dumps_output_parses() {
        // Shape of python/compile's graph export.
        let text = "{\n \"schema\": \"avsm-dnn-graph-v1\",\n \"dtype_bytes\": 2,\n \"layers\": [\n  {\n   \"name\": \"conv1_0\"\n  }\n ]\n}";
        let v = parse(text).unwrap();
        assert_eq!(v.get("schema").as_str(), Some("avsm-dnn-graph-v1"));
    }
}
