//! `avsm serve` — a resident campaign daemon over line-delimited JSON
//! (ROADMAP "Campaign service").
//!
//! One-shot CLI pays a cold process per design question: binary start,
//! disk-cache reopen, recompile-or-load. The daemon keeps the two-tier
//! compile cache and its hot `CompiledNet`s **resident**, so the second
//! time any workload is asked about, the answer comes from the in-memory
//! tier — zero compilations, zero disk reads (asserted end to end by the
//! integration tests and the `scripts/check.sh` smoke).
//!
//! # Protocol
//!
//! One JSON object per line in, one or more JSON objects per line out.
//! Requests ride the same machine-readable formats the CLI already
//! speaks: campaign axis specs are `avsm-campaign-v1` axis arrays,
//! workloads the `--workloads` entry shape. The request envelope:
//!
//! ```json
//! {"v": 1, "id": 7, "kind": "campaign", "nets": ["lenet"], "axes": [...]}
//! ```
//!
//! - `v` — envelope version. **Missing means 1.** Within a major
//!   version, unknown fields are ignored (additive evolution); the first
//!   breaking change bumps `v`, and a request with an unsupported `v` is
//!   rejected with `AVSM061` naming the supported set. Responses echo
//!   `"v": 1`. This is the repo's first negotiated schema (the carried
//!   schema-evolution item): the rule is *receiver-makes-right* — the
//!   daemon never guesses at a version it does not implement.
//! - `id` — any JSON value, echoed verbatim on every response for this
//!   request (default `null`). Correlation only; the daemon never reads
//!   it.
//! - `kind` — `"campaign"`, `"sweep"`, `"solve"`, `"ping"`, or
//!   `"shutdown"`.
//!
//! Every response line carries `"event"` plus the echoed `id` and `v`:
//! `rejected` (with the full `avsm-lint-v1` report under `"lint"`),
//! `accepted`, `point` (one per feasible design point, streamed in
//! completion order), `report` (the final `avsm-campaign-v1` document,
//! byte-identical to `avsm campaign` on the same spec), `solution`,
//! `failed` (admitted but died at runtime), `pong`, and `bye`.
//!
//! # Admission gate
//!
//! A request costs a worker **only after** it passes the same static
//! pre-flight the CLI runs (`analysis::passes` + the campaign
//! `preflight_report`): malformed JSON, a bad envelope, an unknown net,
//! or a spec the lint passes reject all turn into one `rejected` line
//! whose payload is the standard `avsm-lint-v1` report — protocol
//! problems under the `AVSM060`-`AVSM064` family, spec problems under
//! the existing `AVSM03x` codes. A malformed job costs one pass over its
//! bytes, never a pool slot.
//!
//! # Cache residency and coherence
//!
//! Caches are keyed by (net content fingerprint, occurrence index within
//! the request) — the same per-workload layout the CLI builds — and live
//! for the daemon's lifetime. Report counters are per-run deltas
//! ([`campaign::RunHooks`] snapshots), so a warm cache shows up as
//! `memory_hits`, not as another run's compiles. With `--cache-dir` the
//! resident caches share the disk tier with concurrent one-shot CLI
//! invocations; coherence is the existing `index.lock` advisory-lock
//! protocol — the daemon takes no extra ownership of the directory.
//!
//! Jobs are serialized through one runner lock onto one shared
//! `campaign::pool` fan-out: concurrent clients interleave at request
//! granularity (responses never cross connections), and the machine is
//! never oversubscribed by two campaigns racing.

use crate::analysis::{Diagnostic, Report};
use crate::campaign::{self, CampaignOptions, CampaignSpec, PersistentCache, WorkloadSpec};
use crate::compiler::BoundKind;
use crate::config::SystemConfig;
use crate::dse::{self, Axis, DesignPoint, SweepAxes};
use crate::graph::{graph_from_json, models, DnnGraph};
use crate::json::{self, obj, stream, Value};
use crate::report::CampaignReport;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Daemon configuration (CLI flags of `avsm serve`).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Disk tier shared with one-shot CLI runs; `None` keeps the
    /// resident caches memory-only.
    pub cache_dir: Option<PathBuf>,
    pub cache_max_entries: Option<usize>,
    /// Worker threads per admitted job (0 = auto, like the CLI).
    pub threads: usize,
    /// Per-request line cap; over-cap lines are rejected (`AVSM063`)
    /// without buffering them.
    pub max_line: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            cache_dir: None,
            cache_max_entries: None,
            threads: 0,
            max_line: stream::DEFAULT_MAX_FRAME,
        }
    }
}

/// Per-session tallies, returned by [`serve_session`] for tests and the
/// daemon's exit log.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionStats {
    /// Admitted requests that ran to a `report`/`solution`/`pong` line.
    pub served: usize,
    /// Requests refused at the admission gate (one `rejected` line each).
    pub rejected: usize,
    /// Admitted requests that died at runtime (one `failed` line each).
    pub failed: usize,
}

/// The resident state shared by every connection.
pub struct Daemon {
    opts: ServeOptions,
    /// Per-(net fingerprint, occurrence) caches — the same per-workload
    /// cache layout a one-shot campaign builds, kept warm for the
    /// process lifetime.
    caches: Mutex<HashMap<(u64, usize), Arc<PersistentCache>>>,
    /// Serializes admitted jobs onto the shared worker pool.
    runner: Mutex<()>,
    shutdown: AtomicBool,
    /// Set by [`serve_unix`] so a `shutdown` request can unblock the
    /// accept loop with a self-connection.
    socket_path: Mutex<Option<PathBuf>>,
}

impl Daemon {
    pub fn new(opts: ServeOptions) -> Self {
        Daemon {
            opts,
            caches: Mutex::new(HashMap::new()),
            runner: Mutex::new(()),
            shutdown: AtomicBool::new(false),
            socket_path: Mutex::new(None),
        }
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Resident caches for one request's workloads, index-aligned with
    /// the spec. Identical nets in *different* requests share a cache
    /// (that is the residency win); identical nets *within* one request
    /// get one cache per occurrence, exactly like the CLI's per-workload
    /// vector, so per-net report counters attribute the same way.
    fn caches_for(&self, spec: &CampaignSpec) -> Vec<Arc<PersistentCache>> {
        let mut map = lock_recovered(&self.caches);
        let mut seen: HashMap<u64, usize> = HashMap::new();
        spec.workloads
            .iter()
            .map(|w| {
                let fp = net_fingerprint(&w.net);
                let occurrence = seen.entry(fp).or_insert(0);
                let key = (fp, *occurrence);
                *occurrence += 1;
                map.entry(key)
                    .or_insert_with(|| {
                        Arc::new(
                            PersistentCache::with_max_entries(
                                dse::DSE_COMPILE_OPTS,
                                self.opts.cache_dir.clone(),
                                self.opts.cache_max_entries,
                            )
                            .unwrap_or_else(|_| {
                                // An unusable cache dir degrades to a
                                // memory-only cache rather than killing
                                // the daemon; read_errors would have
                                // surfaced per-entry anyway.
                                PersistentCache::with_max_entries(
                                    dse::DSE_COMPILE_OPTS,
                                    None,
                                    self.opts.cache_max_entries,
                                )
                                .expect("memory-only cache cannot fail to open")
                            }),
                        )
                    })
                    .clone()
            })
            .collect()
    }
}

fn lock_recovered<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Content fingerprint for resident-cache keying: the net's canonical
/// JSON through the journal's hasher. Two requests naming byte-identical
/// nets land on the same resident cache.
fn net_fingerprint(net: &DnnGraph) -> u64 {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut h = DefaultHasher::new();
    crate::graph::graph_to_json(net).hash(&mut h);
    h.finish()
}

/// What `handle_request` tells the session loop to do next.
enum Flow {
    Continue,
    Shutdown,
}

/// Serve one connection: requests in via `input`, responses out via
/// `out`. This is the whole daemon in pipe mode (stdin/stdout) and one
/// connection's thread under [`serve_unix`]. Returns when the input
/// closes, a `shutdown` request arrives, or the output dies; protocol
/// errors never return — they are `rejected` lines.
pub fn serve_session<R: Read, W: Write>(
    daemon: &Daemon,
    input: R,
    mut out: W,
) -> Result<SessionStats> {
    let mut frames = stream::FrameReader::new(input).with_max_frame(daemon.opts.max_line);
    let mut stats = SessionStats::default();
    loop {
        let frame = match frames.next_frame() {
            Ok(None) => break,
            Ok(Some(f)) => f.to_vec(),
            Err(e) if stream::is_oversized_frame(&e) => {
                // The offending line is already discarded; the stream
                // continues on the next one.
                let mut report = Report::new(Vec::new());
                report.push(Diagnostic::error(
                    "AVSM063",
                    "request line",
                    format!("{e:#}"),
                ));
                emit_rejected(&mut out, &Value::Null, &report)?;
                stats.rejected += 1;
                continue;
            }
            Err(e) => return Err(e),
        };
        if frame.iter().all(|b| b.is_ascii_whitespace()) {
            continue; // blank keep-alive line
        }
        match handle_request(daemon, &frame, &mut out, &mut stats)? {
            Flow::Continue => {}
            Flow::Shutdown => break,
        }
    }
    Ok(stats)
}

/// Parse, admit, and run one request line, writing every response line
/// for it. Only I/O errors on `out` propagate.
fn handle_request<W: Write>(
    daemon: &Daemon,
    frame: &[u8],
    out: &mut W,
    stats: &mut SessionStats,
) -> Result<Flow> {
    // ---- Envelope validation: AVSM060 (parse), AVSM061 (version),
    // AVSM062 (kind). Anything wrong here is a rejection with id null if
    // the id itself is unreadable.
    let reject = |out: &mut W, stats: &mut SessionStats, id: &Value, d: Diagnostic| {
        let mut report = Report::new(Vec::new());
        report.push(d);
        emit_rejected(out, id, &report)?;
        stats.rejected += 1;
        Ok::<Flow, anyhow::Error>(Flow::Continue)
    };
    let text = match std::str::from_utf8(frame) {
        Ok(t) => t,
        Err(_) => {
            let d = Diagnostic::error("AVSM060", "request", "request line is not valid UTF-8");
            return reject(out, stats, &Value::Null, d);
        }
    };
    let doc = match json::parse(text) {
        Ok(d) => d,
        Err(e) => {
            let d = Diagnostic::error("AVSM060", "request", format!("{e:#}"));
            return reject(out, stats, &Value::Null, d);
        }
    };
    if !matches!(doc, Value::Object(_)) {
        let d = Diagnostic::error("AVSM060", "request", "request must be a JSON object");
        return reject(out, stats, &Value::Null, d);
    }
    let id = doc.get("id").clone();
    let version = match doc.get("v") {
        Value::Null => 1, // missing means 1 — the envelope rule
        v => v.as_u64().unwrap_or(0),
    };
    if version != 1 {
        let d = Diagnostic::error(
            "AVSM061",
            "request envelope",
            format!("unsupported envelope version {:?} (supported: 1)", doc.get("v")),
        )
        .with_help("omit \"v\" or send \"v\": 1");
        return reject(out, stats, &id, d);
    }
    let kind = match doc.get("kind").as_str() {
        Some(k) => k,
        None => {
            let d = Diagnostic::error(
                "AVSM062",
                "request envelope",
                "request needs a string \"kind\"",
            )
            .with_help("one of: campaign, sweep, solve, ping, shutdown");
            return reject(out, stats, &id, d);
        }
    };
    match kind {
        "ping" => {
            emit_event(out, "pong", &id, vec![])?;
            stats.served += 1;
            Ok(Flow::Continue)
        }
        "shutdown" => {
            daemon.shutdown.store(true, Ordering::SeqCst);
            emit_event(out, "bye", &id, vec![])?;
            stats.served += 1;
            // Unblock a blocking unix accept loop, if one is running.
            #[cfg(unix)]
            if let Some(path) = lock_recovered(&daemon.socket_path).clone() {
                let _ = std::os::unix::net::UnixStream::connect(path);
            }
            Ok(Flow::Shutdown)
        }
        "campaign" | "sweep" => run_campaign_request(daemon, &doc, kind, &id, out, stats),
        "solve" => run_solve_request(daemon, &doc, &id, out, stats),
        other => {
            let d = Diagnostic::error(
                "AVSM062",
                "request envelope",
                format!("unknown request kind {other:?}"),
            )
            .with_help("one of: campaign, sweep, solve, ping, shutdown");
            reject(out, stats, &id, d)
        }
    }
}

/// Build and run an admitted campaign (or single-net sweep — the same
/// engine with a one-workload portfolio), streaming `point` lines and the
/// final `report` line.
fn run_campaign_request<W: Write>(
    daemon: &Daemon,
    doc: &Value,
    kind: &str,
    id: &Value,
    out: &mut W,
    stats: &mut SessionStats,
) -> Result<Flow> {
    let (spec, opts) = match campaign_request(daemon, doc, kind) {
        Ok(parts) => parts,
        Err(report) => {
            emit_rejected(out, id, &report)?;
            stats.rejected += 1;
            return Ok(Flow::Continue);
        }
    };
    // Final static gate: exactly the reject set the CLI run would bail
    // on, as a lint report instead of a bail.
    let preflight = campaign::preflight_report(&spec);
    if preflight.has_errors() {
        emit_rejected(out, id, &preflight)?;
        stats.rejected += 1;
        return Ok(Flow::Continue);
    }
    emit_event(
        out,
        "accepted",
        id,
        vec![("kind", Value::Str(kind.to_string()))],
    )?;

    let _job = lock_recovered(&daemon.runner); // one campaign at a time
    let caches = daemon.caches_for(&spec);
    let mut on_point = |net: &str, p: &DesignPoint| {
        // A dead client must not kill the run mid-campaign (the cache
        // still warms); the final report write surfaces the I/O error.
        let _ = emit_event_to(
            out,
            "point",
            id,
            vec![("net", Value::Str(net.to_string())), ("point", dse::point_to_json(p))],
        );
    };
    let hooks = campaign::RunHooks {
        caches: Some(caches),
        on_point: Some(&mut on_point),
    };
    match campaign::run_with_hooks(&spec, &opts, hooks) {
        Ok(result) => {
            let report = CampaignReport::new(&result);
            // The report line is spliced around the report's own bytes
            // (keys emitted in sorted order: event < id < report < v),
            // so the served document is the `write_json` output verbatim
            // — byte-identical to `avsm campaign`'s campaign.json in
            // compact mode, and extractable by suffix/prefix split.
            out.write_all(b"{\"event\":\"report\",\"id\":")?;
            out.write_all(id.to_string_compact().as_bytes())?;
            out.write_all(b",\"report\":")?;
            report.write_json(&mut *out, false)?;
            out.write_all(b",\"v\":1}\n")?;
            out.flush()?;
            stats.served += 1;
        }
        Err(e) => {
            emit_event(out, "failed", id, vec![("error", Value::Str(format!("{e:#}")))])?;
            stats.failed += 1;
        }
    }
    Ok(Flow::Continue)
}

/// Parse a campaign/sweep request body into a runnable spec, or the lint
/// report that rejects it. Field problems (unknown net, bad hw) are
/// `AVSM064`; spec-shape problems reuse the `AVSM03x` passes, so a bad
/// axis spec is rejected with the very same codes `avsm lint` prints.
#[allow(clippy::type_complexity)]
fn campaign_request(
    daemon: &Daemon,
    doc: &Value,
    kind: &str,
) -> std::result::Result<(CampaignSpec, CampaignOptions), Report> {
    use crate::analysis::passes;
    let mut report = Report::new(Vec::new());
    let field_err = |report: &mut Report, site: &str, msg: String| {
        report.push(Diagnostic::error("AVSM064", site, msg));
    };

    let hw = match doc.get("hw") {
        Value::Null => 0u32,
        v => match v.as_u64().and_then(|h| u32::try_from(h).ok()) {
            Some(h) => h,
            None => {
                field_err(&mut report, "request.hw", format!("hw must be a u32, got {v:?}"));
                0
            }
        },
    };
    let base = match doc.get("base") {
        Value::Null => Some(SystemConfig::base_paper()),
        Value::Str(path) => match SystemConfig::from_file(path) {
            Ok(sys) => Some(sys),
            Err(e) => {
                field_err(&mut report, "request.base", format!("{e:#}"));
                None
            }
        },
        v => {
            field_err(
                &mut report,
                "request.base",
                format!("base must be a path to an avsm-system-v1 file, got {v:?}"),
            );
            None
        }
    };

    // Workloads: "sweep" takes a single "net"; "campaign" takes either
    // "workloads" (the --workloads entry shape) or "nets" (array of
    // names). The workloads value is linted first, so shape problems
    // carry the standard AVSM036 diagnostics.
    let mut workloads: Vec<WorkloadSpec> = Vec::new();
    if kind == "sweep" {
        match doc.get("net").as_str() {
            Some(name) => {
                if let Some(w) = workload_by_name(name, hw, &mut report) {
                    workloads.push(w);
                }
            }
            None => field_err(
                &mut report,
                "request.net",
                "sweep needs a string \"net\"".to_string(),
            ),
        }
    } else if !matches!(doc.get("workloads"), Value::Null) {
        let wl = doc.get("workloads");
        report.extend(passes::lint_workloads_value(wl));
        if !report.has_errors() {
            for (i, entry) in wl.as_array().unwrap_or(&[]).iter().enumerate() {
                match workload_from_value(entry, hw) {
                    Ok(w) => workloads.push(w),
                    Err(e) => {
                        field_err(&mut report, &format!("request.workloads[{i}]"), format!("{e:#}"));
                    }
                }
            }
        }
    } else {
        match doc.get("nets").as_array() {
            Some(names) if !names.is_empty() => {
                for (i, v) in names.iter().enumerate() {
                    match v.as_str() {
                        Some(name) => {
                            if let Some(w) = workload_by_name(name, hw, &mut report) {
                                workloads.push(w);
                            }
                        }
                        None => field_err(
                            &mut report,
                            &format!("request.nets[{i}]"),
                            format!("net name must be a string, got {v:?}"),
                        ),
                    }
                }
            }
            _ => field_err(
                &mut report,
                "request",
                "campaign needs \"workloads\" (array of {net, ...} objects) or \"nets\" \
                 (array of names)"
                    .to_string(),
            ),
        }
    }

    // Axes: linted with the standard axis-spec passes (AVSM030-033)
    // before parsing; absent means the CLI's default grid.
    let axes = match doc.get("axes") {
        Value::Null => SweepAxes::new()
            .array_geometries(vec![(16, 32), (32, 64), (64, 64)])
            .nce_freqs_mhz(vec![125, 250, 500]),
        v => {
            report.extend(passes::lint_axis_spec_value(v));
            if report.has_errors() {
                SweepAxes::new()
            } else {
                match SweepAxes::from_value(v) {
                    Ok(a) => a,
                    Err(e) => {
                        field_err(&mut report, "request.axes", format!("{e:#}"));
                        SweepAxes::new()
                    }
                }
            }
        }
    };

    let o = doc.get("options");
    let opts = CampaignOptions {
        threads: match o.get("threads").as_u64() {
            Some(t) => t as usize,
            None => daemon.opts.threads,
        },
        cache_dir: daemon.opts.cache_dir.clone(),
        cache_max_entries: daemon.opts.cache_max_entries,
        keep_points: false,
        prune: o.get("no_prune").as_bool() != Some(true),
        bound: match o.get("bound").as_str() {
            Some(key) => match BoundKind::from_key(key) {
                Ok(b) => b,
                Err(e) => {
                    field_err(&mut report, "request.options.bound", format!("{e:#}"));
                    BoundKind::Max
                }
            },
            None => BoundKind::Max,
        },
        order_by_bound: o.get("no_order").as_bool() != Some(true),
        fail_fast: o.get("fail_fast").as_bool() == Some(true),
        // Admission already ran the pre-flight; journals are a one-shot
        // CLI affair (the daemon's residency is its crash story).
        journal: None,
        resume: false,
        preflight: false,
    };

    if report.has_errors() {
        return Err(report);
    }
    Ok((
        CampaignSpec { workloads, base: base.expect("errors were checked"), axes },
        opts,
    ))
}

/// Resolve one workload by built-in name or `.graph.json` path, pushing
/// an `AVSM064` on failure.
fn workload_by_name(name: &str, hw: u32, report: &mut Report) -> Option<WorkloadSpec> {
    match resolve_net(name, hw) {
        Ok(net) => Some(WorkloadSpec::new(net)),
        Err(e) => {
            report.push(Diagnostic::error(
                "AVSM064",
                format!("net {name:?}"),
                format!("{e:#}"),
            ));
            None
        }
    }
}

/// `--workloads`-entry shape: `{net, hw?, base?, axes?}` — the same
/// resolution the CLI performs.
fn workload_from_value(v: &Value, default_hw: u32) -> Result<WorkloadSpec> {
    let name = v.req_str("net")?;
    let hw = match v.get("hw").as_u64() {
        Some(h) => u32::try_from(h)
            .map_err(|_| anyhow::anyhow!("workload {name:?}: hw {h} exceeds u32"))?,
        None => default_hw,
    };
    let mut w = WorkloadSpec::new(resolve_net(name, hw)?);
    if let Some(path) = v.get("base").as_str() {
        w = w.with_base(
            SystemConfig::from_file(path)
                .with_context(|| format!("workload {name:?} base config"))?,
        );
    }
    if !matches!(v.get("axes"), Value::Null) {
        w = w.with_axes(
            SweepAxes::from_value(v.get("axes"))
                .with_context(|| format!("workload {name:?} axis spec"))?,
        );
    }
    Ok(w)
}

/// Built-in model name or `.graph.json` path — one resolution shared
/// with the CLI via [`models::by_name`].
fn resolve_net(name: &str, hw: u32) -> Result<DnnGraph> {
    match models::by_name(name, hw) {
        Some(net) => Ok(net),
        None => {
            let text = std::fs::read_to_string(name)
                .with_context(|| format!("unknown model (and unreadable as a graph path) {name:?}"))?;
            graph_from_json(&text)
        }
    }
}

/// Run an admitted solve-requirement request, emitting one `solution`
/// line (or `failed`, e.g. on a non-monotone axis without `"scan"`).
fn run_solve_request<W: Write>(
    daemon: &Daemon,
    doc: &Value,
    id: &Value,
    out: &mut W,
    stats: &mut SessionStats,
) -> Result<Flow> {
    use crate::analysis::passes;
    let mut report = Report::new(Vec::new());
    let net = match doc.get("net").as_str() {
        Some(name) => match resolve_net(name, doc.get("hw").as_u64().unwrap_or(0) as u32) {
            Ok(net) => Some(net),
            Err(e) => {
                report.push(Diagnostic::error(
                    "AVSM064",
                    format!("net {name:?}"),
                    format!("{e:#}"),
                ));
                None
            }
        },
        None => {
            report.push(Diagnostic::error(
                "AVSM064",
                "request.net",
                "solve needs a string \"net\"",
            ));
            None
        }
    };
    let axis = match doc.get("axis") {
        Value::Null => Some(Axis::NceFreqMhz),
        v => match v.as_str().ok_or(()).and_then(|k| Axis::from_key(k).map_err(|_| ())) {
            Ok(a) => Some(a),
            Err(()) => {
                report.push(Diagnostic::error(
                    "AVSM064",
                    "request.axis",
                    format!("unknown axis {v:?}"),
                ));
                None
            }
        },
    };
    let target_ps = match (doc.get("target_ms"), doc.get("target_ps").as_u64()) {
        (Value::Null, Some(ps)) => Some(ps),
        (Value::Null, None) => {
            report.push(Diagnostic::error(
                "AVSM064",
                "request.target_ms",
                "solve needs \"target_ms\" (number) or \"target_ps\" (integer)",
            ));
            None
        }
        (v, _) => match v.as_i64().map(|i| i as f64).or_else(|| match v {
            Value::Num(f) => Some(*f),
            _ => None,
        }) {
            Some(ms) if ms > 0.0 => Some((ms * 1e9) as u64),
            _ => {
                report.push(Diagnostic::error(
                    "AVSM064",
                    "request.target_ms",
                    format!("target_ms must be a positive number, got {v:?}"),
                ));
                None
            }
        },
    };
    let lo = doc.get("lo").as_u64().unwrap_or(25);
    let hi = doc.get("hi").as_u64().unwrap_or(2000);
    if let Some(axis) = axis {
        report.extend(passes::lint_requirement_range(axis, lo, hi));
    }
    if report.has_errors() {
        emit_rejected(out, id, &report)?;
        stats.rejected += 1;
        return Ok(Flow::Continue);
    }
    let (net, axis, target_ps) =
        (net.expect("checked"), axis.expect("checked"), target_ps.expect("checked"));
    emit_event(out, "accepted", id, vec![("kind", Value::Str("solve".into()))])?;

    let _job = lock_recovered(&daemon.runner);
    let scan = doc.get("scan").as_bool() == Some(true);
    let sys = SystemConfig::base_paper();
    let solved = if scan {
        dse::solve_requirement_scan(&net, &sys, axis, target_ps, (lo, hi))
    } else {
        dse::solve_requirement(&net, &sys, axis, target_ps, (lo, hi))
    };
    match solved {
        Ok(sol) => {
            emit_event(
                out,
                "solution",
                id,
                vec![
                    ("axis", Value::Str(axis.key().to_string())),
                    (
                        "value",
                        match sol.value {
                            Some(v) => Value::from(v),
                            None => Value::Null,
                        },
                    ),
                    ("probes", Value::from(sol.probes)),
                    ("compiles", Value::from(sol.compiles)),
                ],
            )?;
            stats.served += 1;
        }
        Err(e) => {
            emit_event(out, "failed", id, vec![("error", Value::Str(format!("{e:#}")))])?;
            stats.failed += 1;
        }
    }
    Ok(Flow::Continue)
}

/// One `rejected` line: the `avsm-lint-v1` report as the payload.
fn emit_rejected<W: Write>(out: &mut W, id: &Value, report: &Report) -> Result<()> {
    emit_event(out, "rejected", id, vec![("lint", report.to_json())])
}

/// One compact response line: `event`, echoed `id`, extra fields, and
/// the envelope `v` (keys sorted by the `Value` object representation).
fn emit_event<W: Write>(
    out: &mut W,
    event: &str,
    id: &Value,
    extra: Vec<(&str, Value)>,
) -> Result<()> {
    emit_event_to(out, event, id, extra).map_err(Into::into)
}

fn emit_event_to<W: Write>(
    out: &mut W,
    event: &str,
    id: &Value,
    extra: Vec<(&str, Value)>,
) -> std::io::Result<()> {
    let mut fields: Vec<(&str, Value)> = vec![
        ("event", Value::Str(event.to_string())),
        ("id", id.clone()),
        ("v", Value::Int(1)),
    ];
    fields.extend(extra);
    let line = obj(fields).to_string_compact();
    out.write_all(line.as_bytes())?;
    out.write_all(b"\n")?;
    out.flush()
}

/// Accept loop on a Unix socket: thread per connection over one shared
/// [`Daemon`]. Returns after a `shutdown` request (from any client) has
/// drained the accept loop. The socket file is removed on the way out.
#[cfg(unix)]
pub fn serve_unix(path: &std::path::Path, opts: ServeOptions) -> Result<Arc<Daemon>> {
    use std::os::unix::net::UnixListener;
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .with_context(|| format!("binding unix socket {}", path.display()))?;
    let daemon = Arc::new(Daemon::new(opts));
    *lock_recovered(&daemon.socket_path) = Some(path.to_path_buf());
    let mut sessions = Vec::new();
    for conn in listener.incoming() {
        if daemon.is_shutdown() {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue, // one failed accept is not a dead daemon
        };
        if daemon.is_shutdown() {
            break; // the self-connection that unblocked accept
        }
        let d = Arc::clone(&daemon);
        sessions.push(std::thread::spawn(move || {
            let Ok(reader) = stream.try_clone() else { return };
            // A session error is one client's broken pipe, never fatal
            // to the daemon.
            let _ = serve_session(&d, reader, stream);
        }));
    }
    for s in sessions {
        let _ = s.join();
    }
    let _ = std::fs::remove_file(path);
    Ok(daemon)
}
