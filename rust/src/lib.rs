//! AVSM — Abstract Virtual System Models for end-to-end HW/SW co-design of
//! deep neural network systems.
//!
//! Reproduction of Klaiber et al., "An End-to-End HW/SW Co-Design Methodology
//! to Design Efficient Deep Neural Network Systems using Virtual Models",
//! Embedded Systems Week 2019 (DOI 10.1145/3372394.3372396).
//!
//! Architecture (see DESIGN.md):
//! * [`sim`] — deterministic discrete-event kernel (the SystemC/Platform
//!   Architect substitute).
//! * [`graph`] — DNN graph IR + builders + JSON interchange with the JAX
//!   model definition.
//! * [`config`] — system description files with physical annotations.
//! * [`compiler`] — the deep-learning compiler: hardware-adapted tiling and
//!   lowering of DNN graphs into task graphs.
//! * [`taskgraph`] — the task graph (the paper's "virtual software model").
//! * [`hw`] — abstract virtual hardware models (NCE, DMA, bus, memory, HKP).
//! * [`detailed`] — the cycle-level "physical prototype" reference model.
//! * [`roofline`], [`trace`], [`report`] — Fig 4/5/6/7 analyses.
//! * [`dse`] — design-space exploration sweeps.
//! * [`campaign`] — multi-workload co-design sweeps: shared worker pool,
//!   streaming Pareto frontiers, disk-persistent compile cache.
//! * [`obs`] — span/counter telemetry for the exploration engine itself
//!   (per-worker timelines, latency histograms, the
//!   `avsm-campaign-telemetry-v1` report).
//! * [`analysis`] — static diagnostics (`avsm lint`): pre-flight passes
//!   over nets/configs/specs plus cache and journal fsck, reported as
//!   stable `AVSM0xx` codes and the `avsm-lint-v1` report.
//! * [`serve`] — the resident campaign daemon: sweep/campaign/solve jobs
//!   over a line-delimited JSON protocol, with a process-lifetime compile
//!   cache and lint-gated admission.
//! * [`runtime`] — PJRT loader executing the AOT JAX/Pallas artifacts.
//! * [`coordinator`] — the end-to-end flow of Fig 1 with phase timing (Fig 3).

pub mod analysis;
pub mod benchkit;
pub mod campaign;
pub mod cli;
pub mod compiler;
pub mod config;
pub mod coordinator;
pub mod detailed;
pub mod dse;
pub mod energy;
pub mod graph;
pub mod hw;
pub mod json;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod taskgraph;
pub mod testkit;
pub mod trace;
pub mod util;
