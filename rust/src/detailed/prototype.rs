//! Prototype timing model: the detailed fidelity level (DESIGN.md §2/§6).

use super::dram::DramModel;
use crate::config::SystemConfig;
use crate::hw::TimingModel;
use crate::sim::{ClockDomain, SimTime};
use crate::taskgraph::{BufferKind, TaskKind};

/// Per-transfer bus protocol overhead (arbitration + handshake + response),
/// in bus cycles. Paid once per DMA data phase — AXI-style bursts amortize
/// the handshake across the whole transfer.
const BUS_PROTO_CYCLES: u64 = 6;

#[derive(Debug, Clone)]
pub struct PrototypeTiming {
    nce_clk: ClockDomain,
    bus_clk: ClockDomain,
    hkp_clk: ClockDomain,
    bus_bytes_per_cycle: u64,
    dma_setup_cycles: u64,
    dispatch_cycles: u64,
    pipeline_depth: u64,
    dram: DramModel,
    /// Linear address cursors per tensor region (synthetic address streams:
    /// IFM, weight and OFM tensors live in distinct DRAM regions).
    ifm_cursor: u64,
    w_cursor: u64,
    ofm_cursor: u64,
}

/// Region bases: 1 GiB apart so streams never alias.
const IFM_BASE: u64 = 0;
const W_BASE: u64 = 1 << 30;
const OFM_BASE: u64 = 2 << 30;

impl PrototypeTiming {
    pub fn new(sys: &SystemConfig) -> Self {
        Self {
            nce_clk: ClockDomain::from_mhz(sys.nce.freq_mhz),
            bus_clk: ClockDomain::from_mhz(sys.bus.freq_mhz),
            hkp_clk: ClockDomain::from_mhz(sys.hkp.freq_mhz),
            bus_bytes_per_cycle: sys.bus.bytes_per_cycle,
            dma_setup_cycles: sys.dma.setup_cycles,
            dispatch_cycles: sys.hkp.dispatch_cycles,
            pipeline_depth: sys.nce.pipeline_depth as u64,
            dram: DramModel::new(&sys.memory),
            ifm_cursor: IFM_BASE,
            w_cursor: W_BASE,
            ofm_cursor: OFM_BASE,
        }
    }

    /// DRAM hit-rate observed so far (test/metrics introspection).
    pub fn dram_hit_rate(&self) -> f64 {
        self.dram.hit_rate()
    }
}

impl TimingModel for PrototypeTiming {
    fn dma_pre_ps(&mut self, _kind: &TaskKind) -> SimTime {
        // Descriptor setup only — the *actual* memory latency is paid per
        // burst in the data phase (that is precisely the detail the AVSM
        // abstracts into one flat number).
        self.bus_clk.cycles_to_ps(self.dma_setup_cycles)
    }

    fn dma_bus_ps(&mut self, kind: &TaskKind, bytes: u64, start: SimTime) -> SimTime {
        let bytes = bytes.max(1);
        let cursor = match kind {
            TaskKind::DmaLoad { buffer: BufferKind::Weights, .. } => &mut self.w_cursor,
            TaskKind::DmaLoad { .. } => &mut self.ifm_cursor,
            _ => &mut self.ofm_cursor,
        };
        let addr = *cursor;
        *cursor += bytes;
        // DRAM service time (pipelined commands + data at the memory
        // interface)...
        let dram_ps = self.dram.transfer_ps(addr, bytes, start);
        // ...plus bus-side protocol overhead, once per transfer.
        let proto_ps = self.bus_clk.cycles_to_ps(BUS_PROTO_CYCLES);
        // The interconnect data movement itself cannot beat the bus width:
        // the slower of DRAM and bus paces the transfer.
        let bus_cycles = crate::util::div_ceil64(bytes, self.bus_bytes_per_cycle);
        let bus_ps = self.bus_clk.cycles_to_ps(bus_cycles);
        proto_ps + dram_ps.max(bus_ps)
    }

    fn compute_ps(&mut self, kind: &TaskKind) -> SimTime {
        match *kind {
            TaskKind::Compute { cycles, macs } => {
                // Pipeline fill/drain per tile plus a weight-preload stall.
                // Compute tasks carrying zero MACs are vector ops (no MAC
                // pipeline): charged as-is.
                let extra = if macs > 0 { 2 * self.pipeline_depth + 4 } else { 0 };
                self.nce_clk.cycles_to_ps(cycles + extra)
            }
            _ => 0,
        }
    }

    fn dispatch_ps(&self) -> SimTime {
        // The real HKP firmware takes a little longer per descriptor than
        // the AVSM's annotation assumes (interrupt handling, bookkeeping).
        self.hkp_clk.cycles_to_ps(self.dispatch_cycles + 2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::models;
    use crate::hw::{AvsmTiming, Executor};
    use crate::sim::TraceRecorder;

    fn sys() -> SystemConfig {
        SystemConfig::base_paper()
    }

    #[test]
    fn prototype_runs_dilated_vgg_tiny() {
        let s = sys();
        let c = compile(&models::dilated_vgg_tiny(), &s, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let r = Executor::new(&s, PrototypeTiming::new(&s)).run(&c, &mut tr);
        assert!(r.total_ps > 0);
    }

    #[test]
    fn deviation_from_avsm_is_single_digit_percent() {
        // The headline property (Fig 5): the AVSM predicts the prototype
        // within ~10 % end-to-end.
        let s = sys();
        let c = compile(&models::dilated_vgg_tiny(), &s, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let avsm = Executor::new(&s, AvsmTiming::new(&s)).run(&c, &mut tr);
        let mut tr = TraceRecorder::disabled();
        let proto = Executor::new(&s, PrototypeTiming::new(&s)).run(&c, &mut tr);
        let dev = (avsm.total_ps as f64 - proto.total_ps as f64).abs()
            / proto.total_ps as f64;
        assert!(
            dev < 0.15,
            "AVSM vs prototype deviation {:.1}% out of expected band (avsm {} proto {})",
            dev * 100.0,
            avsm.total_ps,
            proto.total_ps
        );
    }

    #[test]
    fn dram_sees_high_hit_rate_on_dnn_traffic() {
        let s = sys();
        let c = compile(&models::dilated_vgg_tiny(), &s, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let mut timing = PrototypeTiming::new(&s);
        // Run manually to keep access to the model afterwards.
        let mut probe = PrototypeTiming::new(&s);
        for t in c.graph.tasks() {
            if t.kind.is_dma() {
                probe.dma_bus_ps(&t.kind, t.kind.bytes(), 0);
            }
        }
        assert!(probe.dram_hit_rate() > 0.8, "hit rate {}", probe.dram_hit_rate());
        // And the full executor path still works with the same timing.
        let r = Executor::new(&s, std::mem::replace(&mut timing, PrototypeTiming::new(&s)))
            .run(&c, &mut tr);
        assert!(r.total_ps > 0);
    }

    #[test]
    fn pipeline_overhead_only_on_mac_tasks() {
        let s = sys();
        let mut t = PrototypeTiming::new(&s);
        let mac = TaskKind::Compute { cycles: 100, macs: 5 };
        let vec = TaskKind::Compute { cycles: 100, macs: 0 };
        assert!(t.compute_ps(&mac) > t.compute_ps(&vec));
    }

    #[test]
    fn detailed_deterministic() {
        let s = sys();
        let c = compile(&models::lenet(28), &s, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::disabled();
        let a = Executor::new(&s, PrototypeTiming::new(&s)).run(&c, &mut tr);
        let mut tr = TraceRecorder::disabled();
        let b = Executor::new(&s, PrototypeTiming::new(&s)).run(&c, &mut tr);
        assert_eq!(a.total_ps, b.total_ps);
    }
}
