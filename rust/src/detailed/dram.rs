//! DDR-style DRAM timing model with banks, row buffers and refresh.
//!
//! Operates on a synthetic address stream: each on-chip buffer kind (IFM /
//! weights / OFM) walks its own linear address region, because the DNN
//! tensors live in distinct DRAM regions and DMA reads them sequentially.
//! That reproduces the qualitative pattern of tiled CNN traffic: long
//! sequential runs (row hits) punctuated by row-boundary misses, with loads
//! and stores interleaving on different banks.

use crate::config::MemoryConfig;
use crate::sim::{ClockDomain, SimTime};

/// Per-bank open-row state + refresh bookkeeping.
#[derive(Debug, Clone)]
pub struct DramModel {
    clk: ClockDomain,
    banks: u64,
    row_bytes: u64,
    t_rcd: u64,
    t_rp: u64,
    t_cl: u64,
    burst_bytes: u64,
    /// Data beat cycles per burst at the memory interface.
    burst_data_cycles: u64,
    t_refi_ps: SimTime,
    t_rfc: u64,
    open_row: Vec<Option<u64>>,
    /// Absolute time of the next refresh window.
    next_refresh: SimTime,
    // Counters for model introspection/tests.
    pub hits: u64,
    pub misses: u64,
    pub refreshes: u64,
}

impl DramModel {
    pub fn new(mem: &MemoryConfig) -> Self {
        let clk = ClockDomain::from_mhz(mem.freq_mhz);
        Self {
            clk,
            banks: mem.banks as u64,
            row_bytes: mem.row_bytes,
            t_rcd: mem.t_rcd,
            t_rp: mem.t_rp,
            t_cl: mem.t_cl,
            burst_bytes: mem.burst_bytes,
            burst_data_cycles: (mem.burst_bytes + mem.data_bytes_per_cycle - 1)
                / mem.data_bytes_per_cycle,
            t_refi_ps: mem.t_refi_ns * 1000,
            t_rfc: mem.t_rfc,
            open_row: vec![None; mem.banks as usize],
            next_refresh: mem.t_refi_ns * 1000,
            hits: 0,
            misses: 0,
            refreshes: 0,
        }
    }

    /// Bank and row of an address (row-interleaved mapping: consecutive
    /// rows rotate across banks, so a sequential stream engages all banks).
    fn decode(&self, addr: u64) -> (usize, u64) {
        let row_index = addr / self.row_bytes;
        ((row_index % self.banks) as usize, row_index / self.banks)
    }

    /// Time to service one *isolated* burst starting at absolute time `now`
    /// (full command latency exposed — used for random single accesses and
    /// by tests).
    pub fn burst_ps(&mut self, addr: u64, now: SimTime) -> SimTime {
        let mut cycles = self.refresh_cycles(now);
        cycles += self.command_cycles(addr) + self.t_cl + self.burst_data_cycles;
        self.clk.cycles_to_ps(cycles)
    }

    /// Refresh stall cycles if `now` crossed a refresh deadline.
    fn refresh_cycles(&mut self, now: SimTime) -> u64 {
        if now < self.next_refresh {
            return 0;
        }
        while self.next_refresh <= now {
            self.next_refresh += self.t_refi_ps;
        }
        self.refreshes += 1;
        // Refresh closes all rows.
        self.open_row.iter_mut().for_each(|r| *r = None);
        self.t_rfc
    }

    /// Row-state transition cost of accessing `addr`, *excluding* CAS and
    /// data (hit: 0, miss: precharge? + activate).
    fn command_cycles(&mut self, addr: u64) -> u64 {
        let (bank, row) = self.decode(addr);
        if self.open_row[bank] == Some(row) {
            self.hits += 1;
            0
        } else {
            self.misses += 1;
            let c = if self.open_row[bank].is_some() { self.t_rp } else { 0 } + self.t_rcd;
            self.open_row[bank] = Some(row);
            c
        }
    }

    /// Service a sequential transfer of `bytes` starting at `addr`.
    ///
    /// Models a pipelined controller: the CAS latency is paid once up
    /// front; thereafter row hits stream back-to-back at the data rate and
    /// only row misses insert precharge/activate bubbles (plus refresh
    /// stalls) — the behaviour of real burst-mode DDR on sequential DNN
    /// tensor traffic.
    pub fn transfer_ps(&mut self, addr: u64, bytes: u64, start: SimTime) -> SimTime {
        if bytes == 0 {
            return 0;
        }
        let mut cycles = self.t_cl; // initial CAS, then pipelined
        let mut a = addr;
        let mut remaining = bytes;
        while remaining > 0 {
            cycles += self.refresh_cycles(start + self.clk.cycles_to_ps(cycles));
            cycles += self.command_cycles(a); // 0 on hits
            cycles += self.burst_data_cycles;
            let step = self.burst_bytes.min(remaining);
            a += step;
            remaining -= step;
        }
        self.clk.cycles_to_ps(cycles)
    }

    pub fn hit_rate(&self) -> f64 {
        self.hits as f64 / (self.hits + self.misses).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn dram() -> DramModel {
        let sys = SystemConfig::base_paper();
        DramModel::new(&sys.memory)
    }

    #[test]
    fn sequential_stream_is_mostly_hits() {
        let mut d = dram();
        // 64 KiB sequential: 1024 bursts over 32 rows -> 32 row misses
        // (plus possibly a few refresh-induced re-activates).
        let _ = d.transfer_ps(0, 64 * 1024, 0);
        assert_eq!(d.hits + d.misses, 1024);
        assert!(d.misses >= 32 && d.misses <= 32 + d.refreshes + 1, "misses {}", d.misses);
        assert!(d.hit_rate() > 0.95);
    }

    #[test]
    fn row_miss_costs_more_than_hit() {
        let mut d = dram();
        let miss = d.burst_ps(0, 0); // first access: activate + CAS
        let hit = d.burst_ps(64, 0); // same row
        assert!(miss > hit);
        let far = d.burst_ps(d.row_bytes * d.banks * 7, 0); // same bank, other row
        assert!(far >= miss); // precharge + activate + CAS
    }

    #[test]
    fn banks_hold_independent_rows() {
        let mut d = dram();
        let _ = d.burst_ps(0, 0); // bank 0 row 0
        let _ = d.burst_ps(d.row_bytes, 0); // bank 1 row 0
        // Returning to bank 0 row 0 is still a hit.
        let t = d.burst_ps(64, 0);
        assert_eq!(d.misses, 2);
        assert_eq!(d.hits, 1);
        let hit_cycles = d.t_cl + d.burst_data_cycles;
        assert_eq!(t, d.clk.cycles_to_ps(hit_cycles));
    }

    #[test]
    fn refresh_steals_time() {
        let mut d = dram();
        let before = d.burst_ps(0, 0);
        // Jump past the refresh interval.
        let after = d.burst_ps(64, d.t_refi_ps + 1);
        assert_eq!(d.refreshes, 1);
        // The refreshed access pays t_rfc plus a re-activate (refresh
        // closed the row).
        assert!(after > before);
    }

    #[test]
    fn transfer_time_scales_with_bytes() {
        let mut d = dram();
        let small = d.transfer_ps(0, 1024, 0);
        let mut d2 = dram();
        let large = d2.transfer_ps(0, 64 * 1024, 0);
        assert!(large > 10 * small);
    }

    #[test]
    fn pipelined_stream_beats_isolated_bursts() {
        // The streamed transfer must be much faster than summing isolated
        // bursts (CAS amortized away).
        let mut a = dram();
        let streamed = a.transfer_ps(0, 16 * 1024, 0);
        let mut b = dram();
        let mut isolated = 0;
        for i in 0..(16 * 1024 / 64) {
            isolated += b.burst_ps(i * 64, 0);
        }
        assert!(streamed * 3 < isolated * 2, "streamed {streamed} vs isolated {isolated}");
    }

    #[test]
    fn effective_bandwidth_near_interface_rate() {
        // Sequential read: >70% of the raw interface bandwidth.
        let mut d = dram();
        let bytes = 1 << 20;
        let ps = d.transfer_ps(0, bytes, 0);
        let gbs = bytes as f64 / (ps as f64 / 1e12) / 1e9;
        let peak = 8.0 * 533e6 / 1e9; // 4.26 GB/s
        assert!(gbs > 0.7 * peak, "effective {gbs:.2} GB/s of peak {peak} GB/s");
    }
}
