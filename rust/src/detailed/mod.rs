//! The cycle-level "physical prototype" reference model.
//!
//! Stands in for the paper's Virtex7 FPGA measurement (DESIGN.md §2): the
//! experiment needs a ground truth that differs from the AVSM exactly where
//! the paper says real hardware differs — the memory subsystem and low-level
//! engine behaviour. This timing model adds:
//!
//! * **DRAM bank/row state**: transfers are split into bursts; each burst
//!   pays CAS latency on a row hit or precharge+activate+CAS on a row miss,
//!   with a synthetic-but-faithful address stream per buffer kind
//!   (sequential within a tensor, so mostly hits with periodic row-crossing
//!   misses — the access pattern tiled DNN traffic actually has).
//! * **Refresh**: every `t_refi_ns` the DRAM steals `t_rfc` memory cycles.
//! * **Bus protocol overhead**: a per-burst arbitration/handshake charge.
//! * **NCE pipeline**: fill/drain of the MAC pipeline per array pass and a
//!   weight-preload stall per tile.
//!
//! Everything else (task graph, dependencies, queueing, arbitration) is the
//! shared executor — so AVSM-vs-prototype deviation (Fig 5) is purely the
//! abstraction gap.

pub mod dram;
pub mod prototype;

pub use dram::DramModel;
pub use prototype::PrototypeTiming;

use crate::compiler::CompiledNet;
use crate::config::SystemConfig;
use crate::hw::{Executor, SimResult};
use crate::sim::TraceRecorder;

/// Convenience: simulate a compiled net on the detailed prototype timing.
pub fn simulate_prototype(
    compiled: &CompiledNet,
    sys: &SystemConfig,
    trace: &mut TraceRecorder,
) -> SimResult {
    let timing = PrototypeTiming::new(sys);
    Executor::new(sys, timing).run(compiled, trace)
}
