//! Disk-persistent compile cache (schema `avsm-compile-cache-v1`).
//!
//! `compiler::CompileCache` memoizes compilations within one process; this
//! module adds the disk tier that carries them **across CLI invocations**
//! (ROADMAP "cache persistence"): each compiled artifact is serialized —
//! the task graph via [`crate::taskgraph::serialize`], the per-layer
//! records alongside — into one JSON document keyed by
//! [`CompileKey::fingerprint`] (which already covers the net's content
//! fingerprint plus every structural config field). A warm directory makes
//! a repeated campaign or sweep **compile-free**: every structural key is
//! deserialized instead of re-tiled and re-lowered.
//!
//! Safety properties:
//!
//! * Every entry embeds its full [`CompileKey::to_json`]; a load verifies
//!   it field by field against the expected key, so stale entries, hash
//!   collisions and schema drift read as misses, never as wrong artifacts.
//! * Corrupted or truncated files fail JSON parsing or task-graph
//!   validation and fall back to recompilation (counted in
//!   [`PersistentCache::rejected`]); the fresh compile then overwrites the
//!   bad entry.
//! * Writes go through a per-process temp file + rename, so concurrent
//!   processes sharing a cache directory never observe half-written
//!   entries. Within one process the in-memory tier's in-flight marker
//!   already guarantees one writer per key.
//!
//! Infeasible structural points are persisted too, as **negative entries**
//! (sidecar schema `avsm-compile-cache-neg-v1`): a record of the full
//! [`CompileKey::to_json`] plus the tiler's diagnostic, written when a
//! compile fails *past validation* (so only genuine structural
//! infeasibility is ever recorded — never an I/O error or an invalid
//! config). A warm campaign thereby skips re-tiling the infeasible corners
//! of a large grid entirely: zero tiling attempts on persisted-infeasible
//! keys, with the original diagnostic replayed. Negative entries verify
//! their key on load exactly like artifacts; corrupted ones are rejected,
//! re-tiled and rewritten. A positive artifact always shadows a negative
//! record for the same key (lookup order: artifact → negative → compile).
//!
//! # Size bound (LRU eviction)
//!
//! An unbounded shared cache directory grows forever. Constructing the
//! cache with [`PersistentCache::with_max_entries`] bounds the number of
//! structural keys it retains on disk: a small **index sidecar**
//! (`index.json`, schema `avsm-compile-cache-index-v1`) records a logical
//! last-used clock per fingerprint; every disk hit or write *touches* the
//! key, and when the index exceeds the bound the least-recently-used keys
//! are evicted — the artifact file **and** its negative sidecar are both
//! removed, so an evicted key leaves no trace. Eviction is purely a cache
//! policy: an evicted key reads as a miss and recompiles. Keys present on
//! disk but missing from the index (an unbounded cache's leftovers, or a
//! lost index) are adopted into the index the first time they are touched.
//! The index is advisory and crash-tolerant — corrupted or missing, it is
//! restarted empty, never trusted into returning wrong artifacts (entry
//! loads still verify their embedded keys as always). Writes go through
//! the same temp-file + rename protocol as entries.
//!
//! # Cross-process coordination
//!
//! The index read-modify-write (touch → evict → persist) is serialized
//! across *processes* by a pure-std advisory lock: a `index.lock` file
//! created with `create_new` (atomic on every platform) holding the
//! owner's PID. Concurrent campaigns sharing one cache directory
//! therefore lose neither touches nor evictions — each touch reloads the
//! on-disk index under the lock, so another process's updates are merged,
//! not overwritten. Liveness over strictness, in line with the advisory
//! index: a lock whose recorded holder is provably dead (the PID no
//! longer exists) is **stolen** after a liveness check (counted in
//! [`PersistentCache::lock_steals`]), and an acquisition that times out
//! (~500 ms) degrades to the old unlocked last-writer-wins behaviour
//! rather than deadlocking — the bound may momentarily overshoot, the
//! cache is never corrupted. Unbounded caches write no index and take no
//! lock.
//!
//! Every disk touch of this module runs through the named failpoints of
//! [`crate::testkit::faults`] (`store.read`, `store.write`), which the
//! fault-injection suite arms to prove the degradation story above.

use crate::compiler::tiling::VectorTiling;
use crate::compiler::{
    compile, CompileCache, CompileKey, CompileOptions, CompiledLayer, CompiledNet, LayerTiling,
    TilingChoice,
};
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::json::{self, obj, stream, Value};
use crate::taskgraph::serialize;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const SCHEMA: &str = "avsm-compile-cache-v1";
const NEG_SCHEMA: &str = "avsm-compile-cache-neg-v1";
const INDEX_SCHEMA: &str = "avsm-compile-cache-index-v1";

/// File that stores the artifact for `key` under `dir`.
pub fn entry_path(dir: &Path, key: &CompileKey) -> PathBuf {
    entry_path_fp(dir, key.fingerprint())
}

/// Sidecar file recording that `key` is structurally infeasible.
pub fn negative_path(dir: &Path, key: &CompileKey) -> PathBuf {
    negative_path_fp(dir, key.fingerprint())
}

/// LRU index sidecar (only written by size-bounded caches).
pub fn index_path(dir: &Path) -> PathBuf {
    dir.join("index.json")
}

/// Advisory cross-process lock file guarding the index read-modify-write
/// (only taken by size-bounded caches).
pub fn lock_path(dir: &Path) -> PathBuf {
    dir.join("index.lock")
}

fn entry_path_fp(dir: &Path, fp: u64) -> PathBuf {
    dir.join(format!("{fp:016x}.compiled.json"))
}

fn negative_path_fp(dir: &Path, fp: u64) -> PathBuf {
    dir.join(format!("{fp:016x}.infeasible.json"))
}

/// Serialize one compiled artifact (plus its full key, for verification on
/// load) into a single compact JSON document.
pub fn entry_to_json(key: &CompileKey, compiled: &CompiledNet) -> String {
    obj(vec![
        ("schema", SCHEMA.into()),
        ("key", key.to_json()),
        (
            "layers",
            Value::Array(compiled.layers.iter().map(layer_to_value).collect()),
        ),
        // The task graph rides along as an embedded `avsm-task-graph-v1`
        // document (string form), reusing the flow-boundary serializer.
        ("task_graph", serialize::to_json(&compiled.graph).into()),
    ])
    .to_string_compact()
}

fn layer_to_value(l: &CompiledLayer) -> Value {
    let tiling = match l.tiling {
        LayerTiling::Conv(t) => obj(vec![
            ("kind", "conv".into()),
            ("cin_t", t.cin_t.into()),
            ("cout_t", t.cout_t.into()),
            ("oh_t", t.oh_t.into()),
            ("n_cin", t.n_cin.into()),
            ("n_cout", t.n_cout.into()),
            ("n_oh", t.n_oh.into()),
            ("ifm_resident", t.ifm_resident.into()),
        ]),
        LayerTiling::Vector(v) => obj(vec![
            ("kind", "vector".into()),
            ("oh_t", v.oh_t.into()),
            ("n_oh", v.n_oh.into()),
        ]),
    };
    obj(vec![
        ("index", l.index.into()),
        ("name", l.name.as_str().into()),
        ("tiling", tiling),
        ("compute_cycles", l.compute_cycles.into()),
        ("dma_bytes", l.dma_bytes.into()),
        ("macs", l.macs.into()),
        ("barrier", l.barrier.into()),
    ])
}

fn layer_from_value(lv: &Value) -> Result<CompiledLayer> {
    let tv = lv.get("tiling");
    // All narrowing is checked (`req_u32`): a corrupted entry carrying an
    // oversized value must read as rejection, never wrap into a plausible
    // tiling — the module's "corrupted entries never load as wrong
    // artifacts" guarantee.
    let tiling = match tv.get("kind").as_str().unwrap_or_default() {
        "conv" => LayerTiling::Conv(TilingChoice {
            cin_t: tv.req_u32("cin_t")?,
            cout_t: tv.req_u32("cout_t")?,
            oh_t: tv.req_u32("oh_t")?,
            n_cin: tv.req_u32("n_cin")?,
            n_cout: tv.req_u32("n_cout")?,
            n_oh: tv.req_u32("n_oh")?,
            ifm_resident: tv
                .get("ifm_resident")
                .as_bool()
                .context("missing/invalid ifm_resident")?,
        }),
        "vector" => LayerTiling::Vector(VectorTiling {
            oh_t: tv.req_u32("oh_t")?,
            n_oh: tv.req_u32("n_oh")?,
        }),
        other => bail!("unknown tiling kind {other:?}"),
    };
    Ok(CompiledLayer {
        index: lv.req_u32("index")?,
        name: lv.req_str("name")?.to_string(),
        tiling,
        compute_cycles: lv.req_u64("compute_cycles")?,
        dma_bytes: lv.req_u64("dma_bytes")?,
        macs: lv.req_u64("macs")?,
        barrier: lv.req_u32("barrier")?,
    })
}

/// Serialize one negative (infeasible-key) record.
pub fn negative_to_json(key: &CompileKey, diagnostic: &str) -> String {
    obj(vec![
        ("schema", NEG_SCHEMA.into()),
        ("key", key.to_json()),
        ("diagnostic", diagnostic.into()),
    ])
    .to_string_compact()
}

/// Parse and verify one negative record, returning the stored diagnostic.
/// Key verification is identical to artifact entries: any mismatch reads
/// as a miss, so a stale or colliding record can never mark a *feasible*
/// key infeasible.
pub fn negative_from_json(text: &str, expect_key: &CompileKey) -> Result<String> {
    verify_embedded_key(
        text,
        expect_key,
        NEG_SCHEMA,
        "negative cache entry parse",
        "unsupported negative cache schema",
        "negative entry key mismatch (stale entry or fingerprint collision)",
    )?;
    // Fully lazy: the diagnostic is the only payload, so no tree is ever
    // built for a negative hit — scan, extract, done.
    let diag = match stream::path_str(text.as_bytes(), &["diagnostic"])
        .context("negative cache entry parse")?
    {
        Some(d) => d.into_owned(),
        None => bail!("missing/invalid string field \"diagnostic\""),
    };
    // Strict end-of-document check: `path_str` never looks past its target
    // field, so on its own the lazy load would accept a negative record
    // with trailing garbage — exactly the corpse a torn concatenated write
    // leaves — that the tree parser rejects. One skip-scan re-validates the
    // whole document, applying the same trailing-garbage classification
    // the cache-index path uses, so lazy and tree parses agree on every
    // corrupt negative (differential-tested).
    let mut r = stream::Reader::new(text.as_bytes());
    r.skip_value().context("negative cache entry parse")?;
    r.next().context("negative cache entry parse")?;
    Ok(diag)
}

/// Lazy pre-flight shared by artifact and negative loads: verify the
/// `schema` and embedded `key` fields straight off the raw bytes, without
/// materializing a `Value` tree. Both files are written by this module in
/// canonical compact form, so the expected key's serialization can be
/// compared byte-for-byte against the raw field slice; only when the raw
/// bytes differ (a hand-edited or re-formatted entry) does verification
/// fall back to the structural tree compare, preserving the exact
/// accept/reject semantics of the original full-parse path.
fn verify_embedded_key(
    text: &str,
    expect_key: &CompileKey,
    schema: &str,
    parse_ctx: &'static str,
    schema_err: &'static str,
    mismatch_err: &'static str,
) -> Result<()> {
    let bytes = text.as_bytes();
    match stream::path_str(bytes, &["schema"]).context(parse_ctx)? {
        Some(s) if s == schema => {}
        _ => bail!("{schema_err}"),
    }
    let want = expect_key.to_json().to_string_compact();
    match stream::path_raw(bytes, &["key"]).context(parse_ctx)? {
        Some(raw) if raw == want.as_bytes() => Ok(()),
        Some(_) => {
            // Non-canonical bytes: semantically-equal keys must still
            // verify, so decide on the parsed tree.
            let v = json::parse(text).context(parse_ctx)?;
            if v.get("key") != &expect_key.to_json() {
                bail!("{mismatch_err}");
            }
            Ok(())
        }
        None => bail!("{mismatch_err}"),
    }
}

/// Parse and verify one cache entry. `expect_key` is the key the caller is
/// looking up; any mismatch with the stored key is an error (stale entry
/// or fingerprint collision).
pub fn entry_from_json(text: &str, expect_key: &CompileKey) -> Result<CompiledNet> {
    // Cheap lazy precheck first: a stale entry, schema drift, or a
    // fingerprint collision is rejected from the raw bytes before the
    // (much larger) layers/task-graph payload is decoded.
    verify_embedded_key(
        text,
        expect_key,
        SCHEMA,
        "compile cache entry parse",
        "unsupported compile cache schema",
        "cache entry key mismatch (stale entry or fingerprint collision)",
    )?;
    let v = json::parse(text).context("compile cache entry parse")?;
    let graph = serialize::from_json(v.req_str("task_graph")?)
        .context("embedded task graph")?;
    let mut layers = Vec::new();
    for lv in v.req_array("layers")? {
        layers.push(layer_from_value(lv)?);
    }
    if layers.is_empty() {
        bail!("cache entry has no layers");
    }
    for l in &layers {
        if l.barrier as usize >= graph.len() {
            bail!("layer {:?} barrier id out of range", l.name);
        }
    }
    Ok(CompiledNet { graph, layers })
}

/// Write an entry atomically (temp file + rename). The temp name is
/// unique per process *and* per write (atomic counter): the per-key
/// in-flight marker only dedups writers within one `CompileCache`
/// instance, so two caches sharing a directory in one process must not
/// collide on the temp inode either.
pub fn write_entry(dir: &Path, key: &CompileKey, compiled: &CompiledNet) -> Result<()> {
    write_atomic(dir, key.fingerprint(), &entry_path(dir, key), entry_to_json(key, compiled))
}

/// Persist a negative record atomically (same temp-file + rename protocol
/// as [`write_entry`]).
pub fn write_negative(dir: &Path, key: &CompileKey, diagnostic: &str) -> Result<()> {
    write_atomic(
        dir,
        key.fingerprint(),
        &negative_path(dir, key),
        negative_to_json(key, diagnostic),
    )
}

fn write_atomic(dir: &Path, tag: u64, path: &Path, content: String) -> Result<()> {
    let mut span = crate::obs::span("cache.write");
    let result = write_atomic_inner(dir, tag, path, content);
    if result.is_err() {
        span.set_outcome("error");
    }
    result
}

fn write_atomic_inner(dir: &Path, tag: u64, path: &Path, content: String) -> Result<()> {
    match crate::testkit::faults::before_write("store.write", path, content.len()) {
        Ok(None) => {}
        Ok(Some(n)) => {
            // Injected torn write: bypass the temp-file protocol and leave
            // a half-written file at the *final* path, claiming success —
            // the crash the rename protocol exists to prevent. Readers
            // must reject the corpse and heal it.
            std::fs::write(path, &content[..n.min(content.len())])
                .with_context(|| format!("writing cache entry {path:?}"))?;
            return Ok(());
        }
        Err(e) => return Err(e).with_context(|| format!("writing cache entry {path:?}")),
    }
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let tmp = dir.join(format!(
        "{tag:016x}.tmp.{}.{}",
        std::process::id(),
        WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, content)
        .with_context(|| format!("writing cache entry {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing cache entry {path:?}"))?;
    Ok(())
}

/// Held advisory lock on a cache directory's index (see the module docs'
/// "Cross-process coordination"). RAII: dropping releases by unlinking
/// the lock file.
struct CacheLock {
    path: PathBuf,
}

impl Drop for CacheLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Can this platform *prove* a lock holder dead? Only `/proc` platforms
/// can; everywhere else liveness is unknowable cheaply, so stealing is
/// disabled outright (see [`CacheLock::acquire_gated`]) — a live holder
/// and a dead one are indistinguishable there, and stealing a live lock
/// is strictly worse than waiting out the timeout degrade.
pub(crate) const CAN_PROBE_LIVENESS: bool = cfg!(target_os = "linux");

/// Is `pid` a live process? Only meaningful when [`CAN_PROBE_LIVENESS`];
/// elsewhere the answer is a conservative "assume live" and callers must
/// not base a steal on it.
pub(crate) fn pid_alive(pid: u32) -> bool {
    if CAN_PROBE_LIVENESS {
        Path::new(&format!("/proc/{pid}")).exists()
    } else {
        true
    }
}

impl CacheLock {
    /// Try to take the lock: `create_new` (atomic everywhere) plus our PID
    /// as the payload. A holder that is provably dead is stolen (counted
    /// in `steals`); ~500 ms without progress returns `None`, degrading
    /// the caller to unlocked last-writer-wins — an availability choice:
    /// the index is advisory, a deadlocked campaign is not.
    fn acquire(dir: &Path, steals: &AtomicU64) -> Option<CacheLock> {
        Self::acquire_gated(dir, steals, CAN_PROBE_LIVENESS)
    }

    /// [`CacheLock::acquire`] with the steal gate explicit. `allow_steal`
    /// is [`CAN_PROBE_LIVENESS`] in production: where `/proc` does not
    /// exist, *every* holder "looks dead" to a naive probe, so stealing
    /// would break live locks immediately instead of honoring the ~500 ms
    /// degrade. With stealing off, both steal triggers — dead-PID and
    /// persistently unreadable payload — are disabled and an occupied lock
    /// simply times out to last-writer-wins. Parameterized (rather than
    /// `cfg`-duplicated) so the conservative path is unit-testable on any
    /// platform.
    fn acquire_gated(dir: &Path, steals: &AtomicU64, allow_steal: bool) -> Option<CacheLock> {
        let path = lock_path(dir);
        // The whole acquisition (polls, sleeps, steals included) is one
        // `lock.wait` span — its duration is exactly the time this worker
        // spent not compiling because of index contention.
        let mut span = crate::obs::span("lock.wait");
        span.set_outcome("timeout");
        // Unparseable lock payloads are almost always debris from a holder
        // killed between `create_new` and its PID write; give a genuinely
        // racing creator a few polls to finish writing before stealing.
        let mut unreadable_polls = 0u32;
        for _ in 0..50 {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    use std::io::Write;
                    let _ = write!(f, "{}", std::process::id());
                    span.set_outcome("acquired");
                    return Some(CacheLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let holder: Option<u32> = std::fs::read_to_string(&path)
                        .ok()
                        .and_then(|s| s.trim().parse().ok());
                    let stale = allow_steal
                        && match holder {
                            Some(pid) => !pid_alive(pid),
                            None => {
                                unreadable_polls += 1;
                                unreadable_polls > 10
                            }
                        };
                    if stale {
                        // Steal: unlink and retry the atomic create. Two
                        // stealers may race on the unlink; only one wins
                        // the subsequent create_new, so the lock stays
                        // single-holder.
                        let _ = std::fs::remove_file(&path);
                        steals.fetch_add(1, Ordering::Relaxed);
                        crate::obs::instant("lock.steal");
                        continue;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => {
                    span.set_outcome("error");
                    return None;
                }
            }
        }
        None
    }
}

/// In-memory image of the LRU index sidecar: fingerprint → logical
/// last-used stamp, plus the clock the stamps are drawn from. Public so
/// external tooling (and the golden-file schema tests) can inspect and
/// round-trip `index.json` files; the bookkeeping fields stay private.
#[derive(Debug, Default)]
pub struct CacheIndex {
    clock: u64,
    entries: std::collections::BTreeMap<u64, u64>,
}

impl CacheIndex {
    /// Load the index from `dir`. Missing or corrupted files restart the
    /// index empty — it is advisory metadata; artifact loads verify their
    /// own embedded keys regardless.
    fn load(dir: &Path) -> CacheIndex {
        let Ok(text) = std::fs::read_to_string(index_path(dir)) else {
            return CacheIndex::default();
        };
        CacheIndex::from_json(&text).unwrap_or_default()
    }

    /// Parse an `avsm-compile-cache-index-v1` document.
    ///
    /// Pull-parsed straight into the fingerprint map — the touch path runs
    /// this once per disk hit under the index lock, so no `Value` tree is
    /// ever materialized for an index read. Field order on disk is
    /// irrelevant (keys are matched by name); unknown fields are skipped.
    pub fn from_json(text: &str) -> Result<CacheIndex> {
        use stream::Event;
        let mut r = stream::Reader::new(text.as_bytes());
        let mut clock: Option<u64> = None;
        let mut entries: Option<std::collections::BTreeMap<u64, u64>> = None;
        let mut schema_ok = false;
        match r.next().context("cache index parse")? {
            Some(Event::ObjBegin) => {}
            _ => bail!("unsupported cache index schema"),
        }
        loop {
            match r.next().context("cache index parse")? {
                Some(Event::Key(k)) => match k.as_ref() {
                    "schema" => match r.take_value().context("cache index parse")? {
                        Event::Str(s) if s == INDEX_SCHEMA => schema_ok = true,
                        _ => bail!("unsupported cache index schema"),
                    },
                    "clock" => {
                        clock = r.take_value().context("cache index parse")?.as_u64();
                    }
                    "entries" => {
                        match r.next().context("cache index parse")? {
                            Some(Event::ObjBegin) => {}
                            _ => bail!("missing entries object"),
                        }
                        let mut map = std::collections::BTreeMap::new();
                        loop {
                            match r.next().context("cache index parse")? {
                                Some(Event::Key(fp_hex)) => {
                                    let fp = u64::from_str_radix(&fp_hex, 16).with_context(
                                        || format!("bad fingerprint {:?}", fp_hex.as_ref()),
                                    )?;
                                    let stamp = r
                                        .take_value()
                                        .context("cache index parse")?
                                        .as_u64()
                                        .context("bad stamp")?;
                                    map.insert(fp, stamp);
                                }
                                _ => break, // ObjEnd: entries complete
                            }
                        }
                        entries = Some(map);
                    }
                    _ => r.skip_value().context("cache index parse")?,
                },
                _ => break, // ObjEnd: document complete
            }
        }
        // Trailing-garbage check, same classification as a full parse.
        r.next().context("cache index parse")?;
        if !schema_ok {
            bail!("unsupported cache index schema");
        }
        let entries = entries.context("missing entries object")?;
        let clock = clock.ok_or_else(|| {
            anyhow::anyhow!("missing/invalid unsigned field \"clock\"")
        })?;
        Ok(CacheIndex { clock, entries })
    }

    /// Serialize back to the compact on-disk form. Emitted incrementally
    /// (keys in canonical sorted order, matching the historical
    /// `Value`-tree bytes exactly — the golden fixture pins this).
    pub fn to_json(&self) -> String {
        let mut bytes = Vec::with_capacity(64 + self.entries.len() * 28);
        let mut w = stream::Writer::compact(&mut bytes);
        let emit = |w: &mut stream::Writer<&mut Vec<u8>>| -> Result<()> {
            w.begin_obj()?;
            w.key("clock")?;
            w.uint(self.clock)?;
            w.key("entries")?;
            w.begin_obj()?;
            // Fixed-width hex sorts identically to the numeric fingerprint
            // order, so streaming the map in iteration order is canonical.
            for (fp, stamp) in &self.entries {
                w.key(&format!("{fp:016x}"))?;
                w.uint(*stamp)?;
            }
            w.end_obj()?;
            w.key("schema")?;
            w.str(INDEX_SCHEMA)?;
            w.end_obj()?;
            Ok(())
        };
        emit(&mut w)
            .and_then(|_| w.finish().map(|_| ()))
            .expect("serializing the cache index to memory cannot fail");
        String::from_utf8(bytes).expect("writer emits UTF-8")
    }

    /// Fingerprint → last-used stamp, in fingerprint order.
    pub fn entries(&self) -> &std::collections::BTreeMap<u64, u64> {
        &self.entries
    }

    /// The logical clock the stamps are drawn from.
    pub fn clock(&self) -> u64 {
        self.clock
    }

    /// Mark `fp` as just used.
    pub fn touch(&mut self, fp: u64) {
        self.clock += 1;
        self.entries.insert(fp, self.clock);
    }

    /// Least-recently-used fingerprint other than `exclude` (the key being
    /// touched right now must never evict itself).
    fn lru_victim(&self, exclude: u64) -> Option<u64> {
        self.entries
            .iter()
            .filter(|&(&fp, _)| fp != exclude)
            .min_by_key(|&(&fp, &stamp)| (stamp, fp))
            .map(|(&fp, _)| fp)
    }
}

/// Two-tier compile cache: the in-process [`CompileCache`] backed by an
/// optional on-disk directory. Lookup order per structural key: memory →
/// disk → compile (writing the artifact back to disk on success).
#[derive(Debug)]
pub struct PersistentCache {
    mem: CompileCache,
    dir: Option<PathBuf>,
    /// Present only on size-bounded caches: serializes this process's
    /// index read-modify-writes. The index itself lives on disk (the
    /// source of truth for cross-process merging); nothing is cached in
    /// memory between touches.
    lru: Option<std::sync::Mutex<()>>,
    max_entries: usize,
    disk_hits: AtomicU64,
    neg_hits: AtomicU64,
    compiles: AtomicU64,
    rejected: AtomicU64,
    write_errors: AtomicU64,
    read_errors: AtomicU64,
    evictions: AtomicU64,
    lock_steals: AtomicU64,
}

impl PersistentCache {
    /// Create a cache backed by `dir` (created if absent). `None` disables
    /// the disk tier — behaviourally identical to a plain [`CompileCache`].
    /// The disk tier is unbounded; see
    /// [`PersistentCache::with_max_entries`].
    pub fn new(opts: CompileOptions, dir: Option<PathBuf>) -> Result<Self> {
        Self::with_max_entries(opts, dir, None)
    }

    /// Like [`PersistentCache::new`], with an optional bound on the number
    /// of structural keys retained on disk. With `Some(n)`, every disk
    /// access is recorded in the `index.json` sidecar and the
    /// least-recently-used keys are evicted (artifact + negative sidecar
    /// both removed) whenever the index exceeds `n`.
    pub fn with_max_entries(
        opts: CompileOptions,
        dir: Option<PathBuf>,
        max_entries: Option<usize>,
    ) -> Result<Self> {
        if max_entries == Some(0) {
            bail!("cache max_entries must be positive (omit the bound for unlimited)");
        }
        if let Some(d) = &dir {
            std::fs::create_dir_all(d)
                .with_context(|| format!("creating compile cache dir {d:?}"))?;
        }
        let lru = match (&dir, max_entries) {
            (Some(_), Some(_)) => Some(std::sync::Mutex::new(())),
            _ => None,
        };
        Ok(Self {
            mem: CompileCache::new(opts),
            dir,
            lru,
            max_entries: max_entries.unwrap_or(usize::MAX),
            disk_hits: AtomicU64::new(0),
            neg_hits: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
            read_errors: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            lock_steals: AtomicU64::new(0),
        })
    }

    /// Memory-only variant (no disk tier, infallible construction).
    pub fn memory_only(opts: CompileOptions) -> Self {
        Self::new(opts, None).expect("memory-only cache cannot fail")
    }

    pub fn options(&self) -> CompileOptions {
        self.mem.options()
    }

    /// Cached compilation of `(net, sys)` through both tiers. Semantics
    /// match [`CompileCache::get_or_compile`] exactly (validation on every
    /// call, negative memoization of infeasible points in memory, one
    /// source run per key across racing workers); only where a missing
    /// artifact comes *from* differs.
    pub fn get_or_compile(
        &self,
        net: &DnnGraph,
        sys: &SystemConfig,
    ) -> Result<Arc<CompiledNet>> {
        self.mem.get_or_compile_via(net, sys, |key| {
            if let Some(dir) = &self.dir {
                if let Some(compiled) = self.try_load(dir, key) {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    self.touch_index(dir, key.fingerprint());
                    return Ok(Arc::new(compiled));
                }
                // No artifact: a persisted negative record replays the
                // structural-infeasibility diagnostic with zero tiling
                // attempts (the whole point of persisting them).
                if let Some(diag) = self.try_load_negative(dir, key) {
                    self.neg_hits.fetch_add(1, Ordering::Relaxed);
                    self.touch_index(dir, key.fingerprint());
                    return Err(diag);
                }
            }
            self.compiles.fetch_add(1, Ordering::Relaxed);
            // The `compile` span covers only the source compiler run —
            // persisting the result is its own `cache.write` span.
            let compiled_or_err = {
                let mut span = crate::obs::span("compile");
                let r = compile(net, sys, self.mem.options());
                if r.is_err() {
                    span.set_outcome("infeasible");
                }
                r
            };
            match compiled_or_err {
                Ok(compiled) => {
                    if let Some(dir) = &self.dir {
                        // Best-effort persistence: a full disk must not
                        // fail the evaluation, only the warm-start.
                        if write_entry(dir, key, &compiled).is_err() {
                            self.write_errors.fetch_add(1, Ordering::Relaxed);
                        } else {
                            self.touch_index(dir, key.fingerprint());
                        }
                    }
                    Ok(Arc::new(compiled))
                }
                Err(e) => {
                    // Past validation, a compile failure is structural —
                    // safe to persist as a negative entry (best effort,
                    // like artifacts).
                    let diag = format!("{e:#}");
                    if let Some(dir) = &self.dir {
                        if write_negative(dir, key, &diag).is_err() {
                            self.write_errors.fetch_add(1, Ordering::Relaxed);
                        } else {
                            self.touch_index(dir, key.fingerprint());
                        }
                    }
                    Err(diag)
                }
            }
        })
    }

    /// Record a disk-tier use of `fp` in the LRU index: touch it, evict
    /// least-recently-used keys past the bound (artifact and negative
    /// sidecar both removed), and persist the index. No-op on unbounded
    /// caches.
    fn touch_index(&self, dir: &Path, fp: u64) {
        let Some(lru) = &self.lru else { return };
        // The disk index is the source of truth: every touch is a
        // load → touch → evict → persist read-modify-write (pull-parsed
        // and incrementally re-emitted — no JSON tree on this per-disk-hit
        // path, though the full fingerprint map is still read because
        // LRU eviction needs global knowledge), serialized by
        // the in-process mutex (this cache's threads) *and* the advisory
        // `index.lock` (other processes sharing the directory). Reloading
        // under the lock is what *merges* — rather than overwrites — a
        // concurrent process's touches and evictions. The I/O therefore
        // deliberately happens inside the critical section; an RMW split
        // across lock boundaries would reintroduce the lost-update race
        // the lock exists to close. If acquisition times out the same RMW
        // runs unlocked (last writer wins): the bound may momentarily
        // overshoot, nothing corrupts, nothing deadlocks. Poisoning is
        // recovered — the guarded state lives on disk, and an unwinding
        // toucher (e.g. an injected fault) leaves it consistent.
        let _thread_guard =
            lru.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let _process_guard = CacheLock::acquire(dir, &self.lock_steals);
        let mut index = CacheIndex::load(dir);
        index.touch(fp);
        while index.entries.len() > self.max_entries {
            // The key being touched is never its own victim, so a bound
            // of n always retains the n most recent keys, current
            // included.
            let Some(victim) = index.lru_victim(fp) else { break };
            index.entries.remove(&victim);
            let _ = std::fs::remove_file(entry_path_fp(dir, victim));
            let _ = std::fs::remove_file(negative_path_fp(dir, victim));
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        if write_atomic(dir, fp, &index_path(dir), index.to_json()).is_err() {
            self.write_errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn try_load(&self, dir: &Path, key: &CompileKey) -> Option<CompiledNet> {
        let text = self.read_cache_file(&entry_path(dir, key))?;
        match entry_from_json(&text, key) {
            Ok(compiled) => Some(compiled),
            Err(_) => {
                // Corrupted/stale entry: count it and recompile (the write
                // path will replace the bad file).
                self.rejected.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn try_load_negative(&self, dir: &Path, key: &CompileKey) -> Option<String> {
        let text = self.read_cache_file(&negative_path(dir, key))?;
        match negative_from_json(&text, key) {
            Ok(diag) => Some(diag),
            Err(_) => {
                // Corrupted negative record: reject, re-tile, rewrite.
                self.rejected.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Read one cache file, distinguishing "entry absent" (a normal miss)
    /// from a genuine I/O failure, which is *counted* instead of silently
    /// degrading into an eternal miss.
    fn read_cache_file(&self, path: &Path) -> Option<String> {
        let mut span = crate::obs::span("cache.read");
        if crate::testkit::faults::before_read("store.read", path).is_err() {
            self.read_errors.fetch_add(1, Ordering::Relaxed);
            span.set_outcome("error");
            return None;
        }
        match std::fs::read_to_string(path) {
            Ok(text) => Some(text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                span.set_outcome("absent");
                None
            }
            Err(_) => {
                self.read_errors.fetch_add(1, Ordering::Relaxed);
                span.set_outcome("error");
                None
            }
        }
    }

    /// Actual compiler invocations (the number the warm-cache acceptance
    /// check asserts to be zero).
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Keys served by deserializing a disk entry.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Keys answered "infeasible" from a persisted negative record —
    /// structural holes resolved with zero tiling attempts.
    pub fn neg_hits(&self) -> u64 {
        self.neg_hits.load(Ordering::Relaxed)
    }

    /// Disk entries rejected as corrupted or stale.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Failed best-effort entry writes.
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }

    /// Keys evicted from the disk tier by the LRU bound (0 on unbounded
    /// caches).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Disk-tier read failures other than "entry absent" — I/O errors that
    /// would previously have been indistinguishable from cold misses.
    pub fn read_errors(&self) -> u64 {
        self.read_errors.load(Ordering::Relaxed)
    }

    /// Stale `index.lock` files stolen after their recorded holder proved
    /// dead (0 on unbounded caches, which never take the lock).
    pub fn lock_steals(&self) -> u64 {
        self.lock_steals.load(Ordering::Relaxed)
    }

    /// In-memory tier hits (probes that skipped both disk and compiler).
    pub fn mem_hits(&self) -> u64 {
        self.mem.hits()
    }

    /// In-memory tier misses (keys that went to disk and/or the compiler).
    pub fn mem_misses(&self) -> u64 {
        self.mem.misses()
    }

    /// Point-in-time snapshot of every reported counter. Counters only
    /// grow, so a long-lived cache (the `avsm serve` resident tier) can
    /// attribute one run's work as `end.delta_since(start)` — exact as
    /// long as runs on the cache are serialized, which the daemon's job
    /// runner guarantees. A fresh cache's snapshot is all zeros, so the
    /// delta of a single run over a fresh cache equals the raw counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            compiles: self.compiles(),
            disk_hits: self.disk_hits(),
            neg_hits: self.neg_hits(),
            mem_hits: self.mem_hits(),
            rejected: self.rejected(),
            read_errors: self.read_errors(),
            lock_steals: self.lock_steals(),
        }
    }
}

/// Snapshot of a [`PersistentCache`]'s counters (see
/// [`PersistentCache::stats`]); the fields mirror the per-net counters the
/// campaign report carries.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub compiles: u64,
    pub disk_hits: u64,
    pub neg_hits: u64,
    pub mem_hits: u64,
    pub rejected: u64,
    pub read_errors: u64,
    pub lock_steals: u64,
}

impl CacheStats {
    /// Counter growth since `start` (field-wise `self - start`, saturating
    /// so a mismatched pair degrades to zeros instead of wrapping).
    pub fn delta_since(self, start: CacheStats) -> CacheStats {
        CacheStats {
            compiles: self.compiles.saturating_sub(start.compiles),
            disk_hits: self.disk_hits.saturating_sub(start.disk_hits),
            neg_hits: self.neg_hits.saturating_sub(start.neg_hits),
            mem_hits: self.mem_hits.saturating_sub(start.mem_hits),
            rejected: self.rejected.saturating_sub(start.rejected),
            read_errors: self.read_errors.saturating_sub(start.read_errors),
            lock_steals: self.lock_steals.saturating_sub(start.lock_steals),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn opts() -> CompileOptions {
        CompileOptions { double_buffer: true, labels: false }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("avsm_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn entry_roundtrip_is_lossless() {
        let net = models::dilated_vgg_tiny();
        let sys = SystemConfig::base_paper();
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        let back = entry_from_json(&text, &key).unwrap();
        assert_eq!(back, compiled);
    }

    #[test]
    fn entry_rejects_mismatched_key() {
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        // Same file presented under a different net's key must be refused.
        let other = CompileKey::new(&models::dilated_vgg_tiny(), &sys, opts());
        assert!(entry_from_json(&text, &other).is_err());
        // And under a structurally different config.
        let mut wide = sys.clone();
        wide.nce.array_cols *= 2;
        let wider = CompileKey::new(&net, &wide, opts());
        assert!(entry_from_json(&text, &wider).is_err());
    }

    #[test]
    fn warm_directory_skips_compilation() {
        let dir = tmp_dir("warm");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();

        let cold = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let a = cold.get_or_compile(&net, &sys).unwrap();
        assert_eq!((cold.compiles(), cold.disk_hits()), (1, 0));
        assert!(entry_path(&dir, &CompileKey::new(&net, &sys, opts())).exists());

        // Fresh cache instance, same directory: served from disk, zero
        // compiles, identical artifact.
        let warm = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let b = warm.get_or_compile(&net, &sys).unwrap();
        assert_eq!((warm.compiles(), warm.disk_hits()), (0, 1));
        assert_eq!(*a, *b);

        // Second probe of the same key stays in memory.
        warm.get_or_compile(&net, &sys).unwrap();
        assert_eq!((warm.disk_hits(), warm.mem_hits()), (1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_entry_falls_back_to_recompilation() {
        let dir = tmp_dir("corrupt");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let key = CompileKey::new(&net, &sys, opts());

        let seed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let a = seed.get_or_compile(&net, &sys).unwrap();
        std::fs::write(entry_path(&dir, &key), "{ this is not json").unwrap();

        let healed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let b = healed.get_or_compile(&net, &sys).unwrap();
        assert_eq!((healed.compiles(), healed.rejected()), (1, 1));
        assert_eq!(*a, *b);
        // The recompile healed the entry on disk.
        let again = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        again.get_or_compile(&net, &sys).unwrap();
        assert_eq!((again.compiles(), again.disk_hits()), (0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_is_rejected() {
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        assert!(entry_from_json(&text[..text.len() / 2], &key).is_err());
    }

    #[test]
    fn oversized_layer_field_is_rejected_and_healed() {
        // A corrupted entry whose `index` exceeds u32 must be *rejected*
        // (previously `as u32` silently wrapped it to a plausible value),
        // and the persistent tier must recompile and heal the file.
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        let bad = text.replace("\"index\":0", "\"index\":4294967296");
        assert_ne!(bad, text, "fixture must actually corrupt a field");
        let err = entry_from_json(&bad, &key).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds u32"), "{err:#}");

        let dir = tmp_dir("oversized");
        std::fs::write(entry_path(&dir, &key), &bad).unwrap();
        let cache = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let a = cache.get_or_compile(&net, &sys).unwrap();
        assert_eq!((cache.compiles(), cache.rejected()), (1, 1));
        assert_eq!(*a, compiled);
        // Healed on disk: a fresh cache loads it cleanly.
        let again = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        again.get_or_compile(&net, &sys).unwrap();
        assert_eq!((again.compiles(), again.rejected()), (0, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The (net, config) pair from the compiler cache tests whose tiling is
    /// provably infeasible (a 512-px 4-byte input row cannot fit 1 KiB).
    fn infeasible_pair() -> (DnnGraph, SystemConfig) {
        let net = models::dilated_vgg(512, 4, 16);
        let mut tiny = SystemConfig::base_paper();
        tiny.nce.ifm_buffer_kib = 1;
        tiny.nce.weight_buffer_kib = 1;
        tiny.nce.ofm_buffer_kib = 1;
        (net, tiny)
    }

    #[test]
    fn negative_entry_roundtrips_and_verifies_key() {
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let key = CompileKey::new(&net, &sys, opts());
        let text = negative_to_json(&key, "tiling infeasible: no fit");
        assert_eq!(
            negative_from_json(&text, &key).unwrap(),
            "tiling infeasible: no fit"
        );
        // Wrong key refuses — a stale record can never mark a feasible key
        // infeasible.
        let other = CompileKey::new(&models::dilated_vgg_tiny(), &sys, opts());
        assert!(negative_from_json(&text, &other).is_err());
        // Corruption refuses.
        assert!(negative_from_json(&text[..text.len() / 2], &key).is_err());
        // An artifact entry is not a negative entry (schema check).
        let compiled = compile(&net, &sys, opts()).unwrap();
        assert!(negative_from_json(&entry_to_json(&key, &compiled), &key).is_err());
    }

    #[test]
    fn persisted_negative_entry_skips_retiling() {
        let dir = tmp_dir("negative");
        let (net, tiny) = infeasible_pair();

        // Cold: one tiling attempt, fails, negative entry persisted.
        let cold = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let first = cold.get_or_compile(&net, &tiny);
        assert!(first.is_err());
        assert_eq!((cold.compiles(), cold.neg_hits()), (1, 0));
        let key = CompileKey::new(&net, &tiny, opts());
        assert!(negative_path(&dir, &key).exists());

        // Warm (fresh cache, same directory): zero tiling attempts, the
        // diagnostic replays from disk.
        let warm = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let second = warm.get_or_compile(&net, &tiny);
        assert!(second.is_err());
        assert_eq!((warm.compiles(), warm.neg_hits()), (0, 1));
        assert_eq!(
            format!("{:#}", second.unwrap_err()),
            format!("{:#}", first.unwrap_err()),
            "persisted diagnostic must replay verbatim"
        );
        // A second probe of the same key stays in the memory tier.
        assert!(warm.get_or_compile(&net, &tiny).is_err());
        assert_eq!((warm.neg_hits(), warm.mem_hits()), (1, 1));

        // Corrupted negative record: rejected, re-tiled once, rewritten.
        std::fs::write(negative_path(&dir, &key), "{ not a record").unwrap();
        let healed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        assert!(healed.get_or_compile(&net, &tiny).is_err());
        assert_eq!(
            (healed.compiles(), healed.rejected(), healed.neg_hits()),
            (1, 1, 0)
        );
        let again = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        assert!(again.get_or_compile(&net, &tiny).is_err());
        assert_eq!((again.compiles(), again.neg_hits()), (0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Three structurally distinct configs around the base point.
    fn structural_variants() -> Vec<SystemConfig> {
        let base = SystemConfig::base_paper();
        let mut wide = base.clone();
        wide.nce.array_cols *= 2;
        let mut tall = base.clone();
        tall.nce.array_rows *= 2;
        vec![base, wide, tall]
    }

    #[test]
    fn index_round_trips_and_restarts_on_corruption() {
        let mut index = CacheIndex::default();
        index.touch(0xdead_beef);
        index.touch(42);
        index.touch(0xdead_beef); // refresh
        let text = index.to_json();
        let back = CacheIndex::from_json(&text).unwrap();
        assert_eq!(back.clock, 3);
        assert_eq!(back.entries, index.entries);
        assert_eq!(back.lru_victim(u64::MAX), Some(42), "42 is the LRU key");
        assert_eq!(back.lru_victim(42), Some(0xdead_beef), "self-exclusion");
        assert!(CacheIndex::from_json("{ nope").is_err());
        assert!(CacheIndex::from_json("{\"schema\":\"other\"}").is_err());
    }

    #[test]
    fn bounded_cache_evicts_least_recently_used_entries() {
        let dir = tmp_dir("lru");
        let net = models::lenet(28);
        let sys = structural_variants();
        let cache =
            PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        let keys: Vec<CompileKey> =
            sys.iter().map(|s| CompileKey::new(&net, s, opts())).collect();

        cache.get_or_compile(&net, &sys[0]).unwrap();
        cache.get_or_compile(&net, &sys[1]).unwrap();
        assert_eq!(cache.evictions(), 0);
        assert!(entry_path(&dir, &keys[0]).exists());
        assert!(index_path(&dir).exists());

        // Touch key 0 so key 1 becomes the LRU victim of the third write.
        let warm = PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        warm.get_or_compile(&net, &sys[0]).unwrap();
        assert_eq!(warm.disk_hits(), 1);
        warm.get_or_compile(&net, &sys[2]).unwrap();
        assert_eq!(warm.evictions(), 1);
        assert!(entry_path(&dir, &keys[0]).exists(), "recently used survives");
        assert!(!entry_path(&dir, &keys[1]).exists(), "LRU key evicted");
        assert!(entry_path(&dir, &keys[2]).exists());

        // The evicted key reads as a plain miss and recompiles (healing
        // itself back in, evicting the now-oldest key 0).
        let again =
            PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        again.get_or_compile(&net, &sys[1]).unwrap();
        assert_eq!((again.compiles(), again.disk_hits()), (1, 0));
        assert!(!entry_path(&dir, &keys[0]).exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn eviction_removes_negative_sidecars_too() {
        let dir = tmp_dir("lru_neg");
        let (bad_net, tiny) = infeasible_pair();
        let net = models::lenet(28);
        let sys = structural_variants();

        // Seed one negative record, then push two artifacts through a
        // 2-entry cache: the negative key is the LRU victim and its
        // sidecar must disappear with it.
        let cache =
            PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        assert!(cache.get_or_compile(&bad_net, &tiny).is_err());
        let neg_key = CompileKey::new(&bad_net, &tiny, opts());
        assert!(negative_path(&dir, &neg_key).exists());
        cache.get_or_compile(&net, &sys[0]).unwrap();
        cache.get_or_compile(&net, &sys[1]).unwrap();
        assert_eq!(cache.evictions(), 1);
        assert!(!negative_path(&dir, &neg_key).exists(), "negative sidecar evicted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unbounded_cache_writes_no_index() {
        let dir = tmp_dir("no_index");
        let net = models::lenet(28);
        let cache = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        cache.get_or_compile(&net, &SystemConfig::base_paper()).unwrap();
        assert!(!index_path(&dir).exists(), "unbounded caches keep today's layout");
        assert_eq!(cache.evictions(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_index_restarts_empty_and_entries_are_adopted() {
        let dir = tmp_dir("bad_index");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let seed =
            PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(4)).unwrap();
        seed.get_or_compile(&net, &sys).unwrap();
        std::fs::write(index_path(&dir), "{ not an index").unwrap();

        // The entry itself is intact: it loads (key-verified) and gets
        // re-adopted into a fresh index.
        let healed =
            PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(4)).unwrap();
        healed.get_or_compile(&net, &sys).unwrap();
        assert_eq!((healed.compiles(), healed.disk_hits()), (0, 1));
        let text = std::fs::read_to_string(index_path(&dir)).unwrap();
        let index = CacheIndex::from_json(&text).unwrap();
        assert_eq!(index.entries.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn interleaved_cache_instances_merge_index_updates() {
        let dir = tmp_dir("interleave");
        let net = models::lenet(28);
        let sys = structural_variants();
        let a = PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        let b = PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        a.get_or_compile(&net, &sys[0]).unwrap();
        b.get_or_compile(&net, &sys[1]).unwrap();
        // Every touch reloads the on-disk index under the lock, so b's
        // write merged a's touch instead of overwriting it (the lost
        // update the old construction-time snapshot suffered).
        let index =
            CacheIndex::from_json(&std::fs::read_to_string(index_path(&dir)).unwrap()).unwrap();
        assert_eq!(index.entries.len(), 2, "no lost touches across instances");
        // A third key through instance `a` evicts exactly the merged-LRU
        // key — eviction decisions see the other instance's history too.
        a.get_or_compile(&net, &sys[2]).unwrap();
        assert_eq!(a.evictions(), 1);
        let keys: Vec<CompileKey> =
            sys.iter().map(|s| CompileKey::new(&net, s, opts())).collect();
        assert!(!entry_path(&dir, &keys[0]).exists(), "merged-LRU victim evicted");
        assert!(entry_path(&dir, &keys[1]).exists());
        assert!(entry_path(&dir, &keys[2]).exists());
        assert!(!lock_path(&dir).exists(), "lock released after every touch");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn stale_lock_from_a_dead_holder_is_stolen() {
        let dir = tmp_dir("stale_lock");
        // A PID far above any real pid_max: provably dead, so acquisition
        // must steal instead of waiting out the full timeout.
        std::fs::write(lock_path(&dir), "999999999").unwrap();
        let cache =
            PersistentCache::with_max_entries(opts(), Some(dir.clone()), Some(2)).unwrap();
        cache.get_or_compile(&models::lenet(28), &SystemConfig::base_paper()).unwrap();
        assert_eq!(cache.lock_steals(), 1, "dead holder's lock stolen once");
        assert!(!lock_path(&dir).exists(), "stolen lock released on drop");
        let index =
            CacheIndex::from_json(&std::fs::read_to_string(index_path(&dir)).unwrap()).unwrap();
        assert_eq!(index.entries.len(), 1, "the touch went through");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn negative_entries_classify_trailing_garbage_like_the_tree_parser() {
        // Differential regression: the lazy negative load used to stop at
        // the diagnostic field and accept anything after it. Lazy and tree
        // parses must agree on every suffix — benign whitespace accepted,
        // trailing garbage (a torn concatenated write) rejected by both.
        let net = models::lenet(28);
        let key = CompileKey::new(&net, &SystemConfig::base_paper(), opts());
        let text = negative_to_json(&key, "no legal tiling");
        for suffix in ["", " ", "\n", "\t \r\n"] {
            let doc = format!("{text}{suffix}");
            assert!(json::parse(&doc).is_ok(), "tree accepts {suffix:?}");
            assert_eq!(
                negative_from_json(&doc, &key).unwrap(),
                "no legal tiling",
                "lazy accepts {suffix:?}"
            );
        }
        for suffix in ["x", " {}", "1", ",\"k\":0}", &text.clone()] {
            let doc = format!("{text}{suffix}");
            assert!(json::parse(&doc).is_err(), "tree rejects {suffix:?}");
            assert!(
                negative_from_json(&doc, &key).is_err(),
                "lazy must reject {suffix:?} too"
            );
        }
    }

    #[test]
    fn without_liveness_probing_an_occupied_lock_is_never_stolen() {
        // The conservative (non-/proc) path: a lock whose holder cannot be
        // proven dead — here a provably-dead PID *and* an unreadable
        // payload, the two steal triggers — must wait out the full timeout
        // and degrade to None with the file untouched, not steal.
        for payload in ["999999999", "not a pid"] {
            let dir = tmp_dir("no_steal");
            std::fs::write(lock_path(&dir), payload).unwrap();
            let steals = AtomicU64::new(0);
            let got = CacheLock::acquire_gated(&dir, &steals, false);
            assert!(got.is_none(), "acquisition times out on {payload:?}");
            assert_eq!(steals.load(Ordering::Relaxed), 0, "never stolen");
            assert_eq!(
                std::fs::read_to_string(lock_path(&dir)).unwrap(),
                payload,
                "holder's lock file left intact"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn injected_read_fault_counts_and_degrades_to_recompilation() {
        use crate::testkit::faults::{self, FaultKind};
        let dir = tmp_dir("fault_read");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let seed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let a = seed.get_or_compile(&net, &sys).unwrap();

        let _g = faults::arm("store.read", &dir, FaultKind::IoError, 1);
        let cache = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let b = cache.get_or_compile(&net, &sys).unwrap();
        assert_eq!(
            (cache.compiles(), cache.read_errors(), cache.disk_hits()),
            (1, 1, 0),
            "read fault counted, evaluation degraded to a recompile"
        );
        assert_eq!(*a, *b, "the artifact itself is unaffected");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_torn_entry_write_is_rejected_then_healed() {
        use crate::testkit::faults::{self, FaultKind};
        let dir = tmp_dir("fault_torn");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        {
            let _g = faults::arm("store.write", &dir, FaultKind::Torn, 1);
            let cache = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
            // The evaluation itself succeeds — persistence is best-effort.
            cache.get_or_compile(&net, &sys).unwrap();
        }
        // The tear bypassed the rename protocol: a half-written file sits
        // at the final path claiming success. Readers must reject it and
        // heal it, never load it.
        let key = CompileKey::new(&net, &sys, opts());
        assert!(entry_path(&dir, &key).exists(), "torn corpse is present");
        let healed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        healed.get_or_compile(&net, &sys).unwrap();
        assert_eq!((healed.compiles(), healed.rejected()), (1, 1));
        let again = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        again.get_or_compile(&net, &sys).unwrap();
        assert_eq!((again.compiles(), again.disk_hits()), (0, 1), "healed on disk");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn zero_bound_is_rejected() {
        assert!(
            PersistentCache::with_max_entries(opts(), None, Some(0)).is_err(),
            "max_entries == 0 must be a loud configuration error"
        );
    }

    #[test]
    fn memory_only_cache_never_touches_disk() {
        let cache = PersistentCache::memory_only(opts());
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        cache.get_or_compile(&net, &sys).unwrap();
        cache.get_or_compile(&net, &sys).unwrap();
        assert_eq!((cache.compiles(), cache.disk_hits(), cache.mem_hits()), (1, 0, 1));
    }
}
