//! Disk-persistent compile cache (schema `avsm-compile-cache-v1`).
//!
//! `compiler::CompileCache` memoizes compilations within one process; this
//! module adds the disk tier that carries them **across CLI invocations**
//! (ROADMAP "cache persistence"): each compiled artifact is serialized —
//! the task graph via [`crate::taskgraph::serialize`], the per-layer
//! records alongside — into one JSON document keyed by
//! [`CompileKey::fingerprint`] (which already covers the net's content
//! fingerprint plus every structural config field). A warm directory makes
//! a repeated campaign or sweep **compile-free**: every structural key is
//! deserialized instead of re-tiled and re-lowered.
//!
//! Safety properties:
//!
//! * Every entry embeds its full [`CompileKey::to_json`]; a load verifies
//!   it field by field against the expected key, so stale entries, hash
//!   collisions and schema drift read as misses, never as wrong artifacts.
//! * Corrupted or truncated files fail JSON parsing or task-graph
//!   validation and fall back to recompilation (counted in
//!   [`PersistentCache::rejected`]); the fresh compile then overwrites the
//!   bad entry.
//! * Writes go through a per-process temp file + rename, so concurrent
//!   processes sharing a cache directory never observe half-written
//!   entries. Within one process the in-memory tier's in-flight marker
//!   already guarantees one writer per key.
//!
//! Only successful compilations are persisted; infeasible structural
//! points are memoized in memory per process (they are cheap to rediscover
//! and keeping the disk format artifact-only keeps it trivially
//! verifiable).

use crate::compiler::tiling::VectorTiling;
use crate::compiler::{
    compile, CompileCache, CompileKey, CompileOptions, CompiledLayer, CompiledNet, LayerTiling,
    TilingChoice,
};
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::json::{self, obj, Value};
use crate::taskgraph::serialize;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

const SCHEMA: &str = "avsm-compile-cache-v1";

/// File that stores the artifact for `key` under `dir`.
pub fn entry_path(dir: &Path, key: &CompileKey) -> PathBuf {
    dir.join(format!("{:016x}.compiled.json", key.fingerprint()))
}

/// Serialize one compiled artifact (plus its full key, for verification on
/// load) into a single compact JSON document.
pub fn entry_to_json(key: &CompileKey, compiled: &CompiledNet) -> String {
    obj(vec![
        ("schema", SCHEMA.into()),
        ("key", key.to_json()),
        (
            "layers",
            Value::Array(compiled.layers.iter().map(layer_to_value).collect()),
        ),
        // The task graph rides along as an embedded `avsm-task-graph-v1`
        // document (string form), reusing the flow-boundary serializer.
        ("task_graph", serialize::to_json(&compiled.graph).into()),
    ])
    .to_string_compact()
}

fn layer_to_value(l: &CompiledLayer) -> Value {
    let tiling = match l.tiling {
        LayerTiling::Conv(t) => obj(vec![
            ("kind", "conv".into()),
            ("cin_t", t.cin_t.into()),
            ("cout_t", t.cout_t.into()),
            ("oh_t", t.oh_t.into()),
            ("n_cin", t.n_cin.into()),
            ("n_cout", t.n_cout.into()),
            ("n_oh", t.n_oh.into()),
            ("ifm_resident", t.ifm_resident.into()),
        ]),
        LayerTiling::Vector(v) => obj(vec![
            ("kind", "vector".into()),
            ("oh_t", v.oh_t.into()),
            ("n_oh", v.n_oh.into()),
        ]),
    };
    obj(vec![
        ("index", l.index.into()),
        ("name", l.name.as_str().into()),
        ("tiling", tiling),
        ("compute_cycles", l.compute_cycles.into()),
        ("dma_bytes", l.dma_bytes.into()),
        ("macs", l.macs.into()),
        ("barrier", l.barrier.into()),
    ])
}

fn layer_from_value(lv: &Value) -> Result<CompiledLayer> {
    let tv = lv.get("tiling");
    let tiling = match tv.get("kind").as_str().unwrap_or_default() {
        "conv" => LayerTiling::Conv(TilingChoice {
            cin_t: tv.req_u64("cin_t")? as u32,
            cout_t: tv.req_u64("cout_t")? as u32,
            oh_t: tv.req_u64("oh_t")? as u32,
            n_cin: tv.req_u64("n_cin")? as u32,
            n_cout: tv.req_u64("n_cout")? as u32,
            n_oh: tv.req_u64("n_oh")? as u32,
            ifm_resident: tv
                .get("ifm_resident")
                .as_bool()
                .context("missing/invalid ifm_resident")?,
        }),
        "vector" => LayerTiling::Vector(VectorTiling {
            oh_t: tv.req_u64("oh_t")? as u32,
            n_oh: tv.req_u64("n_oh")? as u32,
        }),
        other => bail!("unknown tiling kind {other:?}"),
    };
    Ok(CompiledLayer {
        index: lv.req_u64("index")? as u32,
        name: lv.req_str("name")?.to_string(),
        tiling,
        compute_cycles: lv.req_u64("compute_cycles")?,
        dma_bytes: lv.req_u64("dma_bytes")?,
        macs: lv.req_u64("macs")?,
        barrier: lv.req_u64("barrier")? as u32,
    })
}

/// Parse and verify one cache entry. `expect_key` is the key the caller is
/// looking up; any mismatch with the stored key is an error (stale entry
/// or fingerprint collision).
pub fn entry_from_json(text: &str, expect_key: &CompileKey) -> Result<CompiledNet> {
    let v = json::parse(text).context("compile cache entry parse")?;
    if v.get("schema").as_str() != Some(SCHEMA) {
        bail!("unsupported compile cache schema");
    }
    if v.get("key") != &expect_key.to_json() {
        bail!("cache entry key mismatch (stale entry or fingerprint collision)");
    }
    let graph = serialize::from_json(v.req_str("task_graph")?)
        .context("embedded task graph")?;
    let mut layers = Vec::new();
    for lv in v.req_array("layers")? {
        layers.push(layer_from_value(lv)?);
    }
    if layers.is_empty() {
        bail!("cache entry has no layers");
    }
    for l in &layers {
        if l.barrier as usize >= graph.len() {
            bail!("layer {:?} barrier id out of range", l.name);
        }
    }
    Ok(CompiledNet { graph, layers })
}

/// Write an entry atomically (temp file + rename). The temp name is
/// unique per process *and* per write (atomic counter): the per-key
/// in-flight marker only dedups writers within one `CompileCache`
/// instance, so two caches sharing a directory in one process must not
/// collide on the temp inode either.
pub fn write_entry(dir: &Path, key: &CompileKey, compiled: &CompiledNet) -> Result<()> {
    static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
    let path = entry_path(dir, key);
    let tmp = dir.join(format!(
        "{:016x}.tmp.{}.{}",
        key.fingerprint(),
        std::process::id(),
        WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, entry_to_json(key, compiled))
        .with_context(|| format!("writing cache entry {tmp:?}"))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publishing cache entry {path:?}"))?;
    Ok(())
}

/// Two-tier compile cache: the in-process [`CompileCache`] backed by an
/// optional on-disk directory. Lookup order per structural key: memory →
/// disk → compile (writing the artifact back to disk on success).
#[derive(Debug)]
pub struct PersistentCache {
    mem: CompileCache,
    dir: Option<PathBuf>,
    disk_hits: AtomicU64,
    compiles: AtomicU64,
    rejected: AtomicU64,
    write_errors: AtomicU64,
}

impl PersistentCache {
    /// Create a cache backed by `dir` (created if absent). `None` disables
    /// the disk tier — behaviourally identical to a plain [`CompileCache`].
    pub fn new(opts: CompileOptions, dir: Option<PathBuf>) -> Result<Self> {
        if let Some(d) = &dir {
            std::fs::create_dir_all(d)
                .with_context(|| format!("creating compile cache dir {d:?}"))?;
        }
        Ok(Self {
            mem: CompileCache::new(opts),
            dir,
            disk_hits: AtomicU64::new(0),
            compiles: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
        })
    }

    /// Memory-only variant (no disk tier, infallible construction).
    pub fn memory_only(opts: CompileOptions) -> Self {
        Self::new(opts, None).expect("memory-only cache cannot fail")
    }

    pub fn options(&self) -> CompileOptions {
        self.mem.options()
    }

    /// Cached compilation of `(net, sys)` through both tiers. Semantics
    /// match [`CompileCache::get_or_compile`] exactly (validation on every
    /// call, negative memoization of infeasible points in memory, one
    /// source run per key across racing workers); only where a missing
    /// artifact comes *from* differs.
    pub fn get_or_compile(
        &self,
        net: &DnnGraph,
        sys: &SystemConfig,
    ) -> Result<Arc<CompiledNet>> {
        self.mem.get_or_compile_via(net, sys, |key| {
            if let Some(dir) = &self.dir {
                if let Some(compiled) = self.try_load(dir, key) {
                    self.disk_hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(Arc::new(compiled));
                }
            }
            self.compiles.fetch_add(1, Ordering::Relaxed);
            match compile(net, sys, self.mem.options()) {
                Ok(compiled) => {
                    if let Some(dir) = &self.dir {
                        // Best-effort persistence: a full disk must not
                        // fail the evaluation, only the warm-start.
                        if write_entry(dir, key, &compiled).is_err() {
                            self.write_errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Ok(Arc::new(compiled))
                }
                Err(e) => Err(format!("{e:#}")),
            }
        })
    }

    fn try_load(&self, dir: &Path, key: &CompileKey) -> Option<CompiledNet> {
        let text = std::fs::read_to_string(entry_path(dir, key)).ok()?;
        match entry_from_json(&text, key) {
            Ok(compiled) => Some(compiled),
            Err(_) => {
                // Corrupted/stale entry: count it and recompile (the write
                // path will replace the bad file).
                self.rejected.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Actual compiler invocations (the number the warm-cache acceptance
    /// check asserts to be zero).
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Keys served by deserializing a disk entry.
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Disk entries rejected as corrupted or stale.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Failed best-effort entry writes.
    pub fn write_errors(&self) -> u64 {
        self.write_errors.load(Ordering::Relaxed)
    }

    /// In-memory tier hits (probes that skipped both disk and compiler).
    pub fn mem_hits(&self) -> u64 {
        self.mem.hits()
    }

    /// In-memory tier misses (keys that went to disk and/or the compiler).
    pub fn mem_misses(&self) -> u64 {
        self.mem.misses()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn opts() -> CompileOptions {
        CompileOptions { double_buffer: true, labels: false }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("avsm_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn entry_roundtrip_is_lossless() {
        let net = models::dilated_vgg_tiny();
        let sys = SystemConfig::base_paper();
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        let back = entry_from_json(&text, &key).unwrap();
        assert_eq!(back, compiled);
    }

    #[test]
    fn entry_rejects_mismatched_key() {
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        // Same file presented under a different net's key must be refused.
        let other = CompileKey::new(&models::dilated_vgg_tiny(), &sys, opts());
        assert!(entry_from_json(&text, &other).is_err());
        // And under a structurally different config.
        let mut wide = sys.clone();
        wide.nce.array_cols *= 2;
        let wider = CompileKey::new(&net, &wide, opts());
        assert!(entry_from_json(&text, &wider).is_err());
    }

    #[test]
    fn warm_directory_skips_compilation() {
        let dir = tmp_dir("warm");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();

        let cold = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let a = cold.get_or_compile(&net, &sys).unwrap();
        assert_eq!((cold.compiles(), cold.disk_hits()), (1, 0));
        assert!(entry_path(&dir, &CompileKey::new(&net, &sys, opts())).exists());

        // Fresh cache instance, same directory: served from disk, zero
        // compiles, identical artifact.
        let warm = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let b = warm.get_or_compile(&net, &sys).unwrap();
        assert_eq!((warm.compiles(), warm.disk_hits()), (0, 1));
        assert_eq!(*a, *b);

        // Second probe of the same key stays in memory.
        warm.get_or_compile(&net, &sys).unwrap();
        assert_eq!((warm.disk_hits(), warm.mem_hits()), (1, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_entry_falls_back_to_recompilation() {
        let dir = tmp_dir("corrupt");
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let key = CompileKey::new(&net, &sys, opts());

        let seed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let a = seed.get_or_compile(&net, &sys).unwrap();
        std::fs::write(entry_path(&dir, &key), "{ this is not json").unwrap();

        let healed = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        let b = healed.get_or_compile(&net, &sys).unwrap();
        assert_eq!((healed.compiles(), healed.rejected()), (1, 1));
        assert_eq!(*a, *b);
        // The recompile healed the entry on disk.
        let again = PersistentCache::new(opts(), Some(dir.clone())).unwrap();
        again.get_or_compile(&net, &sys).unwrap();
        assert_eq!((again.compiles(), again.disk_hits()), (0, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_is_rejected() {
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        let compiled = compile(&net, &sys, opts()).unwrap();
        let key = CompileKey::new(&net, &sys, opts());
        let text = entry_to_json(&key, &compiled);
        assert!(entry_from_json(&text[..text.len() / 2], &key).is_err());
    }

    #[test]
    fn memory_only_cache_never_touches_disk() {
        let cache = PersistentCache::memory_only(opts());
        let net = models::lenet(28);
        let sys = SystemConfig::base_paper();
        cache.get_or_compile(&net, &sys).unwrap();
        cache.get_or_compile(&net, &sys).unwrap();
        assert_eq!((cache.compiles(), cache.disk_hits(), cache.mem_hits()), (1, 0, 1));
    }
}
