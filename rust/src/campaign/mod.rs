//! Campaign engine: multi-workload co-design sweeps on one shared worker
//! pool, with streaming Pareto frontiers and a persistent compile cache.
//!
//! The paper's pitch is "design space exploration by a click of a button"
//! across *systems*: a co-design loop ranks one hardware configuration
//! grid against a whole portfolio of workloads (the way SMAUG evaluates
//! full-stack design points across several DNNs, and ANNETTE amortizes
//! per-platform model building across networks). [`crate::dse::sweep`]
//! covers one net; [`run`] covers the portfolio.
//!
//! # Execution model
//!
//! A campaign is `N` workloads, each against its **own** grid: a
//! [`WorkloadSpec`] may override the campaign-wide base [`SystemConfig`]
//! and/or [`SweepAxes`], so one run can sweep a heterogeneous portfolio —
//! each DNN against its own accelerator grid, SMAUG-style — while a
//! homogeneous portfolio ([`CampaignSpec::homogeneous`]) behaves exactly
//! as before. Per-net grids are expanded up front (deterministic axis
//! order) and the full unit list (net-major) fans out over a single
//! worker pool ([`pool`]) in two phases:
//!
//! 1. **Resolve**: every unit resolves its compiled artifact through its
//!    net's [`PersistentCache`] (memory → disk → compile; retime-only
//!    axis moves always share one compilation, exactly as in single-net
//!    DSE) and, when pruning is on, computes its admissible latency lower
//!    bound.
//! 2. **Simulate**: compiled units are re-fanned out — in **ascending
//!    lower-bound order** per net when
//!    [`CampaignOptions::order_by_bound`] is set (the default), so likely
//!    dominators are simulated and inserted into the per-net
//!    [`StreamingFrontier`] first, maximizing the skip rate — and each
//!    simulated [`DesignPoint`] streams back to the coordinating thread,
//!    which folds it into that net's frontier.
//!
//! Each point carries its grid-enumeration index as the frontier sequence
//! number, which makes the final per-net frontier **byte-identical** to
//! batch `dse::pareto(dse::sweep(..))` regardless of worker timing *and*
//! of the evaluation order — the equivalence the test suite enforces.
//!
//! # Bound-and-prune
//!
//! Before simulating a compiled unit, the worker takes the point's
//! **admissible latency lower bound** — by default
//! [`crate::compiler::latency_lower_bound`], the max of the exclusive-
//! resource *occupancy* bound and the *critical-path* (longest dependency
//! chain) bound at the candidate's actual clocks, both O(task graph), no
//! simulation; [`CampaignOptions::bound`] (CLI `--bound`) restricts the
//! run to either component for A/B comparisons — and asks that net's
//! frontier [`StreamingFrontier::admits`] whether a point at
//! `(bound, cost)` could still join. Each skip is attributed in
//! [`NetOutcome`]: would the occupancy bound alone have refused it
//! ([`NetOutcome::skipped_by_occupancy`]), or did it need the
//! critical-path half ([`NetOutcome::skipped_by_critical_path`] — the
//! deep-chain, latency-dominated regions occupancy admits)? A refusal means an existing member *strictly dominates*
//! every latency the candidate could realize, and strict dominance
//! survives later evictions — so skipping the simulation is **lossless**:
//! pruned frontiers are byte-identical to unpruned ones (property-tested),
//! only [`NetOutcome::skipped_by_bound`] changes. Which points get skipped
//! depends on arrival timing under parallelism (a conservative race: a
//! not-yet-inserted dominator just means one extra simulation), never the
//! result; bound-ascending ordering exists precisely to make the lucky
//! order the *common* order. [`CampaignOptions::prune`] (CLI `--no-prune`)
//! is the escape hatch; [`CampaignOptions::keep_points`] disables pruning
//! implicitly because it asks for every feasible point, not just the
//! frontier.
//!
//! # Outcome classification & error policy
//!
//! Every unit resolves to exactly one of *feasible* (simulated),
//! *infeasible* (the tiler proved no legal tiling exists — a real hole in
//! the grid), *error* (invalid swept config — a defect in the sweep, never
//! conflated with infeasibility), *panicked* (the unit's worker unwound —
//! contained per unit, see the failure policy below) or *skipped by
//! bound*. The per-net accounting satisfies `evaluated == feasible +
//! infeasible + errors + panics + skipped_by_bound` and errors/panics are
//! surfaced with sample diagnostics instead of silently vanishing from
//! the results. [`CampaignOptions::fail_fast`] (CLI `--fail-fast`) turns
//! the first *error*- or *panicked*-classified unit into a hard abort of
//! the whole run with that unit's diagnostic — the CI-gate mode;
//! infeasible tilings and bound-skips are legitimate outcomes and never
//! trigger it.
//!
//! # Failure policy
//!
//! A multi-hour campaign must survive a bad unit, a torn cache write or a
//! killed process without losing or corrupting results. Faults therefore
//! *degrade* — to a recompile, an error row or a dropped torn tail —
//! never into wrong numbers, and every degradation is attributed in the
//! report. The contract is exercised by the seeded fault-injection
//! harness ([`crate::testkit::faults`]):
//!
//! | fault | classified as | degradation |
//! |-------|---------------|-------------|
//! | unit worker panics (resolve or simulate) | [`NetOutcome::panics`] + [`NetOutcome::panic_sample`] | contained per job by the pool ([`pool::JobDied`]); every other unit completes; honors `fail_fast` |
//! | cache read error / torn or stale entry | [`NetOutcome::read_errors`] / [`NetOutcome::rejected`] | recompiled and rewritten — frontiers byte-identical to a clean run |
//! | frontier mutex poisoned by a panicking worker | — | lock recovered ([`std::sync::PoisonError::into_inner`]): frontier inserts are atomic-by-construction, so a poisoned frontier is still consistent |
//! | journal torn final line (crash mid-append) | — | torn tail dropped and truncated away on resume ([`journal`]) |
//! | cache lock held by a dead process | lock-steal counter ([`store`]) | stale lock stolen after a liveness check; lock timeout degrades to unlocked last-writer-wins, never a deadlock |
//!
//! # Persistence model
//!
//! With [`CampaignOptions::cache_dir`] set, every successful compilation
//! is serialized (task graph + per-layer records + full [`CompileKey`])
//! into the directory via [`store`]; a later run — same process or a new
//! CLI invocation — resolves every structural key from disk and performs
//! **zero compilations** (assertable via [`CampaignResult::compiles`]).
//! Structurally *infeasible* keys are persisted too (negative records with
//! the tiler's diagnostic), so warm campaigns also perform zero tiling
//! attempts on the infeasible corners of a grid
//! ([`NetOutcome::neg_hits`]). Corrupted or stale entries of either kind
//! are detected (schema/key verification, task-graph validation),
//! rejected, recompiled and rewritten. Without a cache directory the
//! campaign still shares compilations in memory, per net, across the
//! whole grid.
//!
//! Bounded disk caches ([`CampaignOptions::cache_max_entries`]) serialize
//! their LRU index read-modify-write and evictions across *processes* via
//! an advisory lock file (see [`store`]), so concurrent campaigns sharing
//! one cache directory lose neither touches nor evictions. With
//! [`CampaignOptions::journal`] every completed unit is appended to a
//! crash-safe resume journal ([`journal`]);
//! [`CampaignOptions::resume`] replays it, so a killed campaign
//! reproduces its report byte-identically while re-simulating only the
//! unfinished units.
//!
//! # Telemetry
//!
//! With [`crate::obs`] recording on (CLI `--telemetry` / `--trace-out`),
//! every unit's lifecycle lands as spans — `resolve` and `bound` in
//! phase 1, `simulate` or `skipped` in phase 2, plus `compile`,
//! `cache.read`, `cache.write`, `lock.wait`, `lock.steal` and
//! `journal.append` at the persistence sites — tagged with worker id,
//! net, unit and outcome class, and the cache tier totals are pushed as
//! counters. [`run`] dispatches to a monomorphized `OBS` instantiation
//! (the simulator's `TRACED` idiom), so the disabled engine carries no
//! telemetry code, and recording never changes what a campaign computes.
//!
//! [`CompileKey`]: crate::compiler::CompileKey

pub mod frontier;
pub mod journal;
pub mod pool;
pub mod store;

pub use frontier::StreamingFrontier;
pub use store::{CacheStats, PersistentCache};

use crate::compiler::BoundKind;
use crate::config::SystemConfig;
use crate::dse::{self, DesignPoint, SweepAxes};
use crate::graph::DnnGraph;
use anyhow::{bail, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// One workload of a campaign: a net plus optional overrides of the
/// campaign-wide base config and sweep axes. With both overrides `None`
/// the workload sweeps the shared grid, exactly as campaigns always did;
/// setting them gives the net its own accelerator design space
/// (heterogeneous, SMAUG-style portfolios) while still sharing the worker
/// pool, the persistent cache directory and the streaming frontiers.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub net: DnnGraph,
    /// Per-net base system; `None` uses [`CampaignSpec::base`].
    pub base: Option<SystemConfig>,
    /// Per-net sweep axes; `None` uses [`CampaignSpec::axes`].
    pub axes: Option<SweepAxes>,
}

impl WorkloadSpec {
    pub fn new(net: DnnGraph) -> Self {
        Self { net, base: None, axes: None }
    }

    pub fn with_base(mut self, base: SystemConfig) -> Self {
        self.base = Some(base);
        self
    }

    pub fn with_axes(mut self, axes: SweepAxes) -> Self {
        self.axes = Some(axes);
        self
    }
}

/// What to sweep: a portfolio of workloads, each against the shared
/// base x axes grid unless its [`WorkloadSpec`] overrides them.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub workloads: Vec<WorkloadSpec>,
    /// Campaign-wide base system; axes replace fields of this config
    /// (empty axes keep the base value), exactly as in [`dse::sweep`].
    pub base: SystemConfig,
    /// Campaign-wide sweep axes.
    pub axes: SweepAxes,
}

impl CampaignSpec {
    /// The classic homogeneous campaign: every net against one shared
    /// base + axes grid (compatibility constructor).
    pub fn homogeneous(nets: Vec<DnnGraph>, base: SystemConfig, axes: SweepAxes) -> Self {
        Self {
            workloads: nets.into_iter().map(WorkloadSpec::new).collect(),
            base,
            axes,
        }
    }

    /// Effective base config for workload `ni`.
    pub fn base_of(&self, ni: usize) -> &SystemConfig {
        self.workloads[ni].base.as_ref().unwrap_or(&self.base)
    }

    /// Effective sweep axes for workload `ni`.
    pub fn axes_of(&self, ni: usize) -> &SweepAxes {
        self.workloads[ni].axes.as_ref().unwrap_or(&self.axes)
    }
}

/// Execution policy for [`run`].
#[derive(Debug, Clone)]
pub struct CampaignOptions {
    /// Worker threads; 0 (default) = one per available CPU, capped by the
    /// unit count.
    pub threads: usize,
    /// Directory for the persistent compile cache; `None` keeps the cache
    /// in memory only.
    pub cache_dir: Option<PathBuf>,
    /// Bound on the number of structural keys the disk cache retains
    /// (LRU-evicted via the `avsm-compile-cache-index-v1` sidecar; see
    /// [`store`]). `None` (default) = unbounded, today's behaviour.
    pub cache_max_entries: Option<usize>,
    /// Also retain every feasible evaluated point per net (in grid order,
    /// identical to `dse::sweep` output). Off by default: a campaign
    /// normally streams, keeping only the frontier. Implies no pruning —
    /// asking for every point means every point must simulate.
    pub keep_points: bool,
    /// Lower-bound early termination (on by default): skip simulating grid
    /// points whose admissible latency lower bound proves they cannot join
    /// the frontier. Lossless — frontiers are byte-identical either way;
    /// `false` (CLI `--no-prune`) forces every point to simulate.
    pub prune: bool,
    /// Which admissible lower bound gates the pruning (CLI `--bound`).
    /// Default [`BoundKind::Max`] — the tightest of the family; the
    /// occupancy / critical-path restrictions exist as A/B escape hatches
    /// (every kind is lossless, they differ only in skip rate).
    pub bound: BoundKind,
    /// Simulate each net's compiled units in ascending lower-bound order
    /// (on by default): likely dominators enter the frontier first, which
    /// maximizes [`NetOutcome::skipped_by_bound`] under pruning. Purely a
    /// scheduling heuristic — frontiers are byte-identical in any order —
    /// and inert when `prune` is off.
    pub order_by_bound: bool,
    /// Abort the whole run on the first *error*- or *panicked*-classified
    /// unit (invalid swept config, poisoned cache slot, dead worker),
    /// returning that unit's diagnostic as the campaign error — the CI
    /// co-design-gate mode. Infeasible tilings and bound-skips never
    /// trigger it. Off by default.
    pub fail_fast: bool,
    /// Append every completed unit's terminal outcome to this crash-safe
    /// resume journal (CLI `--journal`; see [`journal`]). `None` (default)
    /// journals nothing.
    pub journal: Option<PathBuf>,
    /// Replay an existing journal at [`CampaignOptions::journal`] before
    /// running (CLI `--resume`): journaled units are folded into the
    /// result without re-resolving or re-simulating, an absent journal is
    /// a fresh start, and a spec-fingerprint mismatch refuses loudly.
    /// Ignored without a journal path.
    pub resume: bool,
    /// Run the static pre-flight lint (`analysis::passes`) before anything
    /// else (on by default; CLI `--no-preflight` turns it off). The
    /// pre-flight rejects exactly the specs the plain validation gate
    /// rejects — as a full diagnostic report instead of the first bare
    /// error — and is observation-only: clean-lint campaigns produce
    /// byte-identical results with it on or off, at any thread count
    /// (property-tested).
    pub preflight: bool,
}

impl Default for CampaignOptions {
    fn default() -> Self {
        Self {
            threads: 0,
            cache_dir: None,
            cache_max_entries: None,
            keep_points: false,
            prune: true,
            bound: BoundKind::Max,
            order_by_bound: true,
            fail_fast: false,
            journal: None,
            resume: false,
            preflight: true,
        }
    }
}

/// Per-workload outcome.
#[derive(Debug, Clone)]
pub struct NetOutcome {
    pub net: String,
    /// Name of the base config this net's grid was expanded around —
    /// provenance for heterogeneous portfolios.
    pub base: String,
    /// The axes this net actually swept (its override, or the campaign's).
    pub axes: SweepAxes,
    /// Pareto frontier, ordered by (latency, cost, grid index) — byte-
    /// identical to `dse::pareto(dse::sweep(..))` for the same grid.
    pub frontier: Vec<DesignPoint>,
    /// All feasible points in grid order (empty unless
    /// [`CampaignOptions::keep_points`]).
    pub points: Vec<DesignPoint>,
    /// Grid points evaluated (the full grid). Always equals
    /// `feasible + infeasible + errors + panics + skipped_by_bound`.
    pub evaluated: usize,
    /// Points that compiled and simulated.
    pub feasible: usize,
    /// Structurally infeasible tilings — genuine holes in the grid.
    pub infeasible: usize,
    /// Evaluations that failed for non-structural reasons (invalid swept
    /// config). Never folded into `infeasible`.
    pub errors: usize,
    /// First error diagnostic, for the report.
    pub error_sample: Option<String>,
    /// Units whose worker panicked (resolve or simulate). Contained per
    /// unit by the pool — counted and sampled like errors, kept separate
    /// because a panic is a harness defect, not a sweep defect.
    pub panics: usize,
    /// First panic diagnostic, for the report.
    pub panic_sample: Option<String>,
    /// The bound kind this net was pruned with ([`CampaignOptions::bound`]
    /// — identical across nets of one run; carried per net so a serialized
    /// outcome stays self-describing).
    pub bound: BoundKind,
    /// Grid points whose latency lower bound proved they could not join
    /// the frontier — compiled (or cache-resolved) but never simulated.
    /// Always `skipped_by_occupancy + skipped_by_critical_path`.
    pub skipped_by_bound: usize,
    /// Skips the occupancy bound alone would have produced: at skip time
    /// the frontier already refused the candidate at its occupancy bound.
    pub skipped_by_occupancy: usize,
    /// Skips that *needed* the critical-path bound: the occupancy bound
    /// was still admissible when the tighter bound refused the candidate.
    /// Zero when running with [`BoundKind::Occupancy`].
    pub skipped_by_critical_path: usize,
    /// Feasible points dominated on arrival at the frontier.
    pub dominated: usize,
    /// Former frontier members evicted by later points.
    pub pruned: usize,
    /// Compiler invocations for this net (0 on a warm disk cache).
    pub compiles: u64,
    /// Structural keys served from the disk tier.
    pub disk_hits: u64,
    /// Keys answered "infeasible" from a persisted negative record (zero
    /// tiling attempts).
    pub neg_hits: u64,
    /// Probes served from the in-memory tier.
    pub mem_hits: u64,
    /// Corrupted/stale disk entries rejected.
    pub rejected: u64,
    /// Disk-tier I/O read failures (other than "entry absent").
    pub read_errors: u64,
}

/// Result of one campaign run.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub nets: Vec<NetOutcome>,
    /// Grid points summed across the per-net grids (= total units; with a
    /// heterogeneous portfolio the per-net sizes live in
    /// [`NetOutcome::evaluated`]).
    pub grid_points: usize,
    /// Worker threads actually used.
    pub threads: usize,
    /// Compiler invocations across all nets — zero on a warm disk cache.
    pub compiles: u64,
    pub disk_hits: u64,
    pub neg_hits: u64,
    pub mem_hits: u64,
    pub rejected_entries: u64,
    pub read_errors: u64,
    /// The bound kind the run pruned with ([`CampaignOptions::bound`]).
    pub bound: BoundKind,
    /// Units skipped by lower-bound pruning across all nets.
    pub skipped_by_bound: usize,
    /// Non-structural evaluation failures across all nets.
    pub errors: usize,
    /// Units whose worker panicked, across all nets (contained per unit).
    pub panics: usize,
}

impl CampaignResult {
    /// Feasible evaluations across all workloads.
    pub fn total_feasible(&self) -> usize {
        self.nets.iter().map(|n| n.feasible).sum()
    }

    /// Units evaluated (sum of the per-net grid sizes).
    pub fn total_units(&self) -> usize {
        self.grid_points
    }
}

/// Phase-1 result of one (net, grid point) unit: its compiled artifact
/// plus the bound-and-prune inputs, or its terminal classification.
enum Resolved {
    Compiled {
        compiled: std::sync::Arc<crate::compiler::CompiledNet>,
        /// The configured-kind bound the pruning gate queries.
        bound: u64,
        /// The occupancy component, kept separately for skip provenance:
        /// a skip the frontier would also refuse at `occ_bound` is an
        /// occupancy skip; one it would admit needed the critical path.
        occ_bound: u64,
        cost: f64,
    },
    Infeasible,
    Error(String),
    /// The unit's phase-1 worker panicked (contained by the pool), or the
    /// journal replayed a panic recorded by the interrupted run.
    Panicked(String),
    /// Journal-replayed feasible unit (marker): the point itself is
    /// reconstructed from the journal's persisted latency and folded into
    /// the frontier in append order, without re-resolving or
    /// re-simulating.
    ReplayedFeasible,
    /// Journal-replayed bound-skip: stays skipped on resume.
    ReplayedSkipped { by_occupancy: bool },
    /// Fail-fast cancellation marker: the run is aborting, this unit was
    /// never classified. Only produced when `fail_fast` is set, and a run
    /// that produced any is guaranteed to abort (the flag is only raised
    /// by a real error).
    Cancelled,
}

/// Classified phase-2 result of one compiled unit.
enum UnitOutcome {
    Feasible(DesignPoint),
    /// Skipped; `by_occupancy` records whether the occupancy bound alone
    /// would have refused the candidate at that moment.
    SkippedByBound { by_occupancy: bool },
}

/// Lock with poison recovery: a worker that panicked while *reading* a
/// frontier (the only lock use off the coordinating thread) poisons the
/// mutex without ever leaving the frontier half-mutated — every mutation
/// happens in one `insert_with_seq` call on the coordinating thread — so
/// the data is still consistent and the campaign keeps going instead of
/// cascading one dead unit into a crashed run.
fn lock_recovered<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Fingerprint of everything that determines a campaign's per-unit
/// outcomes, decomposed into four independently hashed
/// [`journal::SpecParts`]: each workload's serialized net (`nets`), the
/// effective base configs (`base`), the axis specs (`axes`), and the
/// result-relevant options — bound kind, effective pruning, evaluation
/// order, point retention (`options`). Thread count and cache settings
/// are deliberately excluded — they may legitimately differ between a
/// run and its resume. Journals refuse to replay across differing
/// combined fingerprints, and because the parts are persisted in the
/// header, the refusal names which part changed.
fn spec_parts(spec: &CampaignSpec, opts: &CampaignOptions, prune: bool) -> journal::SpecParts {
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};
    let mut nets = DefaultHasher::new();
    let mut base = DefaultHasher::new();
    let mut axes = DefaultHasher::new();
    for ni in 0..spec.workloads.len() {
        crate::graph::graph_to_json(&spec.workloads[ni].net).hash(&mut nets);
        spec.base_of(ni).to_json().hash(&mut base);
        spec.axes_of(ni).to_json().to_string_compact().hash(&mut axes);
    }
    let mut options = DefaultHasher::new();
    opts.bound.key().hash(&mut options);
    prune.hash(&mut options);
    opts.order_by_bound.hash(&mut options);
    opts.keep_points.hash(&mut options);
    journal::SpecParts {
        nets: nets.finish(),
        base: base.finish(),
        axes: axes.finish(),
        options: options.finish(),
    }
}

/// Static pre-flight over a campaign spec: exactly the reject set of the
/// validation gate in [`run`] — empty portfolio, invalid base configs,
/// invalid nets — but reported through `analysis::passes`, so the bail
/// carries every problem as a coded diagnostic instead of the first bare
/// error. The Error-severity set mirrors `validate()` condition for
/// condition ("lint never lies", property-tested), which is what makes
/// the pre-flight observation-only: it rejects precisely the specs the
/// gate below would reject, just better.
pub fn preflight_report(spec: &CampaignSpec) -> crate::analysis::Report {
    use crate::analysis::passes;
    let mut report = crate::analysis::Report::default();
    if spec.workloads.is_empty() {
        report.push(crate::analysis::Diagnostic::error(
            "AVSM036",
            "campaign spec",
            "campaign needs at least one workload",
        ));
        return report;
    }
    report.extend(passes::lint_config(&spec.base));
    for w in &spec.workloads {
        report.extend(passes::lint_net(&w.net));
        if let Some(base) = &w.base {
            report.extend(passes::lint_config(base));
        }
    }
    report
}

/// Run a campaign: every workload x its grid in one two-phase fan-out
/// (resolve + bound, then simulate in bound order).
///
/// Dispatches to a monomorphized instantiation on whether telemetry
/// recording ([`crate::obs`]) is on — the simulator's `TRACED` idiom —
/// so the disabled engine contains no per-unit telemetry code at all.
/// Recording never changes results: frontiers are byte-identical with
/// telemetry on vs. off at any thread count, and the full report
/// byte-identical single-threaded (property-tested; under parallel
/// workers the skip counters race benignly either way).
pub fn run(spec: &CampaignSpec, opts: &CampaignOptions) -> Result<CampaignResult> {
    run_with_hooks(spec, opts, RunHooks::default())
}

/// Host hooks for embedding the campaign engine in a resident process
/// (the `serve` daemon). Everything here is optional; `run` passes the
/// default and behaves exactly as before.
#[derive(Default)]
pub struct RunHooks<'h> {
    /// Pre-built per-workload caches to use instead of opening fresh
    /// ones, index-aligned with `spec.workloads` (the run bails if the
    /// lengths differ). This is what makes the daemon's cache *resident*:
    /// the memory tier survives across requests, so a resubmitted job is
    /// compile-free. Report counters stay per-run — the engine snapshots
    /// each cache's [`CacheStats`] at start and reports deltas, so a
    /// long-lived cache's history never bleeds into a report (for fresh
    /// caches the snapshot is all zeros and the arithmetic is the
    /// identity, byte-for-byte).
    pub caches: Option<Vec<Arc<PersistentCache>>>,
    /// Called on the coordinating thread for each feasible design point,
    /// in completion order, with the workload's net name — the daemon's
    /// live frontier stream. Journal-replayed points are delivered too
    /// (before any fresh ones), so a resumed run streams its full set.
    pub on_point: Option<&'h mut dyn FnMut(&str, &DesignPoint)>,
}

/// [`run`] with [`RunHooks`] — the resident-daemon entry point.
pub fn run_with_hooks(
    spec: &CampaignSpec,
    opts: &CampaignOptions,
    hooks: RunHooks,
) -> Result<CampaignResult> {
    if crate::obs::enabled() {
        run_campaign::<true>(spec, opts, hooks)
    } else {
        run_campaign::<false>(spec, opts, hooks)
    }
}

/// One per-unit telemetry site: a tagged span in the recording
/// instantiation, an inert guard (no clock read, no lock) otherwise.
#[inline]
fn unit_span<const OBS: bool>(kind: &'static str, net: &str, unit: usize) -> crate::obs::SpanGuard {
    if OBS {
        crate::obs::span_tagged(kind, net, unit as u64)
    } else {
        crate::obs::SpanGuard::inactive()
    }
}

fn run_campaign<const OBS: bool>(
    spec: &CampaignSpec,
    opts: &CampaignOptions,
    mut hooks: RunHooks,
) -> Result<CampaignResult> {
    // On-by-default static pre-flight (`--no-preflight` opts out): same
    // reject set as the plain validation gate below, but the refusal is a
    // full lint report — every problem, with stable codes and sites.
    if opts.preflight {
        let report = preflight_report(spec);
        if report.has_errors() {
            bail!("campaign pre-flight failed:\n{}", report.render_text());
        }
    }
    if spec.workloads.is_empty() {
        bail!("campaign needs at least one workload");
    }
    spec.base.validate()?;
    for w in &spec.workloads {
        w.net.validate()?;
        if let Some(base) = &w.base {
            base.validate()?;
        }
    }

    // Per-net grids: each workload expands its own effective base x axes
    // (identical across nets for a homogeneous portfolio). Units are laid
    // out net-major: net ni owns units offsets[ni]..offsets[ni + 1].
    let n_nets = spec.workloads.len();
    let grids: Vec<Vec<SystemConfig>> = (0..n_nets)
        .map(|ni| dse::expand_configs(spec.base_of(ni), spec.axes_of(ni)))
        .collect();
    let mut offsets = vec![0usize; n_nets + 1];
    for ni in 0..n_nets {
        offsets[ni + 1] = offsets[ni] + grids[ni].len();
    }
    let jobs = offsets[n_nets];
    let threads = pool::resolve_threads(opts.threads, jobs);
    let locate = |u: usize| -> (usize, usize) {
        let ni = offsets.partition_point(|&o| o <= u) - 1;
        (ni, u - offsets[ni])
    };

    let caches: Vec<Arc<PersistentCache>> = match hooks.caches.take() {
        Some(injected) => {
            if injected.len() != n_nets {
                bail!(
                    "RunHooks supplied {} caches for {} workloads",
                    injected.len(),
                    n_nets
                );
            }
            injected
        }
        None => spec
            .workloads
            .iter()
            .map(|_| {
                PersistentCache::with_max_entries(
                    dse::DSE_COMPILE_OPTS,
                    opts.cache_dir.clone(),
                    opts.cache_max_entries,
                )
                .map(Arc::new)
            })
            .collect::<Result<_>>()?,
    };
    // Counters are reported as deltas against this snapshot, so injected
    // resident caches attribute exactly this run's work (for fresh caches
    // the snapshot is zero and nothing changes).
    let start_stats: Vec<CacheStats> = caches.iter().map(|c| c.stats()).collect();
    let mut on_point = hooks.on_point.take();

    let prune = opts.prune && !opts.keep_points;

    // Crash-safe resume journal: on resume, replay the interrupted run's
    // completed units (refusing loudly on a spec mismatch); otherwise
    // start a fresh journal. `replayed[u]` short-circuits unit `u` in
    // both phases below.
    let mut journal: Option<journal::Journal> = None;
    let mut replay_order: Vec<(usize, journal::UnitRecord)> = Vec::new();
    if let Some(path) = &opts.journal {
        let parts = spec_parts(spec, opts, prune);
        let fp = parts.combined();
        if opts.resume {
            let (j, recs) = journal::Journal::resume_with_parts(path, fp, Some(&parts), jobs)?;
            journal = Some(j);
            replay_order = recs;
        } else {
            journal = Some(journal::Journal::create_with_parts(path, fp, Some(&parts), jobs)?);
        }
    }
    let mut replayed: Vec<Option<&journal::UnitRecord>> = vec![None; jobs];
    for (u, rec) in &replay_order {
        replayed[*u] = Some(rec);
    }

    // Phase 1 — resolve every unit's compiled artifact (memory → disk →
    // compile) and its admissible lower bound. One classifier shared with
    // `dse::evaluate_outcome`: invalid swept configs and poisoned cache
    // slots are errors; a post-validation cache failure is structural
    // tiling infeasibility (possibly replayed from a persisted negative
    // record). A worker that panics is contained by the pool and comes
    // back as a structured `JobDied`, classified `Panicked` for its unit
    // alone. Under fail_fast the first error raises a flag that lets the
    // remaining workers bail out cheaply — the run aborts either way.
    let cancelled = std::sync::atomic::AtomicBool::new(false);
    let resolved: Vec<Resolved> = pool::parallel_map(jobs, opts.threads, |u| {
        use std::sync::atomic::Ordering;
        if opts.fail_fast && cancelled.load(Ordering::Relaxed) {
            return Resolved::Cancelled;
        }
        let (ni, ci) = locate(u);
        let sys = &grids[ni][ci];
        let mut span = unit_span::<OBS>("resolve", &spec.workloads[ni].net.name, u);
        if let Some(rec) = replayed[u] {
            use journal::UnitRecord as R;
            span.set_outcome("replayed");
            return match rec {
                R::Feasible { .. } => Resolved::ReplayedFeasible,
                R::Infeasible => Resolved::Infeasible,
                R::Error { diag } => Resolved::Error(diag.clone()),
                R::Panicked { diag } => Resolved::Panicked(diag.clone()),
                R::Skipped { by_occupancy } => {
                    Resolved::ReplayedSkipped { by_occupancy: *by_occupancy }
                }
            };
        }
        let net = &spec.workloads[ni].net;
        match dse::resolve_classified(net, sys, &sys.name, || {
            caches[ni].get_or_compile(net, sys)
        }) {
            Ok(compiled) => {
                // The occupancy component is computed even when the run
                // prunes on another kind — it is what attributes each
                // skip to "occupancy would have sufficed" vs "needed the
                // critical path" in the report.
                let (bound, occ_bound, cost) = if prune {
                    let _bound_span = unit_span::<OBS>("bound", &spec.workloads[ni].net.name, u);
                    let occ = crate::compiler::occupancy_lower_bound(&compiled, sys);
                    let bound = match opts.bound {
                        BoundKind::Occupancy => occ,
                        BoundKind::CriticalPath => {
                            crate::compiler::critical_path_lower_bound(&compiled, sys)
                        }
                        BoundKind::Max => {
                            occ.max(crate::compiler::critical_path_lower_bound(&compiled, sys))
                        }
                    };
                    (bound, occ, dse::cost_proxy(sys))
                } else {
                    (0, 0, 0.0)
                };
                span.set_outcome("compiled");
                Resolved::Compiled { compiled, bound, occ_bound, cost }
            }
            Err(dse::EvalOutcome::Error { name, reason }) => {
                if opts.fail_fast {
                    cancelled.store(true, Ordering::Relaxed);
                }
                span.set_outcome("error");
                Resolved::Error(format!("{name}: {reason}"))
            }
            Err(_) => {
                span.set_outcome("infeasible");
                Resolved::Infeasible
            }
        }
    })
    .into_iter()
    .enumerate()
    .map(|(u, r)| {
        r.unwrap_or_else(|died| {
            let (ni, ci) = locate(u);
            Resolved::Panicked(format!("{}: {}", grids[ni][ci].name, died.message))
        })
    })
    .collect();

    // Fail-fast gate: abort on the first error or panic in deterministic
    // unit order, before any simulation runs.
    if opts.fail_fast {
        for (u, r) in resolved.iter().enumerate() {
            let reason = match r {
                Resolved::Error(reason) => Some(reason),
                Resolved::Panicked(reason) => Some(reason),
                _ => None,
            };
            if let Some(reason) = reason {
                let (ni, _) = locate(u);
                bail!(
                    "campaign aborted (fail_fast) on workload {:?}: {reason}",
                    spec.workloads[ni].net.name
                );
            }
        }
    }

    // Journal every fresh phase-1 terminal (replayed units are already on
    // disk; compiled units journal their phase-2 outcome as it arrives).
    if let Some(j) = journal.as_mut() {
        for (u, r) in resolved.iter().enumerate() {
            if replayed[u].is_some() {
                continue;
            }
            let rec = match r {
                Resolved::Infeasible => Some(journal::UnitRecord::Infeasible),
                Resolved::Error(d) => Some(journal::UnitRecord::Error { diag: d.clone() }),
                Resolved::Panicked(d) => {
                    Some(journal::UnitRecord::Panicked { diag: d.clone() })
                }
                _ => None,
            };
            if let Some(rec) = rec {
                j.append(u, &rec)?;
            }
        }
    }

    let mut infeasible = vec![0usize; n_nets];
    let mut errors = vec![0usize; n_nets];
    let mut error_sample: Vec<Option<String>> = vec![None; n_nets];
    let mut panics = vec![0usize; n_nets];
    let mut panic_sample: Vec<Option<String>> = vec![None; n_nets];
    for (u, r) in resolved.iter().enumerate() {
        let (ni, _) = locate(u);
        match r {
            Resolved::Infeasible => infeasible[ni] += 1,
            Resolved::Error(reason) => {
                errors[ni] += 1;
                if error_sample[ni].is_none() {
                    error_sample[ni] = Some(reason.clone());
                }
            }
            Resolved::Panicked(reason) => {
                panics[ni] += 1;
                if panic_sample[ni].is_none() {
                    panic_sample[ni] = Some(reason.clone());
                }
            }
            Resolved::Compiled { .. }
            | Resolved::ReplayedFeasible
            | Resolved::ReplayedSkipped { .. } => {}
            Resolved::Cancelled => unreachable!("cancellation implies a fail_fast abort"),
        }
    }

    // Phase-2 schedule: per net, the compiled units — in ascending
    // lower-bound order (grid order breaking ties, so the order is
    // deterministic) when ordering is on and pruning can profit from it,
    // in grid order otherwise.
    let mut eval_units: Vec<usize> = Vec::new();
    for ni in 0..n_nets {
        let start = eval_units.len();
        eval_units.extend(
            (offsets[ni]..offsets[ni + 1])
                .filter(|&u| matches!(resolved[u], Resolved::Compiled { .. })),
        );
        if prune && opts.order_by_bound {
            eval_units[start..].sort_by_key(|&u| match &resolved[u] {
                Resolved::Compiled { bound, .. } => (*bound, u),
                _ => unreachable!(),
            });
        }
    }

    // Frontiers live behind mutexes so *workers* can consult
    // `StreamingFrontier::admits` before paying for a simulation, while
    // insertions stay on the coordinating thread. keep_points asks for
    // every feasible point, so it implies no pruning.
    let frontiers: Vec<std::sync::Mutex<StreamingFrontier>> =
        (0..n_nets).map(|_| std::sync::Mutex::new(StreamingFrontier::new())).collect();
    let mut kept: Vec<Vec<Option<DesignPoint>>> = (0..n_nets)
        .map(|ni| if opts.keep_points { vec![None; grids[ni].len()] } else { Vec::new() })
        .collect();
    let mut feasible = vec![0usize; n_nets];
    let mut skipped_occ = vec![0usize; n_nets];
    let mut skipped_cp = vec![0usize; n_nets];

    // Fold the journal-replayed units in before phase 2 starts, in append
    // order — the interrupted run's completion order, which the journal
    // preserves for free. Frontier membership is order-independent, but
    // the streaming statistics (dominated-on-arrival, evictions) are not;
    // completion order replays them exactly, and pre-seeding the
    // frontiers lets the bound gate prune fresh units against the
    // replayed members exactly as the uninterrupted run would have.
    for (u, rec) in &replay_order {
        let (ni, ci) = locate(*u);
        match rec {
            journal::UnitRecord::Feasible { latency_ps } => {
                feasible[ni] += 1;
                let sys = &grids[ni][ci];
                let p = dse::point_from_latency(sys, sys.name.clone(), *latency_ps);
                if opts.keep_points {
                    kept[ni][ci] = Some(p.clone());
                }
                if let Some(cb) = on_point.as_mut() {
                    cb(&spec.workloads[ni].net.name, &p);
                }
                lock_recovered(&frontiers[ni]).insert_with_seq(p, ci);
            }
            journal::UnitRecord::Skipped { by_occupancy: true } => skipped_occ[ni] += 1,
            journal::UnitRecord::Skipped { by_occupancy: false } => skipped_cp[ni] += 1,
            // Terminal classes (infeasible / error / panicked) were
            // already counted from their `Resolved` markers above.
            _ => {}
        }
    }

    // Phase 2 — simulate the admitted units, streaming arrivals into the
    // per-net frontiers on the coordinating thread. A worker panic
    // arrives as `Err(JobDied)` for that unit alone; the journal append
    // happens here too (the collector is single-threaded, so appends
    // never interleave).
    let mut journal_error: Option<anyhow::Error> = None;
    let mut first_panic: Option<(usize, String)> = None;
    pool::for_each_completed(
        eval_units.len(),
        opts.threads,
        |j| {
            let u = eval_units[j];
            let (ni, ci) = locate(u);
            let sys = &grids[ni][ci];
            let Resolved::Compiled { compiled, bound, occ_bound, cost } = &resolved[u] else {
                unreachable!("eval schedule only lists compiled units");
            };
            if prune {
                let frontier = lock_recovered(&frontiers[ni]);
                if !frontier.admits(*bound, *cost) {
                    // Provenance, under the same lock (same frontier
                    // state): would the occupancy bound alone have
                    // refused this candidate too?
                    let by_occupancy = !frontier.admits(*occ_bound, *cost);
                    if OBS {
                        // A skip is a decision, not work: record it as a
                        // zero-ish-duration span so accounting still sees
                        // every compiled unit (simulate + skipped).
                        let mut s =
                            unit_span::<OBS>("skipped", &spec.workloads[ni].net.name, u);
                        s.set_outcome(if by_occupancy { "occupancy" } else { "critical_path" });
                    }
                    return UnitOutcome::SkippedByBound { by_occupancy };
                }
            }
            let mut span = unit_span::<OBS>("simulate", &spec.workloads[ni].net.name, u);
            let point = dse::evaluate_compiled(compiled, sys, sys.name.clone());
            span.set_outcome("feasible");
            UnitOutcome::Feasible(point)
        },
        |j, outcome| {
            let u = eval_units[j];
            let (ni, ci) = locate(u);
            let rec = match outcome {
                Ok(UnitOutcome::Feasible(p)) => {
                    feasible[ni] += 1;
                    let latency_ps = p.latency_ps;
                    if opts.keep_points {
                        kept[ni][ci] = Some(p.clone());
                    }
                    if let Some(cb) = on_point.as_mut() {
                        cb(&spec.workloads[ni].net.name, &p);
                    }
                    lock_recovered(&frontiers[ni]).insert_with_seq(p, ci);
                    journal::UnitRecord::Feasible { latency_ps }
                }
                Ok(UnitOutcome::SkippedByBound { by_occupancy }) => {
                    if by_occupancy {
                        skipped_occ[ni] += 1;
                    } else {
                        skipped_cp[ni] += 1;
                    }
                    journal::UnitRecord::Skipped { by_occupancy }
                }
                Err(died) => {
                    let diag = format!("{}: {}", grids[ni][ci].name, died.message);
                    panics[ni] += 1;
                    if panic_sample[ni].is_none() {
                        panic_sample[ni] = Some(diag.clone());
                    }
                    if first_panic.is_none() {
                        first_panic = Some((ni, diag.clone()));
                    }
                    journal::UnitRecord::Panicked { diag }
                }
            };
            if journal_error.is_none() {
                if let Some(j) = journal.as_mut() {
                    if let Err(e) = j.append(u, &rec) {
                        journal_error = Some(e);
                    }
                }
            }
        },
    );
    if let Some(e) = journal_error {
        return Err(e);
    }
    if opts.fail_fast {
        if let Some((ni, diag)) = first_panic {
            bail!(
                "campaign aborted (fail_fast) on workload {:?}: {diag}",
                spec.workloads[ni].net.name
            );
        }
    }

    let mut nets = Vec::with_capacity(n_nets);
    let (mut compiles, mut disk_hits, mut neg_hits, mut mem_hits) = (0u64, 0u64, 0u64, 0u64);
    let (mut rejected, mut read_errors) = (0u64, 0u64);
    for (ni, frontier) in frontiers.into_iter().enumerate() {
        let frontier = frontier.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        let stats = caches[ni].stats().delta_since(start_stats[ni]);
        compiles += stats.compiles;
        disk_hits += stats.disk_hits;
        neg_hits += stats.neg_hits;
        mem_hits += stats.mem_hits;
        rejected += stats.rejected;
        read_errors += stats.read_errors;
        let dominated = frontier.dominated();
        let pruned = frontier.pruned();
        nets.push(NetOutcome {
            net: spec.workloads[ni].net.name.clone(),
            base: spec.base_of(ni).name.clone(),
            axes: spec.axes_of(ni).clone(),
            evaluated: grids[ni].len(),
            feasible: feasible[ni],
            infeasible: infeasible[ni],
            errors: errors[ni],
            error_sample: error_sample[ni].take(),
            panics: panics[ni],
            panic_sample: panic_sample[ni].take(),
            bound: opts.bound,
            skipped_by_bound: skipped_occ[ni] + skipped_cp[ni],
            skipped_by_occupancy: skipped_occ[ni],
            skipped_by_critical_path: skipped_cp[ni],
            dominated,
            pruned,
            compiles: stats.compiles,
            disk_hits: stats.disk_hits,
            neg_hits: stats.neg_hits,
            mem_hits: stats.mem_hits,
            rejected: stats.rejected,
            read_errors: stats.read_errors,
            points: kept[ni].drain(..).flatten().collect(),
            frontier: frontier.into_points(),
        });
    }
    if OBS {
        // Cache-tier totals as telemetry counters, so one snapshot carries
        // both the latency histograms and the hit/miss composition.
        crate::obs::count("cache.compiles", compiles);
        crate::obs::count("cache.disk_hits", disk_hits);
        crate::obs::count("cache.neg_hits", neg_hits);
        crate::obs::count("cache.mem_hits", mem_hits);
        crate::obs::count("cache.rejected", rejected);
        crate::obs::count("cache.read_errors", read_errors);
        crate::obs::count(
            "cache.lock_steals",
            caches
                .iter()
                .zip(&start_stats)
                .map(|(c, s)| c.stats().delta_since(*s).lock_steals)
                .sum::<u64>(),
        );
    }
    let skipped_total = nets.iter().map(|n| n.skipped_by_bound).sum();
    Ok(CampaignResult {
        nets,
        grid_points: jobs,
        threads,
        compiles,
        disk_hits,
        neg_hits,
        mem_hits,
        rejected_entries: rejected,
        read_errors,
        bound: opts.bound,
        skipped_by_bound: skipped_total,
        errors: errors.iter().sum(),
        panics: panics.iter().sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn small_spec() -> CampaignSpec {
        CampaignSpec::homogeneous(
            vec![models::lenet(28), models::dilated_vgg_tiny()],
            SystemConfig::base_paper(),
            SweepAxes::new()
                .array_geometries(vec![(16, 32), (32, 64)])
                .nce_freqs_mhz(vec![125, 250]),
        )
    }

    #[test]
    fn empty_portfolio_is_rejected() {
        let spec = CampaignSpec::homogeneous(
            vec![],
            SystemConfig::base_paper(),
            SweepAxes::default(),
        );
        assert!(run(&spec, &CampaignOptions::default()).is_err());
    }

    #[test]
    fn frontier_matches_per_net_sweep_and_points_keep_grid_order() {
        let spec = small_spec();
        let opts = CampaignOptions { keep_points: true, ..Default::default() };
        let result = run(&spec, &opts).unwrap();
        assert_eq!(result.grid_points, 8, "2 nets x 4 grid points");
        assert_eq!(result.nets.len(), 2);
        for (ni, w) in spec.workloads.iter().enumerate() {
            let net = &w.net;
            let sweep = dse::sweep(net, &spec.base, &spec.axes);
            let batch = dse::pareto(&sweep);
            let got = &result.nets[ni];
            assert_eq!(got.net, net.name);
            // keep_points reproduces the sweep exactly, order included.
            assert_eq!(got.points.len(), sweep.len());
            for (a, b) in got.points.iter().zip(&sweep) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            }
            // Streaming frontier == batch frontier.
            assert_eq!(got.frontier.len(), batch.len());
            for (a, b) in got.frontier.iter().zip(&batch) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.sys, b.sys);
            }
            // Accounting adds up.
            assert_eq!(got.feasible, sweep.len());
            assert_eq!(
                got.frontier.len() + got.dominated + got.pruned,
                got.feasible,
                "every feasible point is on the frontier, dominated, or pruned"
            );
            assert_eq!(
                got.evaluated,
                got.feasible + got.infeasible + got.errors + got.skipped_by_bound,
                "every grid point must be classified exactly once"
            );
            // keep_points implies no pruning and this grid has no errors.
            assert_eq!((got.skipped_by_bound, got.errors, got.infeasible), (0, 0, 0));
        }
        // One compile per structural key per net: 2 geometries.
        assert_eq!(result.compiles, 4);
        assert_eq!(result.disk_hits, 0);
    }

    #[test]
    fn pruned_frontiers_are_byte_identical_to_unpruned_and_skip_points() {
        // Frequency-sparse grid: the fast points arrive first (axis order),
        // so low-frequency points' compute-roof lower bounds prove them
        // dominated before simulation. Pruning must change *only* the
        // skipped accounting — frontiers stay byte-identical to batch
        // sweep + pareto at any worker count.
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28), models::dilated_vgg_tiny()],
            SystemConfig::base_paper(),
            SweepAxes::new()
                .array_geometries(vec![(16, 32), (32, 64)])
                .nce_freqs_mhz(vec![500, 250, 125, 50]),
        );
        for threads in [1usize, 0] {
            let pruned =
                run(&spec, &CampaignOptions { threads, ..Default::default() }).unwrap();
            let unpruned = run(
                &spec,
                &CampaignOptions { threads, prune: false, ..Default::default() },
            )
            .unwrap();
            assert_eq!(unpruned.skipped_by_bound, 0);
            for (ni, w) in spec.workloads.iter().enumerate() {
                let net = &w.net;
                let batch = dse::sweep(net, &spec.base, &spec.axes);
                let batch_front = dse::pareto(&batch);
                for (tag, result) in [("pruned", &pruned), ("unpruned", &unpruned)] {
                    let got = &result.nets[ni];
                    assert_eq!(
                        got.frontier.len(),
                        batch_front.len(),
                        "{tag}/{threads}t: {}",
                        net.name
                    );
                    for (a, b) in got.frontier.iter().zip(&batch_front) {
                        assert_eq!(a.name, b.name, "{tag}/{threads}t");
                        assert_eq!(a.latency_ps, b.latency_ps, "{tag}/{threads}t: {}", a.name);
                        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}/{threads}t");
                        assert_eq!(a.sys, b.sys, "{tag}/{threads}t");
                    }
                    assert_eq!(
                        got.evaluated,
                        got.feasible + got.infeasible + got.errors + got.skipped_by_bound,
                        "{tag}/{threads}t: {}",
                        net.name
                    );
                }
            }
        }
        // Single-threaded (deterministic arrival order) the 50 MHz points
        // must actually be skipped: their compute occupancy alone exceeds
        // the 500 MHz member's whole makespan.
        let seq = run(&spec, &CampaignOptions { threads: 1, ..Default::default() }).unwrap();
        assert!(
            seq.skipped_by_bound > 0,
            "expected lower-bound pruning on a frequency-sparse grid"
        );
    }

    #[test]
    fn every_bound_kind_is_lossless_and_skip_split_adds_up() {
        // The A/B escape hatch: every BoundKind must produce frontiers
        // byte-identical to the unpruned batch sweep; only the skip
        // accounting may differ, and its occupancy/critical-path split
        // must always sum to the total.
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28), models::dilated_vgg_tiny()],
            SystemConfig::base_paper(),
            SweepAxes::new()
                .array_geometries(vec![(16, 32), (32, 64)])
                .nce_freqs_mhz(vec![500, 250, 125, 50]),
        );
        for kind in BoundKind::ALL {
            for threads in [1usize, 0] {
                let result = run(
                    &spec,
                    &CampaignOptions { threads, bound: kind, ..Default::default() },
                )
                .unwrap();
                assert_eq!(result.bound, kind);
                for (ni, w) in spec.workloads.iter().enumerate() {
                    let batch = dse::pareto(&dse::sweep(&w.net, &spec.base, &spec.axes));
                    let got = &result.nets[ni];
                    assert_eq!(got.bound, kind);
                    assert_eq!(
                        got.skipped_by_bound,
                        got.skipped_by_occupancy + got.skipped_by_critical_path,
                        "{kind}/{threads}t: skip split must cover every skip"
                    );
                    if kind == BoundKind::Occupancy {
                        assert_eq!(
                            got.skipped_by_critical_path, 0,
                            "occupancy-only runs cannot attribute skips to the critical path"
                        );
                    }
                    assert_eq!(got.frontier.len(), batch.len(), "{kind}/{threads}t");
                    for (a, b) in got.frontier.iter().zip(&batch) {
                        assert_eq!(a.name, b.name, "{kind}/{threads}t");
                        assert_eq!(a.latency_ps, b.latency_ps, "{kind}/{threads}t");
                        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{kind}/{threads}t");
                    }
                }
            }
        }
    }

    #[test]
    fn critical_path_bound_skips_deep_chain_points_occupancy_admits() {
        // The tentpole's acceptance shape: on a deep, low-parallelism
        // chain swept along a dense frequency axis, the occupancy bound
        // (max of two resource totals, both far below the chain's
        // makespan) admits points the critical-path bound proves
        // dominated. Single worker + bound ordering makes the skip sets
        // deterministic.
        let spec = CampaignSpec::homogeneous(
            vec![crate::testkit::deep_chain("deep_chain", 12, 16, 8)],
            SystemConfig::base_paper(),
            SweepAxes::new().nce_freqs_mhz(vec![1000, 800, 600, 500, 400, 300, 250, 200]),
        );
        let run_with = |kind: BoundKind| {
            run(
                &spec,
                &CampaignOptions { threads: 1, bound: kind, ..Default::default() },
            )
            .unwrap()
        };
        let occ = run_with(BoundKind::Occupancy);
        let max = run_with(BoundKind::Max);
        assert!(
            max.skipped_by_bound > occ.skipped_by_bound,
            "critical path must skip strictly more on the deep chain: occ {} vs max {}",
            occ.skipped_by_bound,
            max.skipped_by_bound
        );
        assert!(
            max.nets[0].skipped_by_critical_path > 0,
            "some skips must be attributed to the critical-path bound"
        );
        // Lossless either way: identical frontiers, identical to batch.
        let batch = dse::pareto(&dse::sweep(&spec.workloads[0].net, &spec.base, &spec.axes));
        for result in [&occ, &max] {
            let got = &result.nets[0];
            assert_eq!(got.frontier.len(), batch.len());
            for (a, b) in got.frontier.iter().zip(&batch) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
            }
            assert_eq!(
                got.evaluated,
                got.feasible + got.infeasible + got.errors + got.skipped_by_bound
            );
        }
    }

    #[test]
    fn invalid_swept_config_counts_as_error_not_infeasible() {
        // A 0 MHz point in the frequency axis is a broken sweep, not a
        // hole in the design space; it must surface in the error count
        // with a diagnostic instead of vanishing.
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().nce_freqs_mhz(vec![250, 0]),
        );
        let result = run(&spec, &CampaignOptions::default()).unwrap();
        let got = &result.nets[0];
        assert_eq!((got.feasible, got.errors, got.infeasible), (1, 1, 0));
        let sample = got.error_sample.as_deref().expect("error diagnostic retained");
        assert!(sample.contains("invalid configuration"), "{sample}");
        assert_eq!(result.errors, 1);
        // The feasible point still made the frontier.
        assert_eq!(got.frontier.len(), 1);
    }

    #[test]
    fn single_threaded_run_matches_parallel() {
        let spec = small_spec();
        let par = run(&spec, &CampaignOptions::default()).unwrap();
        let seq = run(
            &spec,
            &CampaignOptions { threads: 1, ..Default::default() },
        )
        .unwrap();
        for (a, b) in par.nets.iter().zip(&seq.nets) {
            assert_eq!(a.frontier.len(), b.frontier.len());
            for (x, y) in a.frontier.iter().zip(&b.frontier) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.latency_ps, y.latency_ps);
            }
        }
    }

    #[test]
    fn heterogeneous_workloads_use_their_own_base_and_axes() {
        // Each net gets its own accelerator design space; the per-net
        // results must match what an independent per-net sweep over that
        // same space produces, and the provenance fields must say whose
        // grid each net swept.
        let mut embedded = SystemConfig::base_paper();
        embedded.name = "embedded".into();
        embedded.nce.ifm_buffer_kib = 256;
        let spec = CampaignSpec {
            workloads: vec![
                WorkloadSpec::new(models::lenet(28)),
                WorkloadSpec::new(models::dilated_vgg_tiny())
                    .with_base(embedded.clone())
                    .with_axes(
                        SweepAxes::new()
                            .array_geometries(vec![(16, 32), (32, 64), (64, 64)]),
                    ),
            ],
            base: SystemConfig::base_paper(),
            axes: SweepAxes::new().nce_freqs_mhz(vec![125, 250]),
        };
        let opts = CampaignOptions { keep_points: true, ..Default::default() };
        let result = run(&spec, &opts).unwrap();
        assert_eq!(result.grid_points, 2 + 3, "heterogeneous grids sum");
        assert_eq!(result.nets[0].evaluated, 2);
        assert_eq!(result.nets[1].evaluated, 3);
        assert_eq!(result.nets[0].base, "base_paper_virtex7");
        assert_eq!(result.nets[1].base, "embedded");
        assert_eq!(result.nets[1].axes, *spec.axes_of(1));
        for ni in 0..2 {
            let sweep = dse::sweep(&spec.workloads[ni].net, spec.base_of(ni), spec.axes_of(ni));
            let batch = dse::pareto(&sweep);
            let got = &result.nets[ni];
            assert_eq!(got.points.len(), sweep.len(), "net {ni}");
            for (a, b) in got.points.iter().zip(&sweep) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.sys, b.sys);
            }
            assert_eq!(got.frontier.len(), batch.len(), "net {ni}");
            for (a, b) in got.frontier.iter().zip(&batch) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            }
        }
        // The override net's points actually carry the embedded base.
        assert!(result.nets[1].points.iter().all(|p| p.sys.nce.ifm_buffer_kib == 256));
    }

    #[test]
    fn fail_fast_aborts_on_error_but_not_on_infeasible() {
        // An invalid swept config (0 MHz) must abort a fail-fast run with
        // the unit's diagnostic...
        let broken = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().nce_freqs_mhz(vec![250, 0]),
        );
        let err = run(
            &broken,
            &CampaignOptions { fail_fast: true, ..Default::default() },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fail_fast"), "{msg}");
        assert!(msg.contains("invalid configuration"), "{msg}");
        // ...while the default policy completes and counts it.
        assert!(run(&broken, &CampaignOptions::default()).is_ok());

        // Structural infeasibility is a legitimate hole, never an abort:
        // tiny buffers cannot fit the 512-px rows, yet fail_fast passes.
        let mut tiny = SystemConfig::base_paper();
        tiny.nce.ifm_buffer_kib = 1;
        tiny.nce.weight_buffer_kib = 1;
        tiny.nce.ofm_buffer_kib = 1;
        let infeasible = CampaignSpec::homogeneous(
            vec![models::dilated_vgg(512, 4, 16)],
            tiny,
            SweepAxes::default(),
        );
        let result = run(
            &infeasible,
            &CampaignOptions { fail_fast: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(result.nets[0].infeasible, 1);
    }

    #[test]
    fn bound_ordering_maximizes_skips_and_keeps_frontiers_identical() {
        // Ascending-frequency grid: in grid order the slowest point
        // arrives first, joins the frontier, and is evicted over and over
        // — nothing gets skipped. Ordered by ascending lower bound the
        // fastest point simulates first and dominates the rest of the
        // axis outright.
        // Same nets + frequency set as the proven-to-skip sparse-frontier
        // test above, just enumerated ascending.
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28), models::dilated_vgg_tiny()],
            SystemConfig::base_paper(),
            SweepAxes::new().nce_freqs_mhz(vec![50, 64, 80, 100, 125, 250, 500, 1000]),
        );
        let ordered = run(
            &spec,
            &CampaignOptions { threads: 1, ..Default::default() },
        )
        .unwrap();
        let unordered = run(
            &spec,
            &CampaignOptions { threads: 1, order_by_bound: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            unordered.skipped_by_bound, 0,
            "ascending arrival order never skips: each point out-runs the members"
        );
        assert!(
            ordered.skipped_by_bound > 0,
            "bound ordering must recover the skips on the ascending grid"
        );
        // Ordering is a scheduling heuristic only: frontiers identical.
        for (ni, w) in spec.workloads.iter().enumerate() {
            let batch = dse::pareto(&dse::sweep(&w.net, &spec.base, &spec.axes));
            for result in [&ordered, &unordered] {
                let got = &result.nets[ni];
                assert_eq!(got.frontier.len(), batch.len(), "{}", w.net.name);
                for (a, b) in got.frontier.iter().zip(&batch) {
                    assert_eq!(a.name, b.name);
                    assert_eq!(a.latency_ps, b.latency_ps);
                    assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                }
                assert_eq!(
                    got.evaluated,
                    got.feasible + got.infeasible + got.errors + got.skipped_by_bound
                );
            }
        }
    }

    fn test_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("avsm_campaign_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn injected_panic_is_contained_and_surviving_units_match_exclusion() {
        use crate::testkit::faults::{self, FaultKind};
        // Geometry-only axes: every unit is its own structural key, so the
        // dead unit's poisoned cache slot cannot leak into any other unit.
        let dir = test_dir("panic");
        let geoms = vec![(8u32, 16u32), (16, 32), (32, 64)];
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().array_geometries(geoms.clone()),
        );
        let result = {
            // threads: 1 makes unit 0 the first (and only) store read the
            // armed failpoint sees, so exactly that unit dies.
            let _g = faults::arm("store.read", &dir, FaultKind::Panic, 1);
            run(
                &spec,
                &CampaignOptions {
                    threads: 1,
                    cache_dir: Some(dir.clone()),
                    ..Default::default()
                },
            )
            .unwrap()
        };
        let got = &result.nets[0];
        assert_eq!(got.panics, 1, "exactly the faulted unit died");
        let sample = got.panic_sample.as_deref().expect("panic diagnostic retained");
        assert!(sample.contains("injected panic"), "{sample}");
        assert_eq!(
            got.evaluated,
            got.feasible + got.infeasible + got.errors + got.panics + got.skipped_by_bound,
            "the panicked unit stays classified exactly once"
        );
        assert_eq!(result.panics, 1);

        // The surviving units' frontier is byte-identical to a clean
        // campaign over the same grid with the dead unit's geometry
        // excluded — one panic subtracts one unit, nothing else.
        let excluded = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().array_geometries(geoms[1..].to_vec()),
        );
        let clean =
            run(&excluded, &CampaignOptions { threads: 1, ..Default::default() }).unwrap();
        let want = &clean.nets[0];
        assert_eq!(got.frontier.len(), want.frontier.len());
        for (a, b) in got.frontier.iter().zip(&want.frontier) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.latency_ps, b.latency_ps);
            assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            assert_eq!(a.throughput.to_bits(), b.throughput.to_bits());
            assert_eq!(a.sys, b.sys);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fail_fast_aborts_on_injected_panic() {
        use crate::testkit::faults::{self, FaultKind};
        let dir = test_dir("ff_panic");
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().array_geometries(vec![(16, 32), (32, 64)]),
        );
        let _g = faults::arm("store.read", &dir, FaultKind::Panic, 1);
        let err = run(
            &spec,
            &CampaignOptions {
                threads: 1,
                cache_dir: Some(dir.clone()),
                fail_fast: true,
                ..Default::default()
            },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fail_fast"), "{msg}");
        assert!(msg.contains("injected panic"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn poisoned_frontier_lock_is_recovered() {
        let m = std::sync::Mutex::new(StreamingFrontier::new());
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("worker died while holding the frontier");
        }));
        assert!(m.is_poisoned(), "the panic above must poison the mutex");
        // The campaign keeps going: reads and inserts still work.
        lock_recovered(&m).insert_with_seq(
            dse::point_from_latency(&SystemConfig::base_paper(), "p".into(), 100),
            0,
        );
        assert_eq!(lock_recovered(&m).len(), 1);
    }

    /// Everything two campaign results must agree on for the resume
    /// contract — all report-visible fields except the cache statistics,
    /// which legitimately differ (a resumed run compiles less).
    fn assert_same_outcomes(a: &CampaignResult, b: &CampaignResult, tag: &str) {
        assert_eq!(a.grid_points, b.grid_points, "{tag}");
        assert_eq!(a.skipped_by_bound, b.skipped_by_bound, "{tag}");
        assert_eq!(a.errors, b.errors, "{tag}");
        assert_eq!(a.panics, b.panics, "{tag}");
        assert_eq!(a.nets.len(), b.nets.len(), "{tag}");
        for (x, y) in a.nets.iter().zip(&b.nets) {
            assert_eq!(x.net, y.net, "{tag}");
            assert_eq!(
                (x.evaluated, x.feasible, x.infeasible, x.errors, x.panics),
                (y.evaluated, y.feasible, y.infeasible, y.errors, y.panics),
                "{tag}: {}",
                x.net
            );
            assert_eq!(
                (x.skipped_by_bound, x.skipped_by_occupancy, x.skipped_by_critical_path),
                (y.skipped_by_bound, y.skipped_by_occupancy, y.skipped_by_critical_path),
                "{tag}: {}",
                x.net
            );
            assert_eq!((x.dominated, x.pruned), (y.dominated, y.pruned), "{tag}: {}", x.net);
            assert_eq!(x.error_sample, y.error_sample, "{tag}");
            assert_eq!(x.panic_sample, y.panic_sample, "{tag}");
            assert_eq!(x.frontier.len(), y.frontier.len(), "{tag}: {}", x.net);
            for (p, q) in x.frontier.iter().zip(&y.frontier) {
                assert_eq!(p.name, q.name, "{tag}");
                assert_eq!(p.latency_ps, q.latency_ps, "{tag}: {}", p.name);
                assert_eq!(p.cost.to_bits(), q.cost.to_bits(), "{tag}: {}", p.name);
                assert_eq!(
                    p.throughput.to_bits(),
                    q.throughput.to_bits(),
                    "{tag}: {}",
                    p.name
                );
                assert_eq!(p.sys, q.sys, "{tag}: {}", p.name);
            }
        }
    }

    #[test]
    fn resumed_campaign_reproduces_the_uninterrupted_result() {
        // Interrupt a journaled run after every possible number of
        // completed units — with and without a torn final line — and
        // resume: every report-visible field must match the uninterrupted
        // run, including the order-sensitive dominated/pruned statistics
        // and the skip attribution on this pruning-heavy grid.
        let dir = test_dir("resume");
        let journal_path = dir.join("run.jsonl");
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new()
                .array_geometries(vec![(16, 32), (32, 64)])
                .nce_freqs_mhz(vec![500, 250, 125, 50]),
        );
        let opts = CampaignOptions {
            threads: 1,
            journal: Some(journal_path.clone()),
            ..Default::default()
        };
        let full = run(&spec, &opts).unwrap();
        let journal_text = std::fs::read_to_string(&journal_path).unwrap();
        let lines: Vec<&str> = journal_text.split_inclusive('\n').collect();
        assert_eq!(lines.len(), 1 + 8, "header + one record per unit");
        assert!(full.skipped_by_bound > 0, "the grid must exercise skip replay");

        let resume_opts = CampaignOptions { resume: true, ..opts.clone() };
        for keep in 0..lines.len() {
            for tear in [false, true] {
                let mut partial: String = lines[..=keep].concat();
                if tear {
                    // A crash mid-append: half of the next record, no
                    // terminating newline. Resume must drop and heal it.
                    let Some(next) = lines.get(keep + 1) else { continue };
                    partial.push_str(&next[..next.len() / 2]);
                }
                std::fs::write(&journal_path, &partial).unwrap();
                let resumed = run(&spec, &resume_opts).unwrap();
                assert_same_outcomes(&full, &resumed, &format!("keep {keep} tear {tear}"));
            }
        }

        // A fully-journaled resume replays everything: zero compilations.
        std::fs::write(&journal_path, &journal_text).unwrap();
        let resumed = run(&spec, &resume_opts).unwrap();
        assert_eq!(resumed.compiles, 0, "nothing left to re-resolve");
        assert_same_outcomes(&full, &resumed, "full journal");

        // --resume with no journal on disk is a fresh start, not an error.
        std::fs::remove_file(&journal_path).unwrap();
        let fresh = run(&spec, &resume_opts).unwrap();
        assert_same_outcomes(&full, &fresh, "absent journal");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_refuses_a_journal_from_a_different_spec() {
        let dir = test_dir("resume_mismatch");
        let journal_path = dir.join("run.jsonl");
        let spec = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().nce_freqs_mhz(vec![250, 125]),
        );
        let opts = CampaignOptions {
            threads: 1,
            journal: Some(journal_path.clone()),
            ..Default::default()
        };
        run(&spec, &opts).unwrap();

        // Same unit count, different grid: replaying would fabricate
        // results, so the fingerprint must refuse.
        let other = CampaignSpec::homogeneous(
            vec![models::lenet(28)],
            SystemConfig::base_paper(),
            SweepAxes::new().nce_freqs_mhz(vec![500, 50]),
        );
        let err = run(&other, &CampaignOptions { resume: true, ..opts }).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("different campaign spec"), "{msg}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
