//! Campaign engine: multi-workload co-design sweeps on one shared worker
//! pool, with streaming Pareto frontiers and a persistent compile cache.
//!
//! The paper's pitch is "design space exploration by a click of a button"
//! across *systems*: a co-design loop ranks one hardware configuration
//! grid against a whole portfolio of workloads (the way SMAUG evaluates
//! full-stack design points across several DNNs, and ANNETTE amortizes
//! per-platform model building across networks). [`crate::dse::sweep`]
//! covers one net; [`run`] covers the portfolio.
//!
//! # Execution model
//!
//! A campaign is `N` workloads x one [`SweepAxes`] grid around a base
//! [`SystemConfig`]. The grid is expanded **once** (deterministic axis
//! order, shared by every net) and the full `N x P` unit matrix fans out
//! over a single worker pool ([`pool`]) — workers do not idle at per-net
//! boundaries the way `N` back-to-back sweeps would. Each unit:
//!
//! 1. resolves its compiled artifact through its net's
//!    [`PersistentCache`] (memory → disk → compile; frequency-only
//!    config changes always share one compilation, exactly as in
//!    single-net DSE),
//! 2. simulates the point (AVSM fast path, traces off), and
//! 3. streams the resulting [`DesignPoint`] back to the coordinating
//!    thread, which folds it into that net's online
//!    [`StreamingFrontier`] — dominated points are dropped on arrival,
//!    so memory stays O(frontier + grid), not O(evaluations), and
//!    frontiers are live while the sweep still runs.
//!
//! Each point carries its grid-enumeration index as the frontier sequence
//! number, which makes the final per-net frontier **byte-identical** to
//! batch `dse::pareto(dse::sweep(..))` regardless of worker timing — the
//! equivalence the test suite enforces.
//!
//! # Bound-and-prune
//!
//! Before simulating a compiled unit, the worker computes the point's
//! **admissible latency lower bound**
//! ([`crate::compiler::latency_lower_bound`]: max of NCE and bus occupancy
//! at the candidate's actual clocks, one O(tasks) pass over the cached
//! graph, no simulation) and asks that net's frontier
//! [`StreamingFrontier::admits`] whether a point at `(bound, cost)` could
//! still join. A refusal means an existing member *strictly dominates*
//! every latency the candidate could realize, and strict dominance
//! survives later evictions — so skipping the simulation is **lossless**:
//! pruned frontiers are byte-identical to unpruned ones (property-tested),
//! only [`NetOutcome::skipped_by_bound`] changes. Which points get skipped
//! depends on arrival timing under parallelism (a conservative race: a
//! not-yet-inserted dominator just means one extra simulation), never the
//! result. [`CampaignOptions::prune`] (CLI `--no-prune`) is the escape
//! hatch; [`CampaignOptions::keep_points`] disables pruning implicitly
//! because it asks for every feasible point, not just the frontier.
//!
//! # Outcome classification
//!
//! Every unit resolves to exactly one of *feasible* (simulated),
//! *infeasible* (the tiler proved no legal tiling exists — a real hole in
//! the grid), *error* (invalid swept config — a defect in the sweep, never
//! conflated with infeasibility) or *skipped by bound*. The per-net
//! accounting satisfies `evaluated == feasible + infeasible + errors +
//! skipped_by_bound` and errors are surfaced with a sample diagnostic
//! instead of silently vanishing from the results.
//!
//! # Persistence model
//!
//! With [`CampaignOptions::cache_dir`] set, every successful compilation
//! is serialized (task graph + per-layer records + full [`CompileKey`])
//! into the directory via [`store`]; a later run — same process or a new
//! CLI invocation — resolves every structural key from disk and performs
//! **zero compilations** (assertable via [`CampaignResult::compiles`]).
//! Structurally *infeasible* keys are persisted too (negative records with
//! the tiler's diagnostic), so warm campaigns also perform zero tiling
//! attempts on the infeasible corners of a grid
//! ([`NetOutcome::neg_hits`]). Corrupted or stale entries of either kind
//! are detected (schema/key verification, task-graph validation),
//! rejected, recompiled and rewritten. Without a cache directory the
//! campaign still shares compilations in memory, per net, across the
//! whole grid.
//!
//! [`CompileKey`]: crate::compiler::CompileKey

pub mod frontier;
pub mod pool;
pub mod store;

pub use frontier::StreamingFrontier;
pub use store::PersistentCache;

use crate::config::SystemConfig;
use crate::dse::{self, DesignPoint, SweepAxes};
use crate::graph::DnnGraph;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// What to sweep: a portfolio of workloads against one config grid.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub nets: Vec<DnnGraph>,
    /// Base system; axes replace fields of this config (empty axes keep
    /// the base value), exactly as in [`dse::sweep`].
    pub base: SystemConfig,
    pub axes: SweepAxes,
}

/// Execution policy for [`run`].
#[derive(Debug, Clone)]
pub struct CampaignOptions {
    /// Worker threads; 0 (default) = one per available CPU, capped by the
    /// unit count.
    pub threads: usize,
    /// Directory for the persistent compile cache; `None` keeps the cache
    /// in memory only.
    pub cache_dir: Option<PathBuf>,
    /// Also retain every feasible evaluated point per net (in grid order,
    /// identical to `dse::sweep` output). Off by default: a campaign
    /// normally streams, keeping only the frontier. Implies no pruning —
    /// asking for every point means every point must simulate.
    pub keep_points: bool,
    /// Lower-bound early termination (on by default): skip simulating grid
    /// points whose admissible latency lower bound proves they cannot join
    /// the frontier. Lossless — frontiers are byte-identical either way;
    /// `false` (CLI `--no-prune`) forces every point to simulate.
    pub prune: bool,
}

impl Default for CampaignOptions {
    fn default() -> Self {
        Self { threads: 0, cache_dir: None, keep_points: false, prune: true }
    }
}

/// Per-workload outcome.
#[derive(Debug, Clone)]
pub struct NetOutcome {
    pub net: String,
    /// Pareto frontier, ordered by (latency, cost, grid index) — byte-
    /// identical to `dse::pareto(dse::sweep(..))` for the same grid.
    pub frontier: Vec<DesignPoint>,
    /// All feasible points in grid order (empty unless
    /// [`CampaignOptions::keep_points`]).
    pub points: Vec<DesignPoint>,
    /// Grid points evaluated (the full grid). Always equals
    /// `feasible + infeasible + errors + skipped_by_bound`.
    pub evaluated: usize,
    /// Points that compiled and simulated.
    pub feasible: usize,
    /// Structurally infeasible tilings — genuine holes in the grid.
    pub infeasible: usize,
    /// Evaluations that failed for non-structural reasons (invalid swept
    /// config). Never folded into `infeasible`.
    pub errors: usize,
    /// First error diagnostic, for the report.
    pub error_sample: Option<String>,
    /// Grid points whose latency lower bound proved they could not join
    /// the frontier — compiled (or cache-resolved) but never simulated.
    pub skipped_by_bound: usize,
    /// Feasible points dominated on arrival at the frontier.
    pub dominated: usize,
    /// Former frontier members evicted by later points.
    pub pruned: usize,
    /// Compiler invocations for this net (0 on a warm disk cache).
    pub compiles: u64,
    /// Structural keys served from the disk tier.
    pub disk_hits: u64,
    /// Keys answered "infeasible" from a persisted negative record (zero
    /// tiling attempts).
    pub neg_hits: u64,
    /// Probes served from the in-memory tier.
    pub mem_hits: u64,
    /// Corrupted/stale disk entries rejected.
    pub rejected: u64,
    /// Disk-tier I/O read failures (other than "entry absent").
    pub read_errors: u64,
}

/// Result of one campaign run.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub nets: Vec<NetOutcome>,
    /// Design points in the (shared) expanded grid.
    pub grid_points: usize,
    /// Worker threads actually used.
    pub threads: usize,
    /// Compiler invocations across all nets — zero on a warm disk cache.
    pub compiles: u64,
    pub disk_hits: u64,
    pub neg_hits: u64,
    pub mem_hits: u64,
    pub rejected_entries: u64,
    pub read_errors: u64,
    /// Units skipped by lower-bound pruning across all nets.
    pub skipped_by_bound: usize,
    /// Non-structural evaluation failures across all nets.
    pub errors: usize,
}

impl CampaignResult {
    /// Feasible evaluations across all workloads.
    pub fn total_feasible(&self) -> usize {
        self.nets.iter().map(|n| n.feasible).sum()
    }

    /// Units (workloads x grid points) evaluated.
    pub fn total_units(&self) -> usize {
        self.nets.len() * self.grid_points
    }
}

/// Classified result of one (net, grid point) unit.
enum UnitOutcome {
    Feasible(DesignPoint),
    Infeasible,
    Error(String),
    SkippedByBound,
}

/// Run a campaign: every workload x every grid point in one fan-out.
pub fn run(spec: &CampaignSpec, opts: &CampaignOptions) -> Result<CampaignResult> {
    if spec.nets.is_empty() {
        bail!("campaign needs at least one workload");
    }
    for net in &spec.nets {
        net.validate()?;
    }
    spec.base.validate()?;

    let configs = dse::expand_configs(&spec.base, &spec.axes);
    let n_nets = spec.nets.len();
    let n_cfg = configs.len();
    let jobs = n_nets * n_cfg;
    let threads = pool::resolve_threads(opts.threads, jobs);

    let caches: Vec<PersistentCache> = spec
        .nets
        .iter()
        .map(|_| PersistentCache::new(dse::DSE_COMPILE_OPTS, opts.cache_dir.clone()))
        .collect::<Result<_>>()?;

    // Frontiers live behind mutexes so *workers* can consult
    // `StreamingFrontier::admits` before paying for a simulation, while
    // insertions stay on the coordinating thread. keep_points asks for
    // every feasible point, so it implies no pruning.
    let prune = opts.prune && !opts.keep_points;
    let frontiers: Vec<std::sync::Mutex<StreamingFrontier>> =
        (0..n_nets).map(|_| std::sync::Mutex::new(StreamingFrontier::new())).collect();
    let mut kept: Vec<Vec<Option<DesignPoint>>> = (0..n_nets)
        .map(|_| if opts.keep_points { vec![None; n_cfg] } else { Vec::new() })
        .collect();
    let mut feasible = vec![0usize; n_nets];
    let mut infeasible = vec![0usize; n_nets];
    let mut errors = vec![0usize; n_nets];
    let mut error_sample: Vec<Option<String>> = vec![None; n_nets];
    let mut skipped = vec![0usize; n_nets];

    // Unit u covers net u / n_cfg at grid point u % n_cfg (net-major, so
    // one net's units are contiguous and its compile cache warms early).
    // Workers classify + evaluate; the coordinating thread streams
    // arrivals into the per-net frontiers.
    pool::for_each_completed(
        jobs,
        opts.threads,
        |u| {
            let (ni, ci) = (u / n_cfg, u % n_cfg);
            let sys = &configs[ci];
            // One classifier shared with `dse::evaluate_outcome`: invalid
            // swept configs and poisoned cache slots are errors; a
            // post-validation cache failure is structural tiling
            // infeasibility (possibly replayed from a persisted negative
            // record).
            let compiled = match dse::resolve_classified(&spec.nets[ni], sys, &sys.name, || {
                caches[ni].get_or_compile(&spec.nets[ni], sys)
            }) {
                Ok(c) => c,
                Err(dse::EvalOutcome::Error { name, reason }) => {
                    return UnitOutcome::Error(format!("{name}: {reason}"))
                }
                Err(_) => return UnitOutcome::Infeasible,
            };
            if prune {
                let bound = crate::compiler::latency_lower_bound(&compiled, sys);
                let admitted =
                    frontiers[ni].lock().unwrap().admits(bound, dse::cost_proxy(sys));
                if !admitted {
                    return UnitOutcome::SkippedByBound;
                }
            }
            UnitOutcome::Feasible(dse::evaluate_compiled(&compiled, sys, sys.name.clone()))
        },
        |u, outcome| {
            let (ni, ci) = (u / n_cfg, u % n_cfg);
            match outcome {
                UnitOutcome::Feasible(p) => {
                    feasible[ni] += 1;
                    if opts.keep_points {
                        kept[ni][ci] = Some(p.clone());
                    }
                    frontiers[ni].lock().unwrap().insert_with_seq(p, ci);
                }
                UnitOutcome::Infeasible => infeasible[ni] += 1,
                UnitOutcome::Error(reason) => {
                    errors[ni] += 1;
                    error_sample[ni].get_or_insert(reason);
                }
                UnitOutcome::SkippedByBound => skipped[ni] += 1,
            }
        },
    );

    let mut nets = Vec::with_capacity(n_nets);
    let (mut compiles, mut disk_hits, mut neg_hits, mut mem_hits) = (0u64, 0u64, 0u64, 0u64);
    let (mut rejected, mut read_errors) = (0u64, 0u64);
    for (ni, frontier) in frontiers.into_iter().enumerate() {
        let frontier = frontier.into_inner().unwrap();
        let cache = &caches[ni];
        compiles += cache.compiles();
        disk_hits += cache.disk_hits();
        neg_hits += cache.neg_hits();
        mem_hits += cache.mem_hits();
        rejected += cache.rejected();
        read_errors += cache.read_errors();
        let dominated = frontier.dominated();
        let pruned = frontier.pruned();
        nets.push(NetOutcome {
            net: spec.nets[ni].name.clone(),
            evaluated: n_cfg,
            feasible: feasible[ni],
            infeasible: infeasible[ni],
            errors: errors[ni],
            error_sample: error_sample[ni].take(),
            skipped_by_bound: skipped[ni],
            dominated,
            pruned,
            compiles: cache.compiles(),
            disk_hits: cache.disk_hits(),
            neg_hits: cache.neg_hits(),
            mem_hits: cache.mem_hits(),
            rejected: cache.rejected(),
            read_errors: cache.read_errors(),
            points: kept[ni].drain(..).flatten().collect(),
            frontier: frontier.into_points(),
        });
    }
    Ok(CampaignResult {
        nets,
        grid_points: n_cfg,
        threads,
        compiles,
        disk_hits,
        neg_hits,
        mem_hits,
        rejected_entries: rejected,
        read_errors,
        skipped_by_bound: skipped.iter().sum(),
        errors: errors.iter().sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn small_spec() -> CampaignSpec {
        CampaignSpec {
            nets: vec![models::lenet(28), models::dilated_vgg_tiny()],
            base: SystemConfig::base_paper(),
            axes: SweepAxes {
                array_geometries: vec![(16, 32), (32, 64)],
                nce_freqs_mhz: vec![125, 250],
                ..Default::default()
            },
        }
    }

    #[test]
    fn empty_portfolio_is_rejected() {
        let spec = CampaignSpec {
            nets: vec![],
            base: SystemConfig::base_paper(),
            axes: SweepAxes::default(),
        };
        assert!(run(&spec, &CampaignOptions::default()).is_err());
    }

    #[test]
    fn frontier_matches_per_net_sweep_and_points_keep_grid_order() {
        let spec = small_spec();
        let opts = CampaignOptions { keep_points: true, ..Default::default() };
        let result = run(&spec, &opts).unwrap();
        assert_eq!(result.grid_points, 4);
        assert_eq!(result.nets.len(), 2);
        for (ni, net) in spec.nets.iter().enumerate() {
            let sweep = dse::sweep(net, &spec.base, &spec.axes);
            let batch = dse::pareto(&sweep);
            let got = &result.nets[ni];
            assert_eq!(got.net, net.name);
            // keep_points reproduces the sweep exactly, order included.
            assert_eq!(got.points.len(), sweep.len());
            for (a, b) in got.points.iter().zip(&sweep) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            }
            // Streaming frontier == batch frontier.
            assert_eq!(got.frontier.len(), batch.len());
            for (a, b) in got.frontier.iter().zip(&batch) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.sys, b.sys);
            }
            // Accounting adds up.
            assert_eq!(got.feasible, sweep.len());
            assert_eq!(
                got.frontier.len() + got.dominated + got.pruned,
                got.feasible,
                "every feasible point is on the frontier, dominated, or pruned"
            );
            assert_eq!(
                got.evaluated,
                got.feasible + got.infeasible + got.errors + got.skipped_by_bound,
                "every grid point must be classified exactly once"
            );
            // keep_points implies no pruning and this grid has no errors.
            assert_eq!((got.skipped_by_bound, got.errors, got.infeasible), (0, 0, 0));
        }
        // One compile per structural key per net: 2 geometries.
        assert_eq!(result.compiles, 4);
        assert_eq!(result.disk_hits, 0);
    }

    #[test]
    fn pruned_frontiers_are_byte_identical_to_unpruned_and_skip_points() {
        // Frequency-sparse grid: the fast points arrive first (axis order),
        // so low-frequency points' compute-roof lower bounds prove them
        // dominated before simulation. Pruning must change *only* the
        // skipped accounting — frontiers stay byte-identical to batch
        // sweep + pareto at any worker count.
        let spec = CampaignSpec {
            nets: vec![models::lenet(28), models::dilated_vgg_tiny()],
            base: SystemConfig::base_paper(),
            axes: SweepAxes {
                array_geometries: vec![(16, 32), (32, 64)],
                nce_freqs_mhz: vec![500, 250, 125, 50],
                ..Default::default()
            },
        };
        for threads in [1usize, 0] {
            let pruned =
                run(&spec, &CampaignOptions { threads, ..Default::default() }).unwrap();
            let unpruned = run(
                &spec,
                &CampaignOptions { threads, prune: false, ..Default::default() },
            )
            .unwrap();
            assert_eq!(unpruned.skipped_by_bound, 0);
            for (ni, net) in spec.nets.iter().enumerate() {
                let batch = dse::sweep(net, &spec.base, &spec.axes);
                let batch_front = dse::pareto(&batch);
                for (tag, result) in [("pruned", &pruned), ("unpruned", &unpruned)] {
                    let got = &result.nets[ni];
                    assert_eq!(
                        got.frontier.len(),
                        batch_front.len(),
                        "{tag}/{threads}t: {}",
                        net.name
                    );
                    for (a, b) in got.frontier.iter().zip(&batch_front) {
                        assert_eq!(a.name, b.name, "{tag}/{threads}t");
                        assert_eq!(a.latency_ps, b.latency_ps, "{tag}/{threads}t: {}", a.name);
                        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{tag}/{threads}t");
                        assert_eq!(a.sys, b.sys, "{tag}/{threads}t");
                    }
                    assert_eq!(
                        got.evaluated,
                        got.feasible + got.infeasible + got.errors + got.skipped_by_bound,
                        "{tag}/{threads}t: {}",
                        net.name
                    );
                }
            }
        }
        // Single-threaded (deterministic arrival order) the 50 MHz points
        // must actually be skipped: their compute occupancy alone exceeds
        // the 500 MHz member's whole makespan.
        let seq = run(&spec, &CampaignOptions { threads: 1, ..Default::default() }).unwrap();
        assert!(
            seq.skipped_by_bound > 0,
            "expected lower-bound pruning on a frequency-sparse grid"
        );
    }

    #[test]
    fn invalid_swept_config_counts_as_error_not_infeasible() {
        // A 0 MHz point in the frequency axis is a broken sweep, not a
        // hole in the design space; it must surface in the error count
        // with a diagnostic instead of vanishing.
        let spec = CampaignSpec {
            nets: vec![models::lenet(28)],
            base: SystemConfig::base_paper(),
            axes: SweepAxes { nce_freqs_mhz: vec![250, 0], ..Default::default() },
        };
        let result = run(&spec, &CampaignOptions::default()).unwrap();
        let got = &result.nets[0];
        assert_eq!((got.feasible, got.errors, got.infeasible), (1, 1, 0));
        let sample = got.error_sample.as_deref().expect("error diagnostic retained");
        assert!(sample.contains("invalid configuration"), "{sample}");
        assert_eq!(result.errors, 1);
        // The feasible point still made the frontier.
        assert_eq!(got.frontier.len(), 1);
    }

    #[test]
    fn single_threaded_run_matches_parallel() {
        let spec = small_spec();
        let par = run(&spec, &CampaignOptions::default()).unwrap();
        let seq = run(
            &spec,
            &CampaignOptions { threads: 1, ..Default::default() },
        )
        .unwrap();
        for (a, b) in par.nets.iter().zip(&seq.nets) {
            assert_eq!(a.frontier.len(), b.frontier.len());
            for (x, y) in a.frontier.iter().zip(&b.frontier) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.latency_ps, y.latency_ps);
            }
        }
    }
}
