//! Campaign engine: multi-workload co-design sweeps on one shared worker
//! pool, with streaming Pareto frontiers and a persistent compile cache.
//!
//! The paper's pitch is "design space exploration by a click of a button"
//! across *systems*: a co-design loop ranks one hardware configuration
//! grid against a whole portfolio of workloads (the way SMAUG evaluates
//! full-stack design points across several DNNs, and ANNETTE amortizes
//! per-platform model building across networks). [`crate::dse::sweep`]
//! covers one net; [`run`] covers the portfolio.
//!
//! # Execution model
//!
//! A campaign is `N` workloads x one [`SweepAxes`] grid around a base
//! [`SystemConfig`]. The grid is expanded **once** (deterministic axis
//! order, shared by every net) and the full `N x P` unit matrix fans out
//! over a single worker pool ([`pool`]) — workers do not idle at per-net
//! boundaries the way `N` back-to-back sweeps would. Each unit:
//!
//! 1. resolves its compiled artifact through its net's
//!    [`PersistentCache`] (memory → disk → compile; frequency-only
//!    config changes always share one compilation, exactly as in
//!    single-net DSE),
//! 2. simulates the point (AVSM fast path, traces off), and
//! 3. streams the resulting [`DesignPoint`] back to the coordinating
//!    thread, which folds it into that net's online
//!    [`StreamingFrontier`] — dominated points are dropped on arrival,
//!    so memory stays O(frontier + grid), not O(evaluations), and
//!    frontiers are live while the sweep still runs.
//!
//! Each point carries its grid-enumeration index as the frontier sequence
//! number, which makes the final per-net frontier **byte-identical** to
//! batch `dse::pareto(dse::sweep(..))` regardless of worker timing — the
//! equivalence the test suite enforces.
//!
//! # Persistence model
//!
//! With [`CampaignOptions::cache_dir`] set, every successful compilation
//! is serialized (task graph + per-layer records + full [`CompileKey`])
//! into the directory via [`store`]; a later run — same process or a new
//! CLI invocation — resolves every structural key from disk and performs
//! **zero compilations** (assertable via [`CampaignResult::compiles`]).
//! Corrupted or stale entries are detected (schema/key verification,
//! task-graph validation), rejected, recompiled and rewritten. Without a
//! cache directory the campaign still shares compilations in memory, per
//! net, across the whole grid.
//!
//! [`CompileKey`]: crate::compiler::CompileKey

pub mod frontier;
pub mod pool;
pub mod store;

pub use frontier::StreamingFrontier;
pub use store::PersistentCache;

use crate::config::SystemConfig;
use crate::dse::{self, DesignPoint, SweepAxes};
use crate::graph::DnnGraph;
use anyhow::{bail, Result};
use std::path::PathBuf;

/// What to sweep: a portfolio of workloads against one config grid.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    pub nets: Vec<DnnGraph>,
    /// Base system; axes replace fields of this config (empty axes keep
    /// the base value), exactly as in [`dse::sweep`].
    pub base: SystemConfig,
    pub axes: SweepAxes,
}

/// Execution policy for [`run`].
#[derive(Debug, Clone, Default)]
pub struct CampaignOptions {
    /// Worker threads; 0 (default) = one per available CPU, capped by the
    /// unit count.
    pub threads: usize,
    /// Directory for the persistent compile cache; `None` keeps the cache
    /// in memory only.
    pub cache_dir: Option<PathBuf>,
    /// Also retain every feasible evaluated point per net (in grid order,
    /// identical to `dse::sweep` output). Off by default: a campaign
    /// normally streams, keeping only the frontier.
    pub keep_points: bool,
}

/// Per-workload outcome.
#[derive(Debug, Clone)]
pub struct NetOutcome {
    pub net: String,
    /// Pareto frontier, ordered by (latency, cost, grid index) — byte-
    /// identical to `dse::pareto(dse::sweep(..))` for the same grid.
    pub frontier: Vec<DesignPoint>,
    /// All feasible points in grid order (empty unless
    /// [`CampaignOptions::keep_points`]).
    pub points: Vec<DesignPoint>,
    /// Grid points evaluated (the full grid).
    pub evaluated: usize,
    /// Points that compiled and simulated (infeasible tilings excluded).
    pub feasible: usize,
    /// Feasible points dominated on arrival at the frontier.
    pub dominated: usize,
    /// Former frontier members evicted by later points.
    pub pruned: usize,
    /// Compiler invocations for this net (0 on a warm disk cache).
    pub compiles: u64,
    /// Structural keys served from the disk tier.
    pub disk_hits: u64,
    /// Probes served from the in-memory tier.
    pub mem_hits: u64,
    /// Corrupted/stale disk entries rejected.
    pub rejected: u64,
}

/// Result of one campaign run.
#[derive(Debug, Clone)]
pub struct CampaignResult {
    pub nets: Vec<NetOutcome>,
    /// Design points in the (shared) expanded grid.
    pub grid_points: usize,
    /// Worker threads actually used.
    pub threads: usize,
    /// Compiler invocations across all nets — zero on a warm disk cache.
    pub compiles: u64,
    pub disk_hits: u64,
    pub mem_hits: u64,
    pub rejected_entries: u64,
}

impl CampaignResult {
    /// Feasible evaluations across all workloads.
    pub fn total_feasible(&self) -> usize {
        self.nets.iter().map(|n| n.feasible).sum()
    }

    /// Units (workloads x grid points) evaluated.
    pub fn total_units(&self) -> usize {
        self.nets.len() * self.grid_points
    }
}

/// Run a campaign: every workload x every grid point in one fan-out.
pub fn run(spec: &CampaignSpec, opts: &CampaignOptions) -> Result<CampaignResult> {
    if spec.nets.is_empty() {
        bail!("campaign needs at least one workload");
    }
    for net in &spec.nets {
        net.validate()?;
    }
    spec.base.validate()?;

    let configs = dse::expand_configs(&spec.base, &spec.axes);
    let n_nets = spec.nets.len();
    let n_cfg = configs.len();
    let jobs = n_nets * n_cfg;
    let threads = pool::resolve_threads(opts.threads, jobs);

    let caches: Vec<PersistentCache> = spec
        .nets
        .iter()
        .map(|_| PersistentCache::new(dse::DSE_COMPILE_OPTS, opts.cache_dir.clone()))
        .collect::<Result<_>>()?;

    let mut frontiers: Vec<StreamingFrontier> =
        (0..n_nets).map(|_| StreamingFrontier::new()).collect();
    let mut kept: Vec<Vec<Option<DesignPoint>>> = (0..n_nets)
        .map(|_| if opts.keep_points { vec![None; n_cfg] } else { Vec::new() })
        .collect();
    let mut feasible = vec![0usize; n_nets];

    // Unit u covers net u / n_cfg at grid point u % n_cfg (net-major, so
    // one net's units are contiguous and its compile cache warms early).
    // Workers evaluate; the coordinating thread streams arrivals into the
    // per-net frontiers.
    pool::for_each_completed(
        jobs,
        opts.threads,
        |u| {
            let (ni, ci) = (u / n_cfg, u % n_cfg);
            let sys = &configs[ci];
            caches[ni]
                .get_or_compile(&spec.nets[ni], sys)
                .ok()
                .map(|compiled| dse::evaluate_compiled(&compiled, sys, sys.name.clone()))
        },
        |u, maybe_point| {
            if let Some(p) = maybe_point {
                let (ni, ci) = (u / n_cfg, u % n_cfg);
                feasible[ni] += 1;
                if opts.keep_points {
                    kept[ni][ci] = Some(p.clone());
                }
                frontiers[ni].insert_with_seq(p, ci);
            }
        },
    );

    let mut nets = Vec::with_capacity(n_nets);
    let (mut compiles, mut disk_hits, mut mem_hits, mut rejected) = (0u64, 0u64, 0u64, 0u64);
    for (ni, frontier) in frontiers.into_iter().enumerate() {
        let cache = &caches[ni];
        compiles += cache.compiles();
        disk_hits += cache.disk_hits();
        mem_hits += cache.mem_hits();
        rejected += cache.rejected();
        let dominated = frontier.dominated();
        let pruned = frontier.pruned();
        nets.push(NetOutcome {
            net: spec.nets[ni].name.clone(),
            evaluated: n_cfg,
            feasible: feasible[ni],
            dominated,
            pruned,
            compiles: cache.compiles(),
            disk_hits: cache.disk_hits(),
            mem_hits: cache.mem_hits(),
            rejected: cache.rejected(),
            points: kept[ni].drain(..).flatten().collect(),
            frontier: frontier.into_points(),
        });
    }
    Ok(CampaignResult {
        nets,
        grid_points: n_cfg,
        threads,
        compiles,
        disk_hits,
        mem_hits,
        rejected_entries: rejected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn small_spec() -> CampaignSpec {
        CampaignSpec {
            nets: vec![models::lenet(28), models::dilated_vgg_tiny()],
            base: SystemConfig::base_paper(),
            axes: SweepAxes {
                array_geometries: vec![(16, 32), (32, 64)],
                nce_freqs_mhz: vec![125, 250],
                ..Default::default()
            },
        }
    }

    #[test]
    fn empty_portfolio_is_rejected() {
        let spec = CampaignSpec {
            nets: vec![],
            base: SystemConfig::base_paper(),
            axes: SweepAxes::default(),
        };
        assert!(run(&spec, &CampaignOptions::default()).is_err());
    }

    #[test]
    fn frontier_matches_per_net_sweep_and_points_keep_grid_order() {
        let spec = small_spec();
        let opts = CampaignOptions { keep_points: true, ..Default::default() };
        let result = run(&spec, &opts).unwrap();
        assert_eq!(result.grid_points, 4);
        assert_eq!(result.nets.len(), 2);
        for (ni, net) in spec.nets.iter().enumerate() {
            let sweep = dse::sweep(net, &spec.base, &spec.axes);
            let batch = dse::pareto(&sweep);
            let got = &result.nets[ni];
            assert_eq!(got.net, net.name);
            // keep_points reproduces the sweep exactly, order included.
            assert_eq!(got.points.len(), sweep.len());
            for (a, b) in got.points.iter().zip(&sweep) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
            }
            // Streaming frontier == batch frontier.
            assert_eq!(got.frontier.len(), batch.len());
            for (a, b) in got.frontier.iter().zip(&batch) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.latency_ps, b.latency_ps);
                assert_eq!(a.cost.to_bits(), b.cost.to_bits());
                assert_eq!(a.sys, b.sys);
            }
            // Accounting adds up.
            assert_eq!(got.feasible, sweep.len());
            assert_eq!(
                got.frontier.len() + got.dominated + got.pruned,
                got.feasible,
                "every feasible point is on the frontier, dominated, or pruned"
            );
        }
        // One compile per structural key per net: 2 geometries.
        assert_eq!(result.compiles, 4);
        assert_eq!(result.disk_hits, 0);
    }

    #[test]
    fn single_threaded_run_matches_parallel() {
        let spec = small_spec();
        let par = run(&spec, &CampaignOptions::default()).unwrap();
        let seq = run(
            &spec,
            &CampaignOptions { threads: 1, ..Default::default() },
        )
        .unwrap();
        for (a, b) in par.nets.iter().zip(&seq.nets) {
            assert_eq!(a.frontier.len(), b.frontier.len());
            for (x, y) in a.frontier.iter().zip(&b.frontier) {
                assert_eq!(x.name, y.name);
                assert_eq!(x.latency_ps, y.latency_ps);
            }
        }
    }
}
