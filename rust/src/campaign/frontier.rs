//! Online (streaming) Pareto frontier over `(latency, cost)`.
//!
//! [`crate::dse::pareto`] recomputes the frontier from a complete sweep;
//! the campaign instead inserts design points *as workers finish* and
//! prunes dominated members incrementally, so a huge multi-workload sweep
//! streams results with O(frontier) memory for the frontier itself instead
//! of buffering every point.
//!
//! # Batch equivalence
//!
//! The maintained set is exactly the non-dominated subset of everything
//! inserted so far, ordered by `(latency, cost, seq)` — the same
//! definition, duplicate handling (all copies of a frontier point are
//! kept) and tie order as [`crate::dse::pareto`]. `seq` is the caller's
//! stable point index ([`StreamingFrontier::insert_with_seq`]); when the
//! campaign passes each point's sweep-enumeration index, the final
//! frontier is **byte-identical to `dse::pareto(dse::sweep(..))`** no
//! matter in which order workers delivered the points — the property the
//! test suite enforces against randomized point sets.
//!
//! Insertion is O(log n) to locate + amortized O(1) per pruned member
//! (each point is evicted at most once over a frontier's lifetime).

use crate::dse::DesignPoint;

#[derive(Debug, Clone)]
struct Entry {
    latency_ps: u64,
    cost: f64,
    seq: usize,
    point: DesignPoint,
}

impl Entry {
    /// Sort key comparison: (latency, cost, seq), total order (costs are
    /// finite by construction).
    fn key_cmp(&self, lat: u64, cost: f64, seq: usize) -> std::cmp::Ordering {
        self.latency_ps
            .cmp(&lat)
            .then_with(|| self.cost.total_cmp(&cost))
            .then_with(|| self.seq.cmp(&seq))
    }
}

/// Incrementally maintained Pareto frontier (minimize latency and cost).
#[derive(Debug, Default)]
pub struct StreamingFrontier {
    /// Invariant: sorted by `(latency, cost, seq)`; costs non-increasing
    /// along the vector — strictly decreasing across distinct latencies,
    /// equal within a latency group (duplicate frontier points).
    entries: Vec<Entry>,
    next_seq: usize,
    inserted: usize,
    dominated: usize,
    pruned: usize,
}

impl StreamingFrontier {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert a point with an auto-assigned sequence number (arrival
    /// order). Use [`StreamingFrontier::insert_with_seq`] when a stable
    /// enumeration index exists and batch-identical tie order matters.
    /// Returns `true` iff the point joined the frontier.
    pub fn insert(&mut self, point: DesignPoint) -> bool {
        let seq = self.next_seq;
        self.insert_with_seq(point, seq)
    }

    /// Insert a point under an explicit sequence number (its index in some
    /// stable enumeration). Ties in `(latency, cost)` keep ascending `seq`
    /// order, which is what makes out-of-order streaming reproduce the
    /// batch frontier exactly. Returns `true` iff the point joined.
    pub fn insert_with_seq(&mut self, point: DesignPoint, seq: usize) -> bool {
        self.next_seq = self.next_seq.max(seq + 1);
        self.inserted += 1;
        let (lat, cost) = (point.latency_ps, point.cost);
        // First entry sorted after (lat, cost, seq).
        let pos = self
            .entries
            .partition_point(|e| e.key_cmp(lat, cost, seq) == std::cmp::Ordering::Less);
        // Dominance test against the cheapest no-slower member: entries
        // before `pos` all have key < (lat, cost, seq), and by the cost
        // invariant the last of them carries the minimum cost among them.
        if pos > 0 {
            let e = &self.entries[pos - 1];
            let strictly_better =
                e.cost < cost || (e.cost == cost && e.latency_ps < lat);
            if strictly_better {
                self.dominated += 1;
                return false;
            }
            // Remaining case: e.cost == cost && e.latency_ps == lat — a
            // duplicate of a frontier point, which the batch definition
            // keeps; fall through and keep it too. (e.cost > cost cannot
            // dominate.)
        }
        self.entries.insert(pos, Entry { latency_ps: lat, cost, seq, point });
        // Prune members the new point dominates. They sit directly after
        // it: skip exact (latency, cost) ties (kept duplicates), then
        // evict while cost has not dropped below the new point's.
        let mut tie_end = pos + 1;
        while tie_end < self.entries.len()
            && self.entries[tie_end].latency_ps == lat
            && self.entries[tie_end].cost == cost
        {
            tie_end += 1;
        }
        let mut prune_end = tie_end;
        while prune_end < self.entries.len() && self.entries[prune_end].cost >= cost {
            prune_end += 1;
        }
        self.pruned += prune_end - tie_end;
        self.entries.drain(tie_end..prune_end);
        true
    }

    /// Bound-and-prune query: could a candidate whose simulated latency is
    /// only known to satisfy `latency >= lower_bound_ps`, at price `cost`,
    /// still join this frontier?
    ///
    /// Returns `false` exactly when an existing member **strictly
    /// dominates the hypothetical point `(lower_bound_ps, cost)`** — in
    /// which case it strictly dominates every realizable candidate
    /// `(latency >= lower_bound_ps, cost)` too, so simulating it is
    /// provably wasted work. Strict dominance also survives later
    /// evictions (whatever evicts the dominating member dominates the
    /// candidate transitively), which is what makes pruning on this query
    /// **lossless**: a refused candidate could never appear on any future
    /// state of the frontier, duplicates-kept tie semantics included.
    ///
    /// Returns `true` (admit → simulate) whenever the candidate *might*
    /// join — including the exact-tie case, which the batch definition
    /// keeps as a duplicate.
    pub fn admits(&self, lower_bound_ps: u64, cost: f64) -> bool {
        // Mirror the insert-time dominance test at the hypothetical key
        // (lower_bound_ps, cost, MAX): the predecessor under the sort order
        // carries the minimum cost among all no-slower members.
        let pos = self.entries.partition_point(|e| {
            e.key_cmp(lower_bound_ps, cost, usize::MAX) == std::cmp::Ordering::Less
        });
        if pos == 0 {
            return true;
        }
        let e = &self.entries[pos - 1];
        !(e.cost < cost || (e.cost == cost && e.latency_ps < lower_bound_ps))
    }

    /// Current frontier, ordered by `(latency, cost, seq)`.
    pub fn points(&self) -> impl Iterator<Item = &DesignPoint> {
        self.entries.iter().map(|e| &e.point)
    }

    /// Consume the frontier into owned points, ordered by
    /// `(latency, cost, seq)`.
    pub fn into_points(self) -> Vec<DesignPoint> {
        self.entries.into_iter().map(|e| e.point).collect()
    }

    /// Members currently on the frontier.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Points offered so far.
    pub fn inserted(&self) -> usize {
        self.inserted
    }

    /// Points rejected on arrival (already dominated).
    pub fn dominated(&self) -> usize {
        self.dominated
    }

    /// Former members evicted by later points.
    pub fn pruned(&self) -> usize {
        self.pruned
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::dse;

    fn pt(lat: u64, cost: f64, i: usize) -> DesignPoint {
        DesignPoint {
            name: format!("p{i}"),
            sys: SystemConfig::base_paper(),
            latency_ps: lat,
            cost,
            throughput: 0.0,
        }
    }

    /// The tie/duplicate-heavy grid from the dse::pareto unit tests.
    fn grid() -> Vec<DesignPoint> {
        [
            (10, 5.0),
            (10, 5.0),
            (10, 4.0),
            (20, 3.0),
            (20, 6.0),
            (5, 9.0),
            (30, 3.0),
            (30, 2.0),
            (40, 2.0),
            (7, 9.0),
            (20, 3.0),
        ]
        .iter()
        .enumerate()
        .map(|(i, &(l, c))| pt(l, c, i))
        .collect()
    }

    fn assert_matches_batch(stream: &[DesignPoint], all: &[DesignPoint]) {
        let batch = dse::pareto(all);
        assert_eq!(stream.len(), batch.len(), "frontier size mismatch");
        for (s, b) in stream.iter().zip(&batch) {
            assert_eq!(s.name, b.name);
            assert_eq!(s.latency_ps, b.latency_ps);
            assert_eq!(s.cost.to_bits(), b.cost.to_bits());
        }
    }

    #[test]
    fn in_order_insertion_matches_batch_pareto() {
        let all = grid();
        let mut f = StreamingFrontier::new();
        for (i, p) in all.iter().enumerate() {
            f.insert_with_seq(p.clone(), i);
        }
        assert_eq!(f.inserted(), all.len());
        let stream: Vec<DesignPoint> = f.into_points();
        assert_matches_batch(&stream, &all);
    }

    #[test]
    fn out_of_order_insertion_matches_batch_pareto() {
        let all = grid();
        // Reversed and interleaved arrival orders.
        for order in [
            (0..all.len()).rev().collect::<Vec<_>>(),
            (0..all.len()).step_by(2).chain((0..all.len()).skip(1).step_by(2)).collect(),
        ] {
            let mut f = StreamingFrontier::new();
            for &i in &order {
                f.insert_with_seq(all[i].clone(), i);
            }
            let stream: Vec<DesignPoint> = f.into_points();
            assert_matches_batch(&stream, &all);
        }
    }

    #[test]
    fn duplicates_of_a_frontier_point_are_kept() {
        let mut f = StreamingFrontier::new();
        assert!(f.insert(pt(10, 5.0, 0)));
        assert!(f.insert(pt(10, 5.0, 1)));
        assert_eq!(f.len(), 2);
        // A strictly better point evicts both copies.
        assert!(f.insert(pt(10, 4.0, 2)));
        assert_eq!(f.len(), 1);
        assert_eq!(f.pruned(), 2);
    }

    #[test]
    fn dominated_arrivals_are_counted_not_stored() {
        let mut f = StreamingFrontier::new();
        assert!(f.insert(pt(10, 5.0, 0)));
        assert!(!f.insert(pt(12, 5.0, 1)), "slower, same cost");
        assert!(!f.insert(pt(10, 6.0, 2)), "same latency, pricier");
        assert!(!f.insert(pt(15, 9.0, 3)), "worse on both");
        assert_eq!((f.len(), f.dominated(), f.pruned()), (1, 3, 0));
        // Incomparable point joins.
        assert!(f.insert(pt(5, 7.0, 4)));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn empty_frontier() {
        let f = StreamingFrontier::new();
        assert!(f.is_empty());
        assert_eq!(f.points().count(), 0);
    }

    #[test]
    fn admits_mirrors_strict_dominance() {
        let mut f = StreamingFrontier::new();
        assert!(f.admits(100, 100.0), "empty frontier admits anything");
        f.insert(pt(10, 5.0, 0));
        // Strictly dominated hypotheticals are refused...
        assert!(!f.admits(11, 5.0), "slower, same cost");
        assert!(!f.admits(10, 6.0), "same bound, pricier");
        assert!(!f.admits(15, 9.0), "worse on both");
        // ...everything that might join is admitted.
        assert!(f.admits(10, 5.0), "exact tie is a kept duplicate");
        assert!(f.admits(9, 6.0), "maybe faster, pricier: incomparable");
        assert!(f.admits(10, 4.0), "cheaper at the same bound");
        assert!(f.admits(20, 3.0), "slower but cheaper");
    }

    #[test]
    fn refused_candidates_could_never_join_even_after_evictions() {
        // A bound-refused candidate must stay off the frontier under every
        // later state: eviction only happens via dominating points, and
        // strict dominance is transitive through them.
        let mut f = StreamingFrontier::new();
        f.insert(pt(10, 5.0, 0));
        assert!(!f.admits(12, 5.0));
        // Evict the member with a strictly better point; the refused
        // candidate is still dominated by the evictor.
        f.insert(pt(9, 4.0, 1));
        assert_eq!(f.len(), 1);
        assert!(!f.admits(12, 5.0), "refusal must survive evictions");
        // Inserting the refused point directly confirms it is dominated.
        assert!(!f.insert(pt(12, 5.0, 2)));
    }
}
