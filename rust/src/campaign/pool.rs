//! Shared worker pool for fan-out across design points, workloads and
//! fidelity levels.
//!
//! One strided-scheduling implementation serves every parallel consumer in
//! the crate — [`crate::dse::sweep`] (points of one net),
//! [`crate::campaign::run`] (workloads x points in a single fan-out) and
//! the Fig 5 AVSM-vs-prototype comparison
//! ([`crate::report::Fig5Report::compute_many`], independent simulation
//! runs). Worker `w` of `T` executes jobs `w, w + T, w + 2T, ...`:
//!
//! * [`parallel_map`] scatters results back by job index, so the output
//!   order is deterministic — identical to the one-worker run — no matter
//!   how workers interleave.
//! * [`for_each_completed`] hands `(index, result)` pairs to a collector
//!   on the calling thread *as workers finish* (mpsc channel), which is
//!   what lets the campaign feed its online Pareto frontier without
//!   buffering a whole sweep first. With more than one worker the arrival
//!   order is timing-dependent; with one worker (or `jobs <= 1`) the
//!   collector runs inline in job order.
//!
//! A panic in a job is *contained*: the worker catches the unwind and that
//! job's slot carries a structured [`JobDied`] error (job index + rendered
//! panic message) instead of tearing down the pool — every other job still
//! runs and delivers its result, so one bad unit cannot kill a campaign.
//! A panic in the collector closes the receiver, which workers observe as
//! a send error and exit.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;

/// A job whose closure panicked: the pool caught the unwind and reports
/// the job index plus a best-effort rendering of the panic payload, so
/// callers see a structured per-job error instead of a process abort.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobDied {
    /// Index of the job whose closure panicked.
    pub job: usize,
    /// Rendered panic payload (see [`panic_message`]).
    pub message: String,
}

impl std::fmt::Display for JobDied {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pool job {} died: {}", self.job, self.message)
    }
}

impl std::error::Error for JobDied {}

/// Best-effort rendering of a panic payload (the `Box<dyn Any>` from
/// `catch_unwind`): `&str` and `String` payloads — everything `panic!`
/// and `assert!` produce — come back verbatim; any other payload type
/// gets a fixed placeholder.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn run_job<T, F>(f: &F, i: usize) -> Result<T, JobDied>
where
    F: Fn(usize) -> T + Sync,
{
    catch_unwind(AssertUnwindSafe(|| f(i)))
        .map_err(|p| JobDied { job: i, message: panic_message(p.as_ref()) })
}

/// Number of workers for `requested` threads (0 = one per available CPU),
/// capped by the job count, floored at one.
pub fn resolve_threads(requested: usize, jobs: usize) -> usize {
    let threads = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    threads.min(jobs).max(1)
}

/// Run `jobs` invocations of `f` on up to `threads` workers (0 = all CPUs)
/// and return the per-job results in job order. A job whose closure
/// panicked occupies its slot as `Err(JobDied)`; all other jobs still run.
pub fn parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Vec<Result<T, JobDied>>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut slots: Vec<Option<Result<T, JobDied>>> = Vec::with_capacity(jobs);
    slots.resize_with(jobs, || None);
    for_each_completed(jobs, threads, f, |i, v| slots[i] = Some(v));
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| {
            // Unreachable in practice (workers catch unwinds and the inline
            // collector cannot drop a send), but an empty slot degrades to
            // a structured error rather than killing the caller.
            s.unwrap_or_else(|| {
                Err(JobDied { job: i, message: "job produced no result".into() })
            })
        })
        .collect()
}

/// Run `jobs` invocations of `f` on up to `threads` workers (0 = all CPUs),
/// delivering each `(job index, result)` to `collect` on the calling thread
/// as soon as it is available — the streaming primitive behind the
/// campaign's online Pareto frontier. A panicking job delivers
/// `Err(JobDied)` for its index; the remaining jobs are unaffected.
pub fn for_each_completed<T, F, C>(jobs: usize, threads: usize, f: F, mut collect: C)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    C: FnMut(usize, Result<T, JobDied>),
{
    if jobs == 0 {
        return;
    }
    let threads = resolve_threads(threads, jobs);
    if threads == 1 {
        for i in 0..jobs {
            let v = run_job(&f, i);
            collect(i, v);
        }
        return;
    }
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, Result<T, JobDied>)>();
        let f = &f;
        for w in 0..threads {
            let tx = tx.clone();
            scope.spawn(move || {
                // Telemetry identity: workers are 1..=threads, leaving 0
                // for the coordinating thread (which also runs the whole
                // inline single-worker path above). One thread-local write
                // per spawned thread, not per job.
                crate::obs::set_worker(w as u32 + 1);
                let mut i = w;
                while i < jobs {
                    // A send error means the receiver is gone (collector
                    // panicked): stop producing.
                    if tx.send((i, run_job(f, i))).is_err() {
                        return;
                    }
                    i += threads;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            collect(i, v);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_job_order_regardless_of_workers() {
        for threads in [0usize, 1, 2, 7] {
            let out: Vec<usize> = parallel_map(23, threads, |i| i * i)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn streaming_delivers_every_job_exactly_once() {
        let mut seen = vec![0u32; 50];
        for_each_completed(50, 4, |i| i, |i, v| {
            assert_eq!(i, v.unwrap());
            seen[i] += 1;
        });
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn single_worker_streams_in_job_order() {
        let mut order = Vec::new();
        for_each_completed(10, 1, |i| i, |i, _| order.push(i));
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_jobs_is_a_no_op() {
        let calls = AtomicUsize::new(0);
        let out: Vec<Result<u32, JobDied>> = parallel_map(0, 4, |_| {
            calls.fetch_add(1, Ordering::Relaxed);
            0
        });
        assert!(out.is_empty());
        assert_eq!(calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn thread_resolution_caps_and_floors() {
        assert_eq!(resolve_threads(8, 3), 3);
        assert_eq!(resolve_threads(2, 100), 2);
        assert!(resolve_threads(0, 100) >= 1);
        assert_eq!(resolve_threads(5, 0), 1);
    }

    #[test]
    fn panicking_job_degrades_to_job_died_and_spares_the_rest() {
        for threads in [1usize, 4] {
            let out = parallel_map(9, threads, |i| {
                if i == 4 {
                    panic!("unit 4 exploded");
                }
                i * 10
            });
            for (i, r) in out.iter().enumerate() {
                if i == 4 {
                    let died = r.as_ref().unwrap_err();
                    assert_eq!(died.job, 4, "threads={threads}");
                    assert_eq!(died.message, "unit 4 exploded", "threads={threads}");
                    assert!(died.to_string().contains("pool job 4 died"));
                } else {
                    assert_eq!(*r.as_ref().unwrap(), i * 10, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn streaming_reports_panics_per_job() {
        let mut died = Vec::new();
        let mut ok = Vec::new();
        for_each_completed(
            12,
            3,
            |i| {
                if i % 5 == 0 {
                    panic!("boom {i}");
                }
                i
            },
            |i, v| match v {
                Ok(v) => ok.push(v),
                Err(d) => {
                    assert_eq!(d.job, i);
                    died.push((i, d.message));
                }
            },
        );
        died.sort();
        ok.sort();
        assert_eq!(
            died,
            vec![(0, "boom 0".into()), (5, "boom 5".into()), (10, "boom 10".into())]
        );
        assert_eq!(ok, vec![1, 2, 3, 4, 6, 7, 8, 9, 11]);
    }

    #[test]
    fn workers_claim_dense_telemetry_ids() {
        // Strided scheduling gives every worker jobs, so ids 1..=3 must
        // all appear; the inline single-worker path stays on the calling
        // thread, which keeps the coordinator id 0.
        let mut ids: Vec<u32> = parallel_map(9, 3, |_| crate::obs::worker())
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids, vec![1, 2, 3]);
        let inline: Vec<u32> = parallel_map(2, 1, |_| crate::obs::worker())
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(inline, vec![0, 0]);
    }

    #[test]
    fn non_string_panic_payload_gets_a_placeholder() {
        let out = parallel_map(1, 1, |_| -> u32 { std::panic::panic_any(42u32) });
        assert_eq!(
            out[0].as_ref().unwrap_err().message,
            "non-string panic payload"
        );
    }
}
