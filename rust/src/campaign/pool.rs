//! Shared worker pool for fan-out across design points, workloads and
//! fidelity levels.
//!
//! One strided-scheduling implementation serves every parallel consumer in
//! the crate — [`crate::dse::sweep`] (points of one net),
//! [`crate::campaign::run`] (workloads x points in a single fan-out) and
//! the Fig 5 AVSM-vs-prototype comparison
//! ([`crate::report::Fig5Report::compute_many`], independent simulation
//! runs). Worker `w` of `T` executes jobs `w, w + T, w + 2T, ...`:
//!
//! * [`parallel_map`] scatters results back by job index, so the output
//!   order is deterministic — identical to the one-worker run — no matter
//!   how workers interleave.
//! * [`for_each_completed`] hands `(index, result)` pairs to a collector
//!   on the calling thread *as workers finish* (mpsc channel), which is
//!   what lets the campaign feed its online Pareto frontier without
//!   buffering a whole sweep first. With more than one worker the arrival
//!   order is timing-dependent; with one worker (or `jobs <= 1`) the
//!   collector runs inline in job order.
//!
//! A panic in a job propagates: the channel drains, the scope joins every
//! worker, and the panic resumes on the caller. A panic in the collector
//! closes the receiver, which workers observe as a send error and exit.

use std::sync::mpsc;

/// Number of workers for `requested` threads (0 = one per available CPU),
/// capped by the job count, floored at one.
pub fn resolve_threads(requested: usize, jobs: usize) -> usize {
    let threads = if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    };
    threads.min(jobs).max(1)
}

/// Run `jobs` invocations of `f` on up to `threads` workers (0 = all CPUs)
/// and return the results in job order.
pub fn parallel_map<T, F>(jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut slots: Vec<Option<T>> = Vec::with_capacity(jobs);
    slots.resize_with(jobs, || None);
    for_each_completed(jobs, threads, f, |i, v| slots[i] = Some(v));
    slots
        .into_iter()
        .map(|s| s.expect("pool: job produced no result"))
        .collect()
}

/// Run `jobs` invocations of `f` on up to `threads` workers (0 = all CPUs),
/// delivering each `(job index, result)` to `collect` on the calling thread
/// as soon as it is available — the streaming primitive behind the
/// campaign's online Pareto frontier.
pub fn for_each_completed<T, F, C>(jobs: usize, threads: usize, f: F, mut collect: C)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    C: FnMut(usize, T),
{
    if jobs == 0 {
        return;
    }
    let threads = resolve_threads(threads, jobs);
    if threads == 1 {
        for i in 0..jobs {
            let v = f(i);
            collect(i, v);
        }
        return;
    }
    std::thread::scope(|scope| {
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        let f = &f;
        for w in 0..threads {
            let tx = tx.clone();
            scope.spawn(move || {
                let mut i = w;
                while i < jobs {
                    // A send error means the receiver is gone (collector
                    // panicked): stop producing.
                    if tx.send((i, f(i))).is_err() {
                        return;
                    }
                    i += threads;
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            collect(i, v);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_job_order_regardless_of_workers() {
        for threads in [0usize, 1, 2, 7] {
            let out = parallel_map(23, threads, |i| i * i);
            assert_eq!(out, (0..23).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn streaming_delivers_every_job_exactly_once() {
        let mut seen = vec![0u32; 50];
        for_each_completed(50, 4, |i| i, |i, v| {
            assert_eq!(i, v);
            seen[i] += 1;
        });
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn single_worker_streams_in_job_order() {
        let mut order = Vec::new();
        for_each_completed(10, 1, |i| i, |i, _| order.push(i));
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_jobs_is_a_no_op() {
        let calls = AtomicUsize::new(0);
        let out: Vec<u32> = parallel_map(0, 4, |_| {
            calls.fetch_add(1, Ordering::Relaxed);
            0
        });
        assert!(out.is_empty());
        assert_eq!(calls.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn thread_resolution_caps_and_floors() {
        assert_eq!(resolve_threads(8, 3), 3);
        assert_eq!(resolve_threads(2, 100), 2);
        assert!(resolve_threads(0, 100) >= 1);
        assert_eq!(resolve_threads(5, 0), 1);
    }
}
