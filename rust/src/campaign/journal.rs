//! Crash-safe campaign resume journal (`avsm-campaign-journal-v1`).
//!
//! A long campaign killed mid-run (SIGKILL, OOM, power cut) should resume
//! to the *byte-identical* report without re-simulating the units it
//! already finished. The journal is the persistence half of that contract:
//! an **append-only, line-delimited** file the campaign writes as units
//! complete, cheap enough to keep on for every journaled run.
//!
//! # Format
//!
//! One JSON document per line, in the crate writer's canonical form
//! (sorted keys, compact). The first line is the header:
//!
//! ```json
//! {"schema":"avsm-campaign-journal-v1","spec":"00f3a4b58e21c97d","units":12}
//! ```
//!
//! `spec` is the campaign's fingerprint — a hash over every workload's
//! serialized net, effective base config and axes, plus the
//! result-relevant options (bound kind, pruning, evaluation order). Every
//! following line records one completed unit's terminal outcome:
//!
//! ```json
//! {"class":"feasible","latency_ps":2400000,"unit":5}
//! {"class":"infeasible","unit":6}
//! {"class":"error","diag":"nce0x0: invalid configuration","unit":7}
//! {"class":"panicked","diag":"worker died","unit":8}
//! {"by_occupancy":true,"class":"skipped","unit":9}
//! ```
//!
//! # Crash model and recovery rules
//!
//! Appends are **line-atomic in effect**: one `write_all` per line,
//! newline included, so a crash mid-append leaves at most one torn final
//! line (a prefix with no terminating newline). [`Journal::resume`]:
//!
//! * drops a torn final line *and truncates the file back to the last
//!   intact line*, so later appends can never concatenate onto the tear;
//! * **refuses loudly** on a header/spec-fingerprint mismatch — replaying
//!   a journal from a different campaign spec would silently fabricate
//!   results (the fingerprint uses the std hasher, so a toolchain upgrade
//!   may also invalidate old journals: the refusal names the cause and
//!   the fix is to re-run without `--resume`);
//! * rejects corruption *before* the final line (that is not a crash
//!   artifact — something else rewrote the file);
//! * treats an absent file as an empty journal (fresh start), so
//!   `--resume` is safe to pass unconditionally.
//!
//! Replay feeds [`run`](super::run): replayed feasible units are
//! reconstructed from their persisted latency (`dse::point_from_latency`
//! rebuilds cost/throughput from the grid config deterministically) and
//! folded into the streaming frontier in **append order** — the
//! interrupted run's completion order, which the file preserves for free.
//! Frontier *membership* is order-independent (the merge is associative
//! and seq-keyed), but the streaming statistics (dominated-on-arrival,
//! evicted members) are not; replaying in completion order makes even
//! those byte-identical to the uninterrupted run, with only unfinished
//! units re-simulating.

use crate::json::{obj, parse, stream, Value};
use crate::testkit::faults;
use anyhow::{anyhow, bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Schema identifier of the journal header line.
pub const SCHEMA: &str = "avsm-campaign-journal-v1";

/// The campaign spec fingerprint, decomposed into the four independently
/// hashed parts it is combined from. Journals written by the campaign
/// engine persist the parts alongside the combined fingerprint, so a
/// `--resume` mismatch can name *which* part of the spec changed (the
/// nets? the base config? the axes? the options?) instead of refusing
/// with two opaque hashes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpecParts {
    /// Hash over every workload's serialized net.
    pub nets: u64,
    /// Hash over every workload's effective base config.
    pub base: u64,
    /// Hash over every workload's axis spec.
    pub axes: u64,
    /// Hash over the result-relevant campaign options (bound kind,
    /// pruning, evaluation order, point retention).
    pub options: u64,
}

impl SpecParts {
    /// Part names, in the fixed `nets`/`base`/`axes`/`options` order.
    pub const NAMES: [&'static str; 4] = ["nets", "base", "axes", "options"];

    fn values(&self) -> [u64; 4] {
        [self.nets, self.base, self.axes, self.options]
    }

    /// The combined campaign fingerprint: a hash over the four parts.
    pub fn combined(&self) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut h = DefaultHasher::new();
        self.values().hash(&mut h);
        h.finish()
    }

    /// Names of the parts where `self` and `other` disagree.
    pub fn differing(&self, other: &SpecParts) -> Vec<&'static str> {
        Self::NAMES
            .iter()
            .zip(self.values())
            .zip(other.values())
            .filter(|((_, a), b)| a != b)
            .map(|((name, _), _)| *name)
            .collect()
    }

    /// JSON form persisted in the journal header (hex strings, like the
    /// combined `spec` field).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("axes", Value::from(format!("{:016x}", self.axes))),
            ("base", Value::from(format!("{:016x}", self.base))),
            ("nets", Value::from(format!("{:016x}", self.nets))),
            ("options", Value::from(format!("{:016x}", self.options))),
        ])
    }

    /// Parse the header's optional `parts` object. `None` when absent or
    /// malformed — journals written before the parts were recorded are
    /// still resumable; they just fall back to the bare refusal.
    pub fn from_json(v: &Value) -> Option<SpecParts> {
        let field = |k: &str| u64::from_str_radix(v.get(k).as_str()?, 16).ok();
        Some(SpecParts {
            nets: field("nets")?,
            base: field("base")?,
            axes: field("axes")?,
            options: field("options")?,
        })
    }
}

/// "axes" / "nets and options" / "nets, axes and options".
fn join_names(names: &[&str]) -> String {
    match names {
        [] => String::new(),
        [one] => (*one).to_string(),
        [init @ .., last] => format!("{} and {last}", init.join(", ")),
    }
}

/// The diagnostic raised when a journal's spec fingerprint does not match
/// the resuming campaign's. When both sides recorded their [`SpecParts`],
/// the message names exactly which parts differ. Also used read-only by
/// `analysis::fsck` for `avsm lint --journal`.
pub fn spec_mismatch_diagnostic(
    path: &Path,
    got: &str,
    got_parts: Option<SpecParts>,
    want: &str,
    want_parts: Option<&SpecParts>,
) -> crate::analysis::Diagnostic {
    let which = match (got_parts, want_parts) {
        (Some(g), Some(w)) => {
            let diff = w.differing(&g);
            if diff.is_empty() {
                // Combined hashes disagree but every part matches: the
                // fingerprint formula itself changed (e.g. a toolchain
                // upgrade re-seeded the std hasher).
                String::from(" — the fingerprint scheme changed")
            } else {
                format!(" — the {} differ", join_names(&diff))
            }
        }
        _ => String::new(),
    };
    crate::analysis::Diagnostic::error(
        "AVSM051",
        format!("journal {}", path.display()),
        format!(
            "journal was written for a different campaign spec{which} \
             (fingerprint {got}, this run is {want}); refusing to replay it"
        ),
    )
    .with_help("re-run without --resume (or delete the journal) to start fresh")
}

/// Parsed journal header line (read-only view, shared with
/// `analysis::fsck`).
#[derive(Debug, Clone, PartialEq)]
pub struct Header {
    pub schema: String,
    pub spec: String,
    pub parts: Option<SpecParts>,
    pub units: usize,
}

/// Parse the first line of a journal file.
pub fn parse_header(line: &str) -> Result<Header> {
    let v = parse(line)?;
    Ok(Header {
        schema: v.req_str("schema")?.to_string(),
        spec: v.req_str("spec")?.to_string(),
        parts: SpecParts::from_json(v.get("parts")),
        units: v.req_u64("units")? as usize,
    })
}

/// Parse one body line of a journal file into `(unit, record)` (read-only
/// view, shared with `analysis::fsck`).
///
/// Pull-parsed in one pass over the line — replay touches millions of
/// records on large resumed campaigns, so no `Value` tree is built per
/// record. Unknown fields are skipped (strictly: the whole line is still
/// validated, including trailing garbage); field order is irrelevant;
/// duplicate keys keep the last occurrence, exactly like the historical
/// tree-based reader.
pub fn parse_record(line: &str) -> Result<(usize, UnitRecord)> {
    use stream::{Event, Reader};
    let mut r = Reader::new(line.as_bytes());
    let mut unit: Option<u64> = None;
    let mut class: Option<String> = None;
    let mut latency_ps: Option<u64> = None;
    let mut diag: Option<String> = None;
    let mut by_occupancy: Option<bool> = None;
    match r.next()? {
        Some(Event::ObjBegin) => {
            loop {
                match r.next()? {
                    Some(Event::Key(k)) => match k.as_ref() {
                        "unit" => unit = r.take_value()?.as_u64(),
                        "class" => class = r.take_value()?.as_str().map(str::to_string),
                        "latency_ps" => latency_ps = r.take_value()?.as_u64(),
                        "diag" => diag = r.take_value()?.as_str().map(str::to_string),
                        "by_occupancy" => by_occupancy = r.take_value()?.as_bool(),
                        _ => r.skip_value()?,
                    },
                    _ => break, // ObjEnd: record complete
                }
            }
            // Trailing-garbage check — same strictness as a full parse.
            r.next()?;
        }
        _ => {
            // Non-object line: validate it whole (for identical syntax
            // errors), then fall through to the missing-field diagnostics.
            parse(line)?;
        }
    }
    let unit =
        unit.ok_or_else(|| anyhow!("missing/invalid unsigned field \"unit\""))? as usize;
    let class = class.ok_or_else(|| anyhow!("missing/invalid string field \"class\""))?;
    let rec = match class.as_str() {
        "feasible" => UnitRecord::Feasible {
            latency_ps: latency_ps
                .ok_or_else(|| anyhow!("missing/invalid unsigned field \"latency_ps\""))?,
        },
        "infeasible" => UnitRecord::Infeasible,
        "error" => UnitRecord::Error {
            diag: diag.ok_or_else(|| anyhow!("missing/invalid string field \"diag\""))?,
        },
        "panicked" => UnitRecord::Panicked {
            diag: diag.ok_or_else(|| anyhow!("missing/invalid string field \"diag\""))?,
        },
        "skipped" => UnitRecord::Skipped {
            by_occupancy: by_occupancy
                .ok_or_else(|| anyhow!("missing/invalid bool field \"by_occupancy\""))?,
        },
        other => bail!("unknown journal record class {other:?}"),
    };
    Ok((unit, rec))
}

/// Terminal outcome of one campaign unit, as journaled.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum UnitRecord {
    /// Simulated; the point is reconstructed from this latency on replay.
    Feasible { latency_ps: u64 },
    /// Structurally infeasible tiling.
    Infeasible,
    /// Non-structural evaluation failure (invalid swept config, poisoned
    /// cache slot).
    Error { diag: String },
    /// The unit's worker panicked; contained and recorded.
    Panicked { diag: String },
    /// Lower-bound pruning skipped the simulation.
    Skipped { by_occupancy: bool },
}

impl UnitRecord {
    /// One record, incrementally emitted in canonical sorted-key order —
    /// byte-identical to the historical `obj(...).to_string_compact()`
    /// form (the journal golden fixture pins this), with no per-append
    /// `Value` tree.
    fn to_line(&self, unit: usize) -> String {
        let mut bytes = Vec::with_capacity(64);
        let mut w = stream::Writer::compact(&mut bytes);
        let emit = |w: &mut stream::Writer<&mut Vec<u8>>| -> Result<()> {
            w.begin_obj()?;
            match self {
                UnitRecord::Feasible { latency_ps } => {
                    w.key("class")?;
                    w.str("feasible")?;
                    w.key("latency_ps")?;
                    w.uint(*latency_ps)?;
                }
                UnitRecord::Infeasible => {
                    w.key("class")?;
                    w.str("infeasible")?;
                }
                UnitRecord::Error { diag } => {
                    w.key("class")?;
                    w.str("error")?;
                    w.key("diag")?;
                    w.str(diag)?;
                }
                UnitRecord::Panicked { diag } => {
                    w.key("class")?;
                    w.str("panicked")?;
                    w.key("diag")?;
                    w.str(diag)?;
                }
                UnitRecord::Skipped { by_occupancy } => {
                    w.key("by_occupancy")?;
                    w.bool(*by_occupancy)?;
                    w.key("class")?;
                    w.str("skipped")?;
                }
            }
            w.key("unit")?;
            w.uint(unit as u64)?;
            w.end_obj()?;
            Ok(())
        };
        emit(&mut w)
            .and_then(|_| w.finish().map(|_| ()))
            .expect("serializing a journal record to memory cannot fail");
        bytes.push(b'\n');
        String::from_utf8(bytes).expect("writer emits UTF-8")
    }
}

fn header_line(spec_fingerprint: u64, parts: Option<&SpecParts>, units: usize) -> String {
    let mut pairs = vec![
        ("schema", Value::from(SCHEMA)),
        ("spec", Value::from(format!("{spec_fingerprint:016x}"))),
        ("units", Value::from(units as u64)),
    ];
    if let Some(p) = parts {
        pairs.push(("parts", p.to_json()));
    }
    let mut line = obj(pairs).to_string_compact();
    line.push('\n');
    line
}

/// An open, append-mode campaign journal.
#[derive(Debug)]
pub struct Journal {
    file: std::fs::File,
    path: PathBuf,
}

impl Journal {
    /// Start a fresh journal at `path` (truncating any previous file) with
    /// the header line already persisted.
    pub fn create(path: &Path, spec_fingerprint: u64, units: usize) -> Result<Journal> {
        Journal::create_with_parts(path, spec_fingerprint, None, units)
    }

    /// Like [`Journal::create`], additionally persisting the fingerprint's
    /// [`SpecParts`] in the header so a later mismatched resume can name
    /// which part of the spec changed. With `None`, the header is
    /// byte-identical to the historical (parts-free) form.
    pub fn create_with_parts(
        path: &Path,
        spec_fingerprint: u64,
        parts: Option<&SpecParts>,
        units: usize,
    ) -> Result<Journal> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating journal directory {}", parent.display()))?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating campaign journal {}", path.display()))?;
        let mut j = Journal { file, path: path.to_path_buf() };
        j.write_line(&header_line(spec_fingerprint, parts, units))?;
        Ok(j)
    }

    /// Load the journal at `path` for resumption: verify the header against
    /// this run's fingerprint and unit count, replay the intact records,
    /// heal a torn final line by truncating it away, and reopen for
    /// appending. An absent file is an empty journal. Returns the open
    /// journal plus the replayed records in **append order** (the
    /// interrupted run's completion order — replaying frontier insertions
    /// in that order keeps even the order-sensitive streaming statistics
    /// byte-identical); per unit the last record wins, keeping its first
    /// position. Units absent from the list never completed.
    pub fn resume(
        path: &Path,
        spec_fingerprint: u64,
        units: usize,
    ) -> Result<(Journal, Vec<(usize, UnitRecord)>)> {
        Journal::resume_with_parts(path, spec_fingerprint, None, units)
    }

    /// Like [`Journal::resume`], additionally carrying this run's
    /// [`SpecParts`]: a spec-fingerprint mismatch against a journal that
    /// also recorded its parts names exactly which parts differ.
    pub fn resume_with_parts(
        path: &Path,
        spec_fingerprint: u64,
        parts: Option<&SpecParts>,
        units: usize,
    ) -> Result<(Journal, Vec<(usize, UnitRecord)>)> {
        let mut records: Vec<(usize, UnitRecord)> = Vec::new();
        if !path.exists() {
            return Ok((Journal::create_with_parts(path, spec_fingerprint, parts, units)?, records));
        }
        faults::before_read("journal.read", path)
            .with_context(|| format!("reading campaign journal {}", path.display()))?;
        // Stream the file line by line through one reused buffer (replay
        // cost is one record's worth of allocation regardless of journal
        // size) instead of materializing the whole file. `read_line` only
        // returns a '\n'-less segment at EOF: only a terminated line was
        // fully appended, so an unterminated tail is the crash tear.
        let file = std::fs::File::open(path)
            .with_context(|| format!("reading campaign journal {}", path.display()))?;
        let mut lines = std::io::BufReader::new(file);
        let mut buf = String::new();
        let mut intact_bytes = 0u64;
        let mut torn = false;
        let mut lineno = 0usize; // 1-based line number of `buf` once read
        let mut pos: Vec<Option<usize>> = Vec::new();
        loop {
            buf.clear();
            let n = std::io::BufRead::read_line(&mut lines, &mut buf)
                .with_context(|| format!("reading campaign journal {}", path.display()))?;
            if n == 0 {
                break;
            }
            if !buf.ends_with('\n') {
                // Torn tail — dropped, and truncated away below.
                torn = true;
                break;
            }
            intact_bytes += n as u64;
            lineno += 1;
            let line = &buf[..buf.len() - 1];
            if lineno == 1 {
                let header = parse_header(line)
                    .with_context(|| format!("corrupt journal header in {}", path.display()))?;
                if header.schema != SCHEMA {
                    bail!(
                        "journal {} has schema {:?}, expected {SCHEMA:?}",
                        path.display(),
                        header.schema
                    );
                }
                let want = format!("{spec_fingerprint:016x}");
                if header.spec != want {
                    let diag =
                        spec_mismatch_diagnostic(path, &header.spec, header.parts, &want, parts);
                    bail!("{}", diag.render());
                }
                if header.units != units {
                    bail!(
                        "journal {} records {} units, this campaign has {units}",
                        path.display(),
                        header.units
                    );
                }
                pos = vec![None; units];
                continue;
            }
            // Corruption before the final line is not a crash artifact —
            // appends are sequential — so it is refused, never skipped.
            let (unit, rec) = parse_record(line).with_context(|| {
                format!("corrupt journal record at {}:{}", path.display(), lineno)
            })?;
            if unit >= units {
                bail!(
                    "journal record at {}:{} names unit {unit} of {units}",
                    path.display(),
                    lineno
                );
            }
            match pos[unit] {
                Some(i) => records[i].1 = rec,
                None => {
                    pos[unit] = Some(records.len());
                    records.push((unit, rec));
                }
            }
        }
        drop(lines);

        if lineno == 0 {
            // Even the header never finished: the previous run crashed
            // before journaling anything. Start over.
            return Ok((Journal::create_with_parts(path, spec_fingerprint, parts, units)?, records));
        }

        let file = std::fs::OpenOptions::new()
            .write(true)
            .open(path)
            .with_context(|| format!("reopening campaign journal {}", path.display()))?;
        if torn {
            // Heal the tear: without this, the next append would
            // concatenate onto the torn prefix and corrupt a record.
            file.set_len(intact_bytes)
                .with_context(|| format!("truncating torn journal tail in {}", path.display()))?;
        }
        let mut j = Journal { file, path: path.to_path_buf() };
        use std::io::Seek;
        j.file
            .seek(std::io::SeekFrom::End(0))
            .with_context(|| format!("seeking campaign journal {}", path.display()))?;
        Ok((j, records))
    }

    /// Append one completed unit's record. One `write_all`, newline
    /// included — a crash mid-call leaves at most a torn final line, which
    /// [`Journal::resume`] drops.
    pub fn append(&mut self, unit: usize, rec: &UnitRecord) -> Result<()> {
        self.write_line(&rec.to_line(unit))
    }

    fn write_line(&mut self, line: &str) -> Result<()> {
        let mut span = crate::obs::span("journal.append");
        let bytes = line.as_bytes();
        let write = || -> std::io::Result<()> {
            match faults::before_write("journal.append", &self.path, bytes.len())? {
                None => self.file.write_all(bytes),
                Some(torn) => {
                    // Injected crash model: persist only a prefix, then
                    // fail the campaign the way a dying process would stop
                    // it — the torn tail stays on disk for resume to heal.
                    let _ = self.file.write_all(&bytes[..torn]);
                    let _ = self.file.flush();
                    Err(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        "injected torn journal append",
                    ))
                }
            }
        };
        let result = write();
        if result.is_err() {
            span.set_outcome("error");
        }
        result.with_context(|| format!("appending to campaign journal {}", self.path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("avsm_journal_{tag}_{}.jsonl", std::process::id()))
    }

    fn write_all_records(path: &Path) -> Vec<(usize, UnitRecord)> {
        let recs = vec![
            (0, UnitRecord::Feasible { latency_ps: 2_400_000 }),
            (3, UnitRecord::Infeasible),
            (1, UnitRecord::Error { diag: "bad config".into() }),
            (4, UnitRecord::Panicked { diag: "worker died".into() }),
            (2, UnitRecord::Skipped { by_occupancy: true }),
            (5, UnitRecord::Skipped { by_occupancy: false }),
        ];
        let mut j = Journal::create(path, 0xDEAD_BEEF, 6).unwrap();
        for (u, r) in &recs {
            j.append(*u, r).unwrap();
        }
        recs
    }

    #[test]
    fn round_trips_every_record_class() {
        let path = tmp("roundtrip");
        let recs = write_all_records(&path);
        let (_, replay) = Journal::resume(&path, 0xDEAD_BEEF, 6).unwrap();
        // Every class round-trips, and the append order is preserved.
        assert_eq!(replay, recs);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn absent_file_resumes_empty_and_creates_the_header() {
        let path = tmp("absent");
        let _ = std::fs::remove_file(&path);
        let (_, replay) = Journal::resume(&path, 7, 3).unwrap();
        assert!(replay.is_empty());
        // The header exists and a second resume still agrees.
        let (_, replay) = Journal::resume(&path, 7, 3).unwrap();
        assert!(replay.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn truncation_at_every_byte_of_the_final_line_drops_only_the_tail() {
        let path = tmp("tear");
        write_all_records(&path);
        let full = std::fs::read_to_string(&path).unwrap();
        let last_line_start = full[..full.len() - 1].rfind('\n').unwrap() + 1;
        for cut in last_line_start..full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let (_, replay) = Journal::resume(&path, 0xDEAD_BEEF, 6).unwrap();
            // Unit 5 lived on the torn line; every earlier record survives
            // in append order.
            assert!(replay.iter().all(|(u, _)| *u != 5), "cut at byte {cut}");
            assert_eq!(replay.len(), 5, "cut at byte {cut}");
            assert_eq!(replay[0], (0, UnitRecord::Feasible { latency_ps: 2_400_000 }));
            assert_eq!(replay[3], (4, UnitRecord::Panicked { diag: "worker died".into() }));
            // The tear was truncated away, so the file parses cleanly and
            // appending after resume stays well-formed.
            let healed = std::fs::read_to_string(&path).unwrap();
            assert_eq!(healed.as_str(), &full[..last_line_start], "cut at byte {cut}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn append_after_healing_a_tear_is_well_formed() {
        let path = tmp("heal_append");
        write_all_records(&path);
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 4]).unwrap();
        let (mut j, _) = Journal::resume(&path, 0xDEAD_BEEF, 6).unwrap();
        j.append(5, &UnitRecord::Skipped { by_occupancy: false }).unwrap();
        let (_, replay) = Journal::resume(&path, 0xDEAD_BEEF, 6).unwrap();
        assert_eq!(
            replay.last(),
            Some(&(5, UnitRecord::Skipped { by_occupancy: false }))
        );
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn fingerprint_unit_count_and_schema_mismatches_refuse_loudly() {
        let path = tmp("mismatch");
        write_all_records(&path);
        let err = Journal::resume(&path, 0xBAD, 6).unwrap_err();
        assert!(format!("{err:#}").contains("different campaign spec"), "{err:#}");
        let err = Journal::resume(&path, 0xDEAD_BEEF, 7).unwrap_err();
        assert!(format!("{err:#}").contains("6 units"), "{err:#}");
        std::fs::write(&path, "{\"schema\":\"other-v1\",\"spec\":\"00\",\"units\":6}\n").unwrap();
        let err = Journal::resume(&path, 0xDEAD_BEEF, 6).unwrap_err();
        assert!(format!("{err:#}").contains("schema"), "{err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mid_file_corruption_is_refused_not_skipped() {
        let path = tmp("midfile");
        write_all_records(&path);
        let full = std::fs::read_to_string(&path).unwrap();
        let mut lines: Vec<&str> = full.lines().collect();
        lines[2] = "{\"class\":\"feasible\",\"latency\"";
        let corrupted = lines.join("\n") + "\n";
        std::fs::write(&path, corrupted).unwrap();
        let err = Journal::resume(&path, 0xDEAD_BEEF, 6).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("corrupt journal record"), "{msg}");
        assert!(msg.contains(":3"), "line number names the culprit: {msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn out_of_range_unit_is_refused() {
        let path = tmp("range");
        let mut j = Journal::create(&path, 1, 2).unwrap();
        j.append(2, &UnitRecord::Infeasible).unwrap();
        let err = Journal::resume(&path, 1, 2).unwrap_err();
        assert!(format!("{err:#}").contains("unit 2 of 2"), "{err:#}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn spec_parts_mismatch_names_the_differing_parts() {
        let path = tmp("parts");
        let a = SpecParts { nets: 1, base: 2, axes: 3, options: 4 };
        Journal::create_with_parts(&path, a.combined(), Some(&a), 2).unwrap();
        let b = SpecParts { axes: 30, options: 40, ..a };
        let err = Journal::resume_with_parts(&path, b.combined(), Some(&b), 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("different campaign spec"), "{msg}");
        assert!(msg.contains("the axes and options differ"), "{msg}");
        assert!(msg.contains("AVSM051"), "{msg}");
        assert!(msg.contains("re-run without --resume"), "{msg}");
        // A matching spec still resumes, parts and all.
        let (_, replay) = Journal::resume_with_parts(&path, a.combined(), Some(&a), 2).unwrap();
        assert!(replay.is_empty());
        let header = parse_header(
            std::fs::read_to_string(&path).unwrap().lines().next().unwrap(),
        )
        .unwrap();
        assert_eq!(header.parts, Some(a));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn parts_free_header_is_byte_identical_to_the_historical_form() {
        let path = tmp("parts_free");
        Journal::create(&path, 0xABCD, 1).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "{\"schema\":\"avsm-campaign-journal-v1\",\
             \"spec\":\"000000000000abcd\",\"units\":1}\n"
        );
        // Resuming a parts-free (old) journal with parts in hand falls
        // back to the bare refusal: no part names to compare against.
        let parts = SpecParts { nets: 1, base: 2, axes: 3, options: 4 };
        let err = Journal::resume_with_parts(&path, 0x1234, Some(&parts), 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("different campaign spec"), "{msg}");
        assert!(!msg.contains("— the"), "{msg}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn spec_parts_differing_and_combined_are_consistent() {
        let a = SpecParts { nets: 1, base: 2, axes: 3, options: 4 };
        assert!(a.differing(&a).is_empty());
        assert_eq!(a.combined(), a.combined());
        let b = SpecParts { nets: 9, ..a };
        assert_eq!(a.differing(&b), vec!["nets"]);
        assert_ne!(a.combined(), b.combined());
        // Round-trip through the header JSON form.
        assert_eq!(SpecParts::from_json(&parse(&a.to_json().to_string_compact()).unwrap()), Some(a));
        assert_eq!(join_names(&["nets", "base", "axes"]), "nets, base and axes");
    }

    #[test]
    fn last_record_for_a_unit_wins() {
        let path = tmp("lastwins");
        let mut j = Journal::create(&path, 1, 1).unwrap();
        j.append(0, &UnitRecord::Infeasible).unwrap();
        j.append(0, &UnitRecord::Feasible { latency_ps: 9 }).unwrap();
        let (_, replay) = Journal::resume(&path, 1, 1).unwrap();
        // Last record wins, keeping the unit's original position.
        assert_eq!(replay, vec![(0, UnitRecord::Feasible { latency_ps: 9 })]);
        std::fs::remove_file(&path).unwrap();
    }
}
