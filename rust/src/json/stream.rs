//! Streaming JSON: a pull-style event reader, an incremental writer, and
//! lazy partial-field extraction — the allocation-light layer under the
//! [`Value`](super::Value) tree API.
//!
//! The tree parser/serializer in `json.rs` is built **on top of** this
//! module, so the two layers cannot drift: `parse()` is an iterative fold
//! over [`Reader`] events and `to_string_compact`/`to_string_pretty` drive
//! [`Writer`], which means every diagnostic (message, byte offset, context
//! snippet) and every emitted byte is shared by construction.
//!
//! Design points, following the picojson idiom:
//!
//! - **No recursion.** Both reader and writer track nesting with a depth
//!   counter plus a 64-bit container-kind bitmap, so arbitrarily deep input
//!   cannot blow the stack. Nesting is bounded at [`MAX_DEPTH`] levels
//!   (documents deeper than that are rejected with a parse error rather
//!   than accepted by one layer and rejected by the other).
//! - **No allocation on the scan path.** [`Reader::next`] borrows string
//!   events straight from the input (`Cow::Borrowed`) unless an escape
//!   forces an owned copy; skipping a value ([`Reader::skip_value`])
//!   validates it without building anything.
//! - **Lazy field extraction.** [`path_raw`]/[`path_str`]/[`path_u64`]
//!   scan to one field and stop — the hot cache-store readers use these to
//!   verify a fingerprint before paying for a full decode. They are strict
//!   about everything they scan *past*, but never look at bytes after the
//!   target field.
//! - **Byte-identical emission.** [`Writer`] produces exactly the bytes of
//!   `to_string_compact`/`to_string_pretty` (golden-fixture pinned), so
//!   multi-thousand-point campaign reports stream to the output file
//!   instead of buffering a whole tree.
//!
//! Sources: byte slices borrow zero-copy. `io::Read` sources go through
//! [`FrameReader`], a refill/compact buffer that frames newline-delimited
//! documents from a socket or pipe and hands each one out as a byte slice
//! — so the slice [`Reader`] is the *only* decoder and every error string
//! and byte offset is identical whether a document arrived in memory or
//! over a wire (offsets are relative to the frame's first byte). The
//! journal replay path keeps its simpler reused `BufRead` line buffer;
//! `FrameReader` exists for long-lived connections where lines must be
//! bounded ([`DEFAULT_MAX_FRAME`]) and an oversized line must be a
//! recoverable per-frame error, not a burst OOM or a dead stream.

use anyhow::{anyhow, bail, Result};
use std::borrow::Cow;
use std::io::{Read, Write};

use super::Value;

/// Maximum container nesting accepted by [`Reader`] and [`Writer`]. One
/// bit of container-kind state per level lives in a `u64`; every schema in
/// the repo nests < 10 deep, so 64 is pure headroom.
pub const MAX_DEPTH: usize = 64;

/// One parse event. String data borrows from the input unless an escape
/// sequence forced a decode.
#[derive(Debug, Clone, PartialEq)]
pub enum Event<'a> {
    ObjBegin,
    ObjEnd,
    ArrBegin,
    ArrEnd,
    /// Object key. The following event (or `Begin`..`End` run) is its value.
    Key(Cow<'a, str>),
    Str(Cow<'a, str>),
    Int(i64),
    Num(f64),
    Bool(bool),
    Null,
}

impl Event<'_> {
    /// Unsigned coercion mirroring [`Value::as_u64`]: exact ints plus
    /// integral in-range floats.
    pub fn as_u64(&self) -> Option<u64> {
        let i = match *self {
            Event::Int(i) => i,
            Event::Num(f) if f.fract() == 0.0 && f.abs() < 9e15 => f as i64,
            _ => return None,
        };
        u64::try_from(i).ok()
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Event::Str(s) | Event::Key(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Event::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Diagnostic anchored at `pos`: the message, the byte offset, and a short
/// window of the raw input around it (lossy-decoded, so binary garbage
/// still renders). The window is clamped to UTF-8 character boundaries —
/// a fixed byte radius can land mid-codepoint on multibyte input, which
/// would render spurious replacement characters at the snippet edges.
pub(crate) fn error_at(bytes: &[u8], pos: usize, msg: impl std::fmt::Display) -> anyhow::Error {
    const WINDOW: usize = 12;
    let is_continuation = |b: u8| matches!(b, 0x80..=0xBF);
    let mut start = pos.saturating_sub(WINDOW);
    let mut end = (pos + WINDOW).min(bytes.len());
    // A UTF-8 character is at most 1 lead + 3 continuation bytes, so three
    // steps suffice; anything still mid-run after that is invalid UTF-8 and
    // the lossy decode below renders it as U+FFFD anyway.
    for _ in 0..3 {
        if start < pos && is_continuation(bytes[start]) {
            start += 1;
        }
    }
    for _ in 0..3 {
        if end > pos && end < bytes.len() && is_continuation(bytes[end]) {
            end -= 1;
        }
    }
    let mut near = String::new();
    if start > 0 {
        near.push_str("...");
    }
    near.push_str(&String::from_utf8_lossy(&bytes[start..end]));
    if end < bytes.len() {
        near.push_str("...");
    }
    anyhow!("{msg} at byte {pos} (near {near:?})")
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte"),
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    /// Before the root value.
    Start,
    /// A value must come next (after `:`, or after `,` in an array).
    Value,
    /// Right after `{`: a key or the closing brace.
    FirstKeyOrEnd,
    /// Right after `[`: a value or the closing bracket.
    FirstValueOrEnd,
    /// After a value inside a container.
    CommaOrEnd,
    /// Root value complete; only the trailing-whitespace check remains.
    Done,
    /// `Ok(None)` already returned.
    Finished,
}

/// Pull-style JSON lexer over a byte slice: call [`Reader::next`] until it
/// returns `Ok(None)`. Strict — it enforces the full document grammar
/// (separators, nesting, trailing garbage) and produces diagnostics
/// identical to [`super::parse`], because `parse` *is* this reader plus a
/// tree fold.
pub struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
    /// Bit `d-1` set ⇒ the container at depth `d` is an object.
    kinds: u64,
    state: State,
}

impl<'a> Reader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Reader { bytes, pos: 0, depth: 0, kinds: 0, state: State::Start }
    }

    /// Byte offset of the next unread input byte.
    pub fn offset(&self) -> usize {
        self.pos
    }

    /// Next event, or `Ok(None)` exactly once after a complete well-formed
    /// document (trailing non-whitespace is an error, as in `parse`).
    pub fn next(&mut self) -> Result<Option<Event<'a>>> {
        loop {
            match self.state {
                State::Finished => return Ok(None),
                State::Done => {
                    self.skip_ws();
                    if self.pos != self.bytes.len() {
                        return Err(self.err_at(self.pos, "trailing characters"));
                    }
                    self.state = State::Finished;
                    return Ok(None);
                }
                State::Start | State::Value => {
                    self.skip_ws();
                    return self.value_event().map(Some);
                }
                State::FirstKeyOrEnd => {
                    self.skip_ws();
                    if self.peek() == Some(b'}') {
                        self.pos += 1;
                        self.pop();
                        return Ok(Some(Event::ObjEnd));
                    }
                    return self.key_event().map(Some);
                }
                State::FirstValueOrEnd => {
                    self.skip_ws();
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                        self.pop();
                        return Ok(Some(Event::ArrEnd));
                    }
                    self.state = State::Value;
                }
                State::CommaOrEnd => {
                    self.skip_ws();
                    let in_obj = self.in_obj();
                    let at = self.pos;
                    match self.bump()? {
                        b',' => {
                            if in_obj {
                                self.skip_ws();
                                return self.key_event().map(Some);
                            }
                            self.state = State::Value;
                        }
                        b'}' if in_obj => {
                            self.pop();
                            return Ok(Some(Event::ObjEnd));
                        }
                        b']' if !in_obj => {
                            self.pop();
                            return Ok(Some(Event::ArrEnd));
                        }
                        other => {
                            let closer = if in_obj { '}' } else { ']' };
                            return Err(self.err_at(
                                at,
                                format!("expected ',' or '{}', got {:?}", closer, other as char),
                            ));
                        }
                    }
                }
            }
        }
    }

    /// Consume the next value whole. Scalars return their event; containers
    /// are scanned (validated, nothing built) to their matching end and
    /// return their opening event.
    pub fn take_value(&mut self) -> Result<Event<'a>> {
        let ev = self
            .next()?
            .ok_or_else(|| anyhow!("stream reader misuse: no value to take"))?;
        if matches!(ev, Event::ObjBegin | Event::ArrBegin) {
            let mut depth = 1usize;
            while depth > 0 {
                match self.next()? {
                    Some(Event::ObjBegin | Event::ArrBegin) => depth += 1,
                    Some(Event::ObjEnd | Event::ArrEnd) => depth -= 1,
                    Some(_) => {}
                    None => bail!("stream reader misuse: document ended inside a container"),
                }
            }
        }
        Ok(ev)
    }

    /// Skip-value fast path: validate and discard the next value without
    /// materializing it (strings are still escape/UTF-8 checked so errors
    /// surface with the same offsets as a full parse).
    pub fn skip_value(&mut self) -> Result<()> {
        self.take_value().map(|_| ())
    }

    // -- lexing ------------------------------------------------------------

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn err_at(&self, pos: usize, msg: impl std::fmt::Display) -> anyhow::Error {
        error_at(self.bytes, pos, msg)
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| self.err_at(self.pos, "unexpected end of input"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let at = self.pos;
        let got = self.bump()?;
        if got != b {
            return Err(
                self.err_at(at, format!("expected {:?}, got {:?}", b as char, got as char))
            );
        }
        Ok(())
    }

    fn in_obj(&self) -> bool {
        self.depth > 0 && (self.kinds >> (self.depth - 1)) & 1 == 1
    }

    fn push(&mut self, obj: bool) -> Result<()> {
        if self.depth == MAX_DEPTH {
            return Err(self.err_at(
                self.pos - 1,
                format!("nesting deeper than {MAX_DEPTH} levels"),
            ));
        }
        let bit = 1u64 << self.depth;
        if obj {
            self.kinds |= bit;
        } else {
            self.kinds &= !bit;
        }
        self.depth += 1;
        Ok(())
    }

    fn pop(&mut self) {
        self.depth -= 1;
        self.state = if self.depth == 0 { State::Done } else { State::CommaOrEnd };
    }

    fn after_scalar(&mut self) {
        self.state = if self.depth == 0 { State::Done } else { State::CommaOrEnd };
    }

    /// Whitespace is already skipped when this is called.
    fn value_event(&mut self) -> Result<Event<'a>> {
        match self
            .peek()
            .ok_or_else(|| self.err_at(self.pos, "unexpected end of input"))?
        {
            b'{' => {
                self.pos += 1;
                self.push(true)?;
                self.state = State::FirstKeyOrEnd;
                Ok(Event::ObjBegin)
            }
            b'[' => {
                self.pos += 1;
                self.push(false)?;
                self.state = State::FirstValueOrEnd;
                Ok(Event::ArrBegin)
            }
            b'"' => {
                let s = self.string()?;
                self.after_scalar();
                Ok(Event::Str(s))
            }
            b't' => {
                self.literal("true")?;
                self.after_scalar();
                Ok(Event::Bool(true))
            }
            b'f' => {
                self.literal("false")?;
                self.after_scalar();
                Ok(Event::Bool(false))
            }
            b'n' => {
                self.literal("null")?;
                self.after_scalar();
                Ok(Event::Null)
            }
            b'-' | b'0'..=b'9' => {
                let ev = self.number()?;
                self.after_scalar();
                Ok(ev)
            }
            other => {
                Err(self.err_at(self.pos, format!("unexpected character {:?}", other as char)))
            }
        }
    }

    fn key_event(&mut self) -> Result<Event<'a>> {
        let key = self.string()?;
        self.skip_ws();
        self.expect(b':')?;
        self.state = State::Value;
        Ok(Event::Key(key))
    }

    fn literal(&mut self, lit: &str) -> Result<()> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err_at(self.pos, format!("invalid literal (expected {lit:?})")))
        }
    }

    /// Borrow the string body straight from the input; the first escape
    /// switches to an owned decode ([`Reader::string_owned_tail`]).
    fn string(&mut self) -> Result<Cow<'a, str>> {
        self.expect(b'"')?;
        let start = self.pos;
        loop {
            let at = self.pos;
            match self.bump()? {
                b'"' => {
                    let body = std::str::from_utf8(&self.bytes[start..at])
                        .map_err(|_| self.err_at(start, "invalid UTF-8 in string"))?;
                    return Ok(Cow::Borrowed(body));
                }
                b'\\' => {
                    let head = std::str::from_utf8(&self.bytes[start..at])
                        .map_err(|_| self.err_at(start, "invalid UTF-8 in string"))?;
                    self.pos = at; // rewind to the backslash
                    return self.string_owned_tail(head.to_string());
                }
                b if b < 0x20 => return Err(self.err_at(at, "raw control character in string")),
                b if b < 0x80 => {}
                b => self.multibyte(b)?,
            }
        }
    }

    /// Continue a string past its first escape, building an owned copy.
    /// Escape handling (including \uXXXX surrogate pairs) anchors errors at
    /// the backslash byte, matching the tree parser's historical offsets.
    fn string_owned_tail(&mut self, mut s: String) -> Result<Cow<'a, str>> {
        loop {
            let at = self.pos;
            match self.bump()? {
                b'"' => return Ok(Cow::Owned(s)),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'b' => s.push('\u{0008}'),
                    b'f' => s.push('\u{000C}'),
                    b'n' => s.push('\n'),
                    b'r' => s.push('\r'),
                    b't' => s.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err_at(at, "invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err_at(at, "bad surrogate pair"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err_at(at, "bad unicode escape"))?,
                            );
                        }
                    }
                    other => {
                        return Err(self.err_at(at, format!("bad escape \\{:?}", other as char)))
                    }
                },
                b if b < 0x20 => return Err(self.err_at(at, "raw control character in string")),
                b if b < 0x80 => s.push(b as char),
                b => {
                    let chunk_start = self.pos - 1;
                    self.multibyte(b)?;
                    // Validated above; re-borrow the whole sequence.
                    s.push_str(
                        std::str::from_utf8(&self.bytes[chunk_start..self.pos]).unwrap(),
                    );
                }
            }
        }
    }

    /// Validate one multi-byte UTF-8 sequence whose lead byte was just
    /// consumed; advances past its continuation bytes.
    fn multibyte(&mut self, lead: u8) -> Result<()> {
        let start = self.pos - 1;
        let len = utf8_len(lead).map_err(|e| self.err_at(start, e))?;
        let end = start + len;
        if end > self.bytes.len() {
            return Err(self.err_at(start, "truncated UTF-8 sequence"));
        }
        std::str::from_utf8(&self.bytes[start..end])
            .map_err(|_| self.err_at(start, "invalid UTF-8 in string"))?;
        self.pos = end;
        Ok(())
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let at = self.pos;
            let b = self.bump()?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err_at(at, "bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Event<'a>> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Event::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Event::Num)
            .map_err(|_| self.err_at(start, format!("invalid number {text:?}")))
    }
}

// ---------------------------------------------------------------------------
// Lazy partial-field extraction
// ---------------------------------------------------------------------------

/// Position the reader on the value of `path` (each segment an object
/// key). `Ok(true)` ⇒ the next value is the target; `Ok(false)` ⇒ a
/// segment is missing or a non-object was traversed.
fn walk_to<'a>(r: &mut Reader<'a>, path: &[&str]) -> Result<bool> {
    assert!(!path.is_empty(), "path must name at least one field");
    if !matches!(r.next()?, Some(Event::ObjBegin)) {
        return Ok(false);
    }
    let mut seg = 0usize;
    loop {
        match r.next()? {
            Some(Event::Key(k)) => {
                if k == path[seg] {
                    if seg + 1 == path.len() {
                        return Ok(true);
                    }
                    seg += 1;
                    if !matches!(r.next()?, Some(Event::ObjBegin)) {
                        return Ok(false);
                    }
                } else {
                    r.skip_value()?;
                }
            }
            _ => return Ok(false), // ObjEnd: key not present at this level
        }
    }
}

/// Raw bytes of the value at `path`, exactly as they appear in the input
/// (no tree, no unescaping — for canonical-form byte comparison against a
/// known serialization). `Ok(None)` when the path is missing; `Err` when
/// the input scanned so far is malformed. Bytes *after* the target field
/// are never examined — that laziness is the point (mik-sdk's ADR-002
/// measured ~33x for exactly this shape of partial extraction).
pub fn path_raw<'a>(bytes: &'a [u8], path: &[&str]) -> Result<Option<&'a [u8]>> {
    let mut r = Reader::new(bytes);
    if !walk_to(&mut r, path)? {
        return Ok(None);
    }
    r.skip_ws();
    let start = r.offset();
    r.skip_value()?;
    Ok(Some(&bytes[start..r.offset()]))
}

/// Decoded string value at `path`; `Ok(None)` when missing or not a string.
pub fn path_str<'a>(bytes: &'a [u8], path: &[&str]) -> Result<Option<Cow<'a, str>>> {
    let mut r = Reader::new(bytes);
    if !walk_to(&mut r, path)? {
        return Ok(None);
    }
    match r.take_value()? {
        Event::Str(s) => Ok(Some(s)),
        _ => Ok(None),
    }
}

/// Unsigned integer value at `path` (same coercion as [`Value::as_u64`]:
/// exact ints, plus integral in-range floats); `Ok(None)` when missing or
/// not numeric.
pub fn path_u64(bytes: &[u8], path: &[&str]) -> Result<Option<u64>> {
    let mut r = Reader::new(bytes);
    if !walk_to(&mut r, path)? {
        return Ok(None);
    }
    Ok(r.take_value()?.as_u64())
}

// ---------------------------------------------------------------------------
// Incremental writer
// ---------------------------------------------------------------------------

/// Incremental JSON emitter: `begin_obj`/`key`/`int`/`end_obj`… straight
/// into any `io::Write`, byte-identical to `to_string_compact` (compact
/// mode) / `to_string_pretty` (pretty mode) — the golden fixtures pin
/// this. Misuse (value without a key, unbalanced end, two root values)
/// is an `Err`, not a debug_assert, so streaming report emitters fail
/// loudly instead of writing a corrupt file.
pub struct Writer<W: Write> {
    out: W,
    indent: Option<usize>,
    depth: usize,
    /// Bit `d-1` set ⇒ the container at depth `d` is an object.
    kinds: u64,
    /// Bit `d-1` set ⇒ the container at depth `d` has at least one element.
    nonempty: u64,
    /// In an object: a key has been written and its value is pending.
    has_key: bool,
    wrote_root: bool,
}

impl<W: Write> Writer<W> {
    /// Single-line output, matching `Value::to_string_compact`.
    pub fn compact(out: W) -> Self {
        Writer::with_indent(out, None)
    }

    /// 1-space-indent output, matching `Value::to_string_pretty`.
    pub fn pretty(out: W) -> Self {
        Writer::with_indent(out, Some(1))
    }

    pub fn with_indent(out: W, indent: Option<usize>) -> Self {
        Writer { out, indent, depth: 0, kinds: 0, nonempty: 0, has_key: false, wrote_root: false }
    }

    pub fn begin_obj(&mut self) -> Result<()> {
        self.pre_value()?;
        self.out.write_all(b"{")?;
        self.push(true)
    }

    pub fn end_obj(&mut self) -> Result<()> {
        if !self.in_obj() {
            bail!("stream writer misuse: end_obj outside an object");
        }
        if self.has_key {
            bail!("stream writer misuse: end_obj with a dangling key");
        }
        let had_elements = self.container_nonempty();
        self.depth -= 1;
        if had_elements {
            self.newline_indent(self.depth)?;
        }
        self.out.write_all(b"}")?;
        Ok(())
    }

    pub fn begin_arr(&mut self) -> Result<()> {
        self.pre_value()?;
        self.out.write_all(b"[")?;
        self.push(false)
    }

    pub fn end_arr(&mut self) -> Result<()> {
        if self.depth == 0 || self.in_obj() {
            bail!("stream writer misuse: end_arr outside an array");
        }
        let had_elements = self.container_nonempty();
        self.depth -= 1;
        if had_elements {
            self.newline_indent(self.depth)?;
        }
        self.out.write_all(b"]")?;
        Ok(())
    }

    /// Emit an object key; the next call must emit its value.
    pub fn key(&mut self, k: &str) -> Result<()> {
        if !self.in_obj() || self.has_key {
            bail!("stream writer misuse: key outside an object slot");
        }
        if self.container_nonempty() {
            self.out.write_all(b",")?;
        }
        self.newline_indent(self.depth)?;
        self.mark_nonempty();
        write_escaped(&mut self.out, k)?;
        self.out.write_all(b":")?;
        if self.indent.is_some() {
            self.out.write_all(b" ")?;
        }
        self.has_key = true;
        Ok(())
    }

    pub fn null(&mut self) -> Result<()> {
        self.pre_value()?;
        self.out.write_all(b"null")?;
        Ok(())
    }

    pub fn bool(&mut self, v: bool) -> Result<()> {
        self.pre_value()?;
        self.out.write_all(if v { b"true" as &[u8] } else { b"false" })?;
        Ok(())
    }

    pub fn int(&mut self, v: i64) -> Result<()> {
        self.pre_value()?;
        write!(self.out, "{v}")?;
        Ok(())
    }

    /// Unsigned helper mirroring `Value::from(u64)`: the integer fast path
    /// when it fits `i64`, the float form (magnitude-preserving) beyond.
    pub fn uint(&mut self, v: u64) -> Result<()> {
        match i64::try_from(v) {
            Ok(i) => self.int(i),
            Err(_) => self.num(v as f64),
        }
    }

    pub fn num(&mut self, v: f64) -> Result<()> {
        self.pre_value()?;
        write_num(&mut self.out, v)?;
        Ok(())
    }

    pub fn str(&mut self, v: &str) -> Result<()> {
        self.pre_value()?;
        write_escaped(&mut self.out, v)?;
        Ok(())
    }

    /// Emit a whole [`Value`] tree (iteratively — no recursion, same
    /// depth bound as the reader).
    pub fn value(&mut self, v: &Value) -> Result<()> {
        enum Task<'v> {
            Emit(&'v Value),
            ObjRest(std::collections::btree_map::Iter<'v, String, Value>),
            ArrRest(std::slice::Iter<'v, Value>),
        }
        let mut stack = vec![Task::Emit(v)];
        while let Some(task) = stack.pop() {
            match task {
                Task::Emit(v) => match v {
                    Value::Null => self.null()?,
                    Value::Bool(b) => self.bool(*b)?,
                    Value::Int(i) => self.int(*i)?,
                    Value::Num(f) => self.num(*f)?,
                    Value::Str(s) => self.str(s)?,
                    Value::Object(map) => {
                        self.begin_obj()?;
                        stack.push(Task::ObjRest(map.iter()));
                    }
                    Value::Array(items) => {
                        self.begin_arr()?;
                        stack.push(Task::ArrRest(items.iter()));
                    }
                },
                Task::ObjRest(mut it) => match it.next() {
                    Some((k, val)) => {
                        self.key(k)?;
                        stack.push(Task::ObjRest(it));
                        stack.push(Task::Emit(val));
                    }
                    None => self.end_obj()?,
                },
                Task::ArrRest(mut it) => match it.next() {
                    Some(val) => {
                        stack.push(Task::ArrRest(it));
                        stack.push(Task::Emit(val));
                    }
                    None => self.end_arr()?,
                },
            }
        }
        Ok(())
    }

    /// Validate that exactly one complete document was written, flush, and
    /// hand back the sink.
    pub fn finish(mut self) -> Result<W> {
        if self.depth != 0 {
            bail!("stream writer misuse: unclosed container");
        }
        if !self.wrote_root {
            bail!("stream writer misuse: no value written");
        }
        self.out.flush()?;
        Ok(self.out)
    }

    // -- plumbing ----------------------------------------------------------

    fn in_obj(&self) -> bool {
        self.depth > 0 && (self.kinds >> (self.depth - 1)) & 1 == 1
    }

    fn container_nonempty(&self) -> bool {
        (self.nonempty >> (self.depth - 1)) & 1 == 1
    }

    fn mark_nonempty(&mut self) {
        self.nonempty |= 1 << (self.depth - 1);
    }

    fn push(&mut self, obj: bool) -> Result<()> {
        if self.depth == MAX_DEPTH {
            bail!("stream writer misuse: nesting deeper than {MAX_DEPTH} levels");
        }
        let bit = 1u64 << self.depth;
        if obj {
            self.kinds |= bit;
        } else {
            self.kinds &= !bit;
        }
        self.nonempty &= !bit;
        self.depth += 1;
        Ok(())
    }

    /// Separator + position bookkeeping before any value lands.
    fn pre_value(&mut self) -> Result<()> {
        if self.depth == 0 {
            if self.wrote_root {
                bail!("stream writer misuse: multiple root values");
            }
            self.wrote_root = true;
        } else if self.in_obj() {
            if !self.has_key {
                bail!("stream writer misuse: object value without a key");
            }
            self.has_key = false;
        } else {
            if self.container_nonempty() {
                self.out.write_all(b",")?;
            }
            self.newline_indent(self.depth)?;
            self.mark_nonempty();
        }
        Ok(())
    }

    fn newline_indent(&mut self, depth: usize) -> Result<()> {
        if let Some(w) = self.indent {
            self.out.write_all(b"\n")?;
            for _ in 0..w * depth {
                self.out.write_all(b" ")?;
            }
        }
        Ok(())
    }
}

/// JSON number formatting shared by the tree and stream writers. Integral
/// floats keep the decimal point (python-json style "2.0"): a bare "2"
/// would re-parse as `Int` and break `Value` round-trips.
pub(crate) fn write_num<W: Write>(out: &mut W, f: f64) -> std::io::Result<()> {
    if !f.is_finite() {
        out.write_all(b"null") // JSON has no Inf/NaN
    } else if f.fract() == 0.0 {
        write!(out, "{f:.1}")
    } else {
        write!(out, "{f}")
    }
}

/// Quoted-and-escaped string emission shared by the tree and stream
/// writers. Runs of plain bytes are written as whole slices; only ASCII
/// needs escaping, so multi-byte UTF-8 passes through untouched.
pub(crate) fn write_escaped<W: Write>(out: &mut W, s: &str) -> std::io::Result<()> {
    out.write_all(b"\"")?;
    let bytes = s.as_bytes();
    let mut start = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        let esc: Option<&[u8]> = match b {
            b'"' => Some(b"\\\""),
            b'\\' => Some(b"\\\\"),
            b'\n' => Some(b"\\n"),
            b'\r' => Some(b"\\r"),
            b'\t' => Some(b"\\t"),
            b if b < 0x20 => Some(b""), // \u escape, formatted below
            _ => None,
        };
        if let Some(esc) = esc {
            out.write_all(&bytes[start..i])?;
            if esc.is_empty() {
                write!(out, "\\u{:04x}", b as u32)?;
            } else {
                out.write_all(esc)?;
            }
            start = i + 1;
        }
    }
    out.write_all(&bytes[start..])?;
    out.write_all(b"\"")
}

/// Default per-frame size cap for [`FrameReader`]: 4 MiB. The largest
/// legitimate request line (a campaign spec with @-inlined axes for a
/// dozen workloads) is well under 100 KiB, so this is generous headroom
/// while still bounding what one misbehaving client can make the daemon
/// buffer.
pub const DEFAULT_MAX_FRAME: usize = 4 << 20;

/// Initial buffer capacity for [`FrameReader`]; grows by doubling up to
/// the frame cap as larger lines arrive.
const FRAME_BUF_INIT: usize = 8 << 10;

/// Incremental newline-delimited framing over any [`io::Read`](Read)
/// source — the carried PR-9 item. Rather than a self-referential
/// incremental JSON decoder, this keeps the layering flat: `FrameReader`
/// owns a refill/compact byte buffer, finds `\n` boundaries, and yields
/// each complete line as a `&[u8]` for the existing slice [`Reader`] to
/// parse. Errors and byte offsets are therefore *byte-identical* to
/// parsing the same line from memory, by construction (and pinned by the
/// differential tests below).
///
/// Contract:
///
/// - [`next_frame`](Self::next_frame) returns `Ok(Some(frame))` per line
///   (without the trailing `\n`; a trailing `\r` is trimmed so CRLF peers
///   work), `Ok(None)` at clean end-of-stream, and `Err` for either an
///   I/O failure (fatal — carries the underlying [`io::Error`](std::io::Error),
///   downcastable) or an oversized line (recoverable — the offending
///   bytes are discarded through the terminating newline, and the next
///   call resumes with the following line).
/// - An unterminated final line at EOF is yielded as a normal frame:
///   pipes closed after the last request still deliver it.
/// - Empty lines are yielded as empty frames; skipping them is the
///   caller's policy, not the framer's.
pub struct FrameReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    /// First unconsumed byte in `buf`.
    start: usize,
    /// One past the last valid byte in `buf`.
    end: usize,
    /// Bytes in `start..scanned` are known newline-free (so a refill only
    /// rescans the fresh tail, keeping the scan linear per byte).
    scanned: usize,
    eof: bool,
    /// An over-cap line's bytes have been dropped; consume through its
    /// terminating newline, then report it as one recoverable error.
    discarding: bool,
    max_frame: usize,
}

impl<R: Read> FrameReader<R> {
    pub fn new(inner: R) -> Self {
        FrameReader {
            inner,
            buf: vec![0u8; FRAME_BUF_INIT],
            start: 0,
            end: 0,
            scanned: 0,
            eof: false,
            discarding: false,
            max_frame: DEFAULT_MAX_FRAME,
        }
    }

    /// Override the per-line byte cap (tests use tiny caps to exercise
    /// the discard path cheaply).
    pub fn with_max_frame(mut self, max_frame: usize) -> Self {
        self.max_frame = max_frame.max(1);
        self
    }

    /// Next newline-delimited frame. See the type-level contract.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>> {
        loop {
            if let Some(off) =
                self.buf[self.scanned..self.end].iter().position(|&b| b == b'\n')
            {
                let nl = self.scanned + off;
                let (fs, fe) = (self.start, nl);
                self.start = nl + 1;
                self.scanned = self.start;
                if self.discarding {
                    self.discarding = false;
                    bail!("oversized frame: line exceeds {} bytes", self.max_frame);
                }
                if fe - fs > self.max_frame {
                    bail!("oversized frame: line exceeds {} bytes", self.max_frame);
                }
                return Ok(Some(trim_cr(&self.buf[fs..fe])));
            }
            self.scanned = self.end;
            if self.eof {
                if self.discarding {
                    self.discarding = false;
                    bail!("oversized frame: line exceeds {} bytes", self.max_frame);
                }
                if self.start == self.end {
                    return Ok(None);
                }
                let (fs, fe) = (self.start, self.end);
                self.start = self.end;
                if fe - fs > self.max_frame {
                    bail!("oversized frame: line exceeds {} bytes", self.max_frame);
                }
                return Ok(Some(trim_cr(&self.buf[fs..fe])));
            }
            self.refill()?;
        }
    }

    /// Pull more bytes from the source: compact the consumed prefix away,
    /// drop (and flag) a partial line already over the cap, grow the
    /// buffer if the live region fills it, then read once.
    fn refill(&mut self) -> Result<()> {
        if self.start > 0 {
            self.buf.copy_within(self.start..self.end, 0);
            self.end -= self.start;
            self.scanned -= self.start;
            self.start = 0;
        }
        if self.end > self.max_frame {
            // The partial line can never become a legal frame; stop
            // buffering it and swallow bytes until its newline.
            self.discarding = true;
            self.end = 0;
            self.scanned = 0;
        }
        if self.end == self.buf.len() {
            let grown = (self.buf.len() * 2).min(self.max_frame + 1);
            self.buf.resize(grown.max(self.buf.len() + 1), 0);
        }
        match self.inner.read(&mut self.buf[self.end..]) {
            Ok(0) => self.eof = true,
            Ok(n) => self.end += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
        Ok(())
    }
}

/// `true` for the one error [`FrameReader::next_frame`] can return and
/// recover from: an over-cap line. Everything else (I/O) is fatal to the
/// stream.
pub fn is_oversized_frame(err: &anyhow::Error) -> bool {
    err.downcast_ref::<std::io::Error>().is_none()
        && err.to_string().starts_with("oversized frame")
}

fn trim_cr(frame: &[u8]) -> &[u8] {
    match frame {
        [rest @ .., b'\r'] => rest,
        _ => frame,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::{self, obj};

    fn events(text: &str) -> Result<Vec<String>> {
        let mut r = Reader::new(text.as_bytes());
        let mut out = Vec::new();
        while let Some(ev) = r.next()? {
            out.push(format!("{ev:?}"));
        }
        Ok(out)
    }

    #[test]
    fn reader_emits_expected_event_sequence() {
        let evs = events(r#"{"a": [1, 2.5, true], "b": null}"#).unwrap();
        assert_eq!(
            evs,
            vec![
                "ObjBegin",
                "Key(\"a\")",
                "ArrBegin",
                "Int(1)",
                "Num(2.5)",
                "Bool(true)",
                "ArrEnd",
                "Key(\"b\")",
                "Null",
                "ObjEnd",
            ]
        );
    }

    #[test]
    fn reader_borrows_escape_free_strings() {
        let text = r#"["plain", "esc\n"]"#;
        let mut r = Reader::new(text.as_bytes());
        assert_eq!(r.next().unwrap(), Some(Event::ArrBegin));
        match r.next().unwrap().unwrap() {
            Event::Str(Cow::Borrowed(s)) => assert_eq!(s, "plain"),
            other => panic!("expected a borrowed string, got {other:?}"),
        }
        match r.next().unwrap().unwrap() {
            Event::Str(Cow::Owned(s)) => assert_eq!(s, "esc\n"),
            other => panic!("expected an owned string, got {other:?}"),
        }
    }

    #[test]
    fn reader_enforces_trailing_garbage_and_depth() {
        let mut r = Reader::new(b"[] []");
        assert_eq!(r.next().unwrap(), Some(Event::ArrBegin));
        assert_eq!(r.next().unwrap(), Some(Event::ArrEnd));
        let err = r.next().unwrap_err();
        assert!(format!("{err:#}").contains("trailing characters"));

        let deep = "[".repeat(MAX_DEPTH + 1);
        let mut r = Reader::new(deep.as_bytes());
        let mut last = Ok(None);
        for _ in 0..=MAX_DEPTH {
            last = r.next();
            if last.is_err() {
                break;
            }
        }
        let msg = format!("{:#}", last.unwrap_err());
        assert!(msg.contains("nesting deeper than 64 levels"), "{msg}");
    }

    #[test]
    fn skip_value_is_strict_about_what_it_scans() {
        // Skipping still validates: the bad escape inside the skipped
        // value surfaces with the same message a full parse gives.
        let mut r = Reader::new(br#"{"a": "\x", "b": 1}"#);
        assert_eq!(r.next().unwrap(), Some(Event::ObjBegin));
        assert!(matches!(r.next().unwrap(), Some(Event::Key(_))));
        let err = r.skip_value().unwrap_err();
        assert!(format!("{err:#}").contains("bad escape"));
    }

    #[test]
    fn lazy_path_helpers_extract_without_a_tree() {
        let doc = br#"{"clock": 41, "entries": {"00ab": 7}, "meta": {"schema": "x-v1"}}"#;
        assert_eq!(path_u64(doc, &["clock"]).unwrap(), Some(41));
        assert_eq!(path_u64(doc, &["entries", "00ab"]).unwrap(), Some(7));
        assert_eq!(path_str(doc, &["meta", "schema"]).unwrap().as_deref(), Some("x-v1"));
        assert_eq!(path_raw(doc, &["entries"]).unwrap(), Some(br#"{"00ab": 7}"# as &[u8]));
        // Missing paths and type mismatches are None, not Err.
        assert_eq!(path_u64(doc, &["nope"]).unwrap(), None);
        assert_eq!(path_u64(doc, &["clock", "deeper"]).unwrap(), None);
        assert_eq!(path_str(doc, &["clock"]).unwrap(), None);
        // Malformed input scanned on the way is an Err.
        assert!(path_u64(br#"{"a": [1,, 2], "clock": 1}"#, &["clock"]).is_err());
        // ...but bytes after the target are never examined (lazy contract).
        assert_eq!(path_u64(br#"{"clock": 9, garbage"#, &["clock"]).unwrap(), Some(9));
    }

    #[test]
    fn writer_matches_tree_serializer_compact_and_pretty() {
        let doc = obj(vec![
            ("empty_arr", json::Value::Array(vec![])),
            ("empty_obj", obj(vec![])),
            ("nested", obj(vec![("xs", vec![1u32, 2, 3].into()), ("f", 2.0f64.into())])),
            ("s", "a\"b\\c\né".into()),
            ("n", json::Value::Null),
        ]);
        for indent in [None, Some(1)] {
            let mut bytes = Vec::new();
            let mut w = Writer::with_indent(&mut bytes, indent);
            w.value(&doc).unwrap();
            w.finish().unwrap();
            let want = match indent {
                None => doc.to_string_compact(),
                Some(_) => doc.to_string_pretty(),
            };
            assert_eq!(String::from_utf8(bytes).unwrap(), want);
        }
    }

    #[test]
    fn incremental_emission_equals_tree_emission() {
        let mut bytes = Vec::new();
        let mut w = Writer::compact(&mut bytes);
        w.begin_obj().unwrap();
        w.key("big").unwrap();
        w.uint(u64::MAX).unwrap();
        w.key("pts").unwrap();
        w.begin_arr().unwrap();
        for i in 0..3i64 {
            w.begin_obj().unwrap();
            w.key("i").unwrap();
            w.int(i).unwrap();
            w.end_obj().unwrap();
        }
        w.end_arr().unwrap();
        w.end_obj().unwrap();
        w.finish().unwrap();
        let want = obj(vec![
            ("big", u64::MAX.into()),
            (
                "pts",
                json::Value::Array(
                    (0..3i64).map(|i| obj(vec![("i", i.into())])).collect(),
                ),
            ),
        ])
        .to_string_compact();
        assert_eq!(String::from_utf8(bytes).unwrap(), want);
    }

    #[test]
    fn writer_rejects_misuse() {
        let mut w = Writer::compact(Vec::new());
        assert!(w.end_obj().is_err()); // nothing open
        w.begin_obj().unwrap();
        assert!(w.int(1).is_err()); // value without a key
        w.key("k").unwrap();
        assert!(w.end_obj().is_err()); // dangling key
        w.int(1).unwrap();
        w.end_obj().unwrap();
        assert!(w.int(2).is_err()); // second root
        let mut w = Writer::compact(Vec::new());
        w.begin_arr().unwrap();
        assert!(w.finish().is_err()); // unclosed container
    }

    #[test]
    fn error_context_window_respects_utf8_boundaries() {
        // Put the defect so the ±12-byte window lands mid-rocket (🚀 is 4
        // bytes): the clamped snippet must contain no replacement chars
        // from slicing — only whole characters.
        let doc = r#"{"k": "🚀🚀🚀", "x": ?}"#;
        let err = json::parse(doc).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unexpected character"), "{msg}");
        assert!(!msg.contains('\u{FFFD}'), "window sliced mid-codepoint: {msg}");
        // The multibyte payload itself still parses fine.
        let ok = json::parse(r#"{"k": "🚀é漢"}"#).unwrap();
        assert_eq!(ok.get("k").as_str(), Some("🚀é漢"));
    }

    #[test]
    fn error_window_clamps_both_edges() {
        // 24 é's (2 bytes each): any ±12 window cuts a pair on each side.
        let body = "é".repeat(24);
        let doc = format!("[\"{body}\", ?]");
        let err = json::parse(&doc).unwrap_err();
        let msg = format!("{err:#}");
        assert!(!msg.contains('\u{FFFD}'), "{msg}");
        // Errors *inside* the run clamp the leading edge too.
        let truncated = format!("[\"{body}"); // unterminated string
        let err = json::parse(&truncated).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unexpected end of input"), "{msg}");
        assert!(!msg.contains('\u{FFFD}'), "{msg}");
    }

    /// `Read` source that returns at most `chunk` bytes per call — the
    /// worst-case socket, where frames arrive in arbitrary fragments.
    struct Chunky<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }

    impl std::io::Read for Chunky<'_> {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            let n = self.chunk.min(out.len()).min(self.data.len() - self.pos);
            out[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn frames_via(data: &[u8], chunk: usize, max: usize) -> Vec<Result<Option<Vec<u8>>>> {
        let mut fr = FrameReader::new(Chunky { data, pos: 0, chunk }).with_max_frame(max);
        let mut out = Vec::new();
        loop {
            match fr.next_frame() {
                Ok(None) => {
                    out.push(Ok(None));
                    return out;
                }
                Ok(Some(f)) => out.push(Ok(Some(f.to_vec()))),
                Err(e) => out.push(Err(e)),
            }
        }
    }

    #[test]
    fn frame_reader_reassembles_lines_from_any_fragmentation() {
        let data = b"{\"a\":1}\n\n[1,2,3]\r\n\"last has no newline\"";
        for chunk in [1, 2, 3, 7, 64] {
            let got = frames_via(data, chunk, DEFAULT_MAX_FRAME);
            let frames: Vec<_> =
                got.iter().map(|r| r.as_ref().unwrap().clone()).collect();
            assert_eq!(
                frames,
                vec![
                    Some(b"{\"a\":1}".to_vec()),
                    Some(b"".to_vec()),
                    Some(b"[1,2,3]".to_vec()), // CR trimmed
                    Some(b"\"last has no newline\"".to_vec()),
                    None,
                ],
                "chunk={chunk}"
            );
        }
    }

    #[test]
    fn frame_reader_slice_reader_is_the_special_case() {
        // The whole point of framing at the byte layer: parsing a frame
        // that arrived 1 byte at a time over a "socket" must yield the
        // exact event sequence — and for corrupt documents, the exact
        // error string with the same (frame-relative) byte offset — as
        // parsing the same line from an in-memory slice.
        let lines = [
            r#"{"v":1,"kind":"ping"}"#,
            r#"{"axes":[["nce_freq_mhz",[125,250]]]}"#,
            r#"{"bad": tru}"#,
            r#"{"unterminated": "x"#,
        ];
        let data = lines.join("\n");
        for chunk in [1, 3] {
            let mut fr = FrameReader::new(Chunky {
                data: data.as_bytes(),
                pos: 0,
                chunk,
            });
            for line in &lines {
                let frame = fr.next_frame().unwrap().unwrap().to_vec();
                assert_eq!(frame, line.as_bytes());
                let streamed = events(std::str::from_utf8(&frame).unwrap());
                let direct = events(line);
                match (streamed, direct) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b),
                    (Err(a), Err(b)) => {
                        assert_eq!(format!("{a:#}"), format!("{b:#}"))
                    }
                    (a, b) => panic!("divergence on {line:?}: {a:?} vs {b:?}"),
                }
            }
            assert!(fr.next_frame().unwrap().is_none());
        }
    }

    #[test]
    fn frame_reader_oversized_line_is_recoverable() {
        // A line over the cap — even one spanning many refills — costs
        // one recoverable error; the stream then resumes on the next
        // line. Bounded memory: the over-cap bytes are discarded, not
        // buffered.
        let long = "x".repeat(50_000);
        let data = format!("{{\"ok\":1}}\n{long}\n{{\"ok\":2}}\n");
        for chunk in [1, 13, 4096] {
            let got = frames_via(data.as_bytes(), chunk, 16);
            assert_eq!(got.len(), 4, "chunk={chunk}");
            assert_eq!(got[0].as_ref().unwrap().as_deref(), Some(b"{\"ok\":1}" as &[u8]));
            let err = got[1].as_ref().unwrap_err();
            assert!(is_oversized_frame(err), "{err:#}");
            assert!(format!("{err:#}").contains("exceeds 16 bytes"), "{err:#}");
            assert_eq!(got[2].as_ref().unwrap().as_deref(), Some(b"{\"ok\":2}" as &[u8]));
            assert!(got[3].as_ref().unwrap().is_none());
        }
        // Oversized *final* frame (no terminating newline) also errors
        // once, then reports clean EOF.
        let got = frames_via(format!("a\n{long}").as_bytes(), 7, 16);
        assert_eq!(got.len(), 3);
        assert_eq!(got[0].as_ref().unwrap().as_deref(), Some(b"a" as &[u8]));
        assert!(is_oversized_frame(got[1].as_ref().unwrap_err()));
        assert!(got[2].as_ref().unwrap().is_none());
    }

    #[test]
    fn frame_reader_io_errors_are_fatal_and_downcastable() {
        struct Failing(usize);
        impl std::io::Read for Failing {
            fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
                if self.0 == 0 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::ConnectionReset,
                        "peer went away",
                    ));
                }
                self.0 -= 1;
                out[0] = b'z';
                Ok(1)
            }
        }
        let mut fr = FrameReader::new(Failing(3));
        let err = fr.next_frame().unwrap_err();
        assert!(!is_oversized_frame(&err));
        let io = err.downcast_ref::<std::io::Error>().expect("io error preserved");
        assert_eq!(io.kind(), std::io::ErrorKind::ConnectionReset);
    }
}
