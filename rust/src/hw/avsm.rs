//! AVSM timing: the abstract (paper §2) fidelity level.
//!
//! Memory transactions are charged a *flat* average latency plus pure
//! bandwidth time on the bus; the NCE runs exactly the compiler's cycle
//! counts. This is deliberately simpler than the detailed prototype model —
//! the paper attributes its 0.6–11.2 % per-layer deviation to exactly this
//! "high-level model of the memory sub-system".

use super::exec::TimingModel;
use crate::config::SystemConfig;
use crate::sim::{ClockDomain, SimTime};
use crate::taskgraph::TaskKind;
use crate::util::div_ceil64;

#[derive(Debug, Clone)]
pub struct AvsmTiming {
    nce_clk: ClockDomain,
    bus_clk: ClockDomain,
    hkp_clk: ClockDomain,
    bus_bytes_per_cycle: u64,
    dma_setup_cycles: u64,
    mem_latency_ps: SimTime,
    dispatch_cycles: u64,
    /// Annotated effective memory time per byte, in femtoseconds/byte —
    /// the one-number bandwidth estimate a designer imports as a physical
    /// annotation (peak DRAM bandwidth derated by `avsm_eff_bw_pct`).
    mem_fs_per_byte: u64,
}

impl AvsmTiming {
    pub fn new(sys: &SystemConfig) -> Self {
        let mem_peak_bytes_per_sec =
            sys.memory.freq_mhz as u128 * 1_000_000 * sys.memory.data_bytes_per_cycle as u128;
        let eff = mem_peak_bytes_per_sec * sys.memory.avsm_eff_bw_pct as u128 / 100;
        // fs per byte = 1e15 / bytes_per_sec.
        let mem_fs_per_byte = (1_000_000_000_000_000u128 / eff.max(1)) as u64;
        Self {
            nce_clk: ClockDomain::from_mhz(sys.nce.freq_mhz),
            bus_clk: ClockDomain::from_mhz(sys.bus.freq_mhz),
            hkp_clk: ClockDomain::from_mhz(sys.hkp.freq_mhz),
            bus_bytes_per_cycle: sys.bus.bytes_per_cycle,
            dma_setup_cycles: sys.dma.setup_cycles,
            mem_latency_ps: sys.memory.avg_latency_ns * 1000,
            dispatch_cycles: sys.hkp.dispatch_cycles,
            mem_fs_per_byte,
        }
    }
}

impl TimingModel for AvsmTiming {
    fn dma_pre_ps(&mut self, _kind: &TaskKind) -> SimTime {
        self.bus_clk.cycles_to_ps(self.dma_setup_cycles) + self.mem_latency_ps
    }

    fn dma_bus_ps(&mut self, _kind: &TaskKind, bytes: u64, _start: SimTime) -> SimTime {
        let cycles = div_ceil64(bytes, self.bus_bytes_per_cycle);
        let bus_ps = self.bus_clk.cycles_to_ps(cycles.max(1));
        // The transfer is paced by the slower of interconnect and the
        // annotated effective memory bandwidth.
        let mem_ps = (bytes * self.mem_fs_per_byte) / 1000;
        bus_ps.max(mem_ps)
    }

    fn compute_ps(&mut self, kind: &TaskKind) -> SimTime {
        match *kind {
            TaskKind::Compute { cycles, .. } => self.nce_clk.cycles_to_ps(cycles),
            _ => 0,
        }
    }

    fn dispatch_ps(&self) -> SimTime {
        self.hkp_clk.cycles_to_ps(self.dispatch_cycles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::taskgraph::BufferKind;

    fn timing() -> AvsmTiming {
        AvsmTiming::new(&SystemConfig::base_paper())
    }

    #[test]
    fn dma_phases() {
        let mut t = timing();
        let load = TaskKind::DmaLoad { bytes: 1600, buffer: BufferKind::Ifm };
        // Pre: 8 bus cycles @250MHz (32 ns) + 60 ns flat latency = 92 ns.
        assert_eq!(t.dma_pre_ps(&load), 8 * 4000 + 60_000);
        // Data: paced by the slower of bus (1600/32 = 50 cycles @4 ns =
        // 200_000 ps) and annotated memory bandwidth
        // (4.26 GB/s * 88% = 3.75 GB/s -> ~426 ns for 1600 B).
        let got = t.dma_bus_ps(&load, load.bytes(), 0);
        assert!(got >= 200_000, "data phase {got} below bus time");
        let eff = 533e6 * 8.0 * 0.85;
        let mem_ps = 1600.0 / eff * 1e12;
        assert!((got as f64 - mem_ps).abs() / mem_ps < 0.01, "{got} vs {mem_ps}");
    }

    #[test]
    fn bus_time_rounds_up_and_has_floor() {
        let mut t = timing();
        let tiny = TaskKind::DmaStore { bytes: 1 };
        assert_eq!(t.dma_bus_ps(&tiny, tiny.bytes(), 0), 4000); // one beat minimum
        let odd = TaskKind::DmaStore { bytes: 33 };
        // 33 B -> 2 beats of 32 (8000 ps) vs memory annotation (~8.8 ns):
        // the slower memory paces.
        let got = t.dma_bus_ps(&odd, odd.bytes(), 0);
        assert!(got >= 2 * 4000 && got < 10_000, "{got}");
    }

    #[test]
    fn big_transfer_paced_by_memory_annotation() {
        // Bus peak (8 GB/s) exceeds annotated memory bw (3.75 GB/s), so
        // big streams run at the memory annotation.
        let mut t = timing();
        let mb = TaskKind::DmaLoad { bytes: 1 << 20, buffer: BufferKind::Ifm };
        let ps = t.dma_bus_ps(&mb, mb.bytes(), 0);
        let gbs = (1u64 << 20) as f64 / (ps as f64 / 1e12) / 1e9;
        assert!(gbs < 4.0 && gbs > 3.5, "effective {gbs:.2} GB/s");
    }

    #[test]
    fn compute_uses_nce_clock() {
        let mut t = timing();
        let c = TaskKind::Compute { cycles: 1000, macs: 0 };
        assert_eq!(t.compute_ps(&c), 4_000_000); // 1000 cycles @ 250 MHz
    }

    #[test]
    fn dispatch_overhead() {
        let t = timing();
        assert_eq!(t.dispatch_ps(), 4 * 4000);
    }
}
