//! Simulation results: end-to-end and per-layer timing plus resource
//! utilization — the numbers Figs 4/5/6/7 are drawn from.

use crate::sim::SimTime;

/// Timing of one DNN layer within a simulated inference.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerTiming {
    pub index: u32,
    pub name: String,
    /// Wall-clock window of the layer: barrier-to-barrier (layers are
    /// serialized by the compiler's barrier nodes, so windows are disjoint
    /// and sum to the total).
    pub start_ps: SimTime,
    pub end_ps: SimTime,
    /// NCE busy time within the window.
    pub nce_busy_ps: SimTime,
    /// Bus busy time within the window.
    pub bus_busy_ps: SimTime,
    pub macs: u64,
    pub dma_bytes: u64,
}

impl LayerTiming {
    pub fn duration_ps(&self) -> SimTime {
        self.end_ps - self.start_ps
    }

    /// NCE occupancy in [0,1] over the layer window.
    pub fn nce_utilization(&self) -> f64 {
        self.nce_busy_ps as f64 / self.duration_ps().max(1) as f64
    }

    /// Bus occupancy in [0,1] over the layer window.
    pub fn bus_utilization(&self) -> f64 {
        self.bus_busy_ps as f64 / self.duration_ps().max(1) as f64
    }

    /// The paper's Fig 4/6 taxonomy: a layer is compute-bound when the NCE
    /// is (nearly) continuously occupied, communication-bound when the bus
    /// is, and "neither" when dependency/latency effects dominate — those
    /// are the layers where extra peak compute or bandwidth would not help.
    pub fn bound_class(&self) -> BoundClass {
        const THRESH: f64 = 0.90;
        let nce = self.nce_utilization();
        let bus = self.bus_utilization();
        if nce >= THRESH && nce >= bus {
            BoundClass::Compute
        } else if bus >= THRESH {
            BoundClass::Communication
        } else {
            BoundClass::Neither
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundClass {
    Compute,
    Communication,
    Neither,
}

impl std::fmt::Display for BoundClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BoundClass::Compute => "compute-bound",
            BoundClass::Communication => "communication-bound",
            BoundClass::Neither => "neither",
        })
    }
}

/// Full result of one simulated inference.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end processing time of the inference.
    pub total_ps: SimTime,
    pub layers: Vec<LayerTiming>,
    /// DES events processed (simulator perf counter).
    pub events: u64,
    /// Tasks executed.
    pub tasks: u64,
}

impl SimResult {
    pub fn total_ms(&self) -> f64 {
        self.total_ps as f64 / 1e9
    }

    pub fn layer(&self, name: &str) -> Option<&LayerTiming> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Achieved MAC/s over the whole inference.
    pub fn macs_per_sec(&self) -> f64 {
        let total_macs: u64 = self.layers.iter().map(|l| l.macs).sum();
        total_macs as f64 / (self.total_ps as f64 / 1e12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(nce: u64, bus: u64, dur: u64) -> LayerTiming {
        LayerTiming {
            index: 0,
            name: "l".into(),
            start_ps: 0,
            end_ps: dur,
            nce_busy_ps: nce,
            bus_busy_ps: bus,
            macs: 100,
            dma_bytes: 10,
        }
    }

    #[test]
    fn bound_classification() {
        assert_eq!(layer(95, 20, 100).bound_class(), BoundClass::Compute);
        assert_eq!(layer(20, 95, 100).bound_class(), BoundClass::Communication);
        assert_eq!(layer(50, 50, 100).bound_class(), BoundClass::Neither);
        // Both saturated: compute wins when nce >= bus.
        assert_eq!(layer(99, 95, 100).bound_class(), BoundClass::Compute);
    }

    #[test]
    fn utilization_math() {
        let l = layer(80, 40, 100);
        assert!((l.nce_utilization() - 0.8).abs() < 1e-12);
        assert!((l.bus_utilization() - 0.4).abs() < 1e-12);
        assert_eq!(l.duration_ps(), 100);
    }
}
