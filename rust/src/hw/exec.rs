//! The task-graph executor: schedules the compiled task graph onto the
//! virtual HKP / DMA channels / shared bus / NCE with full causality.
//!
//! Scheduling semantics (identical for every [`TimingModel`], so fidelity
//! levels differ *only* in timing):
//!
//! * The HKP issues a task `dispatch` after all its dependencies complete.
//! * DMA loads queue on channel 0, stores on the last channel (the classic
//!   in/out split of the paper's Fig 2 DMA); each channel serves FIFO.
//! * A DMA transfer holds its channel for a pre-phase (descriptor setup +
//!   memory latency, overlappable across channels) and then competes for
//!   the single shared bus (round-robin arbitration) for its data phase.
//! * The NCE serves compute tasks FIFO, one at a time.
//! * Barrier tasks complete instantly and mark layer boundaries.

use super::result::{LayerTiming, SimResult};
use crate::compiler::CompiledNet;
use crate::config::SystemConfig;
use crate::sim::{Arbiter, Engine, IntervalKind, SimTime, TraceRecorder};
use crate::taskgraph::{TaskId, TaskKind};
use std::collections::VecDeque;

/// Timing hooks that differentiate the AVSM from the detailed prototype.
pub trait TimingModel {
    /// Channel-held pre-bus phase (descriptor setup + memory access latency).
    fn dma_pre_ps(&mut self, kind: &TaskKind) -> SimTime;
    /// Bus-held data phase for one `bytes`-sized chunk of `kind` (the
    /// executor re-arbitrates per chunk, so `bytes <= kind.bytes()`; the
    /// kind itself is passed for region/direction dispatch). `start` is the
    /// absolute start time (the detailed model uses it for refresh windows).
    fn dma_bus_ps(&mut self, kind: &TaskKind, bytes: u64, start: SimTime) -> SimTime;
    /// NCE occupancy of a compute task.
    fn compute_ps(&mut self, kind: &TaskKind) -> SimTime;
    /// HKP per-task dispatch overhead.
    fn dispatch_ps(&self) -> SimTime;
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// Dependencies met + dispatch overhead elapsed: hand to a resource.
    Issue(TaskId),
    /// A channel finished its pre-phase and wants the bus.
    DmaPre { ch: usize },
    /// Bus data phase done.
    DmaDone { ch: usize },
    NceDone,
}

struct Channel {
    queue: VecDeque<TaskId>,
    /// Task in flight on this channel (pre-phase or data phase).
    current: Option<TaskId>,
    /// When the channel started serving `current` (for occupancy tracing).
    started: SimTime,
    /// Bytes of `current` not yet moved over the bus.
    remaining: u64,
    /// Bytes in the bus transaction currently in flight.
    chunk: u64,
}

/// The executor. Create one per simulation run.
pub struct Executor<'a, T: TimingModel> {
    sys: &'a SystemConfig,
    timing: T,
}

impl<'a, T: TimingModel> Executor<'a, T> {
    pub fn new(sys: &'a SystemConfig, timing: T) -> Self {
        Self { sys, timing }
    }

    /// Run the simulation. Monomorphized over whether tracing is on so the
    /// DSE fast path (disabled recorder) carries zero per-event trace
    /// branches or label bookkeeping.
    pub fn run(self, compiled: &CompiledNet, trace: &mut TraceRecorder) -> SimResult {
        if trace.is_enabled() {
            self.run_inner::<true>(compiled, trace)
        } else {
            self.run_inner::<false>(compiled, trace)
        }
    }

    fn run_inner<const TRACED: bool>(
        mut self,
        compiled: &CompiledNet,
        trace: &mut TraceRecorder,
    ) -> SimResult {
        let tg = &compiled.graph;
        let tasks = tg.tasks();
        let n_layers = tg.layer_count() as usize;
        let fwd = tg.dependents();
        let mut indeg = tg.indegrees();

        // Pre-size the event heap from the task graph: every task produces
        // a bounded number of in-flight events, so this eliminates heap
        // regrowth from the hot loop.
        let mut engine: Engine<Ev> = Engine::with_capacity(tasks.len() + 8);
        let mut nce_queue: VecDeque<TaskId> = VecDeque::new();
        let mut nce_current: Option<TaskId> = None;
        let n_ch = self.sys.dma.channels.max(1) as usize;
        let mut channels: Vec<Channel> = (0..n_ch)
            .map(|_| Channel {
                queue: VecDeque::new(),
                current: None,
                started: 0,
                remaining: 0,
                chunk: 0,
            })
            .collect();
        let max_txn = self.sys.bus.max_transaction_bytes.max(1);
        let mut bus_busy = false;
        let mut bus_wait = Arbiter::new(n_ch);

        // Trace resource rows (paper Fig 4: computation + communication)
        // and per-task label ids, pre-interned once so the traced hot loop
        // does a plain vector read instead of a hash lookup per interval
        // (§Perf: ~25% faster traced simulation). The untraced path skips
        // all of it.
        let (r_nce, r_bus, r_ch, label_ids) = if TRACED {
            let r_nce = trace.intern("nce");
            let r_bus = trace.intern("bus");
            let r_ch: Vec<u32> =
                (0..n_ch).map(|c| trace.intern(&format!("dma{c}"))).collect();
            let empty_label = trace.intern("");
            let label_ids: Vec<u32> = tasks
                .iter()
                .map(|t| {
                    if t.label.is_empty() {
                        empty_label
                    } else {
                        trace.intern(&t.label)
                    }
                })
                .collect();
            (r_nce, r_bus, r_ch, label_ids)
        } else {
            (0, 0, Vec::new(), Vec::new())
        };

        // Per-layer busy accounting (works with tracing disabled too).
        let mut nce_busy = vec![0u64; n_layers];
        let mut bus_busy_ps = vec![0u64; n_layers];
        let mut done_count = 0u64;

        // Layer windows from barrier completion times.
        let mut barrier_done = vec![0u64; n_layers];

        let dispatch = self.timing.dispatch_ps();

        // Seed: every dependency-free task is dispatched at t=0.
        for t in tasks {
            if t.deps.is_empty() {
                engine.schedule(dispatch, Ev::Issue(t.id));
            }
        }

        // Main loop. Completion logic is inlined via a queue of completed
        // tasks to avoid borrow gymnastics.
        let mut completed: Vec<TaskId> = Vec::new();
        loop {
            let Some(ev) = engine.pop() else { break };
            let now = engine.now();
            match ev {
                Ev::Issue(id) => {
                    match tasks[id as usize].kind {
                        TaskKind::Barrier => {
                            let layer = tasks[id as usize].layer as usize;
                            barrier_done[layer] = barrier_done[layer].max(now);
                            completed.push(id);
                        }
                        TaskKind::Compute { .. } => {
                            nce_queue.push_back(id);
                        }
                        TaskKind::DmaLoad { .. } => channels[0].queue.push_back(id),
                        TaskKind::DmaStore { .. } => {
                            channels[n_ch - 1].queue.push_back(id)
                        }
                    }
                }
                Ev::DmaPre { ch } => {
                    bus_wait.request(ch);
                }
                Ev::DmaDone { ch } => {
                    bus_busy = false;
                    let done_chunk = channels[ch].chunk;
                    channels[ch].chunk = 0;
                    channels[ch].remaining =
                        channels[ch].remaining.saturating_sub(done_chunk);
                    if channels[ch].remaining > 0 {
                        // More chunks: re-arbitrate (other channels may cut
                        // in — transfer-level interleaving).
                        bus_wait.request(ch);
                    } else {
                        let id =
                            channels[ch].current.take().expect("channel idle at DmaDone");
                        if TRACED {
                            trace.record(
                                r_ch[ch],
                                label_ids[id as usize],
                                id,
                                IntervalKind::Transfer,
                                channels[ch].started,
                                now,
                            );
                        }
                        completed.push(id);
                    }
                }
                Ev::NceDone => {
                    let id = nce_current.take().expect("NCE idle at NceDone");
                    completed.push(id);
                }
            }

            // Start NCE work if idle.
            if nce_current.is_none() {
                if let Some(id) = nce_queue.pop_front() {
                    let dur = self.timing.compute_ps(&tasks[id as usize].kind);
                    nce_current = Some(id);
                    if TRACED {
                        trace.record(
                            r_nce,
                            label_ids[id as usize],
                            id,
                            IntervalKind::Compute,
                            now,
                            now + dur,
                        );
                    }
                    nce_busy[tasks[id as usize].layer as usize] += dur;
                    engine.schedule(dur, Ev::NceDone);
                }
            }

            // Start channel pre-phases.
            for ch in 0..n_ch {
                if channels[ch].current.is_none() {
                    if let Some(id) = channels[ch].queue.pop_front() {
                        channels[ch].current = Some(id);
                        channels[ch].started = now;
                        channels[ch].remaining = tasks[id as usize].kind.bytes().max(1);
                        let pre = self.timing.dma_pre_ps(&tasks[id as usize].kind);
                        engine.schedule(pre, Ev::DmaPre { ch });
                    }
                }
            }

            // Grant the bus if free — one chunk at a time.
            if !bus_busy {
                let granted = match self.sys.bus.arbitration {
                    crate::config::ArbPolicy::FixedPriority => bus_wait.grant_fixed(),
                    crate::config::ArbPolicy::RoundRobin => bus_wait.grant(),
                };
                if let Some(ch) = granted {
                    let id = channels[ch].current.expect("granted channel has no task");
                    let chunk = channels[ch].remaining.min(max_txn).max(1);
                    channels[ch].chunk = chunk;
                    let dur = self.timing.dma_bus_ps(&tasks[id as usize].kind, chunk, now);
                    bus_busy = true;
                    if TRACED {
                        trace.record(
                            r_bus,
                            label_ids[id as usize],
                            id,
                            IntervalKind::Transfer,
                            now,
                            now + dur,
                        );
                    }
                    bus_busy_ps[tasks[id as usize].layer as usize] += dur;
                    engine.schedule(dur, Ev::DmaDone { ch });
                }
            }

            // Release dependants of completed tasks.
            for id in completed.drain(..) {
                done_count += 1;
                for &nxt in &fwd[id as usize] {
                    indeg[nxt as usize] -= 1;
                    if indeg[nxt as usize] == 0 {
                        // Barriers are bookkeeping, not HKP work.
                        let d = if matches!(tasks[nxt as usize].kind, TaskKind::Barrier) {
                            0
                        } else {
                            dispatch
                        };
                        engine.schedule(d, Ev::Issue(nxt));
                    }
                }
            }
        }

        assert_eq!(
            done_count,
            tasks.len() as u64,
            "simulation quiesced with unfinished tasks (deadlock in the schedule)"
        );

        let total = engine.now();
        // Publish the makespan to the recorder even on the untraced path,
        // where no `record` call ever ran (horizon contract).
        trace.note_horizon(total);
        // Build per-layer windows from barrier completions.
        let mut layers = Vec::with_capacity(compiled.layers.len());
        let mut prev_end = 0u64;
        for cl in &compiled.layers {
            let li = cl.index as usize;
            let end = barrier_done[li].max(prev_end);
            layers.push(LayerTiming {
                index: cl.index,
                name: cl.name.clone(),
                start_ps: prev_end,
                end_ps: end,
                nce_busy_ps: nce_busy[li],
                bus_busy_ps: bus_busy_ps[li],
                macs: cl.macs,
                dma_bytes: cl.dma_bytes,
            });
            prev_end = end;
        }

        SimResult { total_ps: total, layers, events: engine.processed(), tasks: done_count }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::models;
    use crate::hw::AvsmTiming;

    fn run_net(net: &crate::graph::DnnGraph) -> SimResult {
        let sys = SystemConfig::base_paper();
        let c = compile(net, &sys, CompileOptions::default()).unwrap();
        let mut trace = TraceRecorder::new();
        Executor::new(&sys, AvsmTiming::new(&sys)).run(&c, &mut trace)
    }

    #[test]
    fn lenet_completes() {
        let r = run_net(&models::lenet(28));
        assert!(r.total_ps > 0);
        assert_eq!(r.layers.len(), 5);
        // Layer windows are disjoint and sum to total.
        let sum: u64 = r.layers.iter().map(|l| l.duration_ps()).sum();
        assert_eq!(sum, r.total_ps);
    }

    #[test]
    fn layer_windows_are_ordered() {
        let r = run_net(&models::dilated_vgg_tiny());
        let mut prev = 0;
        for l in &r.layers {
            assert_eq!(l.start_ps, prev);
            assert!(l.end_ps >= l.start_ps);
            prev = l.end_ps;
        }
        assert_eq!(prev, r.total_ps);
    }

    #[test]
    fn busy_never_exceeds_window() {
        let r = run_net(&models::dilated_vgg_tiny());
        for l in &r.layers {
            assert!(l.nce_busy_ps <= l.duration_ps(), "layer {}", l.name);
            assert!(l.bus_busy_ps <= l.duration_ps(), "layer {}", l.name);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_net(&models::dilated_vgg_tiny());
        let b = run_net(&models::dilated_vgg_tiny());
        assert_eq!(a.total_ps, b.total_ps);
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn makespan_within_bounds() {
        // makespan >= critical path under the same timing; <= serial sum.
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut trace = TraceRecorder::disabled();
        let r = Executor::new(&sys, AvsmTiming::new(&sys)).run(&c, &mut trace);

        let mut t1 = AvsmTiming::new(&sys);
        let dur = |t: &crate::taskgraph::Task| match t.kind {
            TaskKind::Compute { .. } => t1.compute_ps(&t.kind),
            TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
                t1.dma_pre_ps(&t.kind) + t1.dma_bus_ps(&t.kind, t.kind.bytes(), 0)
            }
            TaskKind::Barrier => 0,
        };
        let cp: u64 = c.graph.critical_path(dur);
        let mut t2 = AvsmTiming::new(&sys);
        let serial: u64 = c.graph.serial_sum(|t| match t.kind {
            TaskKind::Compute { .. } => t2.compute_ps(&t.kind),
            TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
                t2.dma_pre_ps(&t.kind) + t2.dma_bus_ps(&t.kind, t.kind.bytes(), 0)
            }
            TaskKind::Barrier => 0,
        });
        assert!(r.total_ps >= cp, "makespan {} below critical path {cp}", r.total_ps);
        // Dispatch overhead inflates makespan slightly above raw serial sum
        // bound, so allow the HKP term.
        let hkp = crate::sim::ClockDomain::from_mhz(sys.hkp.freq_mhz)
            .cycles_to_ps(sys.hkp.dispatch_cycles)
            * c.graph.len() as u64;
        assert!(
            r.total_ps <= serial + hkp,
            "makespan {} above serial bound {}",
            r.total_ps,
            serial + hkp
        );
    }

    #[test]
    fn trace_has_all_resources() {
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut trace = TraceRecorder::new();
        Executor::new(&sys, AvsmTiming::new(&sys)).run(&c, &mut trace);
        let names: Vec<&str> = trace.resources().iter().map(|&(_, n)| n).collect();
        assert!(names.contains(&"nce"));
        assert!(names.contains(&"bus"));
        assert!(names.contains(&"dma0"));
    }

    #[test]
    fn single_channel_config_works() {
        let mut sys = SystemConfig::base_paper();
        sys.dma.channels = 1;
        let net = models::lenet(28);
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut trace = TraceRecorder::disabled();
        let r = Executor::new(&sys, AvsmTiming::new(&sys)).run(&c, &mut trace);
        assert!(r.total_ps > 0);
    }

    #[test]
    fn nce_intervals_never_overlap() {
        let sys = SystemConfig::base_paper();
        let net = models::dilated_vgg_tiny();
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut trace = TraceRecorder::new();
        Executor::new(&sys, AvsmTiming::new(&sys)).run(&c, &mut trace);
        let nce = trace.lookup("nce").unwrap();
        let mut ivs: Vec<_> = trace.for_resource(nce).collect();
        ivs.sort_by_key(|iv| iv.start);
        for w in ivs.windows(2) {
            assert!(w[0].end <= w[1].start, "NCE double-booked");
        }
        // Bus too.
        let bus = trace.lookup("bus").unwrap();
        let mut ivs: Vec<_> = trace.for_resource(bus).collect();
        ivs.sort_by_key(|iv| iv.start);
        for w in ivs.windows(2) {
            assert!(w[0].end <= w[1].start, "bus double-booked");
        }
    }
}
