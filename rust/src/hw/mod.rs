//! Virtual hardware models and the task-graph executor.
//!
//! [`exec`] is the shared execution engine: it schedules the hardware-
//! adapted task graph onto the virtual HKP / DMA channels / bus / NCE with
//! full causality (dependencies, queueing, round-robin bus arbitration) —
//! the part the paper says analytical models miss. The *timing* of each
//! phase is delegated to a [`TimingModel`]:
//!
//! * [`avsm::AvsmTiming`] — the abstract virtual system model: flat memory
//!   latency + bandwidth bus + the compiler's NCE cycle counts (paper §2).
//! * [`crate::detailed::PrototypeTiming`] — the cycle-level "physical
//!   prototype": DRAM banks/rows/refresh, per-burst bus protocol, NCE
//!   pipeline fill/drain. Stands in for the paper's Virtex7 FPGA
//!   measurement (DESIGN.md §2).
//!
//! Because both fidelity levels share one executor and one task graph, the
//! Fig 5 deviation between them is *purely* the modeling-abstraction gap —
//! mirroring the paper's experiment design.

pub mod avsm;
pub mod exec;
pub mod result;

pub use avsm::AvsmTiming;
pub use exec::{Executor, TimingModel};
pub use result::{LayerTiming, SimResult};

use crate::compiler::CompiledNet;
use crate::config::SystemConfig;
use crate::sim::TraceRecorder;

/// Convenience: simulate a compiled net on the AVSM timing model.
pub fn simulate_avsm(
    compiled: &CompiledNet,
    sys: &SystemConfig,
    trace: &mut TraceRecorder,
) -> SimResult {
    let timing = AvsmTiming::new(sys);
    Executor::new(sys, timing).run(compiled, trace)
}
