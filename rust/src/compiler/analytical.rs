//! Analytical (static) performance estimator — the baseline the paper
//! contrasts with simulation (§1): "Some approaches use statistical methods
//! for performance estimation … whereas simulation considers the causality.
//! Therefore, simulation is more adequate to detect communication
//! bottlenecks and blocking behavior."
//!
//! In the style of Zhang et al. (FPGA'15): each layer's time is simply
//! `max(compute_time, traffic_time)` under peak bandwidth and peak compute —
//! no arbitration, no latency, no dependency stalls, no setup overheads.
//! The comparison bench (`dse_sweep`/EXPERIMENTS.md) shows where this
//! under-predicts: latency-dominated and blocking-prone layers.
//!
//! # Admissible lower bounds on the AVSM-simulated latency
//!
//! Besides the estimators, this module is home to the campaign engine's
//! **bound-and-prune primitives**: cheap O(task-graph) lower bounds on the
//! latency `hw::simulate_avsm` would report for a compiled net under a
//! given (validated) config. Two bounds exist, each admissible on its own:
//!
//! * [`occupancy_lower_bound`] — the makespan can never be below the total
//!   occupancy of either **exclusive resource**: the single NCE serializes
//!   all compute tasks (charged exactly [`AvsmTiming::compute_ps`] each)
//!   and the single shared bus serializes all DMA data phases (charged
//!   exactly [`AvsmTiming::dma_bus_ps`] per chunk, with the executor's
//!   deterministic `max_transaction_bytes` chunking). Hence
//!   `max(Σ compute_ps, Σ_chunks dma_bus_ps) <= makespan`. Tight on
//!   throughput-saturated (wide, resource-bound) graphs; loose on deep
//!   chains that leave both resources mostly idle.
//!
//! * [`critical_path_lower_bound`] — the makespan can never be below the
//!   longest **dependency chain**: along any path of the task graph each
//!   task finishes no earlier than its latest dependency *plus its own
//!   minimum sequential time*, whatever the resource schedule. Per task
//!   that minimum replicates the executor arithmetic-exactly: a compute
//!   task costs one HKP dispatch ([`TimingModel::dispatch_ps`]) plus
//!   [`AvsmTiming::compute_ps`]; a DMA task costs one dispatch, its
//!   channel-held pre-phase ([`AvsmTiming::dma_pre_ps`]) and the sum of
//!   its per-chunk bus data phases (same `max_transaction_bytes`
//!   chunking; chunks of one task never overlap each other); a barrier is
//!   free (the executor issues released barriers with zero dispatch).
//!   Queueing, arbitration and bus contention only ever *add* time, so
//!   the topological longest path under these durations
//!   ([`TaskGraph::critical_path`]) is `<= makespan`. Tight on
//!   latency-dominated (deep-chain, low-parallelism) regions that the
//!   occupancy bound admits; loose on wide graphs.
//!
//! Since both are lower bounds of the same quantity, their maximum is too:
//! [`latency_lower_bound`] returns `max(occupancy, critical_path)`
//! ([`BoundKind::Max`]) — still admissible, and strictly tighter than
//! either alone wherever they disagree. `LB <= simulate` is
//! property-tested across hundreds of randomized nets, configs and
//! retimes (`tests/property.rs`); admissibility is what makes campaign
//! pruning *lossless* (a refused design point provably cannot join the
//! Pareto frontier).
//!
//! [`AvsmTiming::compute_ps`]: crate::hw::AvsmTiming
//! [`AvsmTiming::dma_bus_ps`]: crate::hw::AvsmTiming
//! [`AvsmTiming::dma_pre_ps`]: crate::hw::AvsmTiming
//! [`TimingModel::dispatch_ps`]: crate::hw::TimingModel::dispatch_ps
//! [`TaskGraph::critical_path`]: crate::taskgraph::TaskGraph::critical_path

use super::cost::CostModel;
use super::lower::CompiledNet;
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::sim::{ClockDomain, SimTime};
use crate::taskgraph::TaskKind;

/// Static per-layer estimate.
#[derive(Debug, Clone)]
pub struct AnalyticalEstimate {
    pub layer_names: Vec<String>,
    /// max(compute, traffic) per layer, in ps.
    pub layer_ps: Vec<SimTime>,
    pub compute_ps: Vec<SimTime>,
    pub traffic_ps: Vec<SimTime>,
}

impl AnalyticalEstimate {
    pub fn total_ps(&self) -> SimTime {
        self.layer_ps.iter().sum()
    }
}

/// Estimate using *ideal* (infinite-buffer) compute cycles and one-pass
/// traffic — what an analytical DSE would use before any compiler exists.
pub fn analytical_estimate(net: &DnnGraph, sys: &SystemConfig) -> AnalyticalEstimate {
    let cost = CostModel::from_nce(&sys.nce);
    let nce_clk = ClockDomain::from_mhz(sys.nce.freq_mhz);
    let bus_clk = ClockDomain::from_mhz(sys.bus.freq_mhz);
    let mut shape = net.input;
    let mut est = AnalyticalEstimate {
        layer_names: Vec::new(),
        layer_ps: Vec::new(),
        compute_ps: Vec::new(),
        traffic_ps: Vec::new(),
    };
    for (layer, lc) in net.layers.iter().zip(net.layer_costs()) {
        let cycles = cost.ideal_layer_cycles(&layer.op, shape);
        let compute_ps = nce_clk.cycles_to_ps(cycles);
        let bus_cycles = (lc.total_bytes() + sys.bus.bytes_per_cycle - 1) / sys.bus.bytes_per_cycle;
        let traffic_ps = bus_clk.cycles_to_ps(bus_cycles);
        est.layer_names.push(layer.name.clone());
        est.compute_ps.push(compute_ps);
        est.traffic_ps.push(traffic_ps);
        est.layer_ps.push(compute_ps.max(traffic_ps));
        shape = layer.op.out_shape(shape);
    }
    est
}

/// Same static model but fed with the *compiled* traffic/cycles (tiling
/// overheads included) — isolates "causality effects" from "tiling effects"
/// when compared against the simulators.
pub fn analytical_estimate_compiled(
    compiled: &CompiledNet,
    sys: &SystemConfig,
) -> AnalyticalEstimate {
    let nce_clk = ClockDomain::from_mhz(sys.nce.freq_mhz);
    let bus_clk = ClockDomain::from_mhz(sys.bus.freq_mhz);
    let mut est = AnalyticalEstimate {
        layer_names: Vec::new(),
        layer_ps: Vec::new(),
        compute_ps: Vec::new(),
        traffic_ps: Vec::new(),
    };
    for l in &compiled.layers {
        let compute_ps = nce_clk.cycles_to_ps(l.compute_cycles);
        let bus_cycles = (l.dma_bytes + sys.bus.bytes_per_cycle - 1) / sys.bus.bytes_per_cycle;
        let traffic_ps = bus_clk.cycles_to_ps(bus_cycles);
        est.layer_names.push(l.name.clone());
        est.compute_ps.push(compute_ps);
        est.traffic_ps.push(traffic_ps);
        est.layer_ps.push(compute_ps.max(traffic_ps));
    }
    est
}

/// Which admissible latency lower bound to compute — the campaign's
/// `--bound` A/B escape hatch. All three are provable lower bounds on the
/// AVSM-simulated makespan (see the module docs for the two derivations);
/// they differ only in tightness, never in soundness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BoundKind {
    /// Exclusive-resource occupancy: `max(Σ NCE compute, Σ bus chunks)`.
    Occupancy,
    /// Topological longest dependency chain under per-task minimum times.
    CriticalPath,
    /// `max(occupancy, critical_path)` — the tightest of the family, and
    /// the default everywhere.
    #[default]
    Max,
}

impl BoundKind {
    /// Every kind, in documentation order.
    pub const ALL: [BoundKind; 3] = [BoundKind::Occupancy, BoundKind::CriticalPath, BoundKind::Max];

    /// Stable CLI/JSON identifier.
    pub fn key(self) -> &'static str {
        match self {
            BoundKind::Occupancy => "occupancy",
            BoundKind::CriticalPath => "critical-path",
            BoundKind::Max => "max",
        }
    }

    /// Resolve a CLI/JSON identifier, with the known set in the error.
    pub fn from_key(key: &str) -> anyhow::Result<BoundKind> {
        BoundKind::ALL.into_iter().find(|k| k.key() == key).ok_or_else(|| {
            let known: Vec<&str> = BoundKind::ALL.iter().map(|k| k.key()).collect();
            anyhow::anyhow!("unknown bound {key:?} (known bounds: {})", known.join(", "))
        })
    }
}

impl std::fmt::Display for BoundKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.key())
    }
}

/// Sum of the per-chunk bus data phases of one DMA task — the executor
/// splits transfers at the bus max-transaction size and charges each chunk
/// independently; chunks of one task never overlap each other.
fn dma_data_ps(
    timing: &mut crate::hw::AvsmTiming,
    kind: &TaskKind,
    max_txn: u64,
) -> SimTime {
    use crate::hw::TimingModel;
    let mut remaining = kind.bytes().max(1);
    let mut data_ps: SimTime = 0;
    while remaining > 0 {
        let chunk = remaining.min(max_txn);
        data_ps += timing.dma_bus_ps(kind, chunk, 0);
        remaining -= chunk;
    }
    data_ps
}

/// **Occupancy lower bound**: the makespan can never be below the total
/// occupancy of either exclusive resource,
///
/// ```text
/// LB_occ = max(Σ compute_ps(task), Σ_chunks dma_bus_ps(chunk))
/// ```
///
/// — the compute roof and the bandwidth slope (including the annotated
/// effective-memory derating) at the candidate's actual clocks, replicated
/// arithmetic-exact from the timing model rather than re-derived. One
/// O(tasks) pass; no simulation. Tight when the grid point saturates a
/// resource, loose on deep chains (see the module docs).
///
/// Precondition: `sys` must be validated (clock frequencies positive), as
/// guaranteed on every path through the compile caches.
pub fn occupancy_lower_bound(compiled: &CompiledNet, sys: &SystemConfig) -> SimTime {
    use crate::hw::{AvsmTiming, TimingModel};
    let mut timing = AvsmTiming::new(sys);
    let max_txn = sys.bus.max_transaction_bytes.max(1);
    let mut nce_ps: SimTime = 0;
    let mut bus_ps: SimTime = 0;
    for task in compiled.graph.tasks() {
        match task.kind {
            TaskKind::Compute { .. } => nce_ps += timing.compute_ps(&task.kind),
            TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
                bus_ps += dma_data_ps(&mut timing, &task.kind, max_txn);
            }
            TaskKind::Barrier => {}
        }
    }
    nce_ps.max(bus_ps)
}

/// **Critical-path lower bound**: the topological longest dependency chain
/// through the cached task graph, each task charged its *minimum
/// sequential time* under the executor's exact arithmetic —
///
/// * compute: one HKP dispatch + [`compute_ps`],
/// * DMA: one HKP dispatch + the channel pre-phase ([`dma_pre_ps`]) + the
///   sum of its per-chunk bus data phases (executor `max_transaction`
///   chunking; chunks of one task are strictly sequential),
/// * barrier: 0 (released barriers are issued with zero dispatch).
///
/// Every term is a floor of what the executor actually spends on that task
/// after its dependencies complete (queueing and arbitration only add), so
/// the longest path is `<= makespan` for *any* resource schedule. Tight on
/// latency-dominated deep chains the occupancy bound admits.
///
/// Cost: one O(tasks + edges) topological pass over the cached graph.
/// Precondition: `sys` validated, as for [`occupancy_lower_bound`].
///
/// [`compute_ps`]: crate::hw::AvsmTiming
/// [`dma_pre_ps`]: crate::hw::AvsmTiming
pub fn critical_path_lower_bound(compiled: &CompiledNet, sys: &SystemConfig) -> SimTime {
    use crate::hw::{AvsmTiming, TimingModel};
    let mut timing = AvsmTiming::new(sys);
    let dispatch = timing.dispatch_ps();
    let max_txn = sys.bus.max_transaction_bytes.max(1);
    compiled.graph.critical_path(|task| match task.kind {
        TaskKind::Compute { .. } => dispatch + timing.compute_ps(&task.kind),
        TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
            dispatch
                + timing.dma_pre_ps(&task.kind)
                + dma_data_ps(&mut timing, &task.kind, max_txn)
        }
        TaskKind::Barrier => 0,
    })
}

/// The lower bound of the requested [`BoundKind`].
pub fn lower_bound(compiled: &CompiledNet, sys: &SystemConfig, kind: BoundKind) -> SimTime {
    match kind {
        BoundKind::Occupancy => occupancy_lower_bound(compiled, sys),
        BoundKind::CriticalPath => critical_path_lower_bound(compiled, sys),
        BoundKind::Max => {
            occupancy_lower_bound(compiled, sys).max(critical_path_lower_bound(compiled, sys))
        }
    }
}

/// **Admissible lower bound** on the AVSM-simulated end-to-end latency of a
/// compiled net under `sys`'s clock/width annotations — the bound-and-prune
/// primitive of the campaign engine (skip simulating design points that
/// provably cannot join the Pareto frontier).
///
/// Returns `max(occupancy, critical_path)` ([`BoundKind::Max`]): both
/// components are lower bounds of the same makespan (module docs carry the
/// two derivations), so their maximum is still admissible and strictly
/// tighter wherever they disagree — the occupancy half rules
/// resource-saturated regions, the critical-path half rules deep-chain,
/// latency-dominated regions. Frequency-only config changes reuse one
/// [`CompiledNet`], so a campaign computes this per grid point without
/// ever re-tiling.
pub fn latency_lower_bound(compiled: &CompiledNet, sys: &SystemConfig) -> SimTime {
    lower_bound(compiled, sys, BoundKind::Max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::models;
    use crate::hw::simulate_avsm;
    use crate::sim::TraceRecorder;

    #[test]
    fn estimate_covers_all_layers() {
        let net = models::dilated_vgg_paper();
        let sys = SystemConfig::base_paper();
        let est = analytical_estimate(&net, &sys);
        assert_eq!(est.layer_ps.len(), net.layers.len());
        assert!(est.total_ps() > 0);
    }

    #[test]
    fn conv4_layers_are_compute_bound_analytically() {
        let net = models::dilated_vgg_paper();
        let sys = SystemConfig::base_paper();
        let est = analytical_estimate(&net, &sys);
        for (i, name) in est.layer_names.iter().enumerate() {
            if name.starts_with("conv4_") && name != "conv4_0" {
                assert!(
                    est.compute_ps[i] > est.traffic_ps[i],
                    "{name} should be compute-bound in the static model"
                );
            }
            // Pools move bytes and barely compute.
            if name.starts_with("pool") {
                assert!(
                    est.compute_ps[i] < est.traffic_ps[i],
                    "{name} should be traffic-bound in the static model"
                );
            }
            // Upscaling is the paper's "neither" example: compute and
            // traffic within the same ballpark, no strong winner.
            if name == "upscaling" {
                let ratio = est.compute_ps[i] as f64 / est.traffic_ps[i] as f64;
                assert!((0.3..3.0).contains(&ratio), "upscaling ratio {ratio}");
            }
        }
    }

    #[test]
    fn compiled_estimate_at_least_ideal() {
        // Tiling can only add traffic/cycles, never remove them.
        let net = models::dilated_vgg(128, 2, 16);
        let sys = SystemConfig::base_paper();
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let ideal = analytical_estimate(&net, &sys);
        let comp = analytical_estimate_compiled(&c, &sys);
        for i in 0..ideal.layer_ps.len() {
            assert!(
                comp.traffic_ps[i] >= ideal.traffic_ps[i],
                "layer {} compiled traffic below ideal", ideal.layer_names[i]
            );
            assert!(comp.compute_ps[i] + 1 >= ideal.compute_ps[i]);
        }
    }

    #[test]
    fn lower_bound_is_admissible_on_builtin_nets() {
        // Every member of the bound family must stay below the simulated
        // makespan, on every built-in net.
        let sys = SystemConfig::base_paper();
        for net in [
            models::lenet(28),
            models::dilated_vgg_tiny(),
            models::dilated_vgg(128, 2, 16),
            models::tiny_resnet(32, 16, 3),
        ] {
            let c = compile(&net, &sys, CompileOptions::default()).unwrap();
            let mut tr = TraceRecorder::disabled();
            let sim = simulate_avsm(&c, &sys, &mut tr);
            for kind in BoundKind::ALL {
                let lb = lower_bound(&c, &sys, kind);
                assert!(lb > 0, "{} ({kind})", net.name);
                assert!(
                    lb <= sim.total_ps,
                    "{} ({kind}): lower bound {lb} exceeds simulated {}",
                    net.name,
                    sim.total_ps
                );
            }
        }
    }

    #[test]
    fn max_bound_dominates_both_components_everywhere() {
        let sys = SystemConfig::base_paper();
        for net in [models::lenet(28), models::dilated_vgg_tiny()] {
            let c = compile(&net, &sys, CompileOptions::default()).unwrap();
            let occ = occupancy_lower_bound(&c, &sys);
            let cp = critical_path_lower_bound(&c, &sys);
            let max = latency_lower_bound(&c, &sys);
            assert_eq!(max, occ.max(cp), "{}", net.name);
            assert!(max >= occ && max >= cp, "{}", net.name);
        }
    }

    #[test]
    fn critical_path_bound_beats_occupancy_on_a_deep_chain() {
        // The ROADMAP case the critical-path bound exists for: a deep,
        // low-parallelism chain leaves both exclusive resources mostly
        // idle (occupancy is loose) while the dependency chain itself is
        // nearly the whole makespan.
        let net = crate::testkit::deep_chain("deep_chain", 12, 16, 8);
        let sys = SystemConfig::base_paper();
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let occ = occupancy_lower_bound(&c, &sys);
        let cp = critical_path_lower_bound(&c, &sys);
        assert!(
            cp > occ,
            "deep chain must be latency-dominated: critical path {cp} <= occupancy {occ}"
        );
        let mut tr = TraceRecorder::disabled();
        let sim = simulate_avsm(&c, &sys, &mut tr);
        assert!(cp <= sim.total_ps, "critical path {cp} > simulated {}", sim.total_ps);
    }

    #[test]
    fn bound_kind_keys_round_trip_and_reject_unknowns() {
        for kind in BoundKind::ALL {
            assert_eq!(BoundKind::from_key(kind.key()).unwrap(), kind);
            assert_eq!(format!("{kind}"), kind.key());
        }
        assert_eq!(BoundKind::default(), BoundKind::Max);
        let err = BoundKind::from_key("tightest").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("known bounds"), "{msg}");
        assert!(msg.contains("critical-path"), "{msg}");
    }

    #[test]
    fn lower_bound_retimes_without_recompiling() {
        // One compilation, many clock annotations: the bound must track the
        // candidate's actual clocks and stay admissible for each retime.
        let net = models::dilated_vgg_tiny();
        let base = SystemConfig::base_paper();
        let c = compile(&net, &base, CompileOptions::default()).unwrap();
        let mut prev_lb = u64::MAX;
        for mhz in [64u64, 125, 250, 500, 1000] {
            let mut sys = base.clone();
            sys.nce.freq_mhz = mhz;
            let lb = latency_lower_bound(&c, &sys);
            let mut tr = TraceRecorder::disabled();
            let sim = simulate_avsm(&c, &sys, &mut tr);
            assert!(lb <= sim.total_ps, "{mhz} MHz: {lb} > {}", sim.total_ps);
            // A faster NCE can only lower the compute component.
            assert!(lb <= prev_lb, "{mhz} MHz raised the bound");
            prev_lb = lb;
        }
    }

    #[test]
    fn occupancy_bound_hits_bus_floor_at_high_clocks() {
        // At absurd NCE clocks the occupancy bound is paced by the bus
        // occupancy, which is frequency-independent — the bandwidth-slope
        // half of max(compute roof, bandwidth slope). (The critical-path
        // component keeps a microscopic NCE term, so this floor is a
        // property of the occupancy bound specifically.)
        let net = models::dilated_vgg_tiny();
        let base = SystemConfig::base_paper();
        let c = compile(&net, &base, CompileOptions::default()).unwrap();
        let lb_at = |mhz: u64| {
            let mut sys = base.clone();
            sys.nce.freq_mhz = mhz;
            occupancy_lower_bound(&c, &sys)
        };
        assert_eq!(lb_at(100_000), lb_at(200_000), "bus floor must dominate");
        assert!(lb_at(100_000) > 0);
    }

    #[test]
    fn faster_nce_lowers_compute_time() {
        let net = models::dilated_vgg_tiny();
        let mut sys = SystemConfig::base_paper();
        let slow = analytical_estimate(&net, &sys);
        sys.nce.freq_mhz *= 2;
        let fast = analytical_estimate(&net, &sys);
        for i in 0..slow.compute_ps.len() {
            assert!(fast.compute_ps[i] <= slow.compute_ps[i]);
        }
    }
}
