//! Analytical (static) performance estimator — the baseline the paper
//! contrasts with simulation (§1): "Some approaches use statistical methods
//! for performance estimation … whereas simulation considers the causality.
//! Therefore, simulation is more adequate to detect communication
//! bottlenecks and blocking behavior."
//!
//! In the style of Zhang et al. (FPGA'15): each layer's time is simply
//! `max(compute_time, traffic_time)` under peak bandwidth and peak compute —
//! no arbitration, no latency, no dependency stalls, no setup overheads.
//! The comparison bench (`dse_sweep`/EXPERIMENTS.md) shows where this
//! under-predicts: latency-dominated and blocking-prone layers.

use super::cost::CostModel;
use super::lower::CompiledNet;
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::sim::{ClockDomain, SimTime};
use crate::taskgraph::TaskKind;

/// Static per-layer estimate.
#[derive(Debug, Clone)]
pub struct AnalyticalEstimate {
    pub layer_names: Vec<String>,
    /// max(compute, traffic) per layer, in ps.
    pub layer_ps: Vec<SimTime>,
    pub compute_ps: Vec<SimTime>,
    pub traffic_ps: Vec<SimTime>,
}

impl AnalyticalEstimate {
    pub fn total_ps(&self) -> SimTime {
        self.layer_ps.iter().sum()
    }
}

/// Estimate using *ideal* (infinite-buffer) compute cycles and one-pass
/// traffic — what an analytical DSE would use before any compiler exists.
pub fn analytical_estimate(net: &DnnGraph, sys: &SystemConfig) -> AnalyticalEstimate {
    let cost = CostModel::from_nce(&sys.nce);
    let nce_clk = ClockDomain::from_mhz(sys.nce.freq_mhz);
    let bus_clk = ClockDomain::from_mhz(sys.bus.freq_mhz);
    let mut shape = net.input;
    let mut est = AnalyticalEstimate {
        layer_names: Vec::new(),
        layer_ps: Vec::new(),
        compute_ps: Vec::new(),
        traffic_ps: Vec::new(),
    };
    for (layer, lc) in net.layers.iter().zip(net.layer_costs()) {
        let cycles = cost.ideal_layer_cycles(&layer.op, shape);
        let compute_ps = nce_clk.cycles_to_ps(cycles);
        let bus_cycles = (lc.total_bytes() + sys.bus.bytes_per_cycle - 1) / sys.bus.bytes_per_cycle;
        let traffic_ps = bus_clk.cycles_to_ps(bus_cycles);
        est.layer_names.push(layer.name.clone());
        est.compute_ps.push(compute_ps);
        est.traffic_ps.push(traffic_ps);
        est.layer_ps.push(compute_ps.max(traffic_ps));
        shape = layer.op.out_shape(shape);
    }
    est
}

/// Same static model but fed with the *compiled* traffic/cycles (tiling
/// overheads included) — isolates "causality effects" from "tiling effects"
/// when compared against the simulators.
pub fn analytical_estimate_compiled(
    compiled: &CompiledNet,
    sys: &SystemConfig,
) -> AnalyticalEstimate {
    let nce_clk = ClockDomain::from_mhz(sys.nce.freq_mhz);
    let bus_clk = ClockDomain::from_mhz(sys.bus.freq_mhz);
    let mut est = AnalyticalEstimate {
        layer_names: Vec::new(),
        layer_ps: Vec::new(),
        compute_ps: Vec::new(),
        traffic_ps: Vec::new(),
    };
    for l in &compiled.layers {
        let compute_ps = nce_clk.cycles_to_ps(l.compute_cycles);
        let bus_cycles = (l.dma_bytes + sys.bus.bytes_per_cycle - 1) / sys.bus.bytes_per_cycle;
        let traffic_ps = bus_clk.cycles_to_ps(bus_cycles);
        est.layer_names.push(l.name.clone());
        est.compute_ps.push(compute_ps);
        est.traffic_ps.push(traffic_ps);
        est.layer_ps.push(compute_ps.max(traffic_ps));
    }
    est
}

/// **Admissible lower bound** on the AVSM-simulated end-to-end latency of a
/// compiled net under `sys`'s clock/width annotations — the bound-and-prune
/// primitive of the campaign engine (skip simulating design points that
/// provably cannot join the Pareto frontier).
///
/// Derivation: the executor serializes all compute tasks on the single NCE
/// and all DMA data phases on the single shared bus, charging exactly
/// `AvsmTiming::compute_ps` per compute task and `AvsmTiming::dma_bus_ps`
/// per bus chunk (chunking at `bus.max_transaction_bytes` is deterministic
/// and schedule-independent). The makespan therefore can never be below the
/// total occupancy of either exclusive resource, so
///
/// ```text
/// LB = max(Σ compute_ps(task), Σ_chunks dma_bus_ps(chunk))
/// ```
///
/// is a *provable* lower bound: the compute roof and the bandwidth slope
/// (including the annotated effective-memory derating) at the candidate's
/// actual clocks, replicated arithmetic-exact from the timing model rather
/// than re-derived — no rounding slack, no simulation. `LB ≤ simulate`
/// holds by construction and is property-tested over randomized nets and
/// configs.
///
/// Cost: one O(tasks) pass over the cached task graph — orders of magnitude
/// cheaper than the event-driven simulation it gates. Frequency-only config
/// changes reuse one [`CompiledNet`], so a campaign computes this per grid
/// point without ever re-tiling.
///
/// Precondition: `sys` must be validated (clock frequencies positive), as
/// guaranteed on every path through the compile caches.
pub fn latency_lower_bound(compiled: &CompiledNet, sys: &SystemConfig) -> SimTime {
    use crate::hw::{AvsmTiming, TimingModel};
    let mut timing = AvsmTiming::new(sys);
    let max_txn = sys.bus.max_transaction_bytes.max(1);
    let mut nce_ps: SimTime = 0;
    let mut bus_ps: SimTime = 0;
    for task in compiled.graph.tasks() {
        match task.kind {
            TaskKind::Compute { .. } => nce_ps += timing.compute_ps(&task.kind),
            TaskKind::DmaLoad { .. } | TaskKind::DmaStore { .. } => {
                // Replicate the executor's chunking exactly: transfers are
                // split at the bus max-transaction size and each chunk is
                // charged independently.
                let mut remaining = task.kind.bytes().max(1);
                while remaining > 0 {
                    let chunk = remaining.min(max_txn);
                    bus_ps += timing.dma_bus_ps(&task.kind, chunk, 0);
                    remaining -= chunk;
                }
            }
            TaskKind::Barrier => {}
        }
    }
    nce_ps.max(bus_ps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::graph::models;
    use crate::hw::simulate_avsm;
    use crate::sim::TraceRecorder;

    #[test]
    fn estimate_covers_all_layers() {
        let net = models::dilated_vgg_paper();
        let sys = SystemConfig::base_paper();
        let est = analytical_estimate(&net, &sys);
        assert_eq!(est.layer_ps.len(), net.layers.len());
        assert!(est.total_ps() > 0);
    }

    #[test]
    fn conv4_layers_are_compute_bound_analytically() {
        let net = models::dilated_vgg_paper();
        let sys = SystemConfig::base_paper();
        let est = analytical_estimate(&net, &sys);
        for (i, name) in est.layer_names.iter().enumerate() {
            if name.starts_with("conv4_") && name != "conv4_0" {
                assert!(
                    est.compute_ps[i] > est.traffic_ps[i],
                    "{name} should be compute-bound in the static model"
                );
            }
            // Pools move bytes and barely compute.
            if name.starts_with("pool") {
                assert!(
                    est.compute_ps[i] < est.traffic_ps[i],
                    "{name} should be traffic-bound in the static model"
                );
            }
            // Upscaling is the paper's "neither" example: compute and
            // traffic within the same ballpark, no strong winner.
            if name == "upscaling" {
                let ratio = est.compute_ps[i] as f64 / est.traffic_ps[i] as f64;
                assert!((0.3..3.0).contains(&ratio), "upscaling ratio {ratio}");
            }
        }
    }

    #[test]
    fn compiled_estimate_at_least_ideal() {
        // Tiling can only add traffic/cycles, never remove them.
        let net = models::dilated_vgg(128, 2, 16);
        let sys = SystemConfig::base_paper();
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let ideal = analytical_estimate(&net, &sys);
        let comp = analytical_estimate_compiled(&c, &sys);
        for i in 0..ideal.layer_ps.len() {
            assert!(
                comp.traffic_ps[i] >= ideal.traffic_ps[i],
                "layer {} compiled traffic below ideal", ideal.layer_names[i]
            );
            assert!(comp.compute_ps[i] + 1 >= ideal.compute_ps[i]);
        }
    }

    #[test]
    fn lower_bound_is_admissible_on_builtin_nets() {
        let sys = SystemConfig::base_paper();
        for net in [
            models::lenet(28),
            models::dilated_vgg_tiny(),
            models::dilated_vgg(128, 2, 16),
            models::tiny_resnet(32, 16, 3),
        ] {
            let c = compile(&net, &sys, CompileOptions::default()).unwrap();
            let lb = latency_lower_bound(&c, &sys);
            let mut tr = TraceRecorder::disabled();
            let sim = simulate_avsm(&c, &sys, &mut tr);
            assert!(lb > 0, "{}", net.name);
            assert!(
                lb <= sim.total_ps,
                "{}: lower bound {lb} exceeds simulated {}",
                net.name,
                sim.total_ps
            );
        }
    }

    #[test]
    fn lower_bound_retimes_without_recompiling() {
        // One compilation, many clock annotations: the bound must track the
        // candidate's actual clocks and stay admissible for each retime.
        let net = models::dilated_vgg_tiny();
        let base = SystemConfig::base_paper();
        let c = compile(&net, &base, CompileOptions::default()).unwrap();
        let mut prev_lb = u64::MAX;
        for mhz in [64u64, 125, 250, 500, 1000] {
            let mut sys = base.clone();
            sys.nce.freq_mhz = mhz;
            let lb = latency_lower_bound(&c, &sys);
            let mut tr = TraceRecorder::disabled();
            let sim = simulate_avsm(&c, &sys, &mut tr);
            assert!(lb <= sim.total_ps, "{mhz} MHz: {lb} > {}", sim.total_ps);
            // A faster NCE can only lower the compute component.
            assert!(lb <= prev_lb, "{mhz} MHz raised the bound");
            prev_lb = lb;
        }
    }

    #[test]
    fn lower_bound_hits_bus_floor_at_high_clocks() {
        // At absurd NCE clocks the bound is paced by the bus occupancy,
        // which is frequency-independent — the bandwidth-slope half of
        // max(compute roof, bandwidth slope).
        let net = models::dilated_vgg_tiny();
        let base = SystemConfig::base_paper();
        let c = compile(&net, &base, CompileOptions::default()).unwrap();
        let lb_at = |mhz: u64| {
            let mut sys = base.clone();
            sys.nce.freq_mhz = mhz;
            latency_lower_bound(&c, &sys)
        };
        assert_eq!(lb_at(100_000), lb_at(200_000), "bus floor must dominate");
        assert!(lb_at(100_000) > 0);
    }

    #[test]
    fn faster_nce_lowers_compute_time() {
        let net = models::dilated_vgg_tiny();
        let mut sys = SystemConfig::base_paper();
        let slow = analytical_estimate(&net, &sys);
        sys.nce.freq_mhz *= 2;
        let fast = analytical_estimate(&net, &sys);
        for i in 0..slow.compute_ps.len() {
            assert!(fast.compute_ps[i] <= slow.compute_ps[i]);
        }
    }
}
