//! Config-keyed compilation cache — the heart of the fast DSE pipeline.
//!
//! The compiler's output (tiling + lowered task graph) depends only on a
//! *structural* subset of [`SystemConfig`]: array geometry, per-task setup,
//! on-chip buffer capacities and datapath widths. Clock frequencies are
//! deliberately not part of that subset — the tiler's objective runs at
//! pinned reference clocks (see `compiler::tiling`), and the emitted task
//! graph carries frequency-free quantities (NCE cycles, DMA bytes). A
//! frequency change is therefore a pure *retime*: reuse the cached
//! [`CompiledNet`] and re-simulate under the new annotations, instead of a
//! full recompile per design point. This is what makes "design space
//! exploration by a click of a button" fast: a sweep over G geometries x
//! F frequencies costs G compilations, not G*F, and every `dse::topdown`
//! binary-search probe after the first is compile-free.
//!
//! The cache is internally synchronized (mutex-guarded map + `Arc`'d
//! entries) so parallel sweep workers share one instance by reference.
//! Compilation happens *outside* the lock, so distinct design points
//! compile concurrently; racers on the *same* key find an in-flight
//! marker and wait on a condvar instead of duplicating the compile — a
//! cold parallel sweep does exactly one compile per structural key.
//! Infeasible points are memoized as negative entries, so an infeasible
//! geometry fails once rather than once per frequency point.

use super::lower::{compile, CompileOptions, CompiledNet};
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::json::{obj, Value};
use anyhow::{anyhow, Result};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Content fingerprint of a DNN graph: every field the compiler reads
/// (input shape, dtype, per-layer name/op/skip), so two nets that would
/// compile differently can never share a cache entry even when they carry
/// the same display name.
fn net_fingerprint(net: &DnnGraph) -> u64 {
    let mut h = DefaultHasher::new();
    net.dtype_bytes.hash(&mut h);
    (net.input.n, net.input.c, net.input.h, net.input.w).hash(&mut h);
    for layer in &net.layers {
        layer.name.hash(&mut h);
        layer.op.hash(&mut h);
        layer.skip_from.hash(&mut h);
    }
    h.finish()
}

/// The subset of the compilation inputs that the tiler and the lowering
/// pass actually read. Two `(net, sys)` pairs with equal keys compile to
/// byte-identical [`CompiledNet`]s; in particular the key contains no clock
/// frequency, so frequency-only config changes hit the cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct CompileKey {
    // --- net identity (one cache may serve sweeps over several models) ---
    net_name: String,
    net_fingerprint: u64,
    dtype_bytes: u32,
    // --- NCE structure ---
    array_rows: u32,
    array_cols: u32,
    task_setup_cycles: u64,
    ifm_buffer_kib: u32,
    weight_buffer_kib: u32,
    ofm_buffer_kib: u32,
    // --- datapath widths entering the tiler's objective ---
    bus_bytes_per_cycle: u64,
    mem_data_bytes_per_cycle: u64,
    avsm_eff_bw_pct: u64,
    // --- compiler options ---
    double_buffer: bool,
    labels: bool,
}

impl CompileKey {
    /// Content hash of the whole key, used to *name* persistent cache
    /// entries (`campaign::store`). Deterministic within one Rust release
    /// (DefaultHasher with its fixed default state); a cross-release hash
    /// change merely renames entries, which read as cache misses and
    /// recompile — never as wrong artifacts, because every entry also
    /// embeds [`CompileKey::to_json`] and a load verifies it field by
    /// field.
    pub fn fingerprint(&self) -> u64 {
        let mut h = DefaultHasher::new();
        self.hash(&mut h);
        h.finish()
    }

    /// JSON rendering of every key field — embedded in persistent cache
    /// entries so a load can verify the stored key against the expected
    /// one exactly (stale-entry and hash-collision guard). The 64-bit net
    /// fingerprint is rendered as a hex string to avoid the f64 fallback
    /// for integers beyond i64.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("net_name", self.net_name.as_str().into()),
            ("net_fingerprint", format!("{:016x}", self.net_fingerprint).into()),
            ("dtype_bytes", self.dtype_bytes.into()),
            ("array_rows", self.array_rows.into()),
            ("array_cols", self.array_cols.into()),
            ("task_setup_cycles", self.task_setup_cycles.into()),
            ("ifm_buffer_kib", self.ifm_buffer_kib.into()),
            ("weight_buffer_kib", self.weight_buffer_kib.into()),
            ("ofm_buffer_kib", self.ofm_buffer_kib.into()),
            ("bus_bytes_per_cycle", self.bus_bytes_per_cycle.into()),
            ("mem_data_bytes_per_cycle", self.mem_data_bytes_per_cycle.into()),
            ("avsm_eff_bw_pct", self.avsm_eff_bw_pct.into()),
            ("double_buffer", self.double_buffer.into()),
            ("labels", self.labels.into()),
        ])
    }

    /// Inverse of [`CompileKey::to_json`]: reconstruct a key from its JSON
    /// rendering. Every field is required and checked-narrowed, so a
    /// reconstructed key is exactly the one that was stored — which is
    /// what lets external tooling (and the golden-file schema tests)
    /// verify persisted cache entries without recompiling their nets.
    pub fn from_json(v: &Value) -> anyhow::Result<Self> {
        use anyhow::Context;
        let fp_hex = v.req_str("net_fingerprint")?;
        let net_fingerprint = u64::from_str_radix(fp_hex, 16)
            .with_context(|| format!("bad net_fingerprint {fp_hex:?}"))?;
        Ok(Self {
            net_name: v.req_str("net_name")?.to_string(),
            net_fingerprint,
            dtype_bytes: v.req_u32("dtype_bytes")?,
            array_rows: v.req_u32("array_rows")?,
            array_cols: v.req_u32("array_cols")?,
            task_setup_cycles: v.req_u64("task_setup_cycles")?,
            ifm_buffer_kib: v.req_u32("ifm_buffer_kib")?,
            weight_buffer_kib: v.req_u32("weight_buffer_kib")?,
            ofm_buffer_kib: v.req_u32("ofm_buffer_kib")?,
            bus_bytes_per_cycle: v.req_u64("bus_bytes_per_cycle")?,
            mem_data_bytes_per_cycle: v.req_u64("mem_data_bytes_per_cycle")?,
            avsm_eff_bw_pct: v.req_u64("avsm_eff_bw_pct")?,
            double_buffer: v
                .get("double_buffer")
                .as_bool()
                .context("missing/invalid double_buffer")?,
            labels: v.get("labels").as_bool().context("missing/invalid labels")?,
        })
    }

    pub fn new(net: &DnnGraph, sys: &SystemConfig, opts: CompileOptions) -> Self {
        Self {
            net_name: net.name.clone(),
            net_fingerprint: net_fingerprint(net),
            dtype_bytes: net.dtype_bytes,
            array_rows: sys.nce.array_rows,
            array_cols: sys.nce.array_cols,
            task_setup_cycles: sys.nce.task_setup_cycles,
            ifm_buffer_kib: sys.nce.ifm_buffer_kib,
            weight_buffer_kib: sys.nce.weight_buffer_kib,
            ofm_buffer_kib: sys.nce.ofm_buffer_kib,
            bus_bytes_per_cycle: sys.bus.bytes_per_cycle,
            mem_data_bytes_per_cycle: sys.memory.data_bytes_per_cycle,
            avsm_eff_bw_pct: sys.memory.avsm_eff_bw_pct,
            double_buffer: opts.double_buffer,
            labels: opts.labels,
        }
    }
}

/// Sentinel diagnostic memoized when a cache source unwound mid-compile: a
/// poisoned slot, not a statement about the design point. Outcome
/// classification (`dse::resolve_classified`) matches on this to report an
/// *error* rather than an infeasible tiling.
pub const POISONED_SOURCE_DIAG: &str = "cache source panicked";

/// One memoized outcome: a compiled artifact, or the rendered error of an
/// infeasible structural point (negative entry — an infeasible geometry
/// fails once, not once per frequency point sharing it).
type CacheEntry = Result<Arc<CompiledNet>, String>;

fn entry_to_result(entry: &CacheEntry) -> Result<Arc<CompiledNet>> {
    match entry {
        Ok(compiled) => Ok(Arc::clone(compiled)),
        Err(msg) => Err(anyhow!("{msg}")),
    }
}

/// Map slot: either a finished outcome or a marker that some thread is
/// compiling this key right now (racers wait on the condvar for it).
#[derive(Debug)]
enum Slot {
    InFlight,
    Ready(CacheEntry),
}

/// Thread-safe memoization of [`compile`] keyed by [`CompileKey`].
#[derive(Debug, Default)]
pub struct CompileCache {
    opts: CompileOptions,
    map: Mutex<HashMap<CompileKey, Slot>>,
    done: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CompileCache {
    pub fn new(opts: CompileOptions) -> Self {
        Self {
            opts,
            map: Mutex::new(HashMap::new()),
            done: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn options(&self) -> CompileOptions {
        self.opts
    }

    /// Return the cached compilation for the structural key of `(net, sys)`,
    /// compiling on miss. Infeasible points are memoized too (as errors).
    /// The compile itself runs unlocked so distinct keys compile in
    /// parallel from worker threads; racers on the same key block until
    /// the first thread's result lands, so each key compiles exactly once.
    pub fn get_or_compile(&self, net: &DnnGraph, sys: &SystemConfig) -> Result<Arc<CompiledNet>> {
        self.get_or_compile_via(net, sys, |_| match compile(net, sys, self.opts) {
            Ok(compiled) => Ok(Arc::new(compiled)),
            Err(e) => Err(format!("{e:#}")),
        })
    }

    /// Like [`CompileCache::get_or_compile`], but the artifact for a
    /// missing key comes from `source` instead of the in-process compiler —
    /// the hook the campaign's disk-persistent cache layers on (try a
    /// serialized entry first, fall back to compiling; see
    /// `campaign::store::PersistentCache`). Everything else is identical:
    /// validation runs on every call, `source` runs unlocked exactly once
    /// per key (racers wait on the condvar), and an `Err` return is
    /// memoized as a negative entry. [`CompileCache::misses`] counts
    /// `source` invocations.
    pub fn get_or_compile_via<F>(
        &self,
        net: &DnnGraph,
        sys: &SystemConfig,
        source: F,
    ) -> Result<Arc<CompiledNet>>
    where
        F: FnOnce(&CompileKey) -> Result<Arc<CompiledNet>, String>,
    {
        // Validate the full inputs up front, on every call: validation
        // covers non-structural fields (clocks, DMA channels, DRAM
        // geometry) that are deliberately absent from the key, so a cache
        // hit must not skip it, and a validation failure must never be
        // memoized under the structural key. Past this point, any
        // `source` error is structural (tiling infeasibility) and safe
        // to memoize.
        net.validate()?;
        sys.validate()?;

        let key = CompileKey::new(net, sys, self.opts);
        let mut guard = self.map.lock().unwrap();
        loop {
            match guard.get(&key) {
                None => {
                    guard.insert(key.clone(), Slot::InFlight);
                    break;
                }
                Some(Slot::Ready(entry)) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return entry_to_result(entry);
                }
                Some(Slot::InFlight) => {
                    guard = self.done.wait(guard).unwrap();
                }
            }
        }
        drop(guard);

        // If `source` unwinds, the in-flight marker must not strand the
        // racers blocked on the condvar (std::thread::scope joins every
        // worker before re-raising a panic, so a stranded marker would
        // hang the sweep, not abort it). The guard converts an unwind
        // into a poisoned negative entry and wakes everyone.
        struct Unwind<'a> {
            cache: &'a CompileCache,
            key: Option<CompileKey>,
        }
        impl Drop for Unwind<'_> {
            fn drop(&mut self) {
                if let Some(key) = self.key.take() {
                    let mut map = self.cache.map.lock().unwrap();
                    map.insert(key, Slot::Ready(Err(POISONED_SOURCE_DIAG.into())));
                    self.cache.done.notify_all();
                }
            }
        }
        let mut unwind = Unwind { cache: self, key: Some(key) };

        let entry: CacheEntry =
            source(unwind.key.as_ref().expect("unwind guard already fired"));
        self.misses.fetch_add(1, Ordering::Relaxed);
        let result = entry_to_result(&entry);
        let key = unwind.key.take().expect("unwind guard already fired");
        let mut guard = self.map.lock().unwrap();
        guard.insert(key, Slot::Ready(entry));
        self.done.notify_all();
        result
    }

    /// Cache hits so far (probes that skipped a compile).
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far (source invocations — a compile, or a disk
    /// load for the persistent tier — successful or not; exactly one per
    /// distinct structural key).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct structural keys held (compiled artifacts plus
    /// memoized infeasibilities).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn opts() -> CompileOptions {
        CompileOptions { double_buffer: true, labels: false }
    }

    #[test]
    fn compile_key_json_round_trips_exactly() {
        let key = CompileKey::new(
            &models::lenet(28),
            &SystemConfig::base_paper(),
            opts(),
        );
        let back = CompileKey::from_json(&key.to_json()).unwrap();
        assert_eq!(back, key);
        assert_eq!(back.fingerprint(), key.fingerprint());
        // A missing field is a loud rejection, not a default.
        let mut v = key.to_json();
        if let crate::json::Value::Object(map) = &mut v {
            map.remove("array_rows");
        }
        assert!(CompileKey::from_json(&v).is_err());
        // A corrupt fingerprint string too.
        let mut v = key.to_json();
        if let crate::json::Value::Object(map) = &mut v {
            map.insert("net_fingerprint".into(), "not-hex".into());
        }
        assert!(CompileKey::from_json(&v).is_err());
    }

    #[test]
    fn frequency_change_hits_cache_and_matches_scratch_compile() {
        let net = models::dilated_vgg_tiny();
        let base = SystemConfig::base_paper();
        let cache = CompileCache::new(opts());
        let a = cache.get_or_compile(&net, &base).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));

        // Frequency-only change: must be a cache hit...
        let mut fast = base.clone();
        fast.nce.freq_mhz = 500;
        fast.bus.freq_mhz = 125;
        fast.hkp.freq_mhz = 100;
        let b = cache.get_or_compile(&net, &fast).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b));

        // ...and the shared artifact must equal a from-scratch compile of
        // the retimed config (tiling is clock-independent by construction).
        let scratch = compile(&net, &fast, opts()).unwrap();
        assert_eq!(scratch.graph, b.graph);
    }

    #[test]
    fn structural_change_misses_cache() {
        let net = models::lenet(28);
        let base = SystemConfig::base_paper();
        let cache = CompileCache::new(opts());
        cache.get_or_compile(&net, &base).unwrap();
        let mut wide = base.clone();
        wide.nce.array_cols *= 2;
        cache.get_or_compile(&net, &wide).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn distinct_nets_do_not_collide() {
        let base = SystemConfig::base_paper();
        let cache = CompileCache::new(opts());
        let a = cache.get_or_compile(&models::lenet(28), &base).unwrap();
        let b = cache.get_or_compile(&models::dilated_vgg_tiny(), &base).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn infeasible_config_error_is_memoized() {
        // A 512-wide input row (3 halo rows x 512 px x 2 B = 3 KiB) cannot
        // fit a 1 KiB IFM buffer even at single-channel tiles.
        let net = models::dilated_vgg(512, 4, 16);
        let mut tiny = SystemConfig::base_paper();
        tiny.nce.ifm_buffer_kib = 1;
        tiny.nce.weight_buffer_kib = 1;
        tiny.nce.ofm_buffer_kib = 1;
        let cache = CompileCache::new(opts());
        let first = cache.get_or_compile(&net, &tiny);
        assert!(first.is_err());
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (0, 1, 1));
        // A frequency-only variant of the same infeasible structure fails
        // from the negative entry without re-running the tiler...
        let mut retimed = tiny.clone();
        retimed.nce.freq_mhz = 500;
        let second = cache.get_or_compile(&net, &retimed);
        assert!(second.is_err());
        assert_eq!((cache.hits(), cache.misses(), cache.len()), (1, 1, 1));
        // ...and the memoized error keeps the original diagnostic.
        assert_eq!(
            format!("{:#}", second.unwrap_err()),
            format!("{:#}", first.unwrap_err())
        );
    }

    #[test]
    fn invalid_annotations_rejected_in_both_orders() {
        let net = models::lenet(28);
        let base = SystemConfig::base_paper();
        let mut bad = base.clone();
        bad.nce.freq_mhz = 0; // same structural key as base, invalid clocks

        // Warm-then-invalid: the hit path must still validate.
        let cache = CompileCache::new(opts());
        cache.get_or_compile(&net, &base).unwrap();
        assert!(cache.get_or_compile(&net, &bad).is_err());
        assert_eq!(cache.len(), 1, "validation failures must not be memoized");
        cache.get_or_compile(&net, &base).unwrap();

        // Invalid-then-valid: the failure must not poison the key.
        let cache = CompileCache::new(opts());
        assert!(cache.get_or_compile(&net, &bad).is_err());
        assert!(cache.is_empty());
        cache.get_or_compile(&net, &base).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
    }

    #[test]
    fn racing_workers_compile_each_key_once() {
        // Eight threads hit one structural key (different clocks only) on a
        // cold cache: the in-flight marker must funnel them into a single
        // compile, with everyone else counted as a hit.
        let net = models::lenet(28);
        let base = SystemConfig::base_paper();
        let cache = CompileCache::new(opts());
        std::thread::scope(|s| {
            for i in 0u64..8 {
                let cache = &cache;
                let net = &net;
                let base = &base;
                s.spawn(move || {
                    let mut sys = base.clone();
                    sys.nce.freq_mhz = 100 + i;
                    cache.get_or_compile(net, &sys).unwrap();
                });
            }
        });
        assert_eq!(cache.misses(), 1, "same structural key must compile once");
        assert_eq!(cache.hits(), 7);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn same_name_different_layers_do_not_collide() {
        // Both nets are named "dilated_vgg" with identical input shape,
        // dtype and layer count — only dense2's width differs. The content
        // fingerprint must keep them apart.
        let a = models::dilated_vgg(128, 1, 16);
        let b = models::dilated_vgg(128, 1, 32);
        assert_eq!(a.name, b.name);
        assert_eq!(a.layers.len(), b.layers.len());
        let base = SystemConfig::base_paper();
        let cache = CompileCache::new(opts());
        let ca = cache.get_or_compile(&a, &base).unwrap();
        let cb = cache.get_or_compile(&b, &base).unwrap();
        assert_eq!(cache.misses(), 2, "distinct nets must not share a key");
        assert!(!Arc::ptr_eq(&ca, &cb));
    }
}
