//! Lowering: DNN graph + tiling -> hardware-adapted task graph.
//!
//! Each tile becomes a `load IFM / load W / compute / store OFM` group with
//! dependencies that encode both data flow and on-chip buffer reuse:
//!
//! * data deps — a compute needs its loads; a store needs the last
//!   accumulation compute of its OFM tile; layer N+1 needs layer N's barrier.
//! * buffer deps — with double buffering (the default, matching the paper's
//!   DMA/NCE overlap visible in Fig 4) the load for tile j may start as soon
//!   as the compute of tile j-2 freed its buffer half; without it, tile j
//!   waits for compute j-1 (fully serial load->compute->store).
//!
//! Conv+bias+ReLU are fused into the compute task (the fusion pass): the
//! activation happens on the NCE's output path at no extra cycles, so no
//! separate task is emitted — one of the compiler transformations the paper
//! insists must be visible to the performance model.

use super::cost::CostModel;
use super::tiling::{self, LayerTiling};
use crate::config::SystemConfig;
use crate::graph::{DnnGraph, Op, TensorShape};
use crate::taskgraph::{BufferKind, TaskGraph, TaskId, TaskKind};
use anyhow::{Context, Result};

/// Compiler options (the software half of the design space).
#[derive(Debug, Clone, Copy)]
pub struct CompileOptions {
    /// Overlap DMA with compute using two buffer halves per on-chip buffer.
    pub double_buffer: bool,
    /// Emit human-readable task labels. Costs allocations; disable for DSE
    /// sweeps where the labels are never read.
    pub labels: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self { double_buffer: true, labels: true }
    }
}

/// Per-layer compilation record (feeds Fig 5/6/7 reporting).
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledLayer {
    pub index: u32,
    pub name: String,
    pub tiling: LayerTiling,
    /// Total NCE compute cycles over all tiles of the layer.
    pub compute_cycles: u64,
    /// Total bytes this layer moves over the bus.
    pub dma_bytes: u64,
    pub macs: u64,
    /// The layer's closing barrier task.
    pub barrier: TaskId,
}

/// The compiler's output: the task graph plus per-layer metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct CompiledNet {
    pub graph: TaskGraph,
    pub layers: Vec<CompiledLayer>,
}

impl CompiledNet {
    /// Tasks of one layer (by layer index recorded on each task).
    pub fn layer_tasks(&self, layer: u32) -> impl Iterator<Item = &crate::taskgraph::Task> {
        self.graph.tasks().iter().filter(move |t| t.layer == layer)
    }
}

/// Compile a DNN graph for a system configuration.
pub fn compile(net: &DnnGraph, sys: &SystemConfig, opts: CompileOptions) -> Result<CompiledNet> {
    net.validate()?;
    sys.validate()?;
    let cost = CostModel::from_nce(&sys.nce);
    let mut tg = TaskGraph::new(net.name.clone());
    let mut layers = Vec::with_capacity(net.layers.len());
    let mut prev_barrier: Option<TaskId> = None;
    let mut shape = net.input;
    let shapes = net.layer_shapes();

    for (li, layer) in net.layers.iter().enumerate() {
        let input = shape;
        let out = shapes[li];
        shape = out;
        let tiling = tiling::tile_layer(sys, &layer.op, input, net.dtype_bytes)
            .with_context(|| format!("tiling layer {:?}", layer.name))?;
        let compiled = match tiling {
            LayerTiling::Conv(choice) => lower_conv(
                &mut tg, &cost, li as u32, &layer.name, &layer.op, input, out, choice,
                net.dtype_bytes, prev_barrier, opts,
            ),
            LayerTiling::Vector(vt) => lower_vector(
                &mut tg, &cost, li as u32, layer, input, out, vt, net.dtype_bytes,
                prev_barrier, opts, &shapes,
            ),
        };
        prev_barrier = Some(compiled.barrier);
        layers.push(CompiledLayer { tiling, ..compiled });
    }
    debug_assert!(tg.validate().is_ok());
    Ok(CompiledNet { graph: tg, layers })
}

struct PartialLayer {
    index: u32,
    name: String,
    compute_cycles: u64,
    dma_bytes: u64,
    macs: u64,
    barrier: TaskId,
}

// Conversion helper: PartialLayer + tiling -> CompiledLayer via struct
// update syntax in `compile`.
impl PartialLayer {
    fn into_compiled(self, tiling: LayerTiling) -> CompiledLayer {
        CompiledLayer {
            index: self.index,
            name: self.name,
            tiling,
            compute_cycles: self.compute_cycles,
            dma_bytes: self.dma_bytes,
            macs: self.macs,
            barrier: self.barrier,
        }
    }
}

fn label(opts: CompileOptions, f: impl FnOnce() -> String) -> String {
    if opts.labels {
        f()
    } else {
        String::new()
    }
}

#[allow(clippy::too_many_arguments)]
fn lower_conv(
    tg: &mut TaskGraph,
    cost: &CostModel,
    li: u32,
    lname: &str,
    op: &Op,
    input: TensorShape,
    out: TensorShape,
    t: tiling::TilingChoice,
    dtype: u32,
    prev_barrier: Option<TaskId>,
    opts: CompileOptions,
) -> CompiledLayer {
    let (cin, _cout, kh, kw, stride, dilation) = match *op {
        Op::Conv2d { cin, cout, kh, kw, stride, dilation, .. } => {
            (cin, cout, kh, kw, stride, dilation)
        }
        _ => unreachable!("lower_conv on non-conv"),
    };
    let eff_kh = tiling::effective_k(kh, dilation);
    let base_dep: Vec<TaskId> = prev_barrier.into_iter().collect();

    let mut compute_cycles = 0u64;
    let mut dma_bytes = 0u64;
    let mut macs = 0u64;
    let mut stores: Vec<TaskId> = Vec::new();

    // Buffer-reuse rings: the compute that last used each buffer half.
    let depth = if opts.double_buffer { 2 } else { 1 };
    let mut load_ring: Vec<Option<TaskId>> = vec![None; depth];
    let mut store_ring: Vec<Option<TaskId>> = vec![None; depth];
    let mut tile_idx = 0usize;
    let mut group_idx = 0usize;

    // When the whole-channel stripe is IFM-resident, its loads are hoisted
    // out of the cout loop: one load per (stripe, cin tile), reused by every
    // cout tile; the stripe buffer is recycled per stripe (ring of stripes).
    let mut stripe_ring: Vec<Option<TaskId>> = vec![None; depth];

    for s in 0..t.n_oh {
        let oh0 = s * t.oh_t;
        let rows = t.oh_t.min(out.h - oh0);
        let ih_rows = ((rows - 1) * stride + eff_kh).min(input.h);

        // Hoisted IFM loads (resident stripes only).
        let mut stripe_ifm_loads: Vec<TaskId> = Vec::new();
        if t.ifm_resident {
            let mut load_deps = base_dep.clone();
            if let Some(prev) = stripe_ring[s as usize % depth] {
                load_deps.push(prev);
            }
            for ic in 0..t.n_cin {
                let cin_this = t.cin_t.min(cin - ic * t.cin_t);
                let ifm_bytes =
                    cin_this as u64 * ih_rows as u64 * input.w as u64 * dtype as u64;
                dma_bytes += ifm_bytes;
                stripe_ifm_loads.push(tg.push(
                    li,
                    label(opts, || format!("{lname}/s{s}i{ic}/ld_ifm")),
                    TaskKind::DmaLoad { bytes: ifm_bytes, buffer: BufferKind::Ifm },
                    load_deps.clone(),
                ));
            }
        }
        let mut stripe_last_compute: Option<TaskId> = None;

        for oc in 0..t.n_cout {
            let cout_this = t.cout_t.min(
                match *op {
                    Op::Conv2d { cout, .. } => cout,
                    _ => unreachable!(),
                } - oc * t.cout_t,
            );
            let mut last_compute: Option<TaskId> = None;
            for ic in 0..t.n_cin {
                let cin_this = t.cin_t.min(cin - ic * t.cin_t);
                let w_bytes = (cin_this as u64 * cout_this as u64 * kh as u64 * kw as u64
                    + cout_this as u64)
                    * dtype as u64;

                // Loads wait for the previous tenant of this buffer half.
                let ring_slot = tile_idx % depth;
                let mut load_deps = base_dep.clone();
                if let Some(prev) = load_ring[ring_slot] {
                    load_deps.push(prev);
                }
                let ld_ifm = if t.ifm_resident {
                    stripe_ifm_loads[ic as usize]
                } else {
                    let ifm_bytes =
                        cin_this as u64 * ih_rows as u64 * input.w as u64 * dtype as u64;
                    dma_bytes += ifm_bytes;
                    tg.push(
                        li,
                        label(opts, || format!("{lname}/s{s}o{oc}i{ic}/ld_ifm")),
                        TaskKind::DmaLoad { bytes: ifm_bytes, buffer: BufferKind::Ifm },
                        load_deps.clone(),
                    )
                };
                let ld_w = tg.push(
                    li,
                    label(opts, || format!("{lname}/s{s}o{oc}i{ic}/ld_w")),
                    TaskKind::DmaLoad { bytes: w_bytes, buffer: BufferKind::Weights },
                    load_deps,
                );
                dma_bytes += w_bytes;

                let cycles = cost.conv_tile_cycles(rows, out.w, kh, kw, cin_this, cout_this)
                    + cost.task_setup_cycles;
                let tile_macs =
                    cost.conv_tile_macs(rows, out.w, kh, kw, cin_this, cout_this);
                compute_cycles += cycles;
                macs += tile_macs;

                let mut deps = vec![ld_ifm, ld_w];
                if let Some(prev) = last_compute {
                    deps.push(prev); // accumulate into the same OFM tile
                }
                // First compute of a group claims the OFM buffer half.
                if ic == 0 {
                    if let Some(prev_store) = store_ring[group_idx % depth] {
                        deps.push(prev_store);
                    }
                }
                let comp = tg.push(
                    li,
                    label(opts, || format!("{lname}/s{s}o{oc}i{ic}/mac")),
                    TaskKind::Compute { cycles, macs: tile_macs },
                    deps,
                );
                load_ring[ring_slot] = Some(comp);
                last_compute = Some(comp);
                stripe_last_compute = Some(comp);
                tile_idx += 1;
            }
            let ofm_bytes = cout_this as u64 * rows as u64 * out.w as u64 * dtype as u64;
            dma_bytes += ofm_bytes;
            let st = tg.push(
                li,
                label(opts, || format!("{lname}/s{s}o{oc}/st_ofm")),
                TaskKind::DmaStore { bytes: ofm_bytes },
                vec![last_compute.expect("group has at least one compute")],
            );
            store_ring[group_idx % depth] = Some(st);
            stores.push(st);
            group_idx += 1;
        }
        stripe_ring[s as usize % depth] = stripe_last_compute;
    }

    let barrier = tg.push(li, label(opts, || format!("{lname}/end")), TaskKind::Barrier, stores);
    PartialLayer {
        index: li,
        name: lname.to_string(),
        compute_cycles,
        dma_bytes,
        macs,
        barrier,
    }
    .into_compiled(LayerTiling::Conv(t))
}

#[allow(clippy::too_many_arguments)]
fn lower_vector(
    tg: &mut TaskGraph,
    cost: &CostModel,
    li: u32,
    layer: &crate::graph::Layer,
    input: TensorShape,
    out: TensorShape,
    t: tiling::VectorTiling,
    dtype: u32,
    prev_barrier: Option<TaskId>,
    opts: CompileOptions,
    shapes: &[TensorShape],
) -> CompiledLayer {
    let lname = &layer.name;
    let base_dep: Vec<TaskId> = prev_barrier.into_iter().collect();
    let depth = if opts.double_buffer { 2 } else { 1 };
    let mut ring: Vec<Option<TaskId>> = vec![None; depth];
    let mut stores = Vec::new();
    let mut compute_cycles = 0u64;
    let mut dma_bytes = 0u64;
    let mut macs = 0u64;

    // Per-output-row byte rates.
    let ops_per_elem: u64 = match layer.op {
        Op::MaxPool { window, .. } => (window * window) as u64,
        Op::UpsampleBilinear { .. } => 4,
        Op::EltwiseAdd => 1,
        Op::DepthwiseConv2d { .. } => 0, // costed via the MAC-array model below
        Op::Conv2d { .. } => unreachable!("conv must use lower_conv"),
    };
    // Depthwise weights (c*k*k, small) ride along with the first stripe.
    let dw_weight_bytes: u64 = layer.op.weight_bytes(dtype);
    // Skip operand (eltwise): the second input stripe is loaded too.
    let skip_row_bytes: u64 = layer
        .skip_from
        .map(|src| shapes[src].c as u64 * shapes[src].w as u64 * dtype as u64)
        .unwrap_or(0);

    for s in 0..t.n_oh {
        let oh0 = s * t.oh_t;
        let rows = t.oh_t.min(out.h - oh0);
        let in_rows = match layer.op {
            Op::MaxPool { window, stride } => {
                ((rows - 1) * stride + window).min(input.h)
            }
            Op::UpsampleBilinear { factor } => {
                ((rows + factor - 1) / factor + 1).min(input.h)
            }
            Op::DepthwiseConv2d { kh, stride, dilation, .. } => {
                ((rows - 1) * stride + tiling::effective_k(kh, dilation)).min(input.h)
            }
            _ => rows.min(input.h),
        };
        let mut ifm_bytes = input.c as u64 * in_rows as u64 * input.w as u64 * dtype as u64
            + rows as u64 * skip_row_bytes;
        if s == 0 {
            ifm_bytes += dw_weight_bytes;
        }
        let ofm_bytes = out.c as u64 * rows as u64 * out.w as u64 * dtype as u64;
        dma_bytes += ifm_bytes + ofm_bytes;

        let slot = s as usize % depth;
        let mut load_deps = base_dep.clone();
        if let Some(prev) = ring[slot] {
            load_deps.push(prev);
        }
        let ld = tg.push(
            li,
            label(opts, || format!("{lname}/s{s}/ld")),
            TaskKind::DmaLoad { bytes: ifm_bytes, buffer: BufferKind::Ifm },
            load_deps,
        );
        let out_elems = out.c as u64 * rows as u64 * out.w as u64;
        let (cycles, tile_macs) = match layer.op {
            Op::DepthwiseConv2d { kh, kw, .. } => (
                cost.depthwise_tile_cycles(rows, out.w, kh, kw, out.c)
                    + cost.task_setup_cycles,
                out_elems * kh as u64 * kw as u64,
            ),
            _ => (
                cost.vector_tile_cycles(out_elems, ops_per_elem) + cost.task_setup_cycles,
                0,
            ),
        };
        compute_cycles += cycles;
        macs += tile_macs;
        let comp = tg.push(
            li,
            label(opts, || format!("{lname}/s{s}/vec")),
            TaskKind::Compute { cycles, macs: tile_macs },
            vec![ld],
        );
        ring[slot] = Some(comp);
        let st = tg.push(
            li,
            label(opts, || format!("{lname}/s{s}/st")),
            TaskKind::DmaStore { bytes: ofm_bytes },
            vec![comp],
        );
        stores.push(st);
    }

    let barrier = tg.push(li, label(opts, || format!("{lname}/end")), TaskKind::Barrier, stores);
    PartialLayer {
        index: li,
        name: lname.clone(),
        compute_cycles,
        dma_bytes,
        macs,
        barrier,
    }
    .into_compiled(LayerTiling::Vector(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    fn sys() -> SystemConfig {
        SystemConfig::base_paper()
    }

    #[test]
    fn compiles_lenet() {
        let net = models::lenet(28);
        let c = compile(&net, &sys(), CompileOptions::default()).unwrap();
        c.graph.validate().unwrap();
        assert_eq!(c.layers.len(), net.layers.len());
        assert!(c.graph.len() > net.layers.len());
    }

    #[test]
    fn compiles_paper_dilated_vgg() {
        let net = models::dilated_vgg_paper();
        let c = compile(&net, &sys(), CompileOptions::default()).unwrap();
        c.graph.validate().unwrap();
        // MAC accounting must be exact: compiler MACs == graph-IR MACs.
        let compiled: u64 = c.layers.iter().map(|l| l.macs).sum();
        assert_eq!(compiled, net.total_macs());
    }

    #[test]
    fn layer_barriers_serialize_layers() {
        let net = models::lenet(28);
        let c = compile(&net, &sys(), CompileOptions::default()).unwrap();
        // Every task of layer l+1 must (transitively) depend on the barrier
        // of layer l; direct check: its first loads include the barrier.
        for w in c.layers.windows(2) {
            let barrier = w[0].barrier;
            let next_loads: Vec<_> = c
                .graph
                .tasks()
                .iter()
                .filter(|t| {
                    t.layer == w[1].index && matches!(t.kind, TaskKind::DmaLoad { .. })
                })
                .collect();
            assert!(!next_loads.is_empty());
            for t in next_loads.iter().take(2) {
                assert!(t.deps.contains(&barrier), "{} misses barrier", t.label);
            }
        }
    }

    #[test]
    fn double_buffer_reduces_critical_path() {
        let net = models::dilated_vgg(64, 4, 16);
        let db = compile(&net, &sys(), CompileOptions { double_buffer: true, labels: false })
            .unwrap();
        let sb = compile(&net, &sys(), CompileOptions { double_buffer: false, labels: false })
            .unwrap();
        let dur = |t: &crate::taskgraph::Task| match t.kind {
            TaskKind::Compute { cycles, .. } => cycles,
            TaskKind::DmaLoad { bytes, .. } | TaskKind::DmaStore { bytes } => bytes / 16,
            TaskKind::Barrier => 0,
        };
        let cp_db = db.graph.critical_path(&dur);
        let cp_sb = sb.graph.critical_path(&dur);
        assert!(cp_db <= cp_sb, "double buffering should not lengthen the critical path");
        assert!(cp_db < cp_sb, "on a multi-tile net it should strictly shorten it");
    }

    #[test]
    fn dma_bytes_match_taskgraph() {
        let net = models::dilated_vgg_tiny();
        let c = compile(&net, &sys(), CompileOptions::default()).unwrap();
        let layer_sum: u64 = c.layers.iter().map(|l| l.dma_bytes).sum();
        assert_eq!(layer_sum, c.graph.total_dma_bytes());
        let cycles_sum: u64 = c.layers.iter().map(|l| l.compute_cycles).sum();
        assert_eq!(cycles_sum, c.graph.total_compute_cycles());
    }

    #[test]
    fn ofm_bytes_written_exactly_once() {
        // The accumulate-on-chip schedule writes each output byte once.
        let net = models::dilated_vgg_tiny();
        let c = compile(&net, &sys(), CompileOptions::default()).unwrap();
        let shapes = net.layer_shapes();
        for (li, l) in net.layers.iter().enumerate() {
            let stored: u64 = c
                .graph
                .tasks()
                .iter()
                .filter(|t| t.layer == li as u32)
                .map(|t| match t.kind {
                    TaskKind::DmaStore { bytes } => bytes,
                    _ => 0,
                })
                .sum();
            assert_eq!(
                stored,
                shapes[li].bytes(net.dtype_bytes),
                "layer {} stores wrong byte count", l.name
            );
        }
    }

    #[test]
    fn labels_disabled_are_empty() {
        let net = models::lenet(28);
        let c = compile(&net, &sys(), CompileOptions { double_buffer: true, labels: false })
            .unwrap();
        assert!(c.graph.tasks().iter().all(|t| t.label.is_empty()));
    }

    #[test]
    fn eltwise_skip_traffic_counted() {
        let net = models::tiny_resnet(32, 16, 2);
        let c = compile(&net, &sys(), CompileOptions::default()).unwrap();
        c.graph.validate().unwrap();
        // The add layers load two stripes worth of input.
        let add_layer = net.layer_index("res0_add").unwrap();
        let cost = net.layer_costs()[add_layer];
        let loaded: u64 = c
            .graph
            .tasks()
            .iter()
            .filter(|t| t.layer == add_layer as u32)
            .map(|t| match t.kind {
                TaskKind::DmaLoad { bytes, .. } => bytes,
                _ => 0,
            })
            .sum();
        assert_eq!(loaded, cost.ifm_bytes);
    }
}
