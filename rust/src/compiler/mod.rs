//! The deep-learning compiler — the paper's key insight is that this
//! component belongs *inside* the performance-estimation loop (Fig 1): it
//! converts the DNN graph into a **hardware-adapted task graph** according
//! to the hardware constraints (memory hierarchy, on-chip buffer sizes,
//! supported operations), and those transformations shape the traffic and
//! the timing the virtual system model then simulates.
//!
//! Pipeline: [`tiling`] picks per-layer tile geometry that fits the NCE's
//! on-chip buffers while minimizing external traffic; [`lower`] emits the
//! DMA/compute task graph with a double-buffered schedule; [`cost`] is the
//! NCE cycle model shared with the roofline analysis; [`analytical`] is the
//! statistical/static baseline the paper argues *under*-models causality
//! (no blocking, no arbitration) — reproduced here for the comparison
//! benches; [`cache`] memoizes whole compilations by their structural
//! config subset so DSE sweeps and top-down probes retime instead of
//! recompiling.

pub mod analytical;
pub mod cache;
pub mod cost;
pub mod lower;
pub mod tiling;

pub use analytical::{
    analytical_estimate, analytical_estimate_compiled, critical_path_lower_bound,
    latency_lower_bound, lower_bound, occupancy_lower_bound, AnalyticalEstimate, BoundKind,
};
pub use cache::{CompileCache, CompileKey, POISONED_SOURCE_DIAG};
pub use cost::CostModel;
pub use lower::{compile, CompileOptions, CompiledLayer, CompiledNet};
pub use tiling::{LayerTiling, TilingChoice};
