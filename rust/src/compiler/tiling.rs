//! Hardware-adapted tiling: fit each layer's working set into the NCE's
//! on-chip buffers while minimizing external-memory traffic.
//!
//! This pass is where the paper's "task graph considers the memory
//! hierarchy, the on-chip memory sizes and the supported operations"
//! materialises. Loop order per conv layer (outer to inner):
//!
//! ```text
//! for oh_tile:              # output-row stripes
//!   for cout_tile:          # output-channel groups
//!     for cin_tile:         # input-channel groups (accumulated on-chip)
//!       DMA load  IFM(cin_tile, rows+halo)   -> ifm buffer
//!       DMA load  W(cin_tile, cout_tile)     -> weight buffer
//!       NCE       accumulate partial OFM     -> ofm buffer
//!     DMA store OFM(cout_tile, rows)
//! ```
//!
//! The OFM tile stays resident across the `cin` walk, so each output byte
//! crosses the bus exactly once; IFM is re-read once per `cout` tile and
//! weights once per `oh` tile — the traffic function the tiler minimizes,
//! the same objective as Zhang et al. (FPGA'15) loop tiling.

use crate::config::{NceConfig, SystemConfig};
use crate::graph::{Op, TensorShape};
use crate::util::{div_ceil, div_ceil64};
use anyhow::{bail, Result};

/// Reference clocks for the tiler's compute-vs-traffic objective, fixed at
/// the paper's base design point (NCE 250 MHz, 256-bit AXI @ 250 MHz, DDR3
/// @ 533 MHz). Pinning the objective's clocks — instead of reading the
/// config's frequency annotations — makes the chosen tiling a pure function
/// of *structural* parameters (array geometry, buffer capacities, datapath
/// widths, per-task setup): exactly the fields in
/// [`crate::compiler::CompileKey`]. That is what lets the DSE reuse one
/// compilation across every frequency point of a sweep and every
/// `dse::topdown` probe, with a retime-by-simulation instead of a full
/// recompile. Frequencies still shape the simulated timing of the resulting
/// task graph; they just no longer flip the tiler's argmin between
/// candidates.
///
/// The deliberate tradeoff: for a config whose clock *ratios* differ from
/// the base point (say memory at 400 MHz instead of 533, or an NCE swept
/// to 2x the base clock), the objective prices streaming vs compute at the
/// reference ratio, so the chosen tiling can be modestly off-optimal for
/// that system — feasibility (buffer fits) is still checked exactly, only
/// the argmin among *feasible* candidates is biased, and the simulation of
/// whatever tiling is chosen remains exact. The DSE trades that bounded
/// bias for evaluating frequency axes and top-down probes with zero
/// recompiles; callers who want a clock-ratio-optimal tiling for one
/// specific system can still judge it by simulating competing configs.
const REF_NCE_MHZ: f64 = 250.0;
const REF_BUS_MHZ: f64 = 250.0;
const REF_MEM_MHZ: f64 = 533.0;

/// Tile geometry chosen for a conv layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilingChoice {
    pub cin_t: u32,
    pub cout_t: u32,
    /// Output rows per stripe.
    pub oh_t: u32,
    pub n_cin: u32,
    pub n_cout: u32,
    pub n_oh: u32,
    /// True when the whole-channel IFM stripe fits the IFM buffer: the
    /// stripe is then loaded once and *reused across all cout tiles*
    /// instead of being re-streamed per cout tile — the single most
    /// important reuse decision for weight-heavy layers (conv4_x, dense1).
    pub ifm_resident: bool,
}

impl TilingChoice {
    pub fn tiles(&self) -> u64 {
        self.n_cin as u64 * self.n_cout as u64 * self.n_oh as u64
    }
}

/// Tiling for vector-path layers (pool/upsample/eltwise): output-row
/// stripes with all channels resident.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct VectorTiling {
    pub oh_t: u32,
    pub n_oh: u32,
}

/// Per-layer tiling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerTiling {
    Conv(TilingChoice),
    Vector(VectorTiling),
}

/// Effective kernel extent under dilation.
pub fn effective_k(k: u32, dilation: u32) -> u32 {
    (k - 1) * dilation + 1
}

/// IFM stripe height needed to produce `oh_t` output rows.
fn ifm_rows_for(oh_t: u32, stride: u32, eff_kh: u32, in_h: u32) -> u32 {
    ((oh_t - 1) * stride + eff_kh).min(in_h)
}

/// Bytes of one IFM stripe.
fn ifm_tile_bytes(cin_t: u32, ih_t: u32, in_w: u32, dtype: u32) -> u64 {
    cin_t as u64 * ih_t as u64 * in_w as u64 * dtype as u64
}

fn weight_tile_bytes(cin_t: u32, cout_t: u32, kh: u32, kw: u32, dtype: u32) -> u64 {
    (cin_t as u64 * cout_t as u64 * kh as u64 * kw as u64 + cout_t as u64) * dtype as u64
}

fn ofm_tile_bytes(cout_t: u32, oh_t: u32, out_w: u32, dtype: u32) -> u64 {
    cout_t as u64 * oh_t as u64 * out_w as u64 * dtype as u64
}

/// Candidate channel-tile sizes: multiples of the array dimension (full
/// lanes) capped at the layer size, fractions of the array dimension (for
/// layers whose working set is too fat even at one array pass — e.g. the
/// 7x7 dense1 weights), plus the layer size itself.
fn channel_candidates(total: u32, array_dim: u32) -> Vec<u32> {
    let mut c: Vec<u32> = Vec::new();
    let mut m = array_dim;
    while m < total {
        c.push(m);
        m *= 2;
    }
    let mut f = array_dim / 2;
    while f >= 1 {
        if f < total {
            c.push(f);
        }
        f /= 2;
    }
    c.push(total);
    c.sort_unstable();
    c.dedup();
    c
}

/// External-traffic estimate (bytes) for a candidate tiling — half of the
/// tiler's objective function (see module docs for the reuse argument).
pub fn conv_traffic_bytes(
    choice: &TilingChoice,
    input: TensorShape,
    out: TensorShape,
    kh: u32,
    kw: u32,
    stride: u32,
    dilation: u32,
    cin: u32,
    cout: u32,
    dtype: u32,
) -> u64 {
    let eff_kh = effective_k(kh, dilation);
    // IFM: each oh stripe is read once when resident, else once per cout tile.
    let mut ifm = 0u64;
    for s in 0..choice.n_oh {
        let oh0 = s * choice.oh_t;
        let rows = choice.oh_t.min(out.h - oh0);
        let ih = ifm_rows_for(rows, stride, eff_kh, input.h);
        ifm += ifm_tile_bytes(cin, ih, input.w, dtype);
    }
    if !choice.ifm_resident {
        ifm *= choice.n_cout as u64;
    }
    // Weights: full set re-read once per oh stripe.
    let w_total = (cin as u64 * cout as u64 * kh as u64 * kw as u64 + cout as u64) * dtype as u64;
    let weights = w_total * choice.n_oh as u64;
    // OFM: written exactly once (accumulation stays on-chip).
    let ofm = out.bytes(dtype);
    ifm + weights + ofm
}

/// NCE cycles for a candidate tiling (partial-tile lane waste included) —
/// the other half of the objective.
///
/// Closed form over the uniform-tile grid plus the remainder faces: only
/// the *last* tile along each axis can be partial, so the triple tile loop
/// factors into per-axis sums — O(1) instead of O(tiles). The tiler calls
/// this for every channel-candidate pair, so this cut whole-net compile
/// time ~5x (EXPERIMENTS.md §Perf).
pub fn conv_compute_cycles(
    choice: &TilingChoice,
    nce: &NceConfig,
    out: TensorShape,
    cin: u32,
    cout: u32,
    kh: u32,
    kw: u32,
) -> u64 {
    let cost = crate::compiler::cost::CostModel::from_nce(nce);
    // Per-axis sums: (n-1) full tiles plus one remainder tile.
    let axis_sum = |total: u32, tile: u32, f: &dyn Fn(u32) -> u64| -> u64 {
        let n = div_ceil(total, tile);
        let last = total - (n - 1) * tile;
        (n as u64 - 1) * f(tile) + f(last)
    };
    let kk = kh as u64 * kw as u64;
    let spatial = axis_sum(out.h, choice.oh_t, &|rows| rows as u64 * out.w as u64 * kk);
    let row_passes =
        axis_sum(cin, choice.cin_t, &|c| div_ceil64(c as u64, nce.array_rows as u64));
    let col_passes =
        axis_sum(cout, choice.cout_t, &|c| div_ceil64(c as u64, nce.array_cols as u64));
    let tiles = choice.n_oh as u64 * choice.n_cin as u64 * choice.n_cout as u64;
    // spatial varies over oh tiles only, passes over channel tiles only —
    // the cross product equals the sum over all tiles.
    spatial * row_passes * col_passes + tiles * cost.task_setup_cycles
}

/// Choose a conv tiling that fits the buffers and minimizes the *estimated
/// layer time* `max(compute, traffic)` — a pure-traffic objective would
/// happily shrink channel tiles below the array geometry and waste lanes;
/// a pure-compute objective would re-stream tensors. Ties break on traffic,
/// then on tile count (per-task overhead).
#[allow(clippy::too_many_arguments)]
pub fn tile_conv(
    sys: &SystemConfig,
    input: TensorShape,
    out: TensorShape,
    cin: u32,
    cout: u32,
    kh: u32,
    kw: u32,
    stride: u32,
    dilation: u32,
    dtype: u32,
) -> Result<TilingChoice> {
    let nce = &sys.nce;
    let ifm_cap = nce.ifm_buffer_kib as u64 * 1024;
    let w_cap = nce.weight_buffer_kib as u64 * 1024;
    let ofm_cap = nce.ofm_buffer_kib as u64 * 1024;
    let eff_kh = effective_k(kh, dilation);

    // Effective streaming bandwidth (bytes/s): min of bus and annotated
    // memory, both taken at the *reference* clocks (see REF_* above) so the
    // objective — and therefore the chosen tiling — is independent of the
    // config's frequency annotations. Only the datapath widths and the
    // effective-bandwidth annotation enter.
    let bus_bps = sys.bus.bytes_per_cycle as f64 * REF_BUS_MHZ * 1e6;
    let mem_bps = sys.memory.data_bytes_per_cycle as f64
        * REF_MEM_MHZ
        * 1e6
        * sys.memory.avsm_eff_bw_pct as f64
        / 100.0;
    let stream_bps = bus_bps.min(mem_bps);
    let nce_hz = REF_NCE_MHZ * 1e6;

    let mut best: Option<(f64, u64, TilingChoice)> = None;
    for &cin_t in &channel_candidates(cin, nce.array_rows) {
        for &cout_t in &channel_candidates(cout, nce.array_cols) {
            if weight_tile_bytes(cin_t, cout_t, kh, kw, dtype) > w_cap {
                continue;
            }
            // Largest oh_t whose IFM stripe and OFM stripe both fit.
            let mut oh_t = 0u32;
            for cand in 1..=out.h {
                let ih = ifm_rows_for(cand, stride, eff_kh, input.h);
                if ifm_tile_bytes(cin_t, ih, input.w, dtype) <= ifm_cap
                    && ofm_tile_bytes(cout_t, cand, out.w, dtype) <= ofm_cap
                {
                    oh_t = cand;
                } else {
                    break;
                }
            }
            if oh_t == 0 {
                continue;
            }
            // Residency: the *whole-channel* stripe (all cin tiles at once)
            // fits the IFM buffer.
            let ih = ifm_rows_for(oh_t, stride, eff_kh, input.h);
            let ifm_resident = ifm_tile_bytes(cin, ih, input.w, dtype) <= ifm_cap;
            let choice = TilingChoice {
                cin_t,
                cout_t,
                oh_t,
                n_cin: div_ceil(cin, cin_t),
                n_cout: div_ceil(cout, cout_t),
                n_oh: div_ceil(out.h, oh_t),
                ifm_resident,
            };
            let traffic = conv_traffic_bytes(
                &choice, input, out, kh, kw, stride, dilation, cin, cout, dtype,
            );
            let cycles = conv_compute_cycles(&choice, nce, out, cin, cout, kh, kw);
            let est_time = (traffic as f64 / stream_bps).max(cycles as f64 / nce_hz);
            let better = match &best {
                None => true,
                Some((t, tr, b)) => {
                    est_time < *t * 0.9999
                        || ((est_time - t).abs() <= t * 0.0001
                            && (traffic < *tr
                                || (traffic == *tr && choice.tiles() < b.tiles())))
                }
            };
            if better {
                best = Some((est_time, traffic, choice));
            }
        }
    }
    match best {
        Some((_, _, choice)) => Ok(choice),
        None => bail!(
            "no feasible tiling: buffers (ifm {} KiB, w {} KiB, ofm {} KiB) too small \
             for conv cin={cin} cout={cout} k={kh}x{kw} on {}",
            nce.ifm_buffer_kib, nce.weight_buffer_kib, nce.ofm_buffer_kib, input
        ),
    }
}

/// Tile a vector-path layer into output-row stripes.
pub fn tile_vector(
    nce: &NceConfig,
    op: &Op,
    input: TensorShape,
    out: TensorShape,
    dtype: u32,
) -> Result<VectorTiling> {
    let ifm_cap = nce.ifm_buffer_kib as u64 * 1024;
    let ofm_cap = nce.ofm_buffer_kib as u64 * 1024;
    // Input rows consumed and buffers touched per output row.
    let (in_rows_per_out, extra_in) = match *op {
        Op::MaxPool { window, stride } => (stride, window.saturating_sub(stride)),
        Op::UpsampleBilinear { factor } => {
            // factor output rows per input row; conservatively 2 input rows
            // resident for interpolation.
            let _ = factor;
            (1, 1)
        }
        Op::EltwiseAdd => (1, 0),
        Op::DepthwiseConv2d { kh, stride, dilation, .. } => {
            (stride, effective_k(kh, dilation).saturating_sub(stride))
        }
        Op::Conv2d { .. } => bail!("conv must use tile_conv"),
    };
    let in_row_bytes = input.c as u64 * input.w as u64 * dtype as u64
        * if matches!(op, Op::EltwiseAdd) { 2 } else { 1 };
    let out_row_bytes = out.c as u64 * out.w as u64 * dtype as u64;
    let mut oh_t = 0u32;
    for cand in 1..=out.h {
        let in_rows = match *op {
            Op::UpsampleBilinear { factor } => div_ceil(cand, factor) + extra_in,
            _ => cand * in_rows_per_out + extra_in,
        };
        if in_rows as u64 * in_row_bytes <= ifm_cap && cand as u64 * out_row_bytes <= ofm_cap {
            oh_t = cand;
        } else {
            break;
        }
    }
    if oh_t == 0 {
        bail!("no feasible vector tiling for {op:?} on {input}");
    }
    Ok(VectorTiling { oh_t, n_oh: div_ceil(out.h, oh_t) })
}

/// Tile any layer.
pub fn tile_layer(
    sys: &SystemConfig,
    op: &Op,
    input: TensorShape,
    dtype: u32,
) -> Result<LayerTiling> {
    let out = op.out_shape(input);
    match *op {
        Op::Conv2d { cin, cout, kh, kw, stride, dilation, .. } => Ok(LayerTiling::Conv(
            tile_conv(sys, input, out, cin, cout, kh, kw, stride, dilation, dtype)?,
        )),
        _ => Ok(LayerTiling::Vector(tile_vector(&sys.nce, op, input, out, dtype)?)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::graph::{models, Activation, Padding};

    fn sys() -> SystemConfig {
        SystemConfig::base_paper()
    }

    fn conv_op(cin: u32, cout: u32, k: u32, dilation: u32) -> Op {
        Op::Conv2d {
            cin, cout, kh: k, kw: k, stride: 1, dilation,
            padding: Padding::Same, activation: Activation::Relu,
        }
    }

    #[test]
    fn small_layer_single_tile() {
        let input = TensorShape::new(1, 8, 16, 16);
        let op = conv_op(8, 16, 3, 1);
        let out = op.out_shape(input);
        let t = tile_conv(&sys(), input, out, 8, 16, 3, 3, 1, 1, 2).unwrap();
        assert_eq!((t.n_cin, t.n_cout, t.n_oh), (1, 1, 1));
        assert!(t.ifm_resident);
    }

    #[test]
    fn conv4_layer_is_ifm_resident_full_lanes() {
        // conv4_x of paper-sized DilatedVGG: 512ch 32x32, dilation 2. The
        // whole IFM (1.13 MiB with halo) fits the 1.5 MiB buffer, so the
        // tiler must choose residency and full-lane channel tiles.
        let s = sys();
        let input = TensorShape::new(1, 512, 32, 32);
        let op = conv_op(512, 512, 3, 2);
        let out = op.out_shape(input);
        let t = tile_conv(&s, input, out, 512, 512, 3, 3, 1, 2, 2).unwrap();
        assert!(t.ifm_resident, "conv4 stripe should be IFM-resident: {t:?}");
        assert_eq!(t.cin_t % s.nce.array_rows, 0, "full row lanes: {t:?}");
        assert_eq!(t.cout_t % s.nce.array_cols, 0, "full col lanes: {t:?}");
        // Traffic must be near the one-pass ideal (< 1.5x).
        let traffic = conv_traffic_bytes(&t, input, out, 3, 3, 1, 2, 512, 512, 2);
        let ideal = input.bytes(2) + out.bytes(2) + op.weight_bytes(2);
        assert!(
            traffic < ideal * 3 / 2,
            "conv4 traffic {traffic} vs ideal {ideal} — residency not exploited"
        );
        // Working set must actually fit.
        let eff = effective_k(3, 2);
        let ih = ifm_rows_for(t.oh_t, 1, eff, input.h);
        assert!(ifm_tile_bytes(512, ih, input.w, 2) <= s.nce.ifm_buffer_kib as u64 * 1024);
        assert!(
            weight_tile_bytes(t.cin_t, t.cout_t, 3, 3, 2)
                <= s.nce.weight_buffer_kib as u64 * 1024
        );
        assert!(
            ofm_tile_bytes(t.cout_t, t.oh_t, out.w, 2) <= s.nce.ofm_buffer_kib as u64 * 1024
        );
    }

    #[test]
    fn tile_counts_cover_layer_exactly() {
        // Tiling invariant: tiles x tile size covers the layer with the last
        // tile possibly partial — n_* = ceil(total / tile).
        let input = TensorShape::new(1, 200, 50, 50);
        let op = conv_op(200, 300, 3, 1);
        let out = op.out_shape(input);
        let t = tile_conv(&sys(), input, out, 200, 300, 3, 3, 1, 1, 2).unwrap();
        assert!(t.cin_t * t.n_cin >= 200 && t.cin_t * (t.n_cin - 1) < 200);
        assert!(t.cout_t * t.n_cout >= 300 && t.cout_t * (t.n_cout - 1) < 300);
        assert!(t.oh_t * t.n_oh >= out.h && t.oh_t * (t.n_oh - 1) < out.h);
    }

    #[test]
    fn too_small_buffers_rejected() {
        // Even a single-channel stripe of a 7-row halo on a 4096-wide image
        // (7 * 4096 * 2 B = 56 KiB) cannot fit a 1 KiB IFM buffer.
        let mut s = sys();
        s.nce.ifm_buffer_kib = 1;
        s.nce.weight_buffer_kib = 1;
        s.nce.ofm_buffer_kib = 1;
        let input = TensorShape::new(1, 512, 64, 4096);
        let op = conv_op(512, 512, 7, 1);
        let out = op.out_shape(input);
        assert!(tile_conv(&s, input, out, 512, 512, 7, 7, 1, 1, 2).is_err());
    }

    #[test]
    fn tiny_buffers_fall_back_to_subarray_tiles() {
        // 1 KiB buffers can still tile a small layer by shrinking channel
        // tiles below the array dimensions (underutilising lanes).
        let mut s = sys();
        s.nce.ifm_buffer_kib = 1;
        s.nce.weight_buffer_kib = 1;
        s.nce.ofm_buffer_kib = 1;
        let input = TensorShape::new(1, 16, 16, 16);
        let op = conv_op(16, 16, 3, 1);
        let out = op.out_shape(input);
        let t = tile_conv(&s, input, out, 16, 16, 3, 3, 1, 1, 2).unwrap();
        assert!(t.cin_t < 32 || t.cout_t < 64);
    }

    #[test]
    fn vector_tiling_pool_and_upsample() {
        let n = sys().nce;
        let pool = Op::MaxPool { window: 2, stride: 2 };
        let input = TensorShape::new(1, 64, 256, 256);
        let t = tile_vector(&n, &pool, input, pool.out_shape(input), 2).unwrap();
        assert!(t.oh_t >= 1 && t.n_oh * t.oh_t >= 128);

        let up = Op::UpsampleBilinear { factor: 8 };
        let input = TensorShape::new(1, 16, 32, 32);
        let t = tile_vector(&n, &up, input, up.out_shape(input), 2).unwrap();
        assert!(t.oh_t >= 1);
    }

    #[test]
    fn whole_dilated_vgg_tiles() {
        let g = models::dilated_vgg_paper();
        let s = sys();
        let mut shape = g.input;
        for layer in &g.layers {
            tile_layer(&s, &layer.op, shape, g.dtype_bytes)
                .unwrap_or_else(|e| panic!("layer {}: {e}", layer.name));
            shape = layer.op.out_shape(shape);
        }
    }

    #[test]
    fn bigger_buffers_never_increase_estimated_time() {
        // Monotonicity: doubling every buffer must not worsen the chosen
        // design's estimated layer time (traffic or compute).
        let input = TensorShape::new(1, 256, 64, 64);
        let op = conv_op(256, 256, 3, 1);
        let out = op.out_shape(input);
        let small = sys();
        let mut big = sys();
        big.nce.ifm_buffer_kib *= 2;
        big.nce.weight_buffer_kib *= 2;
        big.nce.ofm_buffer_kib *= 2;
        let ts = tile_conv(&small, input, out, 256, 256, 3, 3, 1, 1, 2).unwrap();
        let tb = tile_conv(&big, input, out, 256, 256, 3, 3, 1, 1, 2).unwrap();
        let time = |s: &SystemConfig, t: &TilingChoice| {
            let traffic = conv_traffic_bytes(t, input, out, 3, 3, 1, 1, 256, 256, 2) as f64;
            let cycles = conv_compute_cycles(t, &s.nce, out, 256, 256, 3, 3) as f64;
            (traffic / 3.75e9).max(cycles / 250e6)
        };
        assert!(
            time(&big, &tb) <= time(&small, &ts) * 1.0001,
            "bigger buffers worsened the design"
        );
    }

    #[test]
    fn tiling_is_frequency_independent() {
        // The DSE compile cache is keyed on structural fields only
        // (`compiler::CompileKey`); that is sound because the tiler's
        // objective runs at pinned reference clocks — changing any clock
        // annotation must leave the chosen tiling bit-identical.
        let input = TensorShape::new(1, 256, 64, 64);
        let op = conv_op(256, 256, 3, 1);
        let out = op.out_shape(input);
        let base = tile_conv(&sys(), input, out, 256, 256, 3, 3, 1, 1, 2).unwrap();
        for f in [50u64, 125, 500, 1000] {
            let mut s = sys();
            s.nce.freq_mhz = f;
            s.bus.freq_mhz = f;
            s.memory.freq_mhz = 2 * f;
            s.hkp.freq_mhz = f;
            let t = tile_conv(&s, input, out, 256, 256, 3, 3, 1, 1, 2).unwrap();
            assert_eq!(t, base, "tiling changed at {f} MHz");
        }
    }

    #[test]
    fn dense1_feasible_with_subarray_cout() {
        // dense1: 7x7 512->1024 on 32x32 — weights (51 MiB) dwarf the
        // buffer, so the tiler must fall back to feasible channel tiles and
        // still cover the layer.
        let s = sys();
        let input = TensorShape::new(1, 512, 32, 32);
        let op = conv_op(512, 1024, 7, 4);
        let out = op.out_shape(input);
        let t = tile_conv(&s, input, out, 512, 1024, 7, 7, 1, 4, 2).unwrap();
        assert!(t.cin_t * t.n_cin >= 512);
        assert!(t.cout_t * t.n_cout >= 1024);
        assert!(
            weight_tile_bytes(t.cin_t, t.cout_t, 7, 7, 2)
                <= s.nce.weight_buffer_kib as u64 * 1024
        );
    }
}
