//! NCE cycle cost model (DESIGN.md §6) — shared by the compiler's tiler,
//! the lowering pass, and the roofline/analytical analyses.
//!
//! The NCE is an `R x C` multiplier array: input channels stream across the
//! R rows, output channels across the C columns. One k x k conv tile of
//! `oh x ow` output pixels with `cin_t` input and `cout_t` output channels
//! therefore takes
//!
//! ```text
//! cycles = oh * ow * kh * kw * ceil(cin_t / R) * ceil(cout_t / C)
//! ```
//!
//! Vector ops (pooling, up-sampling, element-wise) bypass the MAC array and
//! run on the C-lane vector path at one output element per lane per cycle.

use crate::config::NceConfig;
use crate::graph::Op;
use crate::util::div_ceil64;

/// The cost model, parameterised over the NCE geometry — the same machinery
/// models the paper's 32x64 FPGA array, an MXU-like 128x128 array, or any
/// swept geometry in the DSE.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub rows: u32,
    pub cols: u32,
    /// Fixed per-task overhead in cycles (descriptor decode, buffer swap).
    pub task_setup_cycles: u64,
}

impl CostModel {
    pub fn from_nce(nce: &NceConfig) -> Self {
        Self {
            rows: nce.array_rows,
            cols: nce.array_cols,
            task_setup_cycles: nce.task_setup_cycles,
        }
    }

    /// Cycles for one conv tile (excluding setup overhead).
    pub fn conv_tile_cycles(
        &self,
        oh: u32,
        ow: u32,
        kh: u32,
        kw: u32,
        cin_t: u32,
        cout_t: u32,
    ) -> u64 {
        let spatial = oh as u64 * ow as u64 * kh as u64 * kw as u64;
        let row_passes = div_ceil64(cin_t as u64, self.rows as u64);
        let col_passes = div_ceil64(cout_t as u64, self.cols as u64);
        spatial * row_passes * col_passes
    }

    /// MACs actually performed by that tile (for utilization reporting).
    pub fn conv_tile_macs(&self, oh: u32, ow: u32, kh: u32, kw: u32, cin_t: u32, cout_t: u32) -> u64 {
        oh as u64 * ow as u64 * kh as u64 * kw as u64 * cin_t as u64 * cout_t as u64
    }

    /// Cycles for a vector-path tile producing `out_elems` elements with
    /// `ops_per_elem` operations each.
    pub fn vector_tile_cycles(&self, out_elems: u64, ops_per_elem: u64) -> u64 {
        div_ceil64(out_elems * ops_per_elem, self.cols as u64)
    }

    /// Cycles for a whole layer processed as one giant tile — the ideal
    /// (infinite-buffer) compute time, used by the analytical baseline and
    /// the roofline's compute bound.
    pub fn ideal_layer_cycles(&self, op: &Op, input: crate::graph::TensorShape) -> u64 {
        match *op {
            Op::Conv2d { cin, cout, kh, kw, .. } => {
                let out = op.out_shape(input);
                self.conv_tile_cycles(out.h, out.w, kh, kw, cin, cout) * out.n as u64
            }
            Op::MaxPool { window, .. } => {
                let out = op.out_shape(input);
                self.vector_tile_cycles(out.numel(), (window * window) as u64)
            }
            Op::UpsampleBilinear { .. } => {
                let out = op.out_shape(input);
                self.vector_tile_cycles(out.numel(), 4)
            }
            Op::DepthwiseConv2d { kh, kw, .. } => {
                let out = op.out_shape(input);
                self.depthwise_tile_cycles(out.h, out.w, kh, kw, out.c) * out.n as u64
            }
            Op::EltwiseAdd => self.vector_tile_cycles(input.numel(), 1),
        }
    }

    /// Depthwise tile: one channel per array row, columns idle (no
    /// cross-channel reduction to spread over them).
    pub fn depthwise_tile_cycles(&self, oh: u32, ow: u32, kh: u32, kw: u32, c: u32) -> u64 {
        oh as u64 * ow as u64 * kh as u64 * kw as u64
            * div_ceil64(c as u64, self.rows as u64)
    }

    /// Peak MAC throughput per cycle.
    pub fn peak_macs_per_cycle(&self) -> u64 {
        self.rows as u64 * self.cols as u64
    }

    /// Array utilization of a tile in [0, 1]: achieved MACs over
    /// cycles x peak. Partial tiles (cin_t % rows != 0 etc.) waste lanes —
    /// exactly the effect the paper's Fig 6 "neither bound" layers show.
    pub fn tile_utilization(&self, oh: u32, ow: u32, kh: u32, kw: u32, cin_t: u32, cout_t: u32) -> f64 {
        let macs = self.conv_tile_macs(oh, ow, kh, kw, cin_t, cout_t) as f64;
        let cycles = self.conv_tile_cycles(oh, ow, kh, kw, cin_t, cout_t) as f64;
        macs / (cycles * self.peak_macs_per_cycle() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Padding, TensorShape};

    fn model() -> CostModel {
        CostModel { rows: 32, cols: 64, task_setup_cycles: 32 }
    }

    #[test]
    fn full_array_tile_is_ideal() {
        let m = model();
        // 32 input ch, 64 output ch: one pass, so cycles = spatial * k*k.
        assert_eq!(m.conv_tile_cycles(8, 8, 3, 3, 32, 64), 8 * 8 * 9);
        assert!((m.tile_utilization(8, 8, 3, 3, 32, 64) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn partial_channels_round_up() {
        let m = model();
        // 3 input channels still occupy a full row pass (conv1_0!).
        assert_eq!(m.conv_tile_cycles(4, 4, 3, 3, 3, 64), 4 * 4 * 9);
        let util = m.tile_utilization(4, 4, 3, 3, 3, 64);
        assert!((util - 3.0 / 32.0).abs() < 1e-12, "util {util}");
    }

    #[test]
    fn multi_pass_scales_linearly() {
        let m = model();
        let one = m.conv_tile_cycles(8, 8, 3, 3, 32, 64);
        assert_eq!(m.conv_tile_cycles(8, 8, 3, 3, 64, 64), 2 * one);
        assert_eq!(m.conv_tile_cycles(8, 8, 3, 3, 64, 128), 4 * one);
    }

    #[test]
    fn vector_cycles() {
        let m = model();
        // 1024 elems, 4 ops each, 64 lanes: 64 cycles.
        assert_eq!(m.vector_tile_cycles(1024, 4), 64);
        // Rounds up.
        assert_eq!(m.vector_tile_cycles(65, 1), 2);
    }

    #[test]
    fn ideal_layer_matches_macs_at_full_util() {
        let m = model();
        let op = Op::Conv2d {
            cin: 64,
            cout: 128,
            kh: 3,
            kw: 3,
            stride: 1,
            dilation: 1,
            padding: Padding::Same,
            activation: Activation::Relu,
        };
        let input = TensorShape::new(1, 64, 16, 16);
        let cycles = m.ideal_layer_cycles(&op, input);
        // 64/32=2 row passes * 128/64=2 col passes * 16*16*9 spatial.
        assert_eq!(cycles, 4 * 16 * 16 * 9);
        // At full lane occupancy, macs == cycles * peak.
        assert_eq!(op.macs(input), cycles * m.peak_macs_per_cycle());
    }

    #[test]
    fn utilization_never_exceeds_one() {
        let m = model();
        for (cin, cout) in [(1u32, 1u32), (3, 64), (32, 64), (48, 96), (512, 512)] {
            let u = m.tile_utilization(4, 4, 3, 3, cin, cout);
            assert!(u > 0.0 && u <= 1.0 + 1e-12, "{cin}x{cout} -> {u}");
        }
    }
}
