//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! This is the *functional* half of the stack: the timing simulators in
//! `hw`/`detailed` are non-functional (paper §1), so the actual DNN
//! numerics run here — HLO text produced once by `python/compile/aot.py`
//! (`make artifacts`), compiled on the PJRT CPU client and executed from
//! rust. Python never runs at this point.
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

use crate::json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Signature of one artifact entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: PathBuf,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Golden test vector recorded by the AOT step.
#[derive(Debug, Clone)]
pub struct Golden {
    pub input: PathBuf,
    pub expected: PathBuf,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub tolerance: f64,
}

/// Parsed artifacts/manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSig>,
    pub golden: Option<Golden>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading manifest in {dir:?} (run `make artifacts`)"))?;
        let v = json::parse(&text)?;
        let obj = v.as_object().context("manifest is not an object")?;
        let mut artifacts = Vec::new();
        let mut golden = None;
        let shapes = |field: &json::Value| -> Result<Vec<Vec<usize>>> {
            field
                .as_array()
                .context("bad shape list")?
                .iter()
                .map(|io| {
                    io.req_array("shape").map(|s| {
                        s.iter().filter_map(|d| d.as_u64()).map(|d| d as usize).collect()
                    })
                })
                .collect()
        };
        for (name, entry) in obj {
            if name == "golden" {
                golden = Some(Golden {
                    input: dir.join(entry.req_str("input")?),
                    expected: dir.join(entry.req_str("expected")?),
                    input_shape: entry
                        .req_array("input_shape")?
                        .iter()
                        .filter_map(|d| d.as_u64())
                        .map(|d| d as usize)
                        .collect(),
                    output_shape: entry
                        .req_array("output_shape")?
                        .iter()
                        .filter_map(|d| d.as_u64())
                        .map(|d| d as usize)
                        .collect(),
                    tolerance: entry.req_f64("tolerance")?,
                });
                continue;
            }
            artifacts.push(ArtifactSig {
                name: name.clone(),
                file: dir.join(entry.req_str("file")?),
                input_shapes: shapes(entry.get("inputs"))?,
                output_shapes: shapes(entry.get("outputs"))?,
            });
        }
        Ok(Self { dir, artifacts, golden })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSig> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// A compiled, ready-to-run model on the PJRT CPU client.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    pub sig: ArtifactSig,
}

/// The PJRT runtime: one CPU client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, sig: &ArtifactSig) -> Result<LoadedModel> {
        let path = sig
            .file
            .to_str()
            .context("artifact path is not valid UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path).map_err(to_anyhow)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(to_anyhow)?;
        Ok(LoadedModel { exe, sig: sig.clone() })
    }
}

impl LoadedModel {
    /// Execute with f32 inputs (shape-checked against the signature).
    /// Returns the flattened f32 outputs.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.sig.input_shapes.len() {
            bail!(
                "{} expects {} inputs, got {}",
                self.sig.name,
                self.sig.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.sig.input_shapes) {
            let numel: usize = shape.iter().product();
            if data.len() != numel {
                bail!(
                    "{}: input length {} != shape {:?} numel {}",
                    self.sig.name, data.len(), shape, numel
                );
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data).reshape(&dims).map_err(to_anyhow)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(to_anyhow)?;
        // aot.py lowers with return_tuple=True: unwrap the tuple elements.
        let out = result[0][0].to_literal_sync().map_err(to_anyhow)?;
        let tuple = out.to_tuple().map_err(to_anyhow)?;
        let mut vecs = Vec::with_capacity(tuple.len());
        for lit in tuple {
            vecs.push(lit.to_vec::<f32>().map_err(to_anyhow)?);
        }
        Ok(vecs)
    }
}

fn to_anyhow(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("{e}")
}

/// Read a little-endian f32 binary file (the golden vectors).
pub fn read_f32_bin(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path.as_ref()).with_context(|| format!("reading {:?}", path.as_ref()))?;
    if bytes.len() % 4 != 0 {
        bail!("f32 bin file has odd length {}", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Max absolute elementwise difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.artifact("dilated_vgg_tiny").is_some());
        assert!(m.artifact("gemm_tile").is_some());
        assert!(m.golden.is_some());
    }

    #[test]
    fn gemm_tile_runs_and_is_correct() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        let rt = Runtime::cpu().unwrap();
        let model = rt.load(m.artifact("gemm_tile").unwrap()).unwrap();
        // Identity x ones: output rows all equal to 1.
        let n = 256;
        let mut a = vec![0f32; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b = vec![1f32; n * n];
        let out = model.run_f32(&[&a, &b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), n * n);
        assert!(out[0].iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn shape_mismatch_rejected() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(artifacts_dir()).unwrap();
        let rt = Runtime::cpu().unwrap();
        let model = rt.load(m.artifact("gemm_tile").unwrap()).unwrap();
        let bad = vec![0f32; 7];
        assert!(model.run_f32(&[&bad, &bad]).is_err());
        let a = vec![0f32; 256 * 256];
        assert!(model.run_f32(&[&a]).is_err());
    }

    #[test]
    fn f32_bin_roundtrip() {
        let dir = std::env::temp_dir().join(format!("avsm_bin_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(read_f32_bin(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn max_abs_diff_works() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.5, 1.0]), 1.0);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }
}
