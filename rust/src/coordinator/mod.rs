//! The end-to-end virtual-system-based prototyping flow (paper Fig 1,
//! right-hand side), with the phase instrumentation behind Fig 3.
//!
//! `run_flow` executes the full pipeline the paper describes:
//!
//! 1. **ML Compiler & Graph Generation** — validate the DNN graph and run
//!    the deep-learning compiler (tiling + lowering) to produce the
//!    hardware-adapted task graph.
//! 2. **Tool import/export and Model build** — serialize the task graph
//!    across the flow boundary (the paper exchanges it between compiler and
//!    model-generation engine; 91 % of their runtime!), re-import it, and
//!    build the executable virtual system model from the system description
//!    file. Post-simulation result export is charged here too.
//! 3. **Simulation** — execute the AVSM on the DES engine.
//!
//! Python never appears on this path: the DNN graph arrives as JSON
//! produced once by `make artifacts`.

use crate::compiler::{compile, CompileOptions, CompiledNet};
use crate::config::SystemConfig;
use crate::graph::DnnGraph;
use crate::hw::{simulate_avsm, SimResult};
use crate::report::FlowBreakdown;
use crate::sim::TraceRecorder;
use crate::taskgraph;
use crate::trace::{Gantt, GanttOptions};
use anyhow::{Context, Result};
use std::path::Path;
use std::time::Instant;

/// Flow configuration.
#[derive(Debug, Clone)]
pub struct FlowOptions {
    pub compile: CompileOptions,
    /// Record per-interval traces (needed for Gantt; adds memory traffic).
    pub record_trace: bool,
    /// Round-trip the task graph through its JSON serialization, as the
    /// paper's flow does between compiler and model generator. Disable to
    /// measure the in-memory fast path.
    pub roundtrip_taskgraph: bool,
}

impl Default for FlowOptions {
    fn default() -> Self {
        Self {
            compile: CompileOptions::default(),
            record_trace: true,
            roundtrip_taskgraph: true,
        }
    }
}

/// Everything the flow produces.
pub struct FlowOutput {
    pub compiled: CompiledNet,
    pub sim: SimResult,
    pub trace: TraceRecorder,
    pub breakdown: FlowBreakdown,
}

pub const PHASE_COMPILER: &str = "ML Compiler & Graph Generation";
pub const PHASE_BUILD: &str = "Tool import/export and Model build";
pub const PHASE_SIM: &str = "Simulation";

/// Run the complete virtual-system-based prototyping flow; if `outdir` is
/// given, export the result artifacts (task graph, Gantt CSV/SVG, layer
/// table) there.
pub fn run_flow(
    net: &DnnGraph,
    sys: &SystemConfig,
    opts: &FlowOptions,
    outdir: Option<&Path>,
) -> Result<FlowOutput> {
    let mut breakdown = FlowBreakdown::default();

    // Phase 1: the deep-learning compiler.
    let t0 = Instant::now();
    let compiled = compile(net, sys, opts.compile)?;
    breakdown.add(PHASE_COMPILER, t0.elapsed());

    // Phase 2: flow-boundary import/export + model build.
    let t0 = Instant::now();
    let compiled = if opts.roundtrip_taskgraph {
        let text = taskgraph::serialize::to_json(&compiled.graph);
        if let Some(dir) = outdir {
            std::fs::create_dir_all(dir)?;
            std::fs::write(dir.join("task_graph.json"), &text)
                .context("exporting task graph")?;
        }
        let graph = taskgraph::serialize::from_json(&text)?;
        CompiledNet { graph, layers: compiled.layers }
    } else {
        compiled
    };
    // "Model build": allocate the trace/model state for this instance.
    let mut trace = if opts.record_trace {
        TraceRecorder::new()
    } else {
        TraceRecorder::disabled()
    };
    breakdown.add(PHASE_BUILD, t0.elapsed());

    // Phase 3: simulation.
    let t0 = Instant::now();
    let sim = simulate_avsm(&compiled, sys, &mut trace);
    breakdown.add(PHASE_SIM, t0.elapsed());

    // Result export is charged to the import/export row, as in the paper.
    if let Some(dir) = outdir {
        let t0 = Instant::now();
        export_results(dir, &sim, &trace)?;
        breakdown.add(PHASE_BUILD, t0.elapsed());
    }

    Ok(FlowOutput { compiled, sim, trace, breakdown })
}

fn export_results(dir: &Path, sim: &SimResult, trace: &TraceRecorder) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    // Per-layer timing table (CSV).
    let mut csv = String::from("layer,start_ps,end_ps,nce_busy_ps,bus_busy_ps,macs,dma_bytes\n");
    for l in &sim.layers {
        csv.push_str(&format!(
            "{},{},{},{},{},{},{}\n",
            l.name, l.start_ps, l.end_ps, l.nce_busy_ps, l.bus_busy_ps, l.macs, l.dma_bytes
        ));
    }
    std::fs::write(dir.join("layers.csv"), csv)?;
    if trace.is_enabled() {
        let g = Gantt::new(trace, GanttOptions::default());
        std::fs::write(dir.join("gantt.csv"), g.render_csv())?;
        std::fs::write(dir.join("gantt.svg"), g.render_svg())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn flow_runs_end_to_end() {
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        let out = run_flow(&net, &sys, &FlowOptions::default(), None).unwrap();
        assert!(out.sim.total_ps > 0);
        assert_eq!(out.breakdown.phases.len(), 3);
        assert!(out.breakdown.total().as_nanos() > 0);
    }

    #[test]
    fn flow_exports_artifacts() {
        let dir = std::env::temp_dir().join(format!("avsm_flow_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        run_flow(&net, &sys, &FlowOptions::default(), Some(&dir)).unwrap();
        for f in ["task_graph.json", "layers.csv", "gantt.csv", "gantt.svg"] {
            assert!(dir.join(f).exists(), "missing {f}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn roundtrip_preserves_simulation_result() {
        let sys = SystemConfig::base_paper();
        let net = models::dilated_vgg_tiny();
        let with = run_flow(
            &net,
            &sys,
            &FlowOptions { roundtrip_taskgraph: true, ..Default::default() },
            None,
        )
        .unwrap();
        let without = run_flow(
            &net,
            &sys,
            &FlowOptions { roundtrip_taskgraph: false, ..Default::default() },
            None,
        )
        .unwrap();
        assert_eq!(with.sim.total_ps, without.sim.total_ps);
    }

    #[test]
    fn flow_is_fast_enough() {
        // The paper's whole flow took ~20 min (1353 s); DESIGN.md §9 targets
        // <5 s for ours on the paper workload. Tiny net here — sanity only.
        let sys = SystemConfig::base_paper();
        let net = models::dilated_vgg_tiny();
        let out = run_flow(&net, &sys, &FlowOptions::default(), None).unwrap();
        assert!(out.breakdown.total().as_secs_f64() < 30.0);
    }
}
