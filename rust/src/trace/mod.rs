//! Gantt-chart rendering of simulation traces (paper Fig 4): per-resource
//! busy intervals for the computation (NCE) and communication (bus, DMA
//! channels) resources, showing dependency patterns — NCE continuously
//! occupied on compute-bound layers while the DMA idles, and vice versa.

pub mod chrome;
pub mod gantt;

pub use chrome::{spans_to_chrome_trace, to_chrome_trace};
pub use gantt::{Gantt, GanttOptions};
