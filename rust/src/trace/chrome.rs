//! Chrome trace-event (Perfetto-compatible) export of simulation traces.
//!
//! Load the emitted JSON in `chrome://tracing` or https://ui.perfetto.dev
//! to browse the virtual system's schedule interactively — the modern
//! rendition of the paper's Fig 4 Gantt.

use crate::json::{obj, Value};
use crate::sim::{IntervalKind, TraceRecorder};

/// Export the trace in the Chrome trace-event array format. Timestamps are
/// microseconds (`ts`/`dur` floats), one "thread" per traced resource.
pub fn to_chrome_trace(trace: &TraceRecorder) -> String {
    let mut events: Vec<Value> = Vec::with_capacity(trace.intervals().len() + 8);
    // Thread name metadata per resource.
    for (rid, name) in trace.resources() {
        events.push(obj(vec![
            ("name", "thread_name".into()),
            ("ph", "M".into()),
            ("pid", 1u32.into()),
            ("tid", rid.into()),
            ("args", obj(vec![("name", name.into())])),
        ]));
    }
    for iv in trace.intervals() {
        let cat = match iv.kind {
            IntervalKind::Compute => "compute",
            IntervalKind::Transfer => "transfer",
            IntervalKind::Control => "control",
            IntervalKind::Stall => "stall",
        };
        let label = trace.name(iv.label);
        events.push(obj(vec![
            ("name", if label.is_empty() { cat } else { label }.into()),
            ("cat", cat.into()),
            ("ph", "X".into()),
            ("pid", 1u32.into()),
            ("tid", iv.resource.into()),
            ("ts", (iv.start as f64 / 1e6).into()),
            ("dur", (iv.duration() as f64 / 1e6).into()),
            ("args", obj(vec![("task", iv.task.into())])),
        ]));
    }
    Value::Array(events).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::config::SystemConfig;
    use crate::graph::models;
    use crate::hw::simulate_avsm;
    use crate::json;

    #[test]
    fn chrome_trace_is_valid_json_with_events() {
        let sys = SystemConfig::base_paper();
        let c = compile(&models::lenet(28), &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::new();
        simulate_avsm(&c, &sys, &mut tr);
        let text = to_chrome_trace(&tr);
        let v = json::parse(&text).unwrap();
        let events = v.as_array().unwrap();
        assert!(events.len() > tr.intervals().len());
        // Every duration event has the mandatory fields.
        let x_events: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .collect();
        assert_eq!(x_events.len(), tr.intervals().len());
        for e in x_events.iter().take(5) {
            assert!(e.get("ts").as_f64().is_some());
            assert!(e.get("dur").as_f64().is_some());
            assert!(e.get("name").as_str().is_some());
        }
        // Metadata rows name the resources.
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("M")));
    }

    #[test]
    fn empty_trace_exports_cleanly() {
        let tr = TraceRecorder::new();
        let v = json::parse(&to_chrome_trace(&tr)).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 0);
    }
}
