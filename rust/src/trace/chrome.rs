//! Chrome trace-event (Perfetto-compatible) export of simulation traces.
//!
//! Load the emitted JSON in `chrome://tracing` or https://ui.perfetto.dev
//! to browse the virtual system's schedule interactively — the modern
//! rendition of the paper's Fig 4 Gantt.

use crate::json::{obj, Value};
use crate::obs::Span;
use crate::sim::{IntervalKind, TraceRecorder};

/// Export the trace in the Chrome trace-event array format. Timestamps are
/// microseconds (`ts`/`dur` floats), one "thread" per traced resource.
pub fn to_chrome_trace(trace: &TraceRecorder) -> String {
    let mut events: Vec<Value> = Vec::with_capacity(trace.intervals().len() + 8);
    // Thread name metadata per resource.
    for (rid, name) in trace.resources() {
        events.push(obj(vec![
            ("name", "thread_name".into()),
            ("ph", "M".into()),
            ("pid", 1u32.into()),
            ("tid", rid.into()),
            ("args", obj(vec![("name", name.into())])),
        ]));
    }
    for iv in trace.intervals() {
        let cat = match iv.kind {
            IntervalKind::Compute => "compute",
            IntervalKind::Transfer => "transfer",
            IntervalKind::Control => "control",
            IntervalKind::Stall => "stall",
        };
        let label = trace.name(iv.label);
        events.push(obj(vec![
            ("name", if label.is_empty() { cat } else { label }.into()),
            ("cat", cat.into()),
            ("ph", "X".into()),
            ("pid", 1u32.into()),
            ("tid", iv.resource.into()),
            ("ts", (iv.start as f64 / 1e6).into()),
            ("dur", (iv.duration() as f64 / 1e6).into()),
            ("args", obj(vec![("task", iv.task.into())])),
        ]));
    }
    Value::Array(events).to_string_compact()
}

/// Export engine telemetry spans ([`crate::obs`]) in the same Chrome
/// trace-event array format — the campaign engine's own Gantt, sibling to
/// the simulator's: one "thread" per pool worker (tid 0 is the
/// coordinating thread), `cat` is the span kind, and `args` carry the
/// net / unit / outcome tags. Timestamps convert ns → µs.
pub fn spans_to_chrome_trace(spans: &[Span]) -> String {
    let mut workers: Vec<u32> = spans.iter().map(|s| s.worker).collect();
    workers.sort_unstable();
    workers.dedup();
    let mut events: Vec<Value> = Vec::with_capacity(spans.len() + workers.len());
    for &w in &workers {
        let name =
            if w == 0 { "coordinator".to_string() } else { format!("worker {}", w - 1) };
        events.push(obj(vec![
            ("name", "thread_name".into()),
            ("ph", "M".into()),
            ("pid", 1u32.into()),
            ("tid", w.into()),
            ("args", obj(vec![("name", name.into())])),
        ]));
    }
    for s in spans {
        let mut args: Vec<(&str, Value)> = vec![("outcome", s.outcome.into())];
        if let Some(net) = &s.net {
            args.push(("net", net.as_str().into()));
        }
        if let Some(unit) = s.unit {
            args.push(("unit", unit.into()));
        }
        events.push(obj(vec![
            ("name", s.kind.into()),
            ("cat", s.kind.into()),
            ("ph", "X".into()),
            ("pid", 1u32.into()),
            ("tid", s.worker.into()),
            ("ts", (s.start_ns as f64 / 1e3).into()),
            ("dur", ((s.end_ns - s.start_ns) as f64 / 1e3).into()),
            ("args", obj(args)),
        ]));
    }
    Value::Array(events).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::config::SystemConfig;
    use crate::graph::models;
    use crate::hw::simulate_avsm;
    use crate::json;

    #[test]
    fn chrome_trace_is_valid_json_with_events() {
        let sys = SystemConfig::base_paper();
        let c = compile(&models::lenet(28), &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::new();
        simulate_avsm(&c, &sys, &mut tr);
        let text = to_chrome_trace(&tr);
        let v = json::parse(&text).unwrap();
        let events = v.as_array().unwrap();
        assert!(events.len() > tr.intervals().len());
        // Every duration event has the mandatory fields.
        let x_events: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("X"))
            .collect();
        assert_eq!(x_events.len(), tr.intervals().len());
        for e in x_events.iter().take(5) {
            assert!(e.get("ts").as_f64().is_some());
            assert!(e.get("dur").as_f64().is_some());
            assert!(e.get("name").as_str().is_some());
        }
        // Metadata rows name the resources.
        assert!(events.iter().any(|e| e.get("ph").as_str() == Some("M")));
    }

    #[test]
    fn empty_trace_exports_cleanly() {
        let tr = TraceRecorder::new();
        let v = json::parse(&to_chrome_trace(&tr)).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 0);
    }

    fn span(kind: &'static str, worker: u32, start_ns: u64, end_ns: u64) -> Span {
        Span {
            kind,
            worker,
            net: Some("lenet".into()),
            unit: Some(3),
            outcome: "feasible",
            start_ns,
            end_ns,
        }
    }

    #[test]
    fn engine_spans_export_one_thread_per_worker() {
        let spans = vec![
            span("simulate", 1, 1_000, 3_500),
            span("simulate", 2, 1_000, 2_000),
            Span {
                kind: "journal.append",
                worker: 0,
                net: None,
                unit: None,
                outcome: "ok",
                start_ns: 4_000,
                end_ns: 4_100,
            },
        ];
        let v = json::parse(&spans_to_chrome_trace(&spans)).unwrap();
        let events = v.as_array().unwrap();
        // One metadata row per distinct worker, coordinator included.
        let meta: Vec<_> =
            events.iter().filter(|e| e.get("ph").as_str() == Some("M")).collect();
        assert_eq!(meta.len(), 3);
        assert_eq!(meta[0].get("args").get("name").as_str(), Some("coordinator"));
        assert_eq!(meta[1].get("args").get("name").as_str(), Some("worker 0"));
        assert_eq!(meta[2].get("args").get("name").as_str(), Some("worker 1"));
        let x: Vec<_> = events.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
        assert_eq!(x.len(), 3);
        // ns → µs conversion and the tag args.
        assert_eq!(x[0].get("ts").as_f64(), Some(1.0));
        assert_eq!(x[0].get("dur").as_f64(), Some(2.5));
        assert_eq!(x[0].get("cat").as_str(), Some("simulate"));
        assert_eq!(x[0].get("args").get("net").as_str(), Some("lenet"));
        assert_eq!(x[0].get("args").get("unit").as_u64(), Some(3));
        assert_eq!(x[0].get("args").get("outcome").as_str(), Some("feasible"));
        // Untagged coordinator span carries only the outcome.
        assert!(x[2].get("args").get("net").as_str().is_none());
    }

    #[test]
    fn empty_span_set_exports_cleanly() {
        let v = json::parse(&spans_to_chrome_trace(&[])).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 0);
    }
}
