//! Gantt renderer: ASCII for terminals, SVG + CSV artifacts for reports.

use crate::sim::{IntervalKind, SimTime, TraceRecorder};

/// Rendering options.
#[derive(Debug, Clone, Copy)]
pub struct GanttOptions {
    /// Window start/end in ps (None = whole trace).
    pub window: Option<(SimTime, SimTime)>,
    /// Character width of the ASCII rendering.
    pub width: usize,
}

impl Default for GanttOptions {
    fn default() -> Self {
        Self { window: None, width: 100 }
    }
}

/// A Gantt view over a recorded trace.
pub struct Gantt<'a> {
    trace: &'a TraceRecorder,
    opts: GanttOptions,
}

impl<'a> Gantt<'a> {
    pub fn new(trace: &'a TraceRecorder, opts: GanttOptions) -> Self {
        Self { trace, opts }
    }

    fn window(&self) -> (SimTime, SimTime) {
        self.opts.window.unwrap_or((0, self.trace.horizon().max(1)))
    }

    /// ASCII art: one row per resource, `#` compute, `=` transfer,
    /// `.` idle — the terminal Fig 4.
    pub fn render_ascii(&self) -> String {
        let (w0, w1) = self.window();
        let span = (w1 - w0).max(1);
        let width = self.opts.width.max(10);
        let mut out = String::new();
        out.push_str(&format!(
            "gantt {:.3} ms .. {:.3} ms ({} cols, {:.1} us/col)\n",
            w0 as f64 / 1e9,
            w1 as f64 / 1e9,
            width,
            span as f64 / width as f64 / 1e6
        ));
        for (rid, name) in self.trace.resources() {
            let mut row = vec!['.'; width];
            for iv in self.trace.for_resource(rid) {
                let s = iv.start.max(w0);
                let e = iv.end.min(w1);
                if s >= e {
                    continue;
                }
                let c0 = ((s - w0) as u128 * width as u128 / span as u128) as usize;
                let c1 = (((e - w0) as u128 * width as u128).div_ceil(span as u128) as usize)
                    .min(width);
                let ch = match iv.kind {
                    IntervalKind::Compute => '#',
                    IntervalKind::Transfer => '=',
                    IntervalKind::Control => '+',
                    IntervalKind::Stall => 'x',
                };
                for c in row.iter_mut().take(c1).skip(c0) {
                    *c = ch;
                }
            }
            out.push_str(&format!("{name:>6} |"));
            out.extend(row);
            out.push_str("|\n");
        }
        out
    }

    /// CSV export: resource,label,task,kind,start_ps,end_ps.
    pub fn render_csv(&self) -> String {
        let (w0, w1) = self.window();
        let mut out = String::from("resource,label,task,kind,start_ps,end_ps\n");
        for iv in self.trace.intervals() {
            if iv.end <= w0 || iv.start >= w1 {
                continue;
            }
            out.push_str(&format!(
                "{},{},{},{:?},{},{}\n",
                self.trace.name(iv.resource),
                self.trace.name(iv.label),
                iv.task,
                iv.kind,
                iv.start,
                iv.end
            ));
        }
        out
    }

    /// SVG rendering with one lane per resource.
    pub fn render_svg(&self) -> String {
        self.render_svg_with_legend(&[])
    }

    /// [`render_svg`](Self::render_svg) plus a trailing axis-name legend
    /// caption (see `report::campaign::axis_legend`) decoding swept-axis
    /// name tokens for readers of campaign artifacts. An empty legend
    /// renders byte-identically to the plain form.
    pub fn render_svg_with_legend(&self, legend: &[(&'static str, String)]) -> String {
        let (w0, w1) = self.window();
        let span = (w1 - w0).max(1) as f64;
        let resources = self.trace.resources();
        let lane_h = 28.0;
        let ml = 64.0;
        let w = 900.0;
        let h = 30.0 + lane_h * resources.len() as f64 + 30.0;
        let hsvg = h + if legend.is_empty() { 0.0 } else { 16.0 };
        let x = |t: SimTime| ml + (t.saturating_sub(w0)) as f64 / span * (w - ml - 10.0);
        let mut s = format!(
            r#"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{hsvg}" font-family="monospace" font-size="11">"#
        );
        s.push_str(&format!(r#"<rect width="{w}" height="{hsvg}" fill="white"/>"#));
        for (li, (rid, name)) in resources.iter().enumerate() {
            let y0 = 20.0 + lane_h * li as f64;
            s.push_str(&format!(
                r#"<text x="4" y="{:.1}">{name}</text>"#,
                y0 + lane_h * 0.6
            ));
            for iv in self.trace.for_resource(*rid) {
                let a = iv.start.max(w0);
                let b = iv.end.min(w1);
                if a >= b {
                    continue;
                }
                let color = match iv.kind {
                    IntervalKind::Compute => "#c0392b",
                    IntervalKind::Transfer => "#2980b9",
                    IntervalKind::Control => "#27ae60",
                    IntervalKind::Stall => "#f39c12",
                };
                s.push_str(&format!(
                    r#"<rect x="{:.2}" y="{:.1}" width="{:.2}" height="{:.1}" fill="{color}"/>"#,
                    x(a),
                    y0 + 4.0,
                    (x(b) - x(a)).max(0.4),
                    lane_h - 8.0
                ));
            }
        }
        s.push_str(&format!(
            r#"<text x="{:.0}" y="{:.0}">time: {:.3} .. {:.3} ms</text>"#,
            w / 2.0 - 90.0,
            h - 8.0,
            w0 as f64 / 1e9,
            w1 as f64 / 1e9
        ));
        if !legend.is_empty() {
            let entries: Vec<String> =
                legend.iter().map(|(key, desc)| format!("{key} = {desc}")).collect();
            s.push_str(&format!(
                r#"<text x="4" y="{:.0}">name legend: {}</text>"#,
                hsvg - 6.0,
                entries.join(", ")
            ));
        }
        s.push_str("</svg>");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, CompileOptions};
    use crate::config::SystemConfig;
    use crate::graph::models;
    use crate::hw::simulate_avsm;

    fn traced() -> (TraceRecorder, crate::hw::SimResult) {
        let sys = SystemConfig::base_paper();
        let net = models::lenet(28);
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::new();
        let sim = simulate_avsm(&c, &sys, &mut tr);
        (tr, sim)
    }

    #[test]
    fn ascii_has_all_resources_and_marks() {
        let (tr, _) = traced();
        let g = Gantt::new(&tr, GanttOptions::default());
        let txt = g.render_ascii();
        assert!(txt.contains("nce") && txt.contains("bus"));
        assert!(txt.contains('#'), "no compute marks:\n{txt}");
        assert!(txt.contains('='), "no transfer marks:\n{txt}");
    }

    #[test]
    fn windowed_view_clips() {
        let (tr, sim) = traced();
        let mid = sim.total_ps / 2;
        let g = Gantt::new(&tr, GanttOptions { window: Some((0, mid)), width: 50 });
        let txt = g.render_ascii();
        assert!(txt.contains("gantt"));
        let csv_all = Gantt::new(&tr, GanttOptions::default()).render_csv();
        let csv_half = g.render_csv();
        assert!(csv_half.lines().count() <= csv_all.lines().count());
    }

    #[test]
    fn csv_schema() {
        let (tr, _) = traced();
        let csv = Gantt::new(&tr, GanttOptions::default()).render_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "resource,label,task,kind,start_ps,end_ps");
        let first = lines.next().unwrap();
        assert_eq!(first.split(',').count(), 6);
    }

    #[test]
    fn svg_is_wellformed_enough() {
        let (tr, _) = traced();
        let svg = Gantt::new(&tr, GanttOptions::default()).render_svg();
        assert!(svg.starts_with("<svg") && svg.ends_with("</svg>"));
        assert!(svg.matches("<rect").count() > 3);
    }

    #[test]
    fn svg_legend_caption_decodes_axis_tokens() {
        let (tr, _) = traced();
        let g = Gantt::new(&tr, GanttOptions::default());
        let legend = vec![("f", "NCE frequency (MHz)".to_string())];
        let svg = g.render_svg_with_legend(&legend);
        assert!(svg.contains("name legend: f = NCE frequency (MHz)"), "{svg}");
        // The legend-free form is byte-identical to plain render_svg.
        assert_eq!(g.render_svg_with_legend(&[]), g.render_svg());
        assert!(!g.render_svg().contains("name legend"));
    }

    #[test]
    fn compute_and_comm_bound_phases_visible() {
        // Fig 4's observation: some windows have busy NCE + idle DMA and
        // others the reverse. Check utilization asymmetry across windows.
        let sys = SystemConfig::base_paper();
        let net = models::dilated_vgg_paper();
        let c = compile(&net, &sys, CompileOptions::default()).unwrap();
        let mut tr = TraceRecorder::new();
        let sim = simulate_avsm(&c, &sys, &mut tr);
        // dense1 window: NCE busy; pool1 window: bus busy.
        let dense1 = sim.layer("dense1").unwrap();
        let pool1 = sim.layer("pool1").unwrap();
        assert!(dense1.nce_utilization() > 0.9 && dense1.bus_utilization() < 0.5);
        assert!(pool1.bus_utilization() > 0.9 && pool1.nce_utilization() < 0.5);
    }
}
