//! Minimal benchmark harness (the offline environment has no criterion).
//!
//! `cargo bench` runs the `benches/*.rs` binaries (harness = false); each
//! uses this module to time closures with warmup, report median/mean/min
//! and print a stable, grep-friendly table. Not statistics-grade, but
//! deterministic workloads + medians give repeatable numbers.
//!
//! [`Bench::write_json`] additionally emits a machine-readable
//! `BENCH_<group>.json` snapshot (median_ns per case plus free-form
//! headline metrics) so the perf trajectory can be tracked across PRs.

use crate::json::{obj, Value};
use std::path::Path;
use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Nearest-rank latency percentiles (see [`crate::metrics::Summary`]);
    /// with few iters these collapse toward `max`, by construction.
    pub p50: Duration,
    pub p90: Duration,
    pub p99: Duration,
}

impl BenchResult {
    pub fn per_iter_line(&self) -> String {
        format!(
            "bench {:<44} {:>12} median {:>12} mean {:>12} min ({} iters)",
            self.name,
            fmt_dur(self.median),
            fmt_dur(self.mean),
            fmt_dur(self.min),
            self.iters
        )
    }
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.3} s", d.as_secs_f64())
    } else if ns >= 1_000_000 {
        format!("{:.3} ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.3} us", ns as f64 / 1e3)
    } else {
        format!("{ns} ns")
    }
}

/// A group of benchmark cases with shared iteration policy.
pub struct Bench {
    group: String,
    warmup: u32,
    iters: u32,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: impl Into<String>) -> Self {
        // Respect a quick mode for CI-ish runs: AVSM_BENCH_FAST=1.
        let fast = std::env::var("AVSM_BENCH_FAST").is_ok();
        Self {
            group: group.into(),
            warmup: if fast { 1 } else { 2 },
            iters: if fast { 3 } else { 10 },
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, warmup: u32, iters: u32) -> Self {
        self.warmup = warmup;
        self.iters = iters.max(1);
        self
    }

    /// Time `f`, keeping its result alive (prevents trivial DCE).
    pub fn case<T>(&mut self, name: impl Into<String>, mut f: impl FnMut() -> T) -> &BenchResult {
        let name = name.into();
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        samples.sort();
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let ns: Vec<f64> = samples.iter().map(|d| d.as_nanos() as f64).collect();
        let stats = crate::metrics::summarize(&ns);
        let res = BenchResult {
            name: format!("{}/{}", self.group, name),
            iters: self.iters,
            median,
            mean,
            min: samples[0],
            max: *samples.last().unwrap(),
            p50: Duration::from_nanos(stats.p50 as u64),
            p90: Duration::from_nanos(stats.p90 as u64),
            p99: Duration::from_nanos(stats.p99 as u64),
        };
        println!("{}", res.per_iter_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Emit a free-form metric row (throughput, deviation, ...) in the same
    /// grep-friendly format.
    pub fn metric(&self, name: &str, value: f64, unit: &str) {
        println!("metric {:<43} {value:>14.4} {unit}", format!("{}/{name}", self.group));
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Machine-readable snapshot: `{"name": <group>, "median_ns": ...,
    /// "cases": [...], <headline metrics>}`. `median_ns` at the top level
    /// is the first recorded case's median (the group's headline timing);
    /// `headline` metrics (e.g. `points_per_sec`) are flattened to top
    /// level for trivial downstream parsing.
    pub fn to_json(&self, headline: &[(&str, f64)]) -> Value {
        let cases = Value::Array(
            self.results
                .iter()
                .map(|r| {
                    obj(vec![
                        ("name", r.name.as_str().into()),
                        ("median_ns", (r.median.as_nanos() as u64).into()),
                        ("mean_ns", (r.mean.as_nanos() as u64).into()),
                        ("min_ns", (r.min.as_nanos() as u64).into()),
                        ("max_ns", (r.max.as_nanos() as u64).into()),
                        ("p50_ns", (r.p50.as_nanos() as u64).into()),
                        ("p90_ns", (r.p90.as_nanos() as u64).into()),
                        ("p99_ns", (r.p99.as_nanos() as u64).into()),
                        ("iters", r.iters.into()),
                    ])
                })
                .collect(),
        );
        let mut pairs: Vec<(&str, Value)> = vec![
            ("name", self.group.as_str().into()),
            (
                "median_ns",
                self.results
                    .first()
                    .map(|r| r.median.as_nanos() as u64)
                    .unwrap_or(0)
                    .into(),
            ),
        ];
        for &(k, v) in headline {
            pairs.push((k, v.into()));
        }
        pairs.push(("cases", cases));
        obj(pairs)
    }

    /// Write [`Bench::to_json`] to `path`.
    pub fn write_json(&self, path: impl AsRef<Path>, headline: &[(&str, f64)]) -> std::io::Result<()> {
        std::fs::write(path, self.to_json(headline).to_string_pretty() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_measures_and_records() {
        let mut b = Bench::new("test").with_iters(0, 3);
        let r = b.case("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.min <= r.median && r.median <= r.max);
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].name.contains("test/spin"));
    }

    #[test]
    fn json_snapshot_has_headline_and_cases() {
        let mut b = Bench::new("dse_sweep").with_iters(0, 2);
        b.case("sweep_9_points", || 42u64);
        let j = b.to_json(&[("points_per_sec", 123.5)]);
        assert_eq!(j.get("name").as_str(), Some("dse_sweep"));
        assert!(j.get("median_ns").as_u64().is_some());
        assert!((j.get("points_per_sec").as_f64().unwrap() - 123.5).abs() < 1e-9);
        let cases = j.get("cases").as_array().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").as_str(), Some("dse_sweep/sweep_9_points"));
        assert!(cases[0].get("median_ns").as_u64().is_some());
        let (p50, p99) = (
            cases[0].get("p50_ns").as_u64().unwrap(),
            cases[0].get("p99_ns").as_u64().unwrap(),
        );
        assert!(p50 <= p99, "percentiles must be monotone");
        assert!(p99 <= cases[0].get("max_ns").as_u64().unwrap());
        // Round-trips through the writer.
        let text = j.to_string_pretty();
        let back = crate::json::parse(&text).unwrap();
        assert_eq!(back.get("name").as_str(), Some("dse_sweep"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.500 ms");
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000 s");
    }
}
