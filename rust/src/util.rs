//! Small shared numeric helpers.
//!
//! One definition of ceiling division for the whole crate — the compiler's
//! tiler, the graph IR's shape arithmetic and the cost model all round the
//! same way, and a single copy keeps them provably consistent.

/// `ceil(a / b)` for `u32`. `b` must be non-zero.
pub fn div_ceil(a: u32, b: u32) -> u32 {
    (a + b - 1) / b
}

/// `ceil(a / b)` for `u64`. `b` must be non-zero.
pub fn div_ceil64(a: u64, b: u64) -> u64 {
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounds_up() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
        assert_eq!(div_ceil64(0, 3), 0);
        assert_eq!(div_ceil64(6, 3), 2);
        assert_eq!(div_ceil64(7, 3), 3);
        assert_eq!(div_ceil64(u64::from(u32::MAX) + 1, 2), 1 << 31);
    }
}
