//! Post-simulation statistics derived from the trace: per-resource
//! utilization and occupancy — what the paper's Gantt analysis (Fig 4) reads
//! off to classify layers as compute- vs communication-bound.

use super::{SimTime, TraceRecorder};

/// Utilization summary for one traced resource.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceStats {
    pub name: String,
    pub busy_ps: SimTime,
    pub intervals: usize,
    /// busy / horizon, in [0, 1].
    pub utilization: f64,
}

/// Compute per-resource stats over a window (or the whole run when
/// `window = None`). Windowed stats power the per-layer bound
/// classification: a layer is compute-bound when NCE utilization ~ 1 within
/// the layer's window while the bus idles, and vice versa.
pub fn resource_stats(
    trace: &TraceRecorder,
    window: Option<(SimTime, SimTime)>,
) -> Vec<ResourceStats> {
    let (w0, w1) = window.unwrap_or((0, trace.horizon()));
    let span = (w1 - w0).max(1);
    trace
        .resources()
        .into_iter()
        .map(|(id, name)| {
            let mut busy = 0;
            let mut n = 0;
            for iv in trace.for_resource(id) {
                let s = iv.start.max(w0);
                let e = iv.end.min(w1);
                if s < e {
                    busy += e - s;
                    n += 1;
                }
            }
            ResourceStats {
                name: name.to_string(),
                busy_ps: busy,
                intervals: n,
                utilization: busy as f64 / span as f64,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::IntervalKind;

    fn demo_trace() -> TraceRecorder {
        let mut tr = TraceRecorder::new();
        let nce = tr.intern("nce");
        let bus = tr.intern("bus");
        let l = tr.intern("t");
        tr.record(nce, l, 0, IntervalKind::Compute, 0, 80);
        tr.record(bus, l, 0, IntervalKind::Transfer, 0, 20);
        tr.record(bus, l, 1, IntervalKind::Transfer, 80, 100);
        tr
    }

    #[test]
    fn whole_run_utilization() {
        let tr = demo_trace();
        let stats = resource_stats(&tr, None);
        let nce = stats.iter().find(|s| s.name == "nce").unwrap();
        let bus = stats.iter().find(|s| s.name == "bus").unwrap();
        assert_eq!(nce.busy_ps, 80);
        assert!((nce.utilization - 0.8).abs() < 1e-12);
        assert_eq!(bus.busy_ps, 40);
        assert_eq!(bus.intervals, 2);
    }

    #[test]
    fn windowed_utilization_clips_intervals() {
        let tr = demo_trace();
        let stats = resource_stats(&tr, Some((10, 30)));
        let nce = stats.iter().find(|s| s.name == "nce").unwrap();
        assert_eq!(nce.busy_ps, 20); // clipped to [10,30)
        let bus = stats.iter().find(|s| s.name == "bus").unwrap();
        assert_eq!(bus.busy_ps, 10); // only first transfer overlaps
    }

    #[test]
    fn empty_window_yields_zero() {
        let tr = demo_trace();
        let stats = resource_stats(&tr, Some((200, 300)));
        assert!(stats.iter().all(|s| s.busy_ps == 0));
    }
}
