//! Deterministic discrete-event simulation (DES) kernel.
//!
//! This is the substrate that replaces the paper's generated-SystemC +
//! Synopsys Platform Architect simulation backend (DESIGN.md §2): an
//! event-driven kernel with TLM-ish helper components (servers, arbitrated
//! bandwidth channels), per-resource busy-interval tracing and utilization
//! statistics. Both the abstract virtual system model (`crate::hw`) and the
//! detailed "physical prototype" model (`crate::detailed`) are built on it.
//!
//! Determinism: events are ordered by `(time, priority, seq)` where `seq`
//! is the insertion sequence number — simultaneous events fire in a fixed,
//! reproducible order regardless of heap internals.

pub mod clock;
pub mod engine;
pub mod resource;
pub mod stats;
pub mod trace;

pub use clock::ClockDomain;
pub use engine::{Engine, SimTime};
pub use resource::{Arbiter, BandwidthChannel, Server};
pub use stats::ResourceStats;
pub use trace::{Interval, IntervalKind, TraceRecorder};

/// One picosecond resolution; lets 250 MHz NCE, bus and DRAM clock domains
/// coexist without rounding (4000 ps, 1250 ps, ... periods are exact).
pub const PS_PER_SEC: u64 = 1_000_000_000_000;
