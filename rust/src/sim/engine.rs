//! The event queue at the heart of the DES kernel.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulated time in picoseconds since simulation start.
pub type SimTime = u64;

#[derive(Debug, Clone, PartialEq, Eq)]
struct Queued<E> {
    time: SimTime,
    /// Lower fires first among same-time events; used by models to order
    /// e.g. "release resource" before "try dispatch".
    priority: u8,
    seq: u64,
    event: E,
}

impl<E: Eq> Ord for Queued<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.priority, self.seq).cmp(&(other.time, other.priority, other.seq))
    }
}
impl<E: Eq> PartialOrd for Queued<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic discrete-event engine, generic over the event payload.
///
/// The owning simulator drives the loop:
/// ```no_run
/// // (no_run: doctest binaries don't inherit the rpath to the PJRT
/// //  shared libraries this crate links; the same loop is exercised by
/// //  the unit tests below.)
/// # use avsm::sim::Engine;
/// let mut eng: Engine<&'static str> = Engine::new();
/// eng.schedule(10, "tick");
/// while let Some(ev) = eng.pop() {
///     assert_eq!(eng.now(), 10);
///     assert_eq!(ev, "tick");
/// }
/// ```
#[derive(Debug)]
pub struct Engine<E> {
    queue: BinaryHeap<Reverse<Queued<E>>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E: Eq> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: Eq> Engine<E> {
    pub fn new() -> Self {
        Self { queue: BinaryHeap::new(), now: 0, seq: 0, processed: 0 }
    }

    /// An engine whose event heap is pre-sized for `cap` pending events.
    /// The executor sizes this from the task-graph length so the hot loop
    /// never reallocates the heap mid-simulation.
    pub fn with_capacity(cap: usize) -> Self {
        Self { queue: BinaryHeap::with_capacity(cap), now: 0, seq: 0, processed: 0 }
    }

    /// Current simulated time (time of the most recently popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Total events processed so far (perf counter for the engine bench).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of pending events.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule `event` at `now + delay` with default priority.
    pub fn schedule(&mut self, delay: SimTime, event: E) {
        self.schedule_prio(delay, 128, event);
    }

    /// Schedule with an explicit same-time ordering priority (lower first).
    pub fn schedule_prio(&mut self, delay: SimTime, priority: u8, event: E) {
        self.schedule_at(self.now.saturating_add(delay), priority, event);
    }

    /// Schedule at an absolute time; must not be in the past.
    pub fn schedule_at(&mut self, time: SimTime, priority: u8, event: E) {
        debug_assert!(time >= self.now, "event scheduled in the past");
        let seq = self.seq;
        self.seq += 1;
        self.queue.push(Reverse(Queued { time: time.max(self.now), priority, seq, event }));
    }

    /// Pop the next event, advancing simulated time. Returns `None` when the
    /// simulation has quiesced.
    pub fn pop(&mut self) -> Option<E> {
        let Reverse(q) = self.queue.pop()?;
        debug_assert!(q.time >= self.now);
        self.now = q.time;
        self.processed += 1;
        Some(q.event)
    }

    /// Peek at the time of the next event without popping.
    pub fn next_time(&self) -> Option<SimTime> {
        self.queue.peek().map(|Reverse(q)| q.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule(30, 3);
        eng.schedule(10, 1);
        eng.schedule(20, 2);
        assert_eq!(eng.pop(), Some(1));
        assert_eq!(eng.now(), 10);
        assert_eq!(eng.pop(), Some(2));
        assert_eq!(eng.pop(), Some(3));
        assert_eq!(eng.now(), 30);
        assert_eq!(eng.pop(), None);
    }

    #[test]
    fn same_time_fifo_by_seq() {
        let mut eng: Engine<u32> = Engine::new();
        for i in 0..100 {
            eng.schedule(5, i);
        }
        for i in 0..100 {
            assert_eq!(eng.pop(), Some(i));
        }
    }

    #[test]
    fn priority_orders_same_time_events() {
        let mut eng: Engine<&str> = Engine::new();
        eng.schedule_prio(5, 200, "late");
        eng.schedule_prio(5, 10, "early");
        assert_eq!(eng.pop(), Some("early"));
        assert_eq!(eng.pop(), Some("late"));
    }

    #[test]
    fn time_advances_monotonically() {
        let mut eng: Engine<u64> = Engine::new();
        eng.schedule(10, 10);
        eng.schedule(10, 11);
        eng.schedule(25, 25);
        let mut last = 0;
        while let Some(_) = eng.pop() {
            assert!(eng.now() >= last);
            last = eng.now();
        }
        assert_eq!(eng.processed(), 3);
    }

    #[test]
    fn schedule_from_within_loop() {
        // A chain of events each scheduling the next — the fundamental
        // causality pattern every component model relies on.
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule(1, 0);
        let mut fired = vec![];
        while let Some(ev) = eng.pop() {
            fired.push((eng.now(), ev));
            if ev < 4 {
                eng.schedule(7, ev + 1);
            }
        }
        assert_eq!(fired, vec![(1, 0), (8, 1), (15, 2), (22, 3), (29, 4)]);
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut eng: Engine<u32> = Engine::with_capacity(64);
        eng.schedule(5, 1);
        eng.schedule(3, 0);
        assert_eq!(eng.pop(), Some(0));
        assert_eq!(eng.pop(), Some(1));
        assert_eq!(eng.pop(), None);
        assert_eq!(eng.processed(), 2);
    }

    #[test]
    fn next_time_peeks_without_advancing() {
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule(42, 1);
        assert_eq!(eng.next_time(), Some(42));
        assert_eq!(eng.now(), 0);
        assert_eq!(eng.pending(), 1);
    }
}
