//! Clock domains: translate between component cycles and picosecond
//! simulation time. Physical annotations (the paper's "imported" clock
//! frequencies, §2) enter the AVSM through these.

use super::{SimTime, PS_PER_SEC};

/// A frequency-annotated clock domain (e.g. the 250 MHz NCE clock).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClockDomain {
    freq_hz: u64,
}

impl ClockDomain {
    pub fn from_hz(freq_hz: u64) -> Self {
        assert!(freq_hz > 0, "clock frequency must be positive");
        Self { freq_hz }
    }

    pub fn from_mhz(mhz: u64) -> Self {
        Self::from_hz(mhz * 1_000_000)
    }

    pub fn freq_hz(&self) -> u64 {
        self.freq_hz
    }

    /// Clock period in picoseconds, rounded to nearest.
    pub fn period_ps(&self) -> SimTime {
        (PS_PER_SEC + self.freq_hz / 2) / self.freq_hz
    }

    /// Duration of `cycles` cycles in ps (u128 intermediate, no overflow for
    /// any realistic cycle count).
    pub fn cycles_to_ps(&self, cycles: u64) -> SimTime {
        ((cycles as u128 * PS_PER_SEC as u128 + self.freq_hz as u128 / 2)
            / self.freq_hz as u128) as SimTime
    }

    /// Cycles elapsed in `ps` picoseconds (rounded up: a partial cycle
    /// occupies the whole cycle, matching RTL behaviour).
    pub fn ps_to_cycles(&self, ps: SimTime) -> u64 {
        ((ps as u128 * self.freq_hz as u128 + PS_PER_SEC as u128 - 1)
            / PS_PER_SEC as u128) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nce_250mhz_period() {
        let clk = ClockDomain::from_mhz(250);
        assert_eq!(clk.period_ps(), 4000);
        assert_eq!(clk.cycles_to_ps(1), 4000);
        assert_eq!(clk.cycles_to_ps(1000), 4_000_000);
    }

    #[test]
    fn ddr_800mhz_period() {
        let clk = ClockDomain::from_mhz(800);
        assert_eq!(clk.period_ps(), 1250);
    }

    #[test]
    fn cycle_roundtrip() {
        let clk = ClockDomain::from_mhz(333);
        for c in [1u64, 7, 1000, 123_456_789] {
            let ps = clk.cycles_to_ps(c);
            let back = clk.ps_to_cycles(ps);
            assert!(back == c || back == c + 1, "{c} -> {ps} -> {back}");
        }
    }

    #[test]
    fn partial_cycle_rounds_up() {
        let clk = ClockDomain::from_mhz(250); // 4000 ps period
        assert_eq!(clk.ps_to_cycles(1), 1);
        assert_eq!(clk.ps_to_cycles(4000), 1);
        assert_eq!(clk.ps_to_cycles(4001), 2);
    }

    #[test]
    #[should_panic]
    fn zero_frequency_rejected() {
        ClockDomain::from_hz(0);
    }

    #[test]
    fn no_overflow_long_sim() {
        // One year of 1 GHz cycles must not overflow the ps conversion.
        let clk = ClockDomain::from_mhz(1000);
        let cycles = 3_600 * 24 * 365 * 1_000_000_000u64 / 1000;
        let _ = clk.cycles_to_ps(cycles);
    }
}
