//! Busy-interval tracing — the raw material for the Gantt chart (Fig 4),
//! per-layer timing (Fig 5) and resource-utilization analysis.
//!
//! Labels are interned to keep the hot recording path allocation-free.

use super::SimTime;
use std::collections::HashMap;

/// What a resource was doing during an interval.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum IntervalKind {
    /// NCE (or other PE) computing a tile.
    Compute,
    /// DMA/bus moving bytes.
    Transfer,
    /// Control/dispatch overhead (HKP).
    Control,
    /// Resource stalled waiting (back-pressure, bank conflict, refresh).
    Stall,
}

/// A closed busy interval on one resource.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Interval {
    /// Interned resource name id (see [`TraceRecorder::resource_id`]).
    pub resource: u32,
    /// Interned task label id.
    pub label: u32,
    /// Task-graph node id this interval executed, `u32::MAX` if n/a.
    pub task: u32,
    pub kind: IntervalKind,
    pub start: SimTime,
    pub end: SimTime,
}

impl Interval {
    pub fn duration(&self) -> SimTime {
        self.end - self.start
    }
}

/// Records busy intervals with interned resource/label names.
#[derive(Debug, Default, Clone)]
pub struct TraceRecorder {
    names: Vec<String>,
    ids: HashMap<String, u32>,
    intervals: Vec<Interval>,
    enabled: bool,
    /// End of the last recorded interval — the simulated makespan.
    horizon: SimTime,
}

impl TraceRecorder {
    pub fn new() -> Self {
        Self { enabled: true, ..Default::default() }
    }

    /// A recorder that only tracks the horizon — for perf-critical sweeps
    /// (DSE) where per-interval storage is wasted work.
    pub fn disabled() -> Self {
        Self { enabled: false, ..Default::default() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Intern a name, returning a stable id.
    pub fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.ids.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.ids.insert(name.to_string(), id);
        id
    }

    pub fn name(&self, id: u32) -> &str {
        &self.names[id as usize]
    }

    pub fn lookup(&self, name: &str) -> Option<u32> {
        self.ids.get(name).copied()
    }

    /// Record one busy interval. `start <= end` is required.
    pub fn record(
        &mut self,
        resource: u32,
        label: u32,
        task: u32,
        kind: IntervalKind,
        start: SimTime,
        end: SimTime,
    ) {
        debug_assert!(start <= end, "interval ends before it starts");
        self.horizon = self.horizon.max(end);
        if self.enabled {
            self.intervals.push(Interval { resource, label, task, kind, start, end });
        }
    }

    /// Advance the horizon without recording an interval. The untraced
    /// executor fast path skips `record` entirely, so it publishes the
    /// final makespan through this instead — keeping the documented
    /// "disabled recorder still tracks the horizon" contract intact.
    pub fn note_horizon(&mut self, t: SimTime) {
        self.horizon = self.horizon.max(t);
    }

    pub fn intervals(&self) -> &[Interval] {
        &self.intervals
    }

    pub fn horizon(&self) -> SimTime {
        self.horizon
    }

    /// All intervals on a given resource, in recording order.
    pub fn for_resource(&self, resource: u32) -> impl Iterator<Item = &Interval> {
        self.intervals.iter().filter(move |iv| iv.resource == resource)
    }

    /// Total busy time per resource id.
    pub fn busy_time(&self) -> HashMap<u32, SimTime> {
        let mut busy: HashMap<u32, SimTime> = HashMap::new();
        for iv in &self.intervals {
            *busy.entry(iv.resource).or_default() += iv.duration();
        }
        busy
    }

    /// Resource names that appear in the trace, sorted by id.
    pub fn resources(&self) -> Vec<(u32, &str)> {
        let mut ids: Vec<u32> = {
            let mut seen: Vec<u32> = self.intervals.iter().map(|iv| iv.resource).collect();
            seen.sort_unstable();
            seen.dedup();
            seen
        };
        ids.sort_unstable();
        ids.into_iter().map(|id| (id, self.name(id))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_stable() {
        let mut tr = TraceRecorder::new();
        let a = tr.intern("nce");
        let b = tr.intern("bus");
        assert_ne!(a, b);
        assert_eq!(tr.intern("nce"), a);
        assert_eq!(tr.name(a), "nce");
        assert_eq!(tr.lookup("bus"), Some(b));
        assert_eq!(tr.lookup("nope"), None);
    }

    #[test]
    fn records_and_horizons() {
        let mut tr = TraceRecorder::new();
        let r = tr.intern("nce");
        let l = tr.intern("conv1_0/t0");
        tr.record(r, l, 0, IntervalKind::Compute, 100, 500);
        tr.record(r, l, 1, IntervalKind::Compute, 500, 900);
        assert_eq!(tr.intervals().len(), 2);
        assert_eq!(tr.horizon(), 900);
        assert_eq!(tr.busy_time()[&r], 800);
    }

    #[test]
    fn disabled_recorder_still_tracks_horizon() {
        let mut tr = TraceRecorder::disabled();
        let r = tr.intern("bus");
        tr.record(r, r, 0, IntervalKind::Transfer, 0, 1234);
        assert!(tr.intervals().is_empty());
        assert_eq!(tr.horizon(), 1234);
    }

    #[test]
    fn note_horizon_advances_without_intervals() {
        let mut tr = TraceRecorder::disabled();
        tr.note_horizon(500);
        tr.note_horizon(200); // never moves backwards
        assert_eq!(tr.horizon(), 500);
        assert!(tr.intervals().is_empty());
    }

    #[test]
    fn for_resource_filters() {
        let mut tr = TraceRecorder::new();
        let nce = tr.intern("nce");
        let bus = tr.intern("bus");
        let l = tr.intern("x");
        tr.record(nce, l, 0, IntervalKind::Compute, 0, 10);
        tr.record(bus, l, 0, IntervalKind::Transfer, 0, 20);
        tr.record(nce, l, 1, IntervalKind::Compute, 10, 30);
        assert_eq!(tr.for_resource(nce).count(), 2);
        assert_eq!(tr.for_resource(bus).count(), 1);
        assert_eq!(tr.resources(), vec![(nce, "nce"), (bus, "bus")]);
    }
}
