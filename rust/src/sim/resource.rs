//! Reusable TLM-style resource models: single-occupancy servers, round-robin
//! arbiters and shared bandwidth channels. The virtual hardware models in
//! `crate::hw` / `crate::detailed` compose these.

use super::SimTime;
use std::collections::VecDeque;

/// A single-occupancy resource (an NCE, a DMA channel): at most one job in
/// service; excess jobs queue FIFO. The resource does not know durations —
/// the owning model computes them and calls [`Server::start`]/[`Server::finish`].
#[derive(Debug, Clone)]
pub struct Server<J> {
    queue: VecDeque<J>,
    busy_with: Option<J>,
    busy_until: SimTime,
    total_busy: SimTime,
    served: u64,
}

impl<J: Clone> Default for Server<J> {
    fn default() -> Self {
        Self::new()
    }
}

impl<J: Clone> Server<J> {
    pub fn new() -> Self {
        Self {
            queue: VecDeque::new(),
            busy_with: None,
            busy_until: 0,
            total_busy: 0,
            served: 0,
        }
    }

    pub fn is_busy(&self) -> bool {
        self.busy_with.is_some()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn busy_until(&self) -> SimTime {
        self.busy_until
    }

    /// Enqueue a job; returns it immediately if the server is idle (the
    /// caller should then `start` it).
    pub fn enqueue(&mut self, job: J) -> Option<J> {
        if self.busy_with.is_none() && self.queue.is_empty() {
            Some(job)
        } else {
            self.queue.push_back(job);
            None
        }
    }

    /// Mark the server busy with `job` from `now` for `duration`.
    /// Panics if already busy — double-booking is a model bug.
    pub fn start(&mut self, job: J, now: SimTime, duration: SimTime) {
        assert!(self.busy_with.is_none(), "server double-booked");
        self.busy_with = Some(job);
        self.busy_until = now + duration;
        self.total_busy += duration;
        self.served += 1;
    }

    /// Complete the in-service job; returns the next queued job, if any.
    pub fn finish(&mut self) -> (J, Option<J>) {
        let done = self.busy_with.take().expect("finish on idle server");
        (done, self.queue.pop_front())
    }

    pub fn total_busy(&self) -> SimTime {
        self.total_busy
    }

    pub fn served(&self) -> u64 {
        self.served
    }
}

/// Work-conserving round-robin arbiter over `n` requesters (the paper's
/// interconnect grants bus access to DMA channels and the HKP).
#[derive(Debug, Clone)]
pub struct Arbiter {
    n: usize,
    next: usize,
    pending: Vec<bool>,
}

impl Arbiter {
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        Self { n, next: 0, pending: vec![false; n] }
    }

    pub fn request(&mut self, who: usize) {
        self.pending[who] = true;
    }

    pub fn cancel(&mut self, who: usize) {
        self.pending[who] = false;
    }

    pub fn has_pending(&self) -> bool {
        self.pending.iter().any(|&p| p)
    }

    /// Grant the lowest-index pending requester (fixed priority, e.g.
    /// read-before-write buses), clearing its request.
    pub fn grant_fixed(&mut self) -> Option<usize> {
        for i in 0..self.n {
            if self.pending[i] {
                self.pending[i] = false;
                return Some(i);
            }
        }
        None
    }

    /// Grant the next pending requester in round-robin order, clearing its
    /// request. Returns `None` if nothing is pending.
    pub fn grant(&mut self) -> Option<usize> {
        for i in 0..self.n {
            let idx = (self.next + i) % self.n;
            if self.pending[idx] {
                self.pending[idx] = false;
                self.next = (idx + 1) % self.n;
                return Some(idx);
            }
        }
        None
    }
}

/// A serialized shared channel with finite bandwidth (the AVSM bus model):
/// a transfer of `bytes` occupies the channel for
/// `ceil(bytes / bytes_per_ps)` — expressed as bytes-per-cycle at a clock to
/// stay in integer math.
#[derive(Debug, Clone)]
pub struct BandwidthChannel {
    /// Bytes moved per channel clock cycle (bus width x words/cycle).
    bytes_per_cycle: u64,
    period_ps: SimTime,
    free_at: SimTime,
    total_bytes: u64,
    total_busy: SimTime,
}

impl BandwidthChannel {
    pub fn new(bytes_per_cycle: u64, period_ps: SimTime) -> Self {
        assert!(bytes_per_cycle > 0 && period_ps > 0);
        Self { bytes_per_cycle, period_ps, free_at: 0, total_bytes: 0, total_busy: 0 }
    }

    pub fn bytes_per_cycle(&self) -> u64 {
        self.bytes_per_cycle
    }

    /// Pure duration of a `bytes` transfer (no queueing).
    pub fn transfer_time(&self, bytes: u64) -> SimTime {
        let cycles = (bytes + self.bytes_per_cycle - 1) / self.bytes_per_cycle;
        cycles * self.period_ps
    }

    /// Reserve the channel for a transfer starting no earlier than `now`.
    /// Returns `(start, end)` — start is delayed if the channel is busy.
    pub fn reserve(&mut self, now: SimTime, bytes: u64) -> (SimTime, SimTime) {
        let start = now.max(self.free_at);
        let end = start + self.transfer_time(bytes);
        self.free_at = end;
        self.total_bytes += bytes;
        self.total_busy += end - start;
        (start, end)
    }

    pub fn free_at(&self) -> SimTime {
        self.free_at
    }

    pub fn total_bytes(&self) -> u64 {
        self.total_bytes
    }

    pub fn total_busy(&self) -> SimTime {
        self.total_busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_fifo_order() {
        let mut s: Server<u32> = Server::new();
        assert_eq!(s.enqueue(1), Some(1)); // idle -> run immediately
        s.start(1, 0, 100);
        assert!(s.enqueue(2).is_none());
        assert!(s.enqueue(3).is_none());
        let (done, next) = s.finish();
        assert_eq!((done, next), (1, Some(2)));
        s.start(2, 100, 50);
        let (done, next) = s.finish();
        assert_eq!((done, next), (2, Some(3)));
        assert_eq!(s.served(), 2);
        assert_eq!(s.total_busy(), 150);
    }

    #[test]
    #[should_panic(expected = "double-booked")]
    fn server_rejects_double_booking() {
        let mut s: Server<u32> = Server::new();
        s.start(1, 0, 10);
        s.start(2, 0, 10);
    }

    #[test]
    fn arbiter_round_robin_is_fair() {
        let mut a = Arbiter::new(3);
        a.request(0);
        a.request(1);
        a.request(2);
        assert_eq!(a.grant(), Some(0));
        a.request(0); // re-request immediately
        assert_eq!(a.grant(), Some(1));
        assert_eq!(a.grant(), Some(2));
        assert_eq!(a.grant(), Some(0)); // only now 0 again
        assert_eq!(a.grant(), None);
    }

    #[test]
    fn arbiter_skips_idle_requesters() {
        let mut a = Arbiter::new(4);
        a.request(2);
        assert_eq!(a.grant(), Some(2));
        a.request(1);
        a.request(3);
        assert_eq!(a.grant(), Some(3)); // RR pointer at 3 after granting 2
        assert_eq!(a.grant(), Some(1));
    }

    #[test]
    fn fixed_priority_always_prefers_low_index() {
        let mut a = Arbiter::new(3);
        a.request(2);
        a.request(0);
        assert_eq!(a.grant_fixed(), Some(0));
        a.request(1);
        assert_eq!(a.grant_fixed(), Some(1));
        assert_eq!(a.grant_fixed(), Some(2));
        assert_eq!(a.grant_fixed(), None);
    }

    #[test]
    fn channel_serializes_transfers() {
        // 8 bytes/cycle at 4000 ps (250 MHz, 64-bit bus).
        let mut ch = BandwidthChannel::new(8, 4000);
        let (s1, e1) = ch.reserve(0, 64); // 8 cycles
        assert_eq!((s1, e1), (0, 32_000));
        let (s2, e2) = ch.reserve(10_000, 8); // must wait for first
        assert_eq!((s2, e2), (32_000, 36_000));
        assert_eq!(ch.total_bytes(), 72);
    }

    #[test]
    fn channel_rounds_partial_beats_up() {
        let ch = BandwidthChannel::new(8, 1000);
        assert_eq!(ch.transfer_time(1), 1000);
        assert_eq!(ch.transfer_time(8), 1000);
        assert_eq!(ch.transfer_time(9), 2000);
    }
}
