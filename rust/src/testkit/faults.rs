//! Named failpoints for fault-injection testing of the persistence layer.
//!
//! The campaign's robustness contract — cache faults degrade to
//! recompiles/error rows, journal tears drop the torn tail, a panicking
//! unit becomes a `panics` row — is only trustworthy if tests can *make*
//! those faults happen on demand. This module is the switchboard: the
//! production I/O sites in [`crate::campaign::store`] and
//! [`crate::campaign::journal`] consult [`before_read`] / [`before_write`]
//! at every disk touch, and tests arm a failpoint with [`arm`] to inject
//! an [`io::Error`], a torn (prefix-only) write that still claims success
//! at the site, or a panic.
//!
//! Design constraints:
//!
//! * **Inert in production.** Nothing ever arms a failpoint outside tests;
//!   the per-I/O cost of an unarmed registry is a single relaxed atomic
//!   load ([`ARMED`]).
//! * **Test isolation.** Failpoints are scoped to a *path prefix* (the
//!   test's unique temp directory) in addition to the site name, so
//!   concurrently running tests never trip each other's faults. Arming
//!   returns an RAII [`FaultGuard`] that disarms on drop, panicking
//!   included.
//! * **Deterministic.** A failpoint fires on its first `hits` matching
//!   I/O operations and then exhausts. Which operation that is, is a pure
//!   function of the (seeded) campaign schedule — property tests draw the
//!   armed site/kind from the shared [`crate::testkit::NetGen`] RNG, so a
//!   failing `AVSM_TEST_SEED` replays the exact fault.

use std::io;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// What an armed failpoint injects at its I/O site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The operation fails with an injected [`io::Error`]
    /// (`ErrorKind::Other`, message tagged `injected fault`).
    IoError,
    /// A write persists only a prefix of its bytes yet reports success at
    /// the write syscall — the power-cut / torn-page model. Read sites
    /// treat it like [`FaultKind::IoError`].
    Torn,
    /// The operation panics mid-I/O — the model for a worker dying inside
    /// the persistence layer.
    Panic,
}

struct Failpoint {
    id: u64,
    site: &'static str,
    prefix: PathBuf,
    kind: FaultKind,
    skip: usize,
    remaining: usize,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(0);
static REGISTRY: Mutex<Vec<Failpoint>> = Mutex::new(Vec::new());

fn registry() -> std::sync::MutexGuard<'static, Vec<Failpoint>> {
    // A panic fault unwinding through a caller that held the lock cannot
    // happen (the lock is released before injecting), but recover anyway:
    // the registry's state is a plain Vec, always consistent.
    REGISTRY.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// RAII handle for an armed failpoint: dropping it (normally or during a
/// panic) removes the failpoint and lowers the fast-path flag when the
/// registry empties.
pub struct FaultGuard {
    id: u64,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        let mut reg = registry();
        reg.retain(|fp| fp.id != self.id);
        if reg.is_empty() {
            ARMED.store(false, Ordering::Relaxed);
        }
    }
}

/// Arm `site` to inject `kind` on its next `hits` I/O operations whose
/// target path lives under `prefix`. Returns the RAII disarm guard.
pub fn arm(site: &'static str, prefix: &Path, kind: FaultKind, hits: usize) -> FaultGuard {
    arm_after(site, prefix, kind, 0, hits)
}

/// Like [`arm`], but let the first `skip` matching operations pass through
/// untouched before injecting — the tool for killing a run *partway*
/// through a deterministic sequence of I/O operations (e.g. tear the
/// journal on its Nth append, after the header and N-1 records landed).
pub fn arm_after(
    site: &'static str,
    prefix: &Path,
    kind: FaultKind,
    skip: usize,
    hits: usize,
) -> FaultGuard {
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    registry().push(Failpoint {
        id,
        site,
        prefix: prefix.to_path_buf(),
        kind,
        skip,
        remaining: hits,
    });
    ARMED.store(true, Ordering::Relaxed);
    FaultGuard { id }
}

/// Consume one hit of the first armed failpoint matching `(site, path)`.
fn take(site: &str, path: &Path) -> Option<FaultKind> {
    if !ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut reg = registry();
    let fp = reg.iter_mut().find(|fp| {
        fp.site == site && (fp.skip > 0 || fp.remaining > 0) && path.starts_with(&fp.prefix)
    })?;
    if fp.skip > 0 {
        fp.skip -= 1;
        return None;
    }
    fp.remaining -= 1;
    Some(fp.kind)
}

fn injected_error(site: &str, path: &Path) -> io::Error {
    io::Error::new(
        io::ErrorKind::Other,
        format!("injected fault at {site} ({})", path.display()),
    )
}

/// Failpoint check for a read-side I/O site. [`FaultKind::IoError`] and
/// [`FaultKind::Torn`] both surface as an injected error;
/// [`FaultKind::Panic`] unwinds from here.
pub fn before_read(site: &str, path: &Path) -> io::Result<()> {
    match take(site, path) {
        None => Ok(()),
        Some(FaultKind::Panic) => panic!("injected panic at {site} ({})", path.display()),
        Some(FaultKind::IoError) | Some(FaultKind::Torn) => Err(injected_error(site, path)),
    }
}

/// Failpoint check for a non-I/O operation site (e.g. `sim.evaluate`,
/// consulted by [`crate::dse::evaluate_compiled`]), scoped by a
/// *pseudo-path*: the campaign passes `<net name>/<point name>`, so a
/// test arms against its own uniquely named net and never trips a
/// concurrently running test. Only [`FaultKind::Panic`] is meaningful
/// here — there is no I/O result to fail — so an armed `IoError`/`Torn`
/// is consumed but passes through untouched.
pub fn before_op(site: &str, scope: &Path) {
    if take(site, scope) == Some(FaultKind::Panic) {
        panic!("injected panic at {site} ({})", scope.display());
    }
}

/// Failpoint check for a write-side I/O site about to persist `len` bytes.
///
/// * `Ok(None)` — no fault: perform the real write.
/// * `Ok(Some(n))` — torn write: persist only the first `n < len` bytes
///   and report success to the caller, as a crashed machine would.
/// * `Err(_)` — injected I/O error; write nothing.
///
/// [`FaultKind::Panic`] unwinds from here.
pub fn before_write(site: &str, path: &Path, len: usize) -> io::Result<Option<usize>> {
    match take(site, path) {
        None => Ok(None),
        Some(FaultKind::Panic) => panic!("injected panic at {site} ({})", path.display()),
        Some(FaultKind::IoError) => Err(injected_error(site, path)),
        Some(FaultKind::Torn) => Ok(Some(len / 2)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("avsm_faults_{tag}_{}", std::process::id()))
    }

    #[test]
    fn unarmed_sites_pass_through() {
        let dir = tmp("unarmed");
        assert!(before_read("store.read", &dir.join("x")).is_ok());
        assert_eq!(before_write("store.write", &dir.join("x"), 100).unwrap(), None);
    }

    #[test]
    fn armed_fault_fires_hit_count_times_then_exhausts() {
        let dir = tmp("hits");
        let guard = arm("faults.test.read", &dir, FaultKind::IoError, 2);
        let p = dir.join("entry.json");
        assert!(before_read("faults.test.read", &p).is_err());
        assert!(before_read("faults.test.read", &p).is_err());
        assert!(before_read("faults.test.read", &p).is_ok(), "exhausted after 2 hits");
        drop(guard);
    }

    #[test]
    fn arm_after_passes_through_the_skip_window_then_fires() {
        let dir = tmp("skip");
        let guard = arm_after("faults.test.skip", &dir, FaultKind::IoError, 2, 1);
        let p = dir.join("entry.json");
        assert!(before_read("faults.test.skip", &p).is_ok(), "skip 1");
        assert!(before_read("faults.test.skip", &p).is_ok(), "skip 2");
        assert!(before_read("faults.test.skip", &p).is_err(), "fires on the 3rd");
        assert!(before_read("faults.test.skip", &p).is_ok(), "exhausted");
        drop(guard);
    }

    #[test]
    fn faults_are_scoped_to_site_and_path_prefix() {
        let dir = tmp("scope");
        let other = tmp("scope_other");
        let guard = arm("faults.test.scoped", &dir, FaultKind::IoError, 1);
        // Wrong site: passes.
        assert!(before_read("faults.test.unrelated", &dir.join("x")).is_ok());
        // Wrong directory: passes.
        assert!(before_read("faults.test.scoped", &other.join("x")).is_ok());
        // Matching both: fires.
        let err = before_read("faults.test.scoped", &dir.join("x")).unwrap_err();
        assert!(err.to_string().contains("injected fault at faults.test.scoped"), "{err}");
        drop(guard);
    }

    #[test]
    fn guard_disarms_on_drop() {
        let dir = tmp("disarm");
        {
            let _guard = arm("faults.test.disarm", &dir, FaultKind::IoError, 100);
            assert!(before_read("faults.test.disarm", &dir.join("x")).is_err());
        }
        assert!(before_read("faults.test.disarm", &dir.join("x")).is_ok());
    }

    #[test]
    fn torn_write_reports_a_prefix_length() {
        let dir = tmp("torn");
        let guard = arm("faults.test.torn", &dir, FaultKind::Torn, 1);
        let n = before_write("faults.test.torn", &dir.join("x"), 101).unwrap();
        assert_eq!(n, Some(50));
        drop(guard);
    }

    #[test]
    fn op_site_panics_on_panic_kind_and_ignores_io_kinds() {
        let dir = tmp("op");
        {
            let _guard = arm("faults.test.op", &dir, FaultKind::IoError, 1);
            // Consumed but inert: an op site has no I/O result to fail.
            before_op("faults.test.op", &dir.join("x"));
        }
        let _guard = arm("faults.test.op", &dir, FaultKind::Panic, 1);
        let payload =
            std::panic::catch_unwind(|| before_op("faults.test.op", &dir.join("x"))).unwrap_err();
        let msg = crate::campaign::pool::panic_message(payload.as_ref());
        assert!(msg.contains("injected panic at faults.test.op"), "{msg}");
        before_op("faults.test.op", &dir.join("x")); // exhausted
    }

    #[test]
    fn injected_panic_unwinds_with_a_recognizable_message() {
        let dir = tmp("panic");
        let guard = arm("faults.test.panic", &dir, FaultKind::Panic, 1);
        let p = dir.join("x");
        let payload = std::panic::catch_unwind(|| {
            let _ = before_read("faults.test.panic", &p);
        })
        .unwrap_err();
        let msg = crate::campaign::pool::panic_message(payload.as_ref());
        assert!(msg.contains("injected panic at faults.test.panic"), "{msg}");
        drop(guard);
    }
}
