//! Fig 3: distribution of flow run-time for generation and simulation of
//! the AVSM. The paper reports (on a Xeon E5620): ML compiler & graph
//! generation 16.6 s, simulation 105.8 s, tool import/export + model build
//! 1231 s (~91 % of the total, "not optimized for performance yet").
//! We reproduce the same three-phase breakdown for our flow.

use crate::json::{obj, Value};
use std::time::Duration;

/// One phase of the end-to-end flow (paper Fig 3 rows).
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: String,
    pub wall: Duration,
}

/// The flow-runtime breakdown table.
#[derive(Debug, Clone, Default)]
pub struct FlowBreakdown {
    pub phases: Vec<Phase>,
}

impl FlowBreakdown {
    /// Add wall time to a phase; repeated names accumulate (the paper's
    /// "Tool import/export and Model build" row covers both the pre-sim
    /// import/build and the post-sim result export).
    pub fn add(&mut self, name: impl Into<String>, wall: Duration) {
        let name = name.into();
        if let Some(p) = self.phases.iter_mut().find(|p| p.name == name) {
            p.wall += wall;
        } else {
            self.phases.push(Phase { name, wall });
        }
    }

    pub fn total(&self) -> Duration {
        self.phases.iter().map(|p| p.wall).sum()
    }

    pub fn share_pct(&self, name: &str) -> f64 {
        let total = self.total().as_secs_f64();
        if total == 0.0 {
            return 0.0;
        }
        self.phases
            .iter()
            .filter(|p| p.name == name)
            .map(|p| p.wall.as_secs_f64())
            .sum::<f64>()
            / total
            * 100.0
    }

    /// The paper's Fig 3 table layout.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("{:<42} {:>12} {:>8}\n", "Task", "Time [s]", "Share"));
        for p in &self.phases {
            out.push_str(&format!(
                "{:<42} {:>12.6} {:>7.1}%\n",
                p.name,
                p.wall.as_secs_f64(),
                self.share_pct(&p.name)
            ));
        }
        out.push_str(&format!("{:<42} {:>12.6}\n", "Σ", self.total().as_secs_f64()));
        out
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            (
                "phases",
                Value::Array(
                    self.phases
                        .iter()
                        .map(|p| {
                            obj(vec![
                                ("name", p.name.as_str().into()),
                                ("seconds", p.wall.as_secs_f64().into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("total_seconds", self.total().as_secs_f64().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shares_and_total() {
        let mut b = FlowBreakdown::default();
        b.add("ML Compiler & Graph Generation", Duration::from_millis(166));
        b.add("Simulation", Duration::from_millis(1058));
        b.add("Tool import/export and Model build", Duration::from_millis(12310));
        assert!((b.total().as_secs_f64() - 13.534).abs() < 1e-9);
        // The paper's shape: import/export+build dominates.
        assert!(b.share_pct("Tool import/export and Model build") > 85.0);
    }

    #[test]
    fn renders_table() {
        let mut b = FlowBreakdown::default();
        b.add("Simulation", Duration::from_secs(1));
        let txt = b.render_text();
        assert!(txt.contains("Task") && txt.contains("Σ"));
        let j = b.to_json();
        assert_eq!(j.get("phases").as_array().unwrap().len(), 1);
    }

    #[test]
    fn empty_breakdown_is_safe() {
        let b = FlowBreakdown::default();
        assert_eq!(b.total(), Duration::ZERO);
        assert_eq!(b.share_pct("x"), 0.0);
    }
}
