//! Figure/table regeneration (DESIGN.md §5 experiment index).
//!
//! * [`fig5`] — per-layer AVSM vs "hardware" (detailed prototype) timing
//!   comparison with deviations (paper Fig 5 + the 8.3 % headline).
//! * [`fig3`] — flow runtime breakdown table (paper Fig 3), fed by the
//!   coordinator's phase timers.
//! * [`campaign`] — multi-workload campaign report: per-net frontiers plus
//!   the cross-net summary (which configs survive every workload), and the
//!   engine-telemetry report (`avsm-campaign-telemetry-v1`).
//! * Fig 4 lives in [`crate::trace`], Fig 6/7 in [`crate::roofline`].

pub mod campaign;
pub mod fig3;
pub mod fig5;

pub use campaign::{axis_legend, CampaignReport, KindStats, TelemetryReport};
pub use fig3::FlowBreakdown;
pub use fig5::Fig5Report;
